"""Multi-process replica serving over ONE blob file (paper §6.2 taken to
its logical end): N read-only reader processes + 1 writer process share a
single ``index.blob``; no sockets, no daemon — the FILE is the interface.

The writer mutates (inserts, deletes, one final compaction) and every
mutation commits through ``core/lifecycle.publish_generation``: a single
header ``pwrite`` that publishes the bumped ``generation`` together with
the new counts/registry/tombstones.  Readers poll that generation with
``refresh()`` and re-search.  The invariants this demo asserts — per
reader, from a separate process:

  * the raw blob header is NEVER torn: magic + length framing + JSON
    always parse, at any poll instant, mid-burst or not;
  * the observed generation sequence is monotonically non-decreasing;
  * every observed generation was actually published by the writer
    (no phantom states) — checked post-hoc against the writer's log;
  * searches stay available throughout, and any transiently-invalid
    result (a reader one generation stale can catch the writer reusing
    a slot its view still references — cross-process readers hold no
    pins) heals on ``refresh()`` + retry while the writer is live;
  * once the writer has exited, a final refresh + search is STRICT:
    every returned id must be one the final generation can contain.

Run it::

    PYTHONPATH=src python examples/replica_readers.py            # full demo
    PYTHONPATH=src python examples/replica_readers.py --smoke    # CI-sized

Exit code 0 = every invariant held in every process.
"""
from __future__ import annotations

import argparse
import json
import multiprocessing as mp
import os
import sys
import time
from pathlib import Path

import numpy as np

DIM = 16
MAGIC = b"ECPBLOB1"


# ------------------------------------------------------------- header peek
def peek_header(blob_path: str) -> dict:
    """Read the raw blob header the way an external observer would: one
    open, one read, parse.  Raises if the header is torn."""
    with open(blob_path, "rb") as f:
        head = f.read(16)
        if head[:8] != MAGIC:
            raise AssertionError(f"torn header: bad magic {head[:8]!r}")
        hlen = int.from_bytes(head[8:16], "little")
        raw = f.read(hlen)
    if len(raw) != hlen:
        raise AssertionError(f"torn header: short read {len(raw)} < {hlen}")
    return json.loads(raw)  # a torn JSON body raises here


# ------------------------------------------------------------------ writer
def writer_proc(blob_path: str, log_path: str, n_rounds: int, batch: int) -> None:
    from repro.core import open_index

    rng = np.random.default_rng(1234)
    fd = os.open(log_path, os.O_WRONLY | os.O_CREAT | os.O_APPEND)
    try:
        with open_index(blob_path, mode="file", backend="blob") as idx:
            os.write(fd, f"{idx.info.generation}\n".encode())  # initial state
            next_id = idx.info.next_id
            for r in range(n_rounds):
                vecs = rng.normal(size=(batch, DIM)).astype(np.float32)
                ids = list(range(next_id, next_id + batch))
                next_id += batch
                res = idx.insert(vecs, ids=ids)
                os.write(fd, f"{res['generation']}\n".encode())
                if r % 3 == 2:  # tombstone a few of the rows just added
                    idx.delete(ids[: batch // 4])
                    os.write(fd, f"{idx.info.generation}\n".encode())
                time.sleep(0.01)
            # structural rewrite: compaction swaps the file via os.replace;
            # readers must ride through it on refresh()
            idx.compact()
            os.write(fd, f"{idx.info.generation}\n".encode())
    finally:
        os.close(fd)


# ------------------------------------------------------------------ reader
def reader_proc(
    blob_path: str, log_path: str, stop_path: str, poll_s: float
) -> None:
    from repro.core import open_index

    fd = os.open(log_path, os.O_WRONLY | os.O_CREAT | os.O_APPEND)

    def probe(idx, q, *, tries: int, pause: float) -> None:
        """One validated search.  A reader whose view is a generation
        stale can catch the writer recycling a slot its view still
        references (cross-process readers hold no pins): the symptom is
        either a search error on torn node bytes or out-of-range result
        ids.  While the writer is live (``tries > 1``) that must HEAL on
        refresh + retry; at quiescence (``tries == 1``) it must not
        happen at all."""
        err = None
        for t in range(tries):
            if t:
                time.sleep(pause)
                idx.refresh()
            try:
                rs = idx.search(q, k=5, b=4)
            except (KeyError, ValueError, IndexError) as e:
                err = f"search raised {e!r}"
                continue
            bad = [rid for _, rid in rs.pairs() if not 0 <= rid < idx.info.next_id]
            if not bad:
                return
            err = f"ids {bad} impossible"
        raise AssertionError(
            f"{err} at generation {idx.info.generation}"
            + (" after writer exit" if tries == 1 else " even after refresh+retry")
        )

    try:
        with open_index(blob_path, mode="file", backend="blob") as idx:
            q = np.zeros(DIM, dtype=np.float32)
            last = -1
            while True:
                writer_done = os.path.exists(stop_path)
                # 1. the raw file must parse at ANY instant
                hdr = peek_header(blob_path)
                raw_gen = int(hdr["info"]["generation"])
                assert raw_gen >= last, f"raw header went backwards: {raw_gen} < {last}"
                # 2. the library-level view: poll generation via refresh()
                idx.refresh()
                gen = idx.info.generation
                assert gen >= last, f"refresh went backwards: {gen} < {last}"
                last = gen
                os.write(fd, f"{gen}\n".encode())
                # 3. the observed state answers queries (see probe())
                probe(idx, q, tries=1 if writer_done else 6, pause=poll_s)
                if writer_done:
                    break
                time.sleep(poll_s)
    finally:
        os.close(fd)


# ----------------------------------------------------------------- harness
def run(n_readers: int = 3, n_rounds: int = 12, batch: int = 32) -> dict:
    import tempfile

    from repro.core import ECPBuildConfig, build_index, convert
    from repro.data import clustered_vectors

    data, _ = clustered_vectors(0, n=1500, dim=DIM, n_clusters=12)
    ctx = mp.get_context("spawn")  # clean children: no inherited locks/fds
    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        build_index(data, str(td / "idx"), ECPBuildConfig(levels=2, cluster_cap=64))
        blob = str(convert(str(td / "idx"), td / "index.blob"))
        stop = str(td / "STOP")
        wlog = str(td / "published.log")
        rlogs = [str(td / f"reader_{i}.log") for i in range(n_readers)]

        readers = [
            ctx.Process(target=reader_proc, args=(blob, rlogs[i], stop, 0.005))
            for i in range(n_readers)
        ]
        writer = ctx.Process(target=writer_proc, args=(blob, wlog, n_rounds, batch))
        for p in readers:
            p.start()
        writer.start()
        writer.join(timeout=120)
        assert writer.exitcode == 0, f"writer failed: exit {writer.exitcode}"
        Path(stop).touch()  # writer is done; let readers observe the final state
        for p in readers:
            p.join(timeout=60)
            assert p.exitcode == 0, f"reader failed: exit {p.exitcode}"

        published = [int(x) for x in Path(wlog).read_text().split()]
        final_gen = published[-1]
        summary = {"published": len(published), "final_gen": final_gen, "readers": []}
        for i, rl in enumerate(rlogs):
            seen = [int(x) for x in Path(rl).read_text().split()]
            assert seen, f"reader {i} observed nothing"
            assert all(a <= b for a, b in zip(seen, seen[1:])), (
                f"reader {i} saw a non-monotonic sequence: {seen}"
            )
            phantom = set(seen) - set(published)
            assert not phantom, (
                f"reader {i} observed generations the writer never "
                f"published (torn/phantom state): {sorted(phantom)}"
            )
            summary["readers"].append({"observations": len(seen), "distinct": len(set(seen))})
        return summary


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--readers", type=int, default=3)
    args = ap.parse_args()
    if args.smoke:
        summary = run(n_readers=2, n_rounds=6, batch=16)
    else:
        summary = run(n_readers=args.readers)
    print(
        f"replica demo OK: {summary['published']} published generations "
        f"(final={summary['final_gen']}); "
        + "; ".join(
            f"reader{i}: {r['observations']} polls, {r['distinct']} distinct gens"
            for i, r in enumerate(summary["readers"])
        )
    )
    print("no reader ever observed a torn or unpublished generation")


if __name__ == "__main__":
    main()
