"""Incremental retrieval deep-dive (paper §4.3): filters that trigger the
internal b-doubling, external get-next-k sessions via Query handles, and
query-state persistence INSIDE the index's own file structure.

    PYTHONPATH=src python examples/incremental_search.py
"""
import tempfile

from repro.core import ECPBuildConfig, QueryClosedError, build_index, open_index
from repro.data import clustered_vectors

with tempfile.TemporaryDirectory() as td:
    path = td + "/idx"
    data, _ = clustered_vectors(7, n=30_000, dim=64, n_clusters=128)
    build_index(data, path, ECPBuildConfig(levels=2, cluster_cap=150))
    index = open_index(path, mode="file")
    fresh = None
    q = data[42]

    # -- External continuation: a long-running session asking for more
    rs = index.search(q, k=20, b=4)
    print(f"first 20, best dist {rs.pairs()[0][0]:.4f}")
    handle = rs.query
    for round_ in range(3):
        more = handle.next(20)
        print(f"  round {round_}: {len(more)} more, "
              f"b={handle.b}, leaves={handle.stats.leaves_opened}")

    # -- Internal continuation: filters starve the result set; the search
    #    resumes itself, doubling b (paper's 'Internal' case)
    blocked = {i for _, i in rs.pairs()}   # pretend a filter rejects these
    rs2 = index.search(q, k=20, b=2, mx_inc=6, exclude=blocked)
    st = rs2.query.stats
    print(f"\nfiltered search: got {len(rs2)} (none in filter: "
          f"{not ({i for _, i in rs2.pairs()} & blocked)}), b grew to {rs2.query.b} "
          f"({st.increments} doublings)")

    # -- Persistence: the query state is saved INTO the file structure and
    #    resumed by a completely fresh process/index instance (paper §6.2)
    token = handle.save()
    fresh = open_index(path, mode="file")  # closed at the end, with `index`
    resumed = fresh.load_query(token)
    a = handle.next(10)
    b = resumed.next(10)
    print(f"\npersisted continuation ({token!r}) identical:",
          [i for _, i in a.pairs()] == [i for _, i in b.pairs()])

    # -- Closing a handle frees its state; further use is a clear error
    handle.close()
    try:
        handle.next(10)
    except QueryClosedError as e:
        print("closed handle raises:", e)

    # -- Indexes are context managers too; close() frees prefetch executors
    index.close()
    fresh.close()
