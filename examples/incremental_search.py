"""Incremental retrieval deep-dive (paper §4.3): filters that trigger the
internal b-doubling, external get-next-k sessions, and query-state
persistence INSIDE the index's own file structure.

    PYTHONPATH=src python examples/incremental_search.py
"""
import tempfile

import numpy as np

from repro.core import ECPBuildConfig, ECPIndex, build_index
from repro.data import clustered_vectors

with tempfile.TemporaryDirectory() as td:
    path = td + "/idx"
    data, _ = clustered_vectors(7, n=30_000, dim=64, n_clusters=128)
    build_index(data, path, ECPBuildConfig(levels=2, cluster_cap=150))
    index = ECPIndex(path)
    q = data[42]

    # -- External continuation: a long-running session asking for more
    res, qid = index.new_search(q, k=20, b=4)
    print(f"q_id={qid}: first 20, best dist {res[0][0]:.4f}")
    for round_ in range(3):
        more = index.get_next_k(qid, 20)
        print(f"  round {round_}: {len(more)} more, "
              f"b={index.QS[qid].b}, leaves={index.QS[qid].stats.leaves_opened}")

    # -- Internal continuation: filters starve the result set; the search
    #    resumes itself, doubling b (paper's 'Internal' case)
    blocked = {i for _, i in res}          # pretend a filter rejects these
    res2, qid2 = index.new_search(q, k=20, b=2, mx_inc=6, exclude=blocked)
    st = index.QS[qid2]
    print(f"\nfiltered search: got {len(res2)} (none in filter: "
          f"{not ({i for _, i in res2} & blocked)}), b grew to {st.b} "
          f"({st.increments} doublings)")

    # -- Persistence: the query state is saved INTO the file structure and
    #    resumed by a completely fresh process/index instance (paper §6.2)
    index.save_query_state(qid)
    fresh = ECPIndex(path)
    qid_re = fresh.load_query_state(qid)
    a = index.get_next_k(qid, 10)
    b = fresh.get_next_k(qid_re, 10)
    print("\npersisted continuation identical:", [i for _, i in a] == [i for _, i in b])
