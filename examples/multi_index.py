"""The paper's headline scenario: SEVERAL co-located indexes under one
tight memory budget — per-index LRU node caps keep the total footprint
fixed while every collection stays searchable (paper §1, §6.1).

    PYTHONPATH=src python examples/multi_index.py
"""
import tempfile

import numpy as np

from repro.core import ECPBuildConfig, ECPIndex, build_index
from repro.data import clustered_vectors

COLLECTIONS = {"lifelog": 0, "video_kf": 1, "docs": 2}
BUDGET_NODES = 24          # global node budget across ALL indexes

with tempfile.TemporaryDirectory() as td:
    indexes = {}
    for name, seed in COLLECTIONS.items():
        data, _ = clustered_vectors(seed, n=20_000, dim=64, n_clusters=96)
        path = f"{td}/{name}"
        build_index(data, path, ECPBuildConfig(levels=2, cluster_cap=150))
        indexes[name] = (ECPIndex(path, cache_max_nodes=BUDGET_NODES // len(COLLECTIONS)), data)

    rng = np.random.default_rng(9)
    for round_ in range(3):
        for name, (idx, data) in indexes.items():
            q = data[rng.integers(0, len(data))]
            res, qid = idx.new_search(q, k=5, b=4)
            print(f"[{name:9s}] hit={res[0][1]:6d} d={res[0][0]:.4f} "
                  f"resident={idx.cache.n_resident:2d} "
                  f"bytes={idx.cache.resident_bytes/2**20:6.2f} MiB "
                  f"evictions={idx.cache.evictions}")

    total = sum(i.cache.resident_bytes for i, _ in indexes.values())
    print(f"\ntotal resident node data across 3 indexes: {total/2**20:.2f} MiB "
          f"(vs {sum(20000*64*4 for _ in indexes)/2**20:.0f} MiB if fully loaded)")

    # runtime-tunable: shrink the budget live (paper: limit changeable at run-time)
    for name, (idx, _) in indexes.items():
        idx.cache.resize(2)
    print("after live resize to 2 nodes/index:",
          {n: i.cache.n_resident for n, (i, _) in indexes.items()})
