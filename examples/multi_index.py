"""The paper's headline scenario: SEVERAL co-located indexes under one
tight memory budget (paper §1, §6.1) — now as ONE shared byte-budget
cache.  A ``MultiIndexSession`` opens every collection into a single
globally-LRU ``NodeCache``: a node loaded for any index can evict the
coldest node of any other, so hot collections naturally take more of the
budget, and the limit is changeable at run-time (paper §4.2, fleet-wide).

    PYTHONPATH=src python examples/multi_index.py
"""
import tempfile

import numpy as np

from repro.core import ECPBuildConfig, MultiIndexSession, build_index
from repro.data import clustered_vectors

COLLECTIONS = {"lifelog": 0, "video_kf": 1, "docs": 2}
BUDGET_BYTES = 3 << 19          # 1.5 MiB of node data across ALL indexes

with tempfile.TemporaryDirectory() as td:
    session = MultiIndexSession(cache_bytes=BUDGET_BYTES)
    datasets = {}
    for name, seed in COLLECTIONS.items():
        data, _ = clustered_vectors(seed, n=20_000, dim=64, n_clusters=96)
        path = f"{td}/{name}"
        build_index(data, path, ECPBuildConfig(levels=2, cluster_cap=150))
        session.open(path, name=name)
        datasets[name] = data

    rng = np.random.default_rng(9)
    for round_ in range(3):
        for name, data in datasets.items():
            q = data[rng.integers(0, len(data))]
            rs = session.search(name, q, k=5, b=4)
            st = session.stats()
            mine = st["per_index"][name]
            print(f"[{name:9s}] hit={rs.pairs()[0][1]:6d} d={rs.pairs()[0][0]:.4f} "
                  f"mine={mine['bytes']/2**20:5.2f} MiB "
                  f"total={st['resident_bytes']/2**20:5.2f}/{BUDGET_BYTES/2**20:.1f} MiB "
                  f"evictions={st['evictions']}")

    st = session.stats()
    assert st["resident_bytes"] <= BUDGET_BYTES
    full = sum(20000 * 64 * 4 for _ in COLLECTIONS)
    print(f"\nshared budget held: {st['resident_bytes']/2**20:.2f} MiB resident "
          f"across 3 indexes, {st['evictions']} evictions "
          f"(vs {full/2**20:.0f} MiB if fully loaded)")

    # runtime-tunable: shrink the FLEET budget live (paper: limit
    # changeable at run-time — here one knob governs every index)
    session.resize(cache_bytes=1 << 19)
    st = session.stats()
    print(f"after live resize to 0.5 MiB: {st['resident_bytes']/2**20:.2f} MiB resident, "
          f"per-index: { {n: v['nodes'] for n, v in st['per_index'].items()} }")

    # writes through a session index invalidate exactly the rewritten nodes
    # in the SHARED cache (keys are namespaced), so co-located readers never
    # see stale data — and the other indexes' cached nodes stay resident
    lifelog = session["lifelog"]
    vec = datasets["lifelog"][123] + 0.05
    lifelog.insert(vec[None, :], [20_000])
    rs = session.search("lifelog", vec, k=3, b=8)
    print(f"\nafter insert: hit={rs.pairs()[0][1]} (new item), "
          f"generation={lifelog.generation}")

    # one call closes every index (prefetch executors, store fds) + cache
    session.close()
