"""End-to-end LM training with the full substrate: deterministic data,
AdamW + cosine schedule, checkpoint/restart supervision, optional int8
gradient compression — the driver a real run would use, at laptop scale.

    # ~100M-parameter model, a few hundred steps (CPU: hours; TPU: minutes)
    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300

    # smoke scale (runs in ~1 min on CPU)
    PYTHONPATH=src python examples/train_lm.py --size tiny --steps 30
"""
import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import StepLoader, lm_batch
from repro.distributed import TrainSupervisor
from repro.launch.train import make_lm_trainer
from repro.models import transformer as T
from repro.models.base import param_count

SIZES = {
    # ~107M params: a real small LM
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                 vocab=32768, d_head=64, max_seq=256),
    "10m": dict(n_layers=6, d_model=256, n_heads=8, n_kv_heads=4, d_ff=768,
                vocab=8192, d_head=32, max_seq=256),
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                 vocab=2048, d_head=32, max_seq=128),
}

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=SIZES, default="tiny")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    kw = SIZES[args.size]
    seq = args.seq or kw["max_seq"]
    cfg = T.LMConfig(name=f"lm-{args.size}", dtype=jnp.float32, attn_chunk=128, **kw)
    print(f"model: {param_count(T.param_specs(cfg)):,} params")

    step_jit, init = make_lm_trainer(cfg, lr=3e-4, total_steps=args.steps, compress=args.compress)
    state = init(jax.random.key(0))
    loader = StepLoader(make=partial(lm_batch, batch=args.batch, seq=seq, vocab=cfg.vocab))
    ckpt = CheckpointManager(args.ckpt_dir, keep_n=2)

    losses = []
    sup = TrainSupervisor(
        step_fn=lambda s, b, i: step_jit(s, {"tokens": jnp.asarray(b["tokens"])}),
        loader=loader, ckpt=ckpt, ckpt_every=max(args.steps // 4, 10),
    )
    t0 = time.time()
    state, stats = sup.run(
        state, args.steps,
        on_metrics=lambda i, m, dt: (
            losses.append(float(m["loss"])),
            print(f"step {i:4d} loss {float(m['loss']):.4f} ({dt*1e3:.0f} ms)")
            if i % 10 == 0 else None,
        ),
    )
    print(f"\n{args.steps} steps in {time.time()-t0:.1f}s | "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} | "
          f"checkpoints kept: {ckpt.steps()}")
