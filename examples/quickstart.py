"""Quickstart: build an eCP-FS index, search it, resume the search, and —
the paper's point — read the index with nothing but ls/cat.

    PYTHONPATH=src python examples/quickstart.py
"""
import json
import pathlib
import tempfile

import numpy as np

from repro.core import ECPBuildConfig, build_index, convert, open_index
from repro.data import clustered_vectors

with tempfile.TemporaryDirectory() as td:
    path = pathlib.Path(td) / "my_index"

    # 1) data: 50k CLIP-like embeddings (clustered unit vectors)
    data, _ = clustered_vectors(0, n=50_000, dim=128, n_clusters=256)

    # 2) build: C=200 vectors/cluster, L=2, l2 metric -> transparent files
    build_index(data, str(path), ECPBuildConfig(levels=2, cluster_cap=200, metric="l2"))

    # 3) the index IS a file structure (paper Fig. 1)
    info = json.loads((path / "info" / ".zattrs").read_text())
    print("info/.zattrs:", info)
    print("top-level entries:", sorted(p.name for p in path.iterdir())[:8])
    node0 = path / "lvl_2" / "node_00000000"
    meta = json.loads((node0 / "embeddings" / ".zarray").read_text())
    print("first cluster on disk:", meta["shape"], meta["dtype"], "raw chunks:",
          sorted(p.name for p in (node0 / "embeddings").iterdir() if not p.name.startswith(".")))

    # 4) search with a bounded memory footprint (LRU over 32 nodes); the
    #    index is a context manager — closing frees its prefetch executor
    with open_index(str(path), mode="file", cache_max_nodes=32) as index:
        q = data[1234] + 0.01 * np.random.default_rng(1).normal(size=128).astype(np.float32)
        rs = index.search(q, k=10, b=8)
        print("\ntop-10:", [(round(d, 3), i) for d, i in rs.pairs()])

        # 5) incremental: 10 more WITHOUT re-searching — the ResultSet's Query
        #    handle owns the frontier (T queue) and resumes from it
        more = rs.query.next(10)
        print("next-10:", [(round(d, 3), i) for d, i in more.pairs()])
        print("stats:", rs.query.stats)
        print("cache resident nodes:", index.cache.n_resident, "(bound 32)")
        rs.query.close()

        # 6) the same index as a page-aligned single file (the serialized form
        #    the paper compares against): one pread per node instead of JSON +
        #    chunk files — identical results, measurably less I/O
        blob = convert(path, pathlib.Path(td) / "my_index.blob")
        with open_index(str(blob), mode="file", cache_max_nodes=32) as bindex:
            rsb = bindex.search(q, k=10, b=8)
            assert [i for _, i in rsb.pairs()] == [i for _, i in rs.pairs()]
            print("\nblob file:", blob.name, f"({blob.stat().st_size/2**20:.1f} MiB)")
            print("fstore io:", index.store.io.as_dict())
            print("blob io:  ", bindex.store.io.as_dict())

    # 7) the index is MUTABLE (core/lifecycle.py): ingest, tombstone, then
    #    compact back to exactly what a fresh build would produce
    with open_index(str(path), mode="file") as index:
        new = data[:8] + 0.02 * np.random.default_rng(2).normal(size=(8, 128)).astype(np.float32)
        print("\ninsert:", index.insert(new, np.arange(50_000, 50_008)))
        index.delete([3, 7, 50_001])
        assert 50_002 in index.search(new[2], k=5, b=8).row_ids(0)
        assert 50_001 not in index.search(new[1], k=5, b=8).row_ids(0)  # tombstoned
        print("compact:", index.compact())
