"""Analytic per-cell FLOP and HBM-traffic models.

XLA's cost_analysis() counts while-loop bodies once (measured 88-675x
undercount on scanned models), so the compute and memory roofline terms come
from these closed-form counts instead; the HLO supplies the collective
schedule (loop-aware, hlo_analysis.py) and the peak-memory analysis.

Conventions: FLOPs = 2·M·N·K per matmul. Backward = 2x forward matmuls;
full-remat training recomputes forward once more => train = 4x forward ("3x"
without the remat re-forward; our configs remat). Attention is causal
(=> S²/2 effective). All numbers are GLOBAL (divide by chips for per-chip).
"""
from __future__ import annotations

from dataclasses import dataclass


def _lm_forward_flops(cfg, batch: int, seq: int, *, causal: bool = True) -> dict:
    """Per-forward-pass FLOPs for the LM family, split by component."""
    L, D, Hq, Hkv, dh, F, V = (
        cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
        cfg.d_ff, cfg.vocab,
    )
    T = batch * seq
    qkvo = 2 * T * D * (Hq * dh) * 2 + 2 * T * D * (Hkv * dh) * 2  # wq,wo + wk,wv
    attn_factor = 0.5 if causal else 1.0
    attn = 2 * (2 * batch * Hq * seq * seq * dh) * attn_factor     # QK^T + PV
    if cfg.moe is None:
        ffn_per_layer_tokens = 2 * T * D * F * 3                   # gate,up,down
        n_ffn_dense = L
        ffn = ffn_per_layer_tokens * 1.0
        moe_ffn = 0.0
        n_moe = 0
    else:
        n_moe = L if cfg.moe_every == 1 else L // 2
        n_ffn_dense = 0 if cfg.moe_every == 1 else L // 2
        ffn = 2 * T * D * F * 3                                    # dense part
        # top-1: each token through ONE expert of width moe.d_ff + router
        moe_ffn = 2 * T * D * cfg.moe.d_ff * 3 + 2 * T * D * cfg.moe.n_experts
    logits = 2 * T * D * V
    per_layer_qkvo = qkvo  # qkvo above is for all T through ONE layer
    total = (
        L * per_layer_qkvo
        + L * attn
        + n_ffn_dense * ffn
        + n_moe * moe_ffn
        + logits
    )
    return {
        "total": float(total),
        "qkvo": float(L * per_layer_qkvo),
        "attn": float(L * attn),
        "ffn": float(n_ffn_dense * ffn + n_moe * moe_ffn),
        "logits": float(logits),
    }


def lm_cell_flops(cfg, kind: str, batch: int, seq: int) -> dict:
    if kind == "train":
        f = _lm_forward_flops(cfg, batch, seq - 1)
        mult = 4.0 if cfg.remat else 3.0     # fwd + bwd(2x) [+ remat re-fwd]
        return {k: v * mult for k, v in f.items()}
    if kind == "prefill":
        return _lm_forward_flops(cfg, batch, seq)
    if kind == "decode":
        # one token: weights touched for 1 token; attention over kv_len seq
        f = _lm_forward_flops(cfg, batch, 1, causal=False)
        attn = 2 * (2 * batch * cfg.n_heads * 1 * seq * cfg.d_head)
        f["attn"] = float(cfg.n_layers * attn)
        f["total"] = f["qkvo"] + f["attn"] + f["ffn"] + f["logits"]
        return f
    if kind == "retrieval_decode":
        cs = cfg.retrieval.cluster_size
        nC = -(-seq // cs)
        b = cfg.retrieval.top_clusters
        f = _lm_forward_flops(cfg, batch, 1, causal=False)
        # centroid scoring + attention over (b+1) gathered clusters
        attn = 2 * batch * cfg.n_heads * cfg.d_head * (nC + 2 * (b + 1) * cs)
        f["attn"] = float(cfg.n_layers * attn)
        f["total"] = f["qkvo"] + f["attn"] + f["ffn"] + f["logits"]
        return f
    raise ValueError(kind)


def lm_cell_hbm_bytes(cfg, kind: str, batch: int, seq: int) -> float:
    """Leading-order HBM traffic (global bytes per step).

    Weights stream once per pass from HBM (bf16/f32 per param_dtype);
    activations: residual stream + attention score blocks; caches for
    decode. This is a lower-bound style model — fusion-dependent temporaries
    are excluded — and is reported alongside, never mixed with, HLO bytes.
    """
    import jax.numpy as jnp

    pbytes = 2 if cfg.param_dtype == jnp.bfloat16 else 4
    L, D, F, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab
    n_params_ffn = (
        L * 3 * D * F
        if cfg.moe is None
        else (L // 2 if cfg.moe_every == 2 else 0) * 3 * D * F
        + (L if cfg.moe_every == 1 else L // 2) * (cfg.moe.n_experts * 3 * D * cfg.moe.d_ff)
    )
    n_params = (
        V * D * 2
        + L * (D * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.d_head + cfg.n_heads * cfg.d_head * D)
        + n_params_ffn
    )
    T = batch * max(seq, 1)
    act = 2 * T * D  # bf16 residual per layer touchpoint
    if kind == "train":
        # fwd + remat re-fwd + bwd weight reads, grads write, opt r/w (f32-ish)
        weight_traffic = n_params * pbytes * 3 + n_params * (pbytes + 8)
        act_traffic = L * act * 8  # a handful of reads/writes per layer
        return float(weight_traffic + act_traffic)
    if kind == "prefill":
        return float(n_params * pbytes + L * act * 4 + 2 * L * T * cfg.n_kv_heads * cfg.d_head * 2)
    if kind == "decode":
        cache = L * batch * cfg.n_kv_heads * seq * cfg.d_head * 2 * 2
        return float(n_params * pbytes + cache)  # weights + full cache read
    if kind == "retrieval_decode":
        cs = cfg.retrieval.cluster_size
        nC = -(-seq // cs)
        b = cfg.retrieval.top_clusters
        cents = L * batch * cfg.n_kv_heads * nC * cfg.d_head * 4
        gathered = L * batch * cfg.n_kv_heads * (b + 1) * cs * cfg.d_head * 2 * 2
        return float(n_params * pbytes + cents + gathered)
    raise ValueError(kind)


# ------------------------------------------------------------------ others
def gnn_cell_flops(cfg, sh: dict) -> float:
    d_h = cfg.d_hidden
    if sh["kind"] == "full_graph":
        N, E, d_in = sh["n_nodes"], sh["n_edges"], sh["d_feat"]
        dims = [d_in] + [d_h] * cfg.n_layers
        per = sum(2 * N * dims[i] * dims[i + 1] * 2 for i in range(cfg.n_layers))
        gather = sum(E * dims[i] for i in range(cfg.n_layers))  # segment adds
        return float((per + gather + 2 * N * d_h * cfg.n_classes) * 4)  # train
    if sh["kind"] == "sampled":
        B, d_in = sh["batch_nodes"], sh["d_feat"]
        f1, f2 = sh["fanouts"]
        n0, n1 = B * f1 * f2, B * f1
        fl = 2 * (n1 + B) * d_in * d_h * 2 + 2 * B * d_h * d_h * 2
        return float(fl * 4)
    if sh["kind"] == "graphs":
        G, N, E, d_in = sh["batch"], sh["n_nodes"], sh["n_edges"], sh["d_feat"]
        fl = 2 * G * N * (d_in * d_h * 2 + d_h * d_h * 2) + G * E * d_h
        return float(fl * 4)
    raise ValueError(sh["kind"])


def recsys_cell_flops(cfg, sh: dict) -> float:
    d = cfg.embed_dim
    B = sh.get("batch", 1)
    mlp_dims = list(cfg.mlp)
    if cfg.interaction == "cross":
        x0 = cfg.n_dense + cfg.n_fields * d
        core = 2 * B * x0 * x0 * cfg.n_cross_layers
        mlp_in = x0
    elif cfg.interaction == "self-attn":
        Fd = cfg.n_fields
        hd = cfg.n_heads * cfg.d_attn
        core = cfg.n_blocks * (2 * B * Fd * d * hd * 4 + 2 * B * cfg.n_heads * Fd * Fd * cfg.d_attn * 2)
        mlp_in = Fd * hd
    elif cfg.interaction == "transformer-seq":
        S = cfg.seq_len + 1
        dm = d * cfg.seq_fields
        hd = cfg.n_heads * cfg.d_attn
        core = cfg.n_blocks * (
            2 * B * S * dm * hd * 4 + 2 * B * cfg.n_heads * S * S * cfg.d_attn * 2
            + 2 * B * S * dm * 4 * dm * 2
        )
        mlp_in = S * dm + (cfg.n_fields - cfg.seq_fields) * d
    else:  # augru
        g = cfg.gru_dim
        sd = d * cfg.seq_fields
        core = 2 * cfg.seq_len * B * (sd * 3 * g + g * 3 * g) * 2
        mlp_in = g + (cfg.n_fields - cfg.seq_fields) * d + sd
    mlp = 0
    prev = mlp_in
    for m in mlp_dims:
        mlp += 2 * B * prev * m
        prev = m
    total = core + mlp
    if sh["kind"] == "train":
        total *= 4
    if sh["kind"] == "retrieval":
        total = 2 * sh["n_candidates"] * d
    return float(total)


def cell_flops(meta: dict, kind: str, sh: dict) -> float:
    fam = meta["family"]
    cfg = meta["cfg"]
    if fam == "lm":
        return lm_cell_flops(cfg, kind, sh["batch"], sh["seq"])["total"]
    if fam == "gnn":
        return gnn_cell_flops(cfg, sh)
    return recsys_cell_flops(cfg, sh)


def cell_hbm_bytes(meta: dict, kind: str, sh: dict) -> float:
    fam = meta["family"]
    cfg = meta["cfg"]
    if fam == "lm":
        return lm_cell_hbm_bytes(cfg, kind, sh["batch"], sh["seq"])
    if fam == "gnn":
        if sh["kind"] == "full_graph":
            N, E, d = sh["n_nodes"], sh["n_edges"], sh["d_feat"]
            feats = N * d * 4
            msgs = E * cfg.d_hidden * 4 * cfg.n_layers
            return float((feats + msgs + E * 8) * 4)
        if sh["kind"] == "sampled":
            B, d = sh["batch_nodes"], sh["d_feat"]
            f1, f2 = sh["fanouts"]
            return float(B * f1 * f2 * d * 4 * 4)
        G, N, d = sh["batch"], sh["n_nodes"], sh["d_feat"]
        return float(G * N * d * 4 * 4)
    # recsys: embedding rows touched + dense activations + (train) table grads
    d = cfg.embed_dim
    B = sh.get("batch", 1)
    lookups = B * (cfg.n_fields + 2 * cfg.seq_fields * max(cfg.seq_len, 1)) * d * 4
    act = B * (cfg.n_dense + cfg.n_fields * d + sum(cfg.mlp)) * 4
    total = lookups + act
    if sh["kind"] == "train":
        total *= 3  # read + grad scatter + adam rows
    if sh["kind"] == "retrieval":
        total += sh["n_candidates"] * d * 4
    return float(total)
