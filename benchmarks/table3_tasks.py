"""Table 3: tasks completed per index (1 ground-truth item in top-100 for
any of the task's queries) — plus recall@100 vs exact search.  Every index,
brute force included, answers through the unified ``Searcher`` API."""
from __future__ import annotations

import numpy as np

from .indexes import get_suite


def run() -> list[dict]:
    s = get_suite()
    k = s.params["k"]
    rows = []
    for name, (searcher, b) in s.searchers().items():
        solved = 0
        recalls = []
        try:
            for t in s.ds.tasks:
                ok = False
                for q in t.queries:
                    ids = set(searcher.search(q, k, b=b).row_ids(0))
                    gt = set(s.bf.search(q, k).row_ids(0))
                    recalls.append(len(ids & gt) / k)
                    ok = ok or (t.target in ids)
                solved += int(ok)
        finally:
            if name == "eCP-FS":  # searchers() opened a fresh file-mode index
                searcher.close()
        rows.append(
            {
                "index": name,
                "tasks": f"{solved}/{len(s.ds.tasks)}",
                "recall@100": round(float(np.mean(recalls)), 4),
            }
        )
    return rows
