"""Table 3: tasks completed per index (1 ground-truth item in top-100 for
any of the task's queries) — plus recall@100 vs exact search."""
from __future__ import annotations

import numpy as np

from .indexes import get_suite


def run() -> list[dict]:
    s = get_suite()
    p = s.params
    ecp = s.fresh_ecp()

    def ecp_search(q, k):
        res, qid = ecp.new_search(q, k, b=p["b"])
        ecp.drop_query(qid)
        return None, np.asarray([i for _, i in res])

    searchers = {
        "eCP-FS": ecp_search,
        "IVF": lambda q, k: s.ivf.search(q, k, nprobe=p["nprobe"]),
        "HNSW": lambda q, k: s.hnsw.search(q, k, ef=p["ef"]),
        "DiskANN-lite": lambda q, k: s.vamana.search(q, k, complexity=p["complexity"]),
    }
    rows = []
    for name, fn in searchers.items():
        solved = 0
        recalls = []
        for t in s.ds.tasks:
            ok = False
            for q in t.queries:
                _, ids = fn(q, p["k"])
                ids = set(np.asarray(ids).reshape(-1).tolist())
                gt = set(s.bf.search(q, p["k"])[1].tolist())
                recalls.append(len(ids & gt) / p["k"])
                ok = ok or (t.target in ids)
            solved += int(ok)
        rows.append(
            {
                "index": name,
                "tasks": f"{solved}/{len(s.ds.tasks)}",
                "recall@100": round(float(np.mean(recalls)), 4),
            }
        )
    return rows
