"""Closed-loop serving benchmark — concurrent QPS/latency under writes.

The lifecycle section already showed the single-threaded cost of serving
during ingest (search latency inflates ~15x while insert batches run,
because every query waits for the writer).  This benchmark measures what
the concurrent serving subsystem (launch/scheduler.py + launch/serve.py)
buys back: client threads drive a ``Server`` at a target QPS through two
phases —

  readonly   only searches
  mixed      same search load while a writer thread continuously inserts
             batches and occasionally deletes

and each phase reports p50/p99 latency, achieved QPS, and the scheduler's
admission/deadline accounting (rejected / degraded / deadline misses).
On the blob backend reads are snapshot-isolated, so the mixed-phase p99
should stay within a small factor of the read-only p99 instead of
absorbing whole insert batches.

CI smoke gate::

  PYTHONPATH=src python -m benchmarks.serving --smoke

runs a tiny version and FAILS on either of the subsystem's two hard
invariants:

  * snapshot parity — a pinned snapshot's results, queried while the
    writer keeps mutating (including across further inserts), must be
    bit-identical to a fresh single-threaded index opened on a copy of
    the blob file taken at the pinned generation;
  * deadline accounting — submitted == completed + rejected + failed and
    deadline_misses <= completed once the load drains.
"""
from __future__ import annotations

import shutil
import threading
import time

import numpy as np


def _percentiles(lat_ms: list) -> tuple[float, float, float]:
    if not lat_ms:
        return 0.0, 0.0, 0.0
    a = np.asarray(lat_ms)
    return float(a.mean()), float(np.percentile(a, 50)), float(np.percentile(a, 99))


class _Clients:
    """Closed-loop client pool: each thread issues its next request when
    the previous one finishes, paced to target_qps/n_clients ticks (if a
    request runs long the next fires immediately — saturation behaves
    closed-loop, light load behaves like a paced open loop)."""

    def __init__(self, server, queries, *, k, b, deadline_ms, target_qps, n_clients):
        self.server = server
        self.queries = queries
        self.k, self.b, self.deadline_ms = k, b, deadline_ms
        self.interval = n_clients / target_qps if target_qps else 0.0
        self.n_clients = n_clients
        self.lat_ms: list = []
        self.rejected = 0
        self.errors: list = []
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._threads: list = []

    def _loop(self, tid: int) -> None:
        from repro.launch.scheduler import ServerOverloadedError

        rng = np.random.default_rng(tid)
        next_tick = time.perf_counter()
        while not self._stop.is_set():
            q = self.queries[rng.integers(0, len(self.queries))]
            t0 = time.perf_counter()
            try:
                _, sid = self.server.search(
                    q, self.k, b=self.b, deadline_ms=self.deadline_ms
                )
                self.server.close(sid)
                dt_ms = (time.perf_counter() - t0) * 1e3
                with self._lock:
                    self.lat_ms.append(dt_ms)
            except ServerOverloadedError:
                with self._lock:
                    self.rejected += 1
                time.sleep(self.interval or 1e-3)  # back off, as a client would
            except Exception as e:  # pragma: no cover - surfaced by run()
                with self._lock:
                    self.errors.append(e)
                return
            next_tick += self.interval
            delay = next_tick - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            else:
                next_tick = time.perf_counter()

    def run_for(self, seconds: float) -> dict:
        self.lat_ms, self.rejected = [], 0
        self._stop.clear()
        self._threads = [
            threading.Thread(target=self._loop, args=(i,), daemon=True)
            for i in range(self.n_clients)
        ]
        t0 = time.perf_counter()
        for t in self._threads:
            t.start()
        time.sleep(seconds)
        self._stop.set()
        for t in self._threads:
            t.join()
        if self.errors:
            raise self.errors[0]
        wall = time.perf_counter() - t0
        mean, p50, p99 = _percentiles(self.lat_ms)
        return {
            "completed": len(self.lat_ms),
            "rejected": self.rejected,
            "qps": round(len(self.lat_ms) / wall, 1),
            "mean_ms": round(mean, 3),
            "p50_ms": round(p50, 3),
            "p99_ms": round(p99, 3),
        }


def _writer_loop(server, dim, stop, *, batch=64, period_s=0.005, seed=99):
    """Sustained ingest: insert a batch every ``period_s``, tombstone a
    few ids every 8th batch."""
    rng = np.random.default_rng(seed)
    base = int(server.searcher.info.next_id)
    i = 0
    inserted = deleted = 0
    while not stop.is_set():
        vecs = rng.normal(size=(batch, dim)).astype(np.float32)
        ids = np.arange(base + i * batch, base + (i + 1) * batch)
        server.insert(vecs, ids)
        inserted += batch
        if i % 8 == 7:
            victims = ids[:4]
            deleted += server.delete(victims)
        i += 1
        stop.wait(period_s)
    return inserted, deleted


def run_serving(
    *,
    blob_path: str,
    queries: np.ndarray,
    k: int = 100,
    b: int = 16,
    workers: int = 4,
    n_clients: int = 8,
    target_qps: float = 2000.0,
    deadline_ms: float = 100.0,
    queue_depth: int = 64,
    phase_s: float = 3.0,
    cache_max_nodes: int = 64,
) -> list[dict]:
    """One row per phase (readonly, mixed) for one Server configuration.

    ``blob_path`` may be a single blob file OR a federation root (a
    directory with a ``federation.json`` manifest): ``open_index`` auto-
    detects, and the Server/scheduler machinery is index-agnostic."""
    from repro.core import open_index
    from repro.launch.serve import Server

    idx = open_index(
        blob_path, mode="file", backend="auto", cache_max_nodes=cache_max_nodes
    )
    rows = []
    with Server(idx, workers=workers, queue_depth=queue_depth) as srv:
        clients = _Clients(
            srv,
            queries,
            k=k,
            b=b,
            deadline_ms=deadline_ms,
            target_qps=target_qps,
            n_clients=n_clients,
        )

        r = clients.run_for(phase_s)
        rows.append({"phase": "readonly", **r, "inserts": 0, "deletes": 0})

        stop = threading.Event()
        out: dict = {}

        def writer():
            out["io"] = _writer_loop(srv, queries.shape[1], stop)

        wt = threading.Thread(target=writer, daemon=True)
        wt.start()
        r = clients.run_for(phase_s)
        stop.set()
        wt.join()
        ins, dels = out["io"]
        rows.append({"phase": "mixed", **r, "inserts": ins, "deletes": dels})

        st = srv.scheduler.stats.as_dict()
        for row in rows:
            row["workers"] = workers
        rows.append(
            {
                "phase": "scheduler",
                "completed": st["completed"],
                "rejected": st["rejected"],
                "qps": "",
                "mean_ms": "",
                "p50_ms": "",
                "p99_ms": round(st["queue_wait_ms"] / max(1, st["completed"]), 3),
                "inserts": st["degraded"],
                "deletes": st["deadline_misses"],
                "workers": workers,
            }
        )
        # accounting invariant (all client futures resolved by now)
        assert st["submitted"] == st["completed"] + st["rejected"] + st["failed"], st
        assert st["deadline_misses"] <= st["completed"], st
    return rows


def run(*, fast: bool = True, phase_s: float | None = None) -> list[dict]:
    """The run.py scenario: serving phases over the shared bench suite's
    blob index."""
    from .indexes import get_suite

    s = get_suite()
    queries = np.stack([t.queries[-1] for t in s.ds.tasks])
    return run_serving(
        blob_path=_suite_blob(s),
        queries=queries,
        k=s.params["k"],
        b=s.params["b"]["eCP-FS"],
        phase_s=phase_s if phase_s is not None else (2.0 if fast else 5.0),
    )


def _suite_blob(s) -> str:
    """The serving run mutates its index; work on a throwaway copy of the
    suite's blob so later sections see the original."""
    dst = s.ecp_blob_path + ".serving"
    shutil.copy(s.ecp_blob_path, dst)
    return dst


def smoke(n: int = 4000, dim: int = 32, phase_s: float = 1.5) -> None:
    """Tiny end-to-end gate: run both phases at load, then assert the two
    hard invariants (snapshot parity under continued mutation + deadline
    accounting).  Raises on violation."""
    import tempfile

    from repro.core import ECPBuildConfig, build_index, convert, open_index
    from repro.data import clustered_vectors
    from repro.launch.serve import Server

    data, _ = clustered_vectors(0, n=n, dim=dim, n_clusters=48)
    with tempfile.TemporaryDirectory() as td:
        path = td + "/idx"
        build_index(data, path, ECPBuildConfig(levels=2, cluster_cap=100, metric="l2"))
        blob = str(convert(path, td + "/idx.blob"))
        rng = np.random.default_rng(3)
        queries = data[rng.integers(0, n, 32)]

        rows = run_serving(
            blob_path=blob,
            queries=queries,
            k=20,
            b=8,
            workers=4,
            n_clients=4,
            target_qps=500.0,
            deadline_ms=50.0,
            phase_s=phase_s,
        )
        for row in rows:
            print(row)
        ro = next(r for r in rows if r["phase"] == "readonly")
        mx = next(r for r in rows if r["phase"] == "mixed")
        assert ro["completed"] > 0 and mx["completed"] > 0, rows
        if ro["p99_ms"]:
            print(
                f"serving smoke: mixed/readonly p99 ratio = "
                f"{mx['p99_ms'] / ro['p99_ms']:.2f}x"
            )

        # ---- snapshot parity under continued mutation --------------------
        idx = open_index(blob, mode="file", backend="blob", cache_max_nodes=64)
        with Server(idx, workers=2, queue_depth=16) as srv:
            base = int(idx.info.next_id)  # the phase run above already inserted
            new = data[:64] + 0.02 * rng.normal(size=(64, dim)).astype(np.float32)
            srv.insert(new, np.arange(base, base + 64))
            srv.delete(np.arange(0, 100, 7))
            # pin a generation and copy the at-rest file atomically w.r.t.
            # writers (snapshot() + the copy both under the mutation lock)
            with idx._mut_lock:
                snap = idx.snapshot()
                frozen = td + "/frozen.blob"
                shutil.copy(blob, frozen)
            # keep mutating PAST the pinned generation
            more = data[64:160] + 0.02 * rng.normal(size=(96, dim)).astype(np.float32)
            srv.insert(more, np.arange(base + 64, base + 160))
            srv.delete(np.arange(1, 100, 9))
            srv.compact()

            ref = open_index(frozen, mode="file", backend="blob")
            for q in queries[:16]:
                rs_snap = snap.search(q, k=20, b=8)
                rs_ref = ref.search(q, k=20, b=8)
                np.testing.assert_array_equal(rs_snap.ids, rs_ref.ids)
                np.testing.assert_array_equal(rs_snap.dists, rs_ref.dists)
            snap.close()
            ref.close()
        print("serving smoke OK: snapshot parity bit-identical; accounting holds")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true", help="tiny phases + hard invariants (CI gate)"
    )
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for row in run(fast=False):
            print(row)
