"""Roofline table: read dry-run records, derive the three terms, the
MODEL_FLOPS / HLO_FLOPs utilization ratio, and the bottleneck per cell."""
from __future__ import annotations

import json
from pathlib import Path

from .hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS

RESULTS = Path(__file__).parent / "results"


def set_results_dir(path) -> None:
    global RESULTS
    RESULTS = Path(path)


def model_flops(rec: dict) -> float:
    """6·N·D for train (D = tokens), 2·N_active·D for inference-like steps."""
    n = rec.get("n_params", 0)
    toks = rec.get("tokens", 0) or 0
    arch, kind = rec["arch"], rec["kind"]
    act = n
    if "maverick" in arch:          # 400B total / ~17B active
        act = 17e9
    elif "scout" in arch:           # 109B total / ~17B active
        act = 17e9
    if kind == "train":
        return 6.0 * act * toks
    if kind in ("prefill", "decode", "retrieval_decode", "serve"):
        return 2.0 * act * max(toks, 1)
    if kind == "retrieval":
        return 2.0 * rec.get("n_candidates", 0) * 16  # dot-scoring
    return 0.0


_META_CACHE: dict = {}


def _cell_meta(arch: str, shape: str) -> dict:
    """tokens / n_candidates for records written before meta was embedded."""
    key = (arch, shape)
    if key not in _META_CACHE:
        try:
            from repro.launch.cells import build_cell

            cell = build_cell(arch, shape, mesh_axes=("data", "model"))
            _META_CACHE[key] = {
                "tokens": int(cell.meta.get("tokens", 0)),
                "n_candidates": int(cell.meta.get("n_candidates", 0)),
            }
        except Exception:
            _META_CACHE[key] = {}
    return _META_CACHE[key]


def load_records(mesh_tag: str = "single") -> list[dict]:
    recs = []
    for fp in sorted(RESULTS.glob(f"dryrun_{mesh_tag}_*.json")):
        rec = json.loads(fp.read_text())
        if "tokens" not in rec:
            rec.update(_cell_meta(rec["arch"], rec["shape"]))
        recs.append(rec)
    return recs


def summarize(mesh_tag: str = "single") -> list[dict]:
    rows = []
    for rec in load_records(mesh_tag):
        n_chips = rec["n_chips"]
        mf = model_flops(rec)
        hlo_total = rec["flops_per_chip"] * n_chips
        util = mf / hlo_total if hlo_total else 0.0
        dom = rec["bottleneck"]
        t_dom = rec[f"{dom}_s" if dom != "compute" else "compute_s"]
        rows.append(
            {
                "arch": rec["arch"],
                "shape": rec["shape"],
                "mesh": rec["mesh"],
                "T_comp_s": f"{rec['compute_s']:.3e}",
                "T_mem_s": f"{rec['memory_s']:.3e}",
                "T_coll_s": f"{rec['collective_s']:.3e}",
                "bottleneck": dom,
                "model_flops": f"{mf:.3e}",
                "useful_ratio": round(util, 3),
                "hbm_GiB": round(rec["peak_hbm_adjusted"] / 2**30, 2),
                "compile_s": rec["compile_s"],
            }
        )
    return rows


def print_table(mesh_tag: str = "single") -> None:
    rows = summarize(mesh_tag)
    if not rows:
        print(f"(no dry-run records for mesh={mesh_tag}; run repro.launch.dryrun)")
        return
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
