"""Storage-backend comparison — the paper's file-vs-serialized question
made a measurable axis (core/store.py).

Same index, same queries, same byte-budgeted node cache (the paper's
memory-constrained setting, §6.1): each row is one backend —

  * fstore         the human-readable zarr-v2 hierarchy (JSON + chunk
                   files; several file opens per node read)
  * blob           the page-aligned single-file form (one pread per node,
                   adjacent nodes coalesce)
  * blob+prefetch  blob wrapped in AsyncPrefetchStore (frontier children
                   load on background threads during traversal)

Reported per backend: load time, cold/warm latency, the ``IOStats``
counters (bytes read / files opened / reads issued) accumulated by the
store during the cold pass, the prefetch-accuracy counters over the whole
run (issued / hits / wasted bytes — whether blob+prefetch's extra reads
ever get used, or are evicted unconsumed under the tight budget), plus
the cache-resident bytes under the budget.

Also usable as a CI smoke check::

  PYTHONPATH=src python -m benchmarks.backends --smoke

builds a tiny index, converts fstore -> blob, and asserts bit-identical
search results across all three backends.
"""
from __future__ import annotations

import time

import numpy as np


def compare(
    *,
    ecp_path: str,
    blob_path: str,
    queries: np.ndarray,
    k: int = 100,
    b: int = 16,
    cache_bytes: int = 1 << 20,
    backends=("fstore", "blob", "blob+prefetch"),
    runs: int = 2,
    quant_path: str | None = None,
) -> list[dict]:
    """One row per backend: latency + IOStats under a byte-budgeted cache.

    ``quant_path`` adds a ``quant`` row: the v3 blob searched through the
    quantized scan + full-precision rerank (bit-identical results).  Note
    this scenario is the quantized pipeline's WORST case and the row is
    kept as its honest memory-pressure characterization: under a cache
    budget far below the index's working set, the pipeline's resident
    state (quant companions + per-leaf rerank row caches + promoted fp
    nodes) evicts itself continuously, so the partial reads repeat and
    the byte savings invert.  The pipeline's target regime — cold or
    IO-bound batch scans with a sane cache — is measured by the
    search-engine ``quant/flat-batch`` and ``frontier/*`` scenarios."""
    from repro.core import open_index

    rows = []
    if quant_path is not None:
        backends = tuple(backends) + ("quant",)
    for backend in backends:
        if backend == "quant":
            path, open_kw = quant_path, {"backend": "blob", "quantized": True}
        else:
            path, open_kw = (
                ecp_path if backend == "fstore" else blob_path,
                {"backend": backend},
            )
        t0 = time.perf_counter()
        idx = open_index(path, mode="file", cache_max_bytes=cache_bytes, **open_kw)
        load_s = time.perf_counter() - t0

        with idx:  # close() frees the prefetch executor + store fd
            drain = getattr(idx.store, "drain", None)  # flush async prefetch I/O
            io0 = idx.store.io.snapshot()
            cold, warm = [], []
            for r in range(runs):
                for q in queries:
                    t0 = time.perf_counter()
                    idx.search(q, k, b=b)
                    (cold if r == 0 else warm).append(time.perf_counter() - t0)
                if r == 0:
                    if drain is not None:
                        drain()
                    cold_io = idx.store.io.delta(io0)
            # prefetch accuracy over the WHOLE run (flushing earlier would
            # charge payloads the warm pass is about to hit as wasted)
            if drain is not None:
                drain()
            flush = getattr(idx, "flush_prefetch_stats", None)
            if flush is not None:
                flush()
            full_io = idx.store.io.delta(io0)
            rows.append(
                {
                    "backend": backend,
                    "load_s": round(load_s, 4),
                    "lat_cold_s": round(float(np.mean(cold)), 6),
                    "lat_warm_s": round(float(np.mean(warm)), 6) if warm else 0.0,
                    "bytes_read": cold_io.bytes_read,
                    "files_opened": cold_io.files_opened,
                    "reads_issued": cold_io.reads_issued,
                    "prefetch_issued": full_io.prefetch_issued,
                    "prefetch_hits": full_io.prefetch_hits,
                    "prefetch_wasted": full_io.prefetch_wasted_bytes,
                    "cache_bytes": idx.cache.resident_bytes,
                    "budget_bytes": cache_bytes,
                }
            )
    return rows


def run(backends=("fstore", "blob", "blob+prefetch"), *, runs: int = 2) -> list[dict]:
    """The run.py scenario: compare backends over the shared bench suite
    under a tight shared cache budget (memory-constrained setting); the
    quantized v3 blob rides along as the fourth row."""
    from .indexes import get_suite

    s = get_suite()
    queries = np.stack([t.queries[-1] for t in s.ds.tasks])
    # budget ~ a handful of leaf clusters: forces evictions like §6.1
    dim = s.ds.data.shape[1]
    cache_bytes = 32 * s.params["k"] * dim * 4
    return compare(
        ecp_path=s.ecp_path,
        blob_path=s.ecp_blob_path,
        queries=queries,
        k=s.params["k"],
        b=s.params["b"]["eCP-FS"],
        cache_bytes=cache_bytes,
        backends=backends,
        runs=runs,
        quant_path=s.ecp_quant_path,
    )


def _prefetch_regression_check(
    blob: str, queries: np.ndarray, *, k: int = 100, b: int = 16, tol: float = 1.25
) -> None:
    """The comparison scenario (tight shared cache budget, cold + warm
    pass) that used to show blob+prefetch 2.65x slower than plain blob:
    with the accuracy throttle the gate must close (issues suppressed,
    issued count bounded) and latency must stay within ``tol`` of plain
    blob (best-of-3 interleaved, which absorbs machine noise)."""
    import time

    from repro.core import open_index

    dim = queries.shape[1]
    cache_bytes = 32 * k * dim * 4
    best = {"blob": float("inf"), "blob+prefetch": float("inf")}
    throttle = None
    for _ in range(3):
        for backend in best:
            idx = open_index(
                blob, mode="file", backend=backend, cache_max_bytes=cache_bytes
            )
            with idx:
                t0 = time.perf_counter()
                for _run in range(2):  # cold + warm under the tight budget
                    for q in queries:
                        idx.search(q, k, b=b)
                best[backend] = min(
                    best[backend], (time.perf_counter() - t0) / (2 * len(queries))
                )
                if backend == "blob+prefetch":
                    io = idx.store.io
                    throttle = (
                        io.prefetch_issued,
                        io.prefetch_hits,
                        idx.store.prefetch_suppressed,
                    )
    issued, hits, suppressed = throttle
    assert suppressed > 0, (
        "prefetch throttle never engaged on the low-accuracy scenario: "
        f"issued={issued} hits={hits} suppressed={suppressed}"
    )
    assert issued < suppressed, (
        "throttle should suppress most speculation here: "
        f"issued={issued} suppressed={suppressed}"
    )
    assert best["blob+prefetch"] <= best["blob"] * tol, (
        f"blob+prefetch regresses vs blob: "
        f"{best['blob+prefetch'] * 1e6:.0f}us vs {best['blob'] * 1e6:.0f}us "
        f"(tolerance {tol}x; throttle issued={issued} suppressed={suppressed})"
    )
    print(
        f"prefetch throttle OK: {best['blob+prefetch'] * 1e6:.0f}us vs "
        f"blob {best['blob'] * 1e6:.0f}us (tol {tol}x); "
        f"issued={issued} hits={hits} suppressed={suppressed}"
    )


def smoke(n: int = 2000, dim: int = 16, n_queries: int = 16) -> None:
    """Tiny end-to-end parity check: build -> convert -> bit-identical
    results on fstore, blob, blob+prefetch, and the quantized v3 blob
    (compressed scan + full-precision rerank); blob must issue fewer
    reads than fstore; blob+prefetch must no longer be slower than plain
    blob on the tight-cache comparison scenario (the throttle closes the
    gate when measured accuracy is low).  Raises on any violation.  (The
    quantized path's >=2x cold-bytes gate runs at bench scale in
    ``benchmarks.search_engine --smoke`` — at this toy scale the rerank
    read granularity swamps the code-size savings, so only parity is
    asserted here.)"""
    import tempfile

    from repro.core import ECPBuildConfig, build_index, convert, open_index
    from repro.data import clustered_vectors

    data, _ = clustered_vectors(0, n=n, dim=dim, n_clusters=24)
    with tempfile.TemporaryDirectory() as td:
        path = td + "/idx"
        build_index(data, path, ECPBuildConfig(levels=2, cluster_cap=64))
        blob = str(convert(path, td + "/idx.blob"))
        qblob = str(convert(path, td + "/idx.qblob", quant="int8"))

        rng = np.random.default_rng(7)
        qs = data[rng.integers(0, n, n_queries)]
        fidx = open_index(path, mode="file", backend="fstore")
        bidx = open_index(blob, mode="file", backend="blob")
        pidx = open_index(blob, mode="file", backend="blob", prefetch=True)
        qidx = open_index(qblob, mode="file", backend="blob", quantized=True)
        f_io0 = fidx.store.io.snapshot()
        b_io0 = bidx.store.io.snapshot()
        q_io0 = qidx.store.io.snapshot()
        for q in qs:
            rf = fidx.search(q, k=10, b=8)
            rb = bidx.search(q, k=10, b=8)
            rp = pidx.search(q, k=10, b=8)
            rq = qidx.search(q, k=10, b=8)
            np.testing.assert_array_equal(rf.ids, rb.ids)
            np.testing.assert_array_equal(rf.dists, rb.dists)
            np.testing.assert_array_equal(rf.ids, rp.ids)
            np.testing.assert_array_equal(rf.dists, rp.dists)
            np.testing.assert_array_equal(rf.ids, rq.ids)
            np.testing.assert_array_equal(rf.dists, rq.dists)
        f_io = fidx.store.io.delta(f_io0)
        b_io = bidx.store.io.delta(b_io0)
        q_io = qidx.store.io.delta(q_io0)
        assert b_io.reads_issued < f_io.reads_issued, (
            f"blob should issue fewer reads: blob={b_io} fstore={f_io}"
        )
        fidx.close()
        bidx.close()
        pidx.close()
        qidx.close()
        _prefetch_regression_check(blob, data[rng.integers(0, n, 48)], k=50, b=12)
        print(
            f"backend smoke OK: {n_queries} queries bit-identical; "
            f"fstore reads={f_io.reads_issued} files={f_io.files_opened} "
            f"bytes={f_io.bytes_read} | blob reads={b_io.reads_issued} "
            f"bytes={b_io.bytes_read} | quant reads={q_io.reads_issued} "
            f"bytes={q_io.bytes_read}"
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny build/convert/parity check")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for row in run():
            print(row)
