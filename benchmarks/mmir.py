"""MMIR benchmark (paper §5): an automated replay of LSC/VBS textual
known-item-search under index-swap conditions.

The paper replays real competition queries over SigLIP embeddings; offline
we reproduce the benchmark's *structure* with a synthetic-but-faithful
generator: a clustered embedding collection (mixture of unit-sphere
Gaussians — CLIP-like geometry), and T-KIS tasks whose queries are
progressive refinements of a hidden target item (each step adds
information = less query noise), exactly like LSC's 6-step / VBS's 3-step
textual hints. A task is SOLVED if any of its queries ranks the target in
the top-k (paper's criterion, k=100).

All indexes plug in as unified ``Searcher`` objects (repro.core.api):
``search(q, k, *, b) -> ResultSet``; continuations go through the
``ResultSet.query`` handle — eCP-FS resumes natively, the baselines'
``RestartQuery`` re-searches with ``emitted + k`` (the paper's protocol).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.data import clustered_vectors


@dataclass
class Task:
    target: int
    queries: np.ndarray  # [n_steps, D] progressively refined


@dataclass
class MMIRDataset:
    data: np.ndarray
    tasks: list
    name: str = "synthetic-tkis"


def make_dataset(
    *,
    n_items: int = 20000,
    dim: int = 32,
    n_tasks: int = 40,
    steps: int = 3,
    seed: int = 0,
    noise_hi: float = 0.6,
    noise_lo: float = 0.15,
) -> MMIRDataset:
    data, _ = clustered_vectors(seed, n=n_items, dim=dim, n_clusters=max(64, n_items // 300))
    rng = np.random.default_rng(seed + 1)
    tasks = []
    targets = rng.choice(n_items, size=n_tasks, replace=False)
    sigmas = np.linspace(noise_hi, noise_lo, steps)
    for t in targets:
        qs = np.stack(
            [data[t] + s * rng.normal(size=dim).astype(np.float32) for s in sigmas]
        )
        tasks.append(Task(target=int(t), queries=qs.astype(np.float32)))
    return MMIRDataset(data=data, tasks=tasks)


@dataclass
class WorkloadResult:
    name: str
    load_s: float = 0.0
    lat_first_s: list = field(default_factory=list)   # "disk" (cold) latencies
    lat_warm_s: list = field(default_factory=list)    # in-memory latencies
    workload_s: list = field(default_factory=list)    # total per run
    solved: int = 0
    n_tasks: int = 0

    def row(self) -> dict:
        f = lambda xs: float(np.mean(xs)) if xs else 0.0
        return {
            "index": self.name,
            "load_s": round(self.load_s, 4),
            "lat_disk_s": round(f(self.lat_first_s), 6),
            "lat_mem_s": round(f(self.lat_warm_s), 6),
            "workload_s": round(f(self.workload_s), 4),
            "tasks": f"{self.solved}/{self.n_tasks}",
        }


def single_query_workload(ds: MMIRDataset, name, searcher, *, k=100, b=None, runs=4, load_s=0.0, reset_fn=None):
    """Paper workload 1: every query top-k, repeated; run 0 is 'disk'.

    ``reset_fn() -> Searcher`` (optional) returns a cold instance for the
    first run (e.g. a fresh file-mode index with an empty node cache).
    """
    res = WorkloadResult(name=name, load_s=load_s)
    queries = [q for t in ds.tasks for q in t.queries]
    created = None  # searcher the workload itself opened (and must close)
    try:
        for r in range(runs):
            if r == 0 and reset_fn is not None:
                close = getattr(searcher, "close", None)
                if close is not None:  # the cold replacement orphans it
                    close()
                searcher = created = reset_fn()
            t_run = time.perf_counter()
            for q in queries:
                t0 = time.perf_counter()
                searcher.search(q, k, b=b)
                dt = time.perf_counter() - t0
                (res.lat_first_s if r == 0 else res.lat_warm_s).append(dt)
            res.workload_s.append(time.perf_counter() - t_run)
        # task completion from the warm run
        res.n_tasks = len(ds.tasks)
        for t in ds.tasks:
            ok = False
            for q in t.queries:
                rs = searcher.search(q, k, b=b)
                if t.target in set(rs.row_ids(0)):
                    ok = True
                    break
            res.solved += int(ok)
    finally:
        if created is not None:
            created.close()
    return res


def incremental_workload(ds: MMIRDataset, name, searcher, *, k=100, b=None, rounds=10, runs=3, load_s=0.0):
    """Paper workload 2: top-k then `rounds` x 'k more' per query.

    Continuation is the searcher's own ``Query`` handle: eCP-FS resumes its
    frontier, baselines restart with k + k*round via ``RestartQuery``.
    """
    res = WorkloadResult(name=name, load_s=load_s)
    queries = [q for t in ds.tasks for q in t.queries]
    for r in range(runs):
        t_run = time.perf_counter()
        for q in queries:
            t0 = time.perf_counter()
            rs = searcher.search(q, k, b=b)
            dt0 = time.perf_counter() - t0
            (res.lat_first_s if r == 0 else res.lat_warm_s).append(dt0)
            for rd in range(rounds):
                t1 = time.perf_counter()
                rs.query.next(k)
                res.lat_warm_s.append(time.perf_counter() - t1)
            rs.query.close()
        res.workload_s.append(time.perf_counter() - t_run)
    res.n_tasks = len(ds.tasks)
    return res
