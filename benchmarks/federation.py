"""Shard federation benchmark — one logical eCP index over many blob files.

The scatter-gather question, measured: a ``FederatedIndex`` over the same
collection split N ways must stay comparable to the single-file index at
EQUAL TOTAL effort ``b`` — the router splits ``b`` across probed shards
(conserved, floor ``b_min``), each shard runs its own file-mode traversal,
and one global top-k heap merges the streams.  Rows report latency,
recall@10 vs exact, how many shards were probed, and the aggregated
``SearchStats``/``IOStats`` across shards.

CI smoke gate::

  PYTHONPATH=src python -m benchmarks.federation --smoke

asserts the subsystem's hard invariants on a 4-shard split:

  * recall@10 within 2% of the single-file index at equal total ``b``;
  * per-query effort allocation sums EXACTLY to ``b`` (conservation);
  * aggregated stats are consistent with the per-shard breakdown;
  * mixed search + insert + BACKGROUND per-shard compaction through the
    serving scheduler completes with readers making progress mid-compact
    (snapshot isolation: no reader ever waits out the writer).
"""
from __future__ import annotations

import tempfile
import threading
import time
from pathlib import Path

import numpy as np


def _exact_top(data: np.ndarray, queries: np.ndarray, k: int, metric: str = "l2"):
    from repro.core.distances import np_distances

    return np.argsort(np_distances(queries, data, metric), axis=1, kind="stable")[:, :k]


def _recall(idx, queries, gt, *, k, b) -> tuple[float, float, dict]:
    """(recall@k, mean probed shards, aggregated stats dict) over queries."""
    hits = 0
    probed = []
    agg = {"leaves": 0, "dists": 0, "bytes": 0, "reads": 0}
    for q, g in zip(queries, gt):
        rs = idx.search(q, k=k, b=b)
        hits += len(set(rs.row_ids(0)) & set(int(x) for x in g))
        st = rs.stats
        agg["leaves"] += st.leaves_opened
        agg["dists"] += st.distance_calcs
        agg["bytes"] += st.io.bytes_read
        agg["reads"] += st.io.reads_issued
        alloc = getattr(rs.query, "allocation", None)
        probed.append(len(alloc) if alloc is not None else 1)
        rs.query.close()
    return hits / (len(queries) * k), float(np.mean(probed)), agg


def _timed(idx, queries, *, k, b, runs) -> float:
    best = float("inf")
    for _ in range(runs):
        t0 = time.perf_counter()
        for q in queries:
            idx.search(q, k=k, b=b).query.close()
        best = min(best, (time.perf_counter() - t0) / len(queries))
    return best


def compare(
    *,
    data: np.ndarray,
    single_blob: str,
    queries: np.ndarray,
    n_shards: int = 4,
    k: int = 10,
    b: int = 24,
    runs: int = 2,
    workdir: str | None = None,
    cfg=None,
) -> list[dict]:
    """Build an ``n_shards``-way federation of ``data`` and compare it to
    the single-file index at equal total effort.  One row per config."""
    from repro.core import ECPBuildConfig, build_federation, open_index

    cfg = cfg or ECPBuildConfig(
        levels=2, metric="l2", cluster_cap=max(64, len(data) // 256)
    )
    workdir = Path(workdir or tempfile.mkdtemp(prefix="ecpfs_fed_"))
    root = build_federation(data, workdir / "fed", n_shards=n_shards, cfg=cfg)
    gt = _exact_top(data, queries, k, cfg.metric)

    rows = []
    single = open_index(single_blob, mode="file", backend="blob")
    with single:
        rec, probed, agg = _recall(single, queries, gt, k=k, b=b)
        lat = _timed(single, queries, k=k, b=b, runs=runs)
        rows.append(
            {
                "config": "single", "shards": 1, "b_total": b,
                "lat_s": round(lat, 6), "recall@10": round(rec, 4),
                "probed": probed, **agg,
            }
        )
    fed = open_index(root)
    with fed:
        rec, probed, agg = _recall(fed, queries, gt, k=k, b=b)
        lat = _timed(fed, queries, k=k, b=b, runs=runs)
        rows.append(
            {
                "config": f"scatter-gather/{n_shards}", "shards": n_shards,
                "b_total": b, "lat_s": round(lat, 6), "recall@10": round(rec, 4),
                "probed": round(probed, 2), **agg,
            }
        )
    return rows


def run(*, fast: bool = True, runs: int = 2, n_shards: int = 4) -> list[dict]:
    """The run.py scenario: federate the shared bench suite's collection
    and compare against its single blob index at equal total ``b``."""
    from .indexes import get_suite

    s = get_suite()
    queries = np.stack([t.queries[-1] for t in s.ds.tasks])
    return compare(
        data=s.ds.data,
        single_blob=s.ecp_blob_path,
        queries=queries,
        n_shards=n_shards,
        k=10,
        b=24,
        runs=runs,
    )


# ------------------------------------------------------------------ smoke
def _assert_conservation(fed, queries, *, b: int) -> None:
    for q in queries:
        rs = fed.search(q, k=10, b=b)
        alloc = rs.query.allocation
        total = sum(alloc.values())
        assert total == b, f"effort not conserved: {alloc} sums to {total}, want {b}"
        assert all(v >= fed.b_min for v in alloc.values()), (
            f"allocation below b_min floor: {alloc}"
        )
        rs.query.close()


def _assert_stats_consistent(fed, q, *, b: int) -> None:
    rs = fed.search(q, k=10, b=b)
    per = rs.query.shard_stats
    agg = rs.stats
    assert set(per) == set(rs.query.allocation), (per.keys(), rs.query.allocation)
    for field in ("leaves_opened", "distance_calcs", "node_loads"):
        total = sum(getattr(st, field) for st in per.values())
        got = getattr(agg, field)
        assert got == total, f"{field}: aggregate {got} != sum of shards {total}"
    assert agg.io.bytes_read == sum(st.io.bytes_read for st in per.values())
    rs.query.close()


def _mixed_load_check(root, data, queries, *, dim: int) -> dict:
    """Search + insert + BACKGROUND compaction through the scheduler.

    Readers must make progress *while* the per-shard compaction runs —
    scheduler reads are snapshot-leased, so no search ever waits for the
    writer.  Asserts reader progress during the compact window and that
    the compaction actually rewrote every shard."""
    from repro.core import open_index
    from repro.launch.serve import Server

    fed = open_index(root)
    stop = threading.Event()
    lat: list = []
    errors: list = []
    in_window: list = []

    with Server(fed, workers=2, queue_depth=32) as srv:
        def reader(tid: int) -> None:
            rng = np.random.default_rng(tid)
            while not stop.is_set():
                q = queries[rng.integers(0, len(queries))]
                t0 = time.perf_counter()
                try:
                    _, sid = srv.search(q, k=10, b=8)
                    srv.close(sid)
                except Exception as e:  # noqa: BLE001 - surfaced below
                    errors.append(e)
                    return
                lat.append((time.perf_counter() - t0, compacting.is_set()))

        compacting = threading.Event()
        threads = [threading.Thread(target=reader, args=(i,), daemon=True) for i in range(2)]
        for t in threads:
            t.start()

        rng = np.random.default_rng(7)
        base = int(fed.info.next_id)
        for i in range(4):  # sustained ingest through the scheduler
            vecs = rng.normal(size=(48, dim)).astype(np.float32)
            srv.insert(vecs, np.arange(base + i * 48, base + (i + 1) * 48))
        srv.delete(np.arange(0, 200, 7))

        gen_before = fed.info.generation
        compacting.set()
        fut = fed.compact_async(scheduler=srv.scheduler)
        result = fut.result(timeout=120)
        compacting.clear()
        time.sleep(0.05)
        stop.set()
        for t in threads:
            t.join(timeout=30)

        assert not errors, f"reader failed during mixed load: {errors[0]!r}"
        in_window = [ms for ms, during in lat if during]
        assert in_window, "no search completed during the background compaction"
        assert fed.info.generation > gen_before, "compaction published no generation"
        assert set(result["shards"]) == set(fed.shard_names), result
        st = srv.scheduler.stats.as_dict()
        assert st["submitted"] == st["completed"] + st["rejected"] + st["failed"], st
    return {
        "searches": len(lat),
        "during_compact": len(in_window),
        "max_ms_during_compact": round(max(in_window) * 1e3, 1),
    }


def smoke(n: int = 4000, dim: int = 32, n_queries: int = 64, b: int = 24) -> None:
    """The CI gate (see module docstring).  Raises on any violation."""
    from repro.core import ECPBuildConfig, build_federation, build_index, convert, open_index
    from repro.data import clustered_vectors

    data, _ = clustered_vectors(0, n=n, dim=dim, n_clusters=48)
    cfg = ECPBuildConfig(levels=2, cluster_cap=100, metric="l2")
    rng = np.random.default_rng(100)
    queries = data[rng.integers(0, n, n_queries)]
    gt = _exact_top(data, queries, 10)

    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        build_index(data, str(td / "single"), cfg)
        blob = str(convert(str(td / "single"), td / "single.blob"))
        root = build_federation(data, td / "fed", n_shards=4, cfg=cfg)

        single = open_index(blob, mode="file", backend="blob")
        fed = open_index(root)
        assert fed.shard_names and len(fed.shard_names) == 4, fed.shard_names

        rec_single, _, _ = _recall(single, queries, gt, k=10, b=b)
        rec_fed, probed, _ = _recall(fed, queries, gt, k=10, b=b)
        assert rec_fed >= rec_single - 0.02, (
            f"federated recall@10 {rec_fed:.4f} more than 2% below "
            f"single-file {rec_single:.4f} at equal total b={b}"
        )

        _assert_conservation(fed, queries[:16], b=b)
        for bb in (5, 7, 16):  # conservation at awkward b values too
            _assert_conservation(fed, queries[:4], b=bb)
        _assert_stats_consistent(fed, queries[0], b=b)

        single.close()
        fed.close()

        mixed = _mixed_load_check(root, data, queries, dim=dim)

    print(
        f"federation smoke OK: recall@10 fed={rec_fed:.4f} vs single="
        f"{rec_single:.4f} at b={b} (gap {rec_single - rec_fed:+.4f} <= 0.02), "
        f"avg probed shards {probed:.2f}; effort conserved; "
        f"mixed load: {mixed['searches']} searches "
        f"({mixed['during_compact']} during background compact, "
        f"max {mixed['max_ms_during_compact']}ms)"
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="4-shard invariants gate")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for row in run():
            print(row)
