"""Index-lifecycle benchmark — the write path next to the read path.

One row per lifecycle stage, measured over a private copy of the bench
collection (the shared suite index stays untouched):

  * ``build/one-shot``      classic in-memory build (vectors/s)
  * ``build/streaming``     out-of-core build from 4k-row chunks — same
                            index bit-for-bit, bounded peak memory
  * ``insert[<backend>]``   streaming ingest through ``ECPIndex.insert``
                            (routing + leaf appends + 2-means splits),
                            interleaved with searches: the row also
                            reports search latency *during* writes vs a
                            read-only baseline (the insert-while-search
                            scenario)
  * ``delete``              tombstone throughput
  * ``compact[<backend>]``  spool + deterministic rebuild (live vectors/s)

Also usable as a CI smoke gate::

  PYTHONPATH=src python -m benchmarks.lifecycle --smoke

streamed-builds, inserts, deletes, and compacts a tiny index on BOTH
backends and asserts search parity against a one-shot rebuild of the same
logical collection under BOTH traversal engines.  Raises on any mismatch.
"""
from __future__ import annotations

import time

import numpy as np


def _vps(n: int, seconds: float) -> float:
    return n / seconds if seconds > 0 else float("inf")


def run(*, runs: int = 2, n_insert: int = 512, n_queries: int = 16) -> list[dict]:
    """One row per lifecycle stage over the shared bench collection."""
    import tempfile
    from pathlib import Path

    from repro.core import ECPBuildConfig

    from .indexes import get_suite

    s = get_suite()
    data = s.ds.data
    n, dim = data.shape
    cfg = ECPBuildConfig(levels=2, metric="l2", cluster_cap=max(64, n // 256))
    rng = np.random.default_rng(11)
    queries = np.stack([t.queries[-1] for t in s.ds.tasks])[:n_queries]
    new_vecs = (data[rng.integers(0, n, n_insert)]
                + 0.05 * rng.normal(size=(n_insert, dim))).astype(np.float32)
    rows: list[dict] = []

    workdir = Path(tempfile.mkdtemp(prefix="ecpfs_lifecycle_"))
    try:
        _stages(workdir, rows, data, cfg, queries, new_vecs, runs, n_insert, rng)
    finally:
        import shutil

        shutil.rmtree(workdir, ignore_errors=True)
    return rows


def _stages(workdir, rows, data, cfg, queries, new_vecs, runs, n_insert, rng) -> None:
    """The measured stages, against scratch indexes under ``workdir``
    (removed by the caller — a full-size run leaves several complete index
    copies behind otherwise)."""
    from repro.core import build_index, build_index_streaming, convert, open_index

    n, dim = data.shape

    # ---- builds ----------------------------------------------------------
    t0 = time.perf_counter()
    build_index(data, str(workdir / "one"), cfg)
    one_s = time.perf_counter() - t0
    rows.append({"scenario": "build/one-shot", "n": n,
                 "vectors_per_s": round(_vps(n, one_s), 1), "extra": f"{one_s:.2f}s"})

    def chunks():
        for lo in range(0, n, 4096):
            yield data[lo : lo + 4096]

    t0 = time.perf_counter()
    build_index_streaming(chunks, str(workdir / "streamed"), cfg)
    str_s = time.perf_counter() - t0
    rows.append({"scenario": "build/streaming", "n": n,
                 "vectors_per_s": round(_vps(n, str_s), 1),
                 "extra": f"{str_s:.2f}s; bit-identical, O(chunk) memory"})

    # ---- insert-while-search + compact, per backend ----------------------
    for backend in ("fstore", "blob"):
        path = str(workdir / f"mut_{backend}")
        build_index(data, path, cfg)
        if backend == "blob":
            path = str(convert(path, workdir / "mut.blob"))
        with open_index(path, mode="file", backend=backend) as idx:
            # read-only search baseline
            for q in queries:  # warm the cache like the during-writes pass
                idx.search(q, k=100, b=16)
            t0 = time.perf_counter()
            for _ in range(runs):
                for q in queries:
                    idx.search(q, k=100, b=16)
            base_q = (time.perf_counter() - t0) / (runs * len(queries))

            # interleave: insert a batch, then run the query sweep
            batch = 128
            ins_s = 0.0
            dur_q: list[float] = []
            splits = 0
            for lo in range(0, n_insert, batch):
                t0 = time.perf_counter()
                r = idx.insert(new_vecs[lo : lo + batch],
                               np.arange(n + lo, n + min(lo + batch, n_insert)))
                ins_s += time.perf_counter() - t0
                splits += r["splits"]
                t0 = time.perf_counter()
                for q in queries:
                    idx.search(q, k=100, b=16)
                dur_q.append((time.perf_counter() - t0) / len(queries))
            rows.append({
                "scenario": f"insert[{backend}]",
                "n": n_insert,
                "vectors_per_s": round(_vps(n_insert, ins_s), 1),
                "extra": (f"splits={splits}; search_during_writes="
                          f"{np.mean(dur_q)*1e6:.0f}us vs readonly={base_q*1e6:.0f}us"),
            })

            # deletes: tombstone 5% of the originals
            del_ids = rng.choice(n, max(1, n // 20), replace=False)
            t0 = time.perf_counter()
            idx.delete(del_ids)
            del_s = time.perf_counter() - t0
            if backend == "fstore":
                rows.append({"scenario": "delete", "n": len(del_ids),
                             "vectors_per_s": round(_vps(len(del_ids), del_s), 1),
                             "extra": "tombstones only; purge happens at compact"})

            t0 = time.perf_counter()
            r = idx.compact()
            comp_s = time.perf_counter() - t0
            rows.append({
                "scenario": f"compact[{backend}]",
                "n": r["live"],
                "vectors_per_s": round(_vps(r["live"], comp_s), 1),
                "extra": f"purged={r['purged']}; leaves={r['leaves']}; {comp_s:.2f}s",
            })


def smoke(n: int = 2500, dim: int = 16) -> None:
    """CI gate: streamed build -> insert -> delete -> compact must equal a
    one-shot rebuild of the logical collection, bit for bit, on both
    backends under both engines.  Raises on any violation."""
    import tempfile

    from repro.core import ECPBuildConfig, build_index, build_index_streaming, convert, open_index
    from repro.data import clustered_vectors

    rng = np.random.default_rng(5)
    data, _ = clustered_vectors(0, n=n, dim=dim, n_clusters=24)
    cfg = ECPBuildConfig(levels=2, cluster_cap=64, seed=1)
    n_ins = 200
    new_vecs = (data[rng.integers(0, n, n_ins)]
                + 0.05 * rng.normal(size=(n_ins, dim))).astype(np.float32)
    new_ids = np.arange(n, n + n_ins)
    del_ids = np.concatenate([rng.choice(n, 120, replace=False), new_ids[:25]])
    queries = data[rng.integers(0, n, 12)] + 0.01

    # expected: one-shot build over the logical collection (stored-dtype
    # values of live originals + live inserts, ascending id order)
    live = np.ones(n + n_ins, bool)
    live[del_ids] = False
    stored = np.concatenate([data, new_vecs]).astype(np.float16).astype(np.float32)

    with tempfile.TemporaryDirectory() as td:
        build_index(stored[live], td + "/fresh", cfg, item_ids=np.flatnonzero(live))
        expected = {}
        with open_index(td + "/fresh", mode="file") as fidx:
            for i, q in enumerate(queries):
                rs = fidx.search(q, k=20, b=8)
                expected[i] = (rs.dists.copy(), rs.ids.copy())

        # streamed build (odd chunking) == one-shot build, before mutations
        build_index_streaming(
            (data[lo : lo + 333] for lo in range(0, n, 333)), td + "/idx", cfg
        )
        blob = str(convert(td + "/idx", td + "/blob.blob"))

        for backend, path in (("fstore", td + "/idx"), ("blob", blob)):
            with open_index(path, mode="file", backend=backend) as idx:
                r = idx.insert(new_vecs, new_ids)
                assert r["inserted"] == n_ins
                nd = idx.delete(del_ids)
                assert nd == len(set(del_ids.tolist()))
                # tombstones filtered pre-compact, on both engines
                with open_index(path, mode="file", backend=backend, engine="legacy") as leg:
                    got = set(leg.search(data[del_ids[0]], k=50, b=32).row_ids(0))
                    assert not (got & set(del_ids.tolist())), "legacy engine leaked a tombstone"
                got = set(idx.search(data[del_ids[0]], k=50, b=32).row_ids(0))
                assert not (got & set(del_ids.tolist())), "flat engine leaked a tombstone"
                idx.compact()
            for engine in ("flat", "legacy"):
                with open_index(path, mode="file", backend=backend, engine=engine) as idx:
                    for i, q in enumerate(queries):
                        rs = idx.search(q, k=20, b=8)
                        ed, ei = expected[i]
                        np.testing.assert_array_equal(
                            rs.ids, ei, err_msg=f"{backend}/{engine} ids diverged"
                        )
                        np.testing.assert_array_equal(
                            rs.dists, ed, err_msg=f"{backend}/{engine} dists diverged"
                        )
    print(
        f"lifecycle smoke OK: streamed build + {n_ins} inserts + "
        f"{len(set(del_ids.tolist()))} deletes + compact == one-shot rebuild, "
        "bit-identical on fstore+blob under flat+legacy"
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny build/mutate/compact/parity gate")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for row in run():
            print(row)
