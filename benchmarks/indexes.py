"""Shared index construction for the table benchmarks — build once, reuse.

Emulates the paper's §5 setup at CPU-tractable scale: one collection, four
indexes (eCP-FS + IVF + HNSW + Vamana/DiskANN-lite), matched parameters.
Every index is exposed as a unified ``Searcher`` (repro.core.api); the
per-index effort knob lives in ``params["b"]`` (eCP expansion b == IVF
nprobe; graph indexes use search complexity ~= k, as the paper matches
them).
"""
from __future__ import annotations

import tempfile
import time
from dataclasses import dataclass
from pathlib import Path

from repro.core import ECPBuildConfig, ECPIndex, build_index, convert, open_index
from repro.core.baselines import BruteForce, HNSWLite, IVFIndex, VamanaLite

from .mmir import MMIRDataset, make_dataset

# storage-backend axis for the eCP index (core/store.py)
BACKENDS = ("fstore", "blob", "blob+prefetch")


@dataclass
class BenchSuite:
    ds: MMIRDataset
    ecp_path: str
    ecp_blob_path: str
    ecp_quant_path: str  # blob v3: int8 companion blocks (quantized scan)
    ecp_build_s: float
    ivf: IVFIndex
    ivf_build_s: float
    hnsw: HNSWLite
    hnsw_build_s: float
    vamana: VamanaLite
    vamana_build_s: float
    bf: BruteForce
    params: dict

    def fresh_ecp(self, backend: str = "fstore", **kw) -> ECPIndex:
        """A cold file-mode searcher (empty node cache — 'disk' runs) over
        the chosen storage backend: fstore | blob | blob+prefetch, plus
        "quant" — the v3 blob driven through the quantized scan."""
        if backend == "quant":
            return open_index(
                self.ecp_quant_path, mode="file", backend="blob",
                quantized=True, **kw,
            )
        if backend not in BACKENDS:
            raise ValueError(f"unknown eCP backend: {backend!r} ({'|'.join(BACKENDS)})")
        path = self.ecp_path if backend == "fstore" else self.ecp_blob_path
        return open_index(path, mode="file", backend=backend, **kw)

    def searchers(self) -> dict:
        """name -> (Searcher, effort b) for every index in the suite."""
        p = self.params
        return {
            "eCP-FS": (self.fresh_ecp(), p["b"]["eCP-FS"]),
            "IVF": (self.ivf, p["b"]["IVF"]),
            "HNSW": (self.hnsw, p["b"]["HNSW"]),
            "DiskANN-lite": (self.vamana, p["b"]["DiskANN-lite"]),
        }


_SUITE: BenchSuite | None = None


def get_suite(*, n_items=20000, dim=32, n_tasks=40, seed=0, workdir=None) -> BenchSuite:
    global _SUITE
    if _SUITE is not None:
        return _SUITE
    ds = make_dataset(n_items=n_items, dim=dim, n_tasks=n_tasks, seed=seed)
    workdir = Path(workdir or tempfile.mkdtemp(prefix="ecpfs_bench_"))
    ecp_path = str(workdir / "ecp_index")

    t0 = time.time()
    build_index(
        ds.data, ecp_path,
        ECPBuildConfig(levels=2, metric="l2", cluster_cap=max(64, n_items // 256)),
    )
    ecp_build = time.time() - t0
    ecp_blob_path = str(convert(ecp_path, workdir / "ecp_index.blob"))
    ecp_quant_path = str(
        convert(ecp_path, workdir / "ecp_index.qblob", quant="int8")
    )

    n_lists = max(32, n_items // 256)
    t0 = time.time()
    ivf = IVFIndex(ds.data, n_lists=n_lists, train_iters=6)
    ivf_build = time.time() - t0

    t0 = time.time()
    hnsw = HNSWLite(ds.data, M=12, ef_construction=48)
    hnsw_build = time.time() - t0

    t0 = time.time()
    vamana = VamanaLite(ds.data, R=16, L_build=48)
    vamana_build = time.time() - t0

    _SUITE = BenchSuite(
        ds=ds, ecp_path=ecp_path, ecp_blob_path=ecp_blob_path,
        ecp_quant_path=ecp_quant_path, ecp_build_s=ecp_build,
        ivf=ivf, ivf_build_s=ivf_build, hnsw=hnsw, hnsw_build_s=hnsw_build,
        vamana=vamana, vamana_build_s=vamana_build, bf=BruteForce(ds.data),
        params={
            "k": 100,
            "b": {"eCP-FS": 16, "IVF": 16, "HNSW": 100, "DiskANN-lite": 100},
        },
    )
    return _SUITE
