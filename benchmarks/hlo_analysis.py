"""HLO parsing for the roofline: collective bytes from compiled modules.

``compiled.cost_analysis()`` has no collective accounting, so we parse the
post-SPMD HLO text (per-device shapes) and sum the bytes of every
  all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Byte accounting per op (wire bytes per participating device):
  all-gather         result bytes              (device receives the result)
  all-reduce         2 x result bytes          (ring: reduce-scatter + gather)
  reduce-scatter     result bytes              (receives its shard; sends ~same)
  all-to-all         result bytes
  collective-permute result bytes
These are the standard ring-algorithm estimates; 'start' variants counted,
'done' variants skipped (same transfer).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_WEIGHT = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)

# ---------------------------------------------------------------- loop-aware
# XLA's cost_analysis() (and a naive text scan) counts a while-loop BODY
# exactly once, but a scanned 88-layer model executes it 88 times. We parse
# the HLO module into computations, recover each while's trip count from its
# condition (scan lowers to `compare(iv, constant(N)), direction=LT`), and
# multiply costs through the call graph (while/call/fusion/conditional).

# computation signatures may contain nested tuple types: greedy match to '->'
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_CALLSITE = re.compile(r"(to_apply|body|condition|calls)=%?([\w\.\-]+)")
_CONSTANT = re.compile(r"%?([\w\.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)")
_COMPARE = re.compile(r"compare\(([^)]*)\).*direction=LT")


def _split_computations(hlo_text: str) -> dict:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _COMP_HDR.match(s)
        if m and not s.startswith("%constant"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Recover scan trip count from the while condition computation.

    XLA wraps the `compare(iv, bound), LT` in a kLoop fusion, so the compare
    op is in a callee — but the s32[] bound constant is materialized in the
    condition computation itself, which contains nothing else numeric.
    """
    consts = []
    for ln in cond_lines:
        m = _CONSTANT.search(ln)
        if m:
            consts.append(int(m.group(2)))
    return max(consts) if consts else 1


def loop_aware_collective_bytes(hlo_text: str) -> dict:
    """Collective wire bytes with while-loop trip multiplication.

    Returns {"total_bytes", "by_type", "static_bytes" (once-per-body naive)}.
    """
    comps = _split_computations(hlo_text)
    # map computation -> list of (kind, callee) and local collective bytes
    local: dict[str, dict] = {}
    calls: dict[str, list] = {}
    whiles: dict[str, list] = {}  # comp -> [(body, cond)]
    for name, lines in comps.items():
        by_type: dict[str, float] = {}
        cl, wl = [], []
        for ln in lines:
            m = _OP_RE.search(ln)
            if m and m.group(3) != "-done":
                b = _shape_bytes(m.group(1)) * _COLL_WEIGHT[m.group(2)]
                by_type[m.group(2)] = by_type.get(m.group(2), 0) + b
            if " while(" in ln or "= while(" in ln.replace("  ", " "):
                body = cond = None
                for cm in _CALLSITE.finditer(ln):
                    if cm.group(1) == "body":
                        body = cm.group(2)
                    elif cm.group(1) == "condition":
                        cond = cm.group(2)
                if body:
                    wl.append((body, cond))
            else:
                for cm in _CALLSITE.finditer(ln):
                    if cm.group(1) in ("calls", "to_apply"):
                        cl.append(cm.group(2))
        local[name] = by_type
        calls[name] = cl
        whiles[name] = wl

    memo: dict[str, dict] = {}

    def total(comp: str, depth=0) -> dict:
        if comp in memo or depth > 50 or comp not in local:
            return memo.get(comp, {})
        agg = dict(local[comp])
        for callee in calls[comp]:
            for k, v in total(callee, depth + 1).items():
                agg[k] = agg.get(k, 0) + v
        for body, cond in whiles[comp]:
            trips = _trip_count(comps.get(cond, []))
            for k, v in total(body, depth + 1).items():
                agg[k] = agg.get(k, 0) + v * trips
        memo[comp] = agg
        return agg

    entry = None
    for name in comps:
        if name.startswith("main") or ".main" in name or name.endswith("main"):
            entry = name
            break
    if entry is None:  # fall back: the computation with the most lines
        entry = max(comps, key=lambda n: len(comps[n]))
    agg = total(entry)
    naive = collective_stats(hlo_text)
    return {
        "total_bytes": int(sum(agg.values())),
        "by_type": {k: int(v) for k, v in agg.items()},
        "static_bytes": naive["total_bytes"],
    }


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Returns {"total_bytes", "by_type": {op: {"count", "bytes"}}}."""
    by_type: dict = defaultdict(lambda: {"count": 0, "bytes": 0})
    total = 0.0
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # transfer already counted at -start
        b = _shape_bytes(shape_str) * _COLL_WEIGHT[op]
        by_type[op]["count"] += 1
        by_type[op]["bytes"] += int(b)
        total += b
    return {"total_bytes": int(total), "by_type": dict(by_type)}


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


def top_collectives(hlo_text: str, n: int = 12) -> list[dict]:
    """The n largest collectives with their loop-trip multipliers — the
    hillclimb targeting tool: tells you WHICH tensor's collective to kill."""
    comps = _split_computations(hlo_text)
    # computation -> effective trip multiplier (product along call chain)
    mult: dict[str, float] = {}

    calls: dict[str, list] = {c: [] for c in comps}
    for name, lines in comps.items():
        for ln in lines:
            if "while(" in ln:
                m = dict(_CALLSITE.findall(ln))
                body, cond = m.get("body"), m.get("condition")
                if body:
                    calls[name].append((body, _trip_count(comps.get(cond, []))))
                if cond:
                    calls[name].append((cond, 1))
            else:
                for a, b in _CALLSITE.findall(ln):
                    if a in ("calls", "to_apply"):
                        calls[name].append((b, 1))

    entry = None
    for name in comps:
        if "main" in name:
            entry = name
            break
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c]))

    def walk(comp, m):
        if comp not in comps:
            return
        mult[comp] = max(mult.get(comp, 0), m)
        for callee, trips in calls.get(comp, []):
            walk(callee, m * trips)

    walk(entry, 1)

    rows = []
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for ln in lines:
            om = _OP_RE.search(ln)
            if om and om.group(3) != "-done":
                b = _shape_bytes(om.group(1)) * _COLL_WEIGHT[om.group(2)]
                meta = re.search(r'op_name="([^"]*)"', ln)
                rows.append(
                    {
                        "bytes_total": int(b * m),
                        "bytes_once": int(b),
                        "trips": int(m),
                        "op": om.group(2),
                        "shape": om.group(1)[:60],
                        "where": (meta.group(1)[-90:] if meta else name[:60]),
                    }
                )
    rows.sort(key=lambda r: -r["bytes_total"])
    return rows[:n]


# ----------------------------------------------------------- roofline terms
# TPU v5e hardware constants (assignment-provided)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per chip, one direction)


def roofline_terms(cost: dict, coll_bytes: int, n_chips: int, *, per_device_hlo: bool = True):
    """Three roofline terms in seconds.

    cost: compiled.cost_analysis() dict. With SPMD partitioning the compiled
    module is the PER-DEVICE program, so flops/bytes are per-chip already.
    coll_bytes: per-device wire bytes from collective_stats.
    """
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    if not per_device_hlo:
        flops /= n_chips
        bytes_ /= n_chips
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_ / HBM_BW,
        "collective_s": float(coll_bytes) / ICI_BW,
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_,
        "coll_bytes_per_chip": float(coll_bytes),
    }
