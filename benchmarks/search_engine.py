"""Traversal-engine comparison — how much of eCP-FS's file-mode latency
was interpreter overhead rather than file I/O.

Same index, same queries, three execution paths per storage backend:

  * legacy-single   the reference engine (tuple heap + list-sort I), one
                    query at a time — the paper's original measured path
  * flat-single     the vectorized engine (flat-array frontier, candidate
                    buffer, cached node norms), one query at a time
  * flat-batch      the vectorized engine in round-based batch mode: all
                    B rows advance in lockstep, node demands are deduped
                    across rows and fetched with one coalescing
                    ``get_nodes`` per round
  * quant/flat-batch the batch engine over the v3 blob's int8 companion
                    blocks: one grouped device top-k launch per round,
                    survivors reranked from partial full-precision reads

Every path must return bit-identical (dists, ids) — the run *asserts*
this (CI uses it as the parity gate) and additionally asserts that on the
blob backend the batch path issues fewer cold ``reads_issued`` than B
independent single-query searches (the cross-query dedup guarantee), and
that the quantized scan reads at most half the cold bytes of the plain
blob scan.

Reported per scenario: warm/cold us_per_call, cold-pass IOStats, and for
the batch path the engine's round / dedup counters.
"""
from __future__ import annotations

import time

import numpy as np


def _fresh(path: str, backend: str, **kw):
    from repro.core import open_index

    return open_index(path, mode="file", backend=backend, **kw)


def compare(
    *,
    ecp_path: str,
    blob_path: str,
    queries: np.ndarray,
    k: int = 100,
    b: int = 16,
    runs: int = 2,
    backends=("fstore", "blob"),
    quant_path: str | None = None,
) -> list[dict]:
    """One row per (backend, engine path); raises AssertionError on any
    parity mismatch or on a batch dedup regression (blob).

    ``quant_path`` (a v3 blob) adds the ``quant/flat-batch`` scenario to
    the blob backend's iteration: the quantized scan + rerank pipeline,
    gated on bit-parity with legacy AND on cold ``bytes_read`` being at
    most half of the plain blob flat-batch scan (the compressed-scan
    guarantee)."""
    Q = np.asarray(queries, np.float32)
    B = len(Q)
    rows = []
    for backend in backends:
        path = ecp_path if backend == "fstore" else blob_path

        def single_loop(idx):
            return [idx.search(q, k, b=b) for q in Q]

        scenarios = [
            ("legacy-single", {"engine": "legacy"}, single_loop),
            ("flat-single", {}, single_loop),
            ("flat-batch", {}, lambda idx: idx.search(Q, k, b=b)),
        ]
        if backend == "blob" and quant_path is not None:
            scenarios.append(
                ("quant/flat-batch", {"quantized": True}, lambda idx: idx.search(Q, k, b=b))
            )
        results = {}
        perf = {}
        for name, kw, drive in scenarios:
            idx = _fresh(quant_path if name.startswith("quant/") else path, backend, **kw)
            try:
                io0 = idx.store.io.snapshot()
                t0 = time.perf_counter()
                out = drive(idx)
                cold_s = time.perf_counter() - t0
                cold_io = idx.store.io.delta(io0)
                if isinstance(out, list):
                    d = np.stack([r.dists for r in out])
                    i = np.stack([r.ids for r in out])
                    batch_stats = None
                else:
                    d, i = out.dists, out.ids
                    batch_stats = out.query.batch_stats
                results[name] = (d, i)
                warm = []
                for _ in range(runs):
                    t0 = time.perf_counter()
                    drive(idx)
                    warm.append(time.perf_counter() - t0)
                perf[name] = (cold_s, float(np.mean(warm)), cold_io, batch_stats)
            finally:
                idx.close()

        # ---- parity gate: every path bit-identical to legacy -----------
        ref_d, ref_i = results["legacy-single"]
        for name, _, _ in scenarios[1:]:
            d, i = results[name]
            np.testing.assert_array_equal(
                i, ref_i, err_msg=f"{backend}/{name}: ids diverge from legacy"
            )
            np.testing.assert_array_equal(
                d, ref_d, err_msg=f"{backend}/{name}: dists diverge from legacy"
            )
        # ---- dedup gate: batch must not read more than B singles -------
        if backend == "blob":
            single_reads = perf["flat-single"][2].reads_issued
            batch_reads = perf["flat-batch"][2].reads_issued
            assert batch_reads < single_reads, (
                f"batch dedup regression on blob: batch issued {batch_reads} "
                f"cold reads vs {single_reads} for {B} independent searches"
            )
        # ---- compression gate: quant scan must halve the cold bytes ----
        if "quant/flat-batch" in perf:
            quant_bytes = perf["quant/flat-batch"][2].bytes_read
            plain_bytes = perf["flat-batch"][2].bytes_read
            assert 2 * quant_bytes <= plain_bytes, (
                f"quantized-scan bytes regression: quant read {quant_bytes} "
                f"cold bytes vs {plain_bytes} for the plain blob scan "
                f"(needs >= 2x reduction)"
            )

        legacy_warm = perf["legacy-single"][1]
        for name, _, _ in scenarios:
            cold_s, warm_s, cold_io, batch_stats = perf[name]
            row = {
                "scenario": name if name.startswith("quant/") else f"{backend}/{name}",
                "us_per_call": round(warm_s / B * 1e6, 1),
                "cold_us_per_call": round(cold_s / B * 1e6, 1),
                "speedup_vs_legacy": round(legacy_warm / warm_s, 2) if warm_s else 0.0,
                "bytes_read": cold_io.bytes_read,
                "files_opened": cold_io.files_opened,
                "reads_issued": cold_io.reads_issued,
                "rounds": batch_stats.rounds if batch_stats else 0,
                "dedup_hits": batch_stats.dedup_hits if batch_stats else 0,
                "kernel_launches": getattr(batch_stats, "kernel_launches", 0)
                if batch_stats
                else 0,
            }
            rows.append(row)
    return rows


def frontier(
    *,
    quant_path: str,
    blob_path: str,
    queries: np.ndarray,
    exact_ids: np.ndarray,
    k: int = 100,
    b_values=(4, 8, 16, 32),
    runs: int = 2,
) -> list[dict]:
    """Recall/latency frontier over the effort knob b: for each b, the
    quantized batch pipeline's warm us_per_call + recall@k against the
    exact (brute-force) top-k, with the plain blob batch path alongside
    (same b — quantized parity means recall is identical; the frontier
    shows what the byte/latency trade buys at each effort level)."""
    Q = np.asarray(queries, np.float32)
    B = len(Q)
    exact = [set(map(int, row[:k])) for row in np.asarray(exact_ids)]
    rows = []
    for b in b_values:
        for name, path, kw in (
            ("quant", quant_path, {"quantized": True}),
            ("blob", blob_path, {}),
        ):
            idx = _fresh(path, "blob", **kw)
            try:
                io0 = idx.store.io.snapshot()
                res = idx.search(Q, k, b=b)
                cold_io = idx.store.io.delta(io0)
                warm = []
                for _ in range(runs):
                    t0 = time.perf_counter()
                    idx.search(Q, k, b=b)
                    warm.append(time.perf_counter() - t0)
                hits = sum(
                    len(exact[r] & set(int(i) for i in res.ids[r] if i >= 0))
                    for r in range(B)
                )
                rows.append(
                    {
                        "scenario": f"{name}/b={b}",
                        "us_per_call": round(float(np.mean(warm)) / B * 1e6, 1),
                        "recall": round(hits / (B * k), 4),
                        "bytes_read": cold_io.bytes_read,
                        "reads_issued": cold_io.reads_issued,
                    }
                )
            finally:
                idx.close()
    return rows


def run(*, runs: int = 2, backends=("fstore", "blob")) -> list[dict]:
    """The run.py scenario over the shared bench suite: B = all task
    queries (B >= 16), matched k/b with the paper tables.  Includes the
    ``quant/flat-batch`` scenario (parity + >=2x bytes gates)."""
    from .indexes import get_suite

    s = get_suite()
    queries = np.stack([t.queries[-1] for t in s.ds.tasks])
    return compare(
        ecp_path=s.ecp_path,
        blob_path=s.ecp_blob_path,
        queries=queries,
        k=s.params["k"],
        b=s.params["b"]["eCP-FS"],
        runs=runs,
        backends=backends,
        quant_path=s.ecp_quant_path,
    )


def run_frontier(*, runs: int = 2) -> list[dict]:
    """The run.py frontier section: recall@k/latency per effort b for the
    quantized pipeline vs the plain blob batch path."""
    from .indexes import get_suite

    s = get_suite()
    queries = np.stack([t.queries[-1] for t in s.ds.tasks])
    k = s.params["k"]
    exact_ids = s.bf.search(queries, k).ids
    return frontier(
        quant_path=s.ecp_quant_path,
        blob_path=s.ecp_blob_path,
        queries=queries,
        exact_ids=exact_ids,
        k=k,
        runs=runs,
    )


def smoke(n: int = 6000, dim: int = 32, n_queries: int = 24) -> None:
    """CI quant-smoke: build -> convert (v2 + v3 int8) -> the compare()
    gates at bench-like scale: every engine path bit-identical to legacy,
    batch dedup on blob, and the quantized scan reading at most half the
    plain blob's cold bytes; plus one grouped device launch per
    leaf-bearing traversal round.  Raises on any violation."""
    import tempfile

    from repro.core import ECPBuildConfig, build_index, convert
    from repro.data import clustered_vectors

    data, _ = clustered_vectors(0, n=n, dim=dim, n_clusters=48)
    rng = np.random.default_rng(11)
    queries = data[rng.integers(0, n, n_queries)] + rng.normal(
        0, 0.01, (n_queries, dim)
    ).astype(np.float32)
    with tempfile.TemporaryDirectory() as td:
        path = td + "/idx"
        build_index(
            data, path,
            ECPBuildConfig(levels=2, cluster_cap=max(64, n // 256)),
        )
        blob = str(convert(path, td + "/idx.blob"))
        qblob = str(convert(path, td + "/idx.qblob", quant="int8"))
        rows = compare(
            ecp_path=path,
            blob_path=blob,
            queries=queries,
            k=100,
            b=16,
            runs=1,
            backends=("blob",),
            quant_path=qblob,
        )
        quant = next(r for r in rows if r["scenario"] == "quant/flat-batch")
        assert 0 < quant["kernel_launches"] <= quant["rounds"], (
            f"expected one grouped launch per leaf-bearing round, got "
            f"{quant['kernel_launches']} launches over {quant['rounds']} rounds"
        )
        plain = next(r for r in rows if r["scenario"] == "blob/flat-batch")
        print(
            f"quant smoke OK: {n_queries} queries bit-identical; quant "
            f"bytes={quant['bytes_read']} vs blob {plain['bytes_read']} "
            f"({plain['bytes_read'] / max(1, quant['bytes_read']):.2f}x); "
            f"launches={quant['kernel_launches']} rounds={quant['rounds']}"
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="quant parity + bytes + launch-count gates at bench-like scale",
    )
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for row in run():
            print(row)
