"""Traversal-engine comparison — how much of eCP-FS's file-mode latency
was interpreter overhead rather than file I/O.

Same index, same queries, three execution paths per storage backend:

  * legacy-single   the reference engine (tuple heap + list-sort I), one
                    query at a time — the paper's original measured path
  * flat-single     the vectorized engine (flat-array frontier, candidate
                    buffer, cached node norms), one query at a time
  * flat-batch      the vectorized engine in round-based batch mode: all
                    B rows advance in lockstep, node demands are deduped
                    across rows and fetched with one coalescing
                    ``get_nodes`` per round

Every path must return bit-identical (dists, ids) — the run *asserts*
this (CI uses it as the parity gate) and additionally asserts that on the
blob backend the batch path issues fewer cold ``reads_issued`` than B
independent single-query searches (the cross-query dedup guarantee).

Reported per scenario: warm/cold us_per_call, cold-pass IOStats, and for
the batch path the engine's round / dedup counters.
"""
from __future__ import annotations

import time

import numpy as np


def _fresh(path: str, backend: str, **kw):
    from repro.core import open_index

    return open_index(path, mode="file", backend=backend, **kw)


def compare(
    *,
    ecp_path: str,
    blob_path: str,
    queries: np.ndarray,
    k: int = 100,
    b: int = 16,
    runs: int = 2,
    backends=("fstore", "blob"),
) -> list[dict]:
    """One row per (backend, engine path); raises AssertionError on any
    parity mismatch or on a batch dedup regression (blob)."""
    Q = np.asarray(queries, np.float32)
    B = len(Q)
    rows = []
    for backend in backends:
        path = ecp_path if backend == "fstore" else blob_path

        def single_loop(idx):
            return [idx.search(q, k, b=b) for q in Q]

        scenarios = [
            ("legacy-single", {"engine": "legacy"}, single_loop),
            ("flat-single", {}, single_loop),
            ("flat-batch", {}, lambda idx: idx.search(Q, k, b=b)),
        ]
        results = {}
        perf = {}
        for name, kw, drive in scenarios:
            idx = _fresh(path, backend, **kw)
            try:
                io0 = idx.store.io.snapshot()
                t0 = time.perf_counter()
                out = drive(idx)
                cold_s = time.perf_counter() - t0
                cold_io = idx.store.io.delta(io0)
                if isinstance(out, list):
                    d = np.stack([r.dists for r in out])
                    i = np.stack([r.ids for r in out])
                    batch_stats = None
                else:
                    d, i = out.dists, out.ids
                    batch_stats = out.query.batch_stats
                results[name] = (d, i)
                warm = []
                for _ in range(runs):
                    t0 = time.perf_counter()
                    drive(idx)
                    warm.append(time.perf_counter() - t0)
                perf[name] = (cold_s, float(np.mean(warm)), cold_io, batch_stats)
            finally:
                idx.close()

        # ---- parity gate: all three paths bit-identical ----------------
        ref_d, ref_i = results["legacy-single"]
        for name in ("flat-single", "flat-batch"):
            d, i = results[name]
            np.testing.assert_array_equal(
                i, ref_i, err_msg=f"{backend}/{name}: ids diverge from legacy"
            )
            np.testing.assert_array_equal(
                d, ref_d, err_msg=f"{backend}/{name}: dists diverge from legacy"
            )
        # ---- dedup gate: batch must not read more than B singles -------
        if backend == "blob":
            single_reads = perf["flat-single"][2].reads_issued
            batch_reads = perf["flat-batch"][2].reads_issued
            assert batch_reads < single_reads, (
                f"batch dedup regression on blob: batch issued {batch_reads} "
                f"cold reads vs {single_reads} for {B} independent searches"
            )

        legacy_warm = perf["legacy-single"][1]
        for name, _, _ in scenarios:
            cold_s, warm_s, cold_io, batch_stats = perf[name]
            row = {
                "scenario": f"{backend}/{name}",
                "us_per_call": round(warm_s / B * 1e6, 1),
                "cold_us_per_call": round(cold_s / B * 1e6, 1),
                "speedup_vs_legacy": round(legacy_warm / warm_s, 2) if warm_s else 0.0,
                "bytes_read": cold_io.bytes_read,
                "files_opened": cold_io.files_opened,
                "reads_issued": cold_io.reads_issued,
                "rounds": batch_stats.rounds if batch_stats else 0,
                "dedup_hits": batch_stats.dedup_hits if batch_stats else 0,
            }
            rows.append(row)
    return rows


def run(*, runs: int = 2, backends=("fstore", "blob")) -> list[dict]:
    """The run.py scenario over the shared bench suite: B = all task
    queries (B >= 16), matched k/b with the paper tables."""
    from .indexes import get_suite

    s = get_suite()
    queries = np.stack([t.queries[-1] for t in s.ds.tasks])
    return compare(
        ecp_path=s.ecp_path,
        blob_path=s.ecp_blob_path,
        queries=queries,
        k=s.params["k"],
        b=s.params["b"]["eCP-FS"],
        runs=runs,
        backends=backends,
    )


if __name__ == "__main__":
    for row in run():
        print(row)
