"""Table 4: incremental search workload — top-100 then 10 x '100 more'.

Every index runs the SAME loop over the unified API: search once, then
``rounds`` calls to ``ResultSet.query.next(k)``.  eCP-FS resumes from its
query state (Algorithms 1-3); the baselines have no continuation, so their
``RestartQuery`` handle re-searches with k + k*round (the paper's
protocol) — which is exactly why eCP-FS dominates this table."""
from __future__ import annotations

import time

from .indexes import get_suite
from .mmir import incremental_workload


def run(
    rounds: int = 10, runs: int = 2, backend: str = "fstore", *, baselines: bool = True
) -> list[dict]:
    s = get_suite()
    p = s.params
    k = p["k"]
    rows = []

    # --- eCP-FS: native continuation via its query handle, over the chosen
    #     storage backend (fstore | blob | blob+prefetch)
    t0 = time.perf_counter()
    ecp = s.fresh_ecp(backend)
    load_s = time.perf_counter() - t0
    with ecp:
        r = incremental_workload(
            s.ds, f"eCP-FS[{backend}]", ecp, k=k, b=p["b"]["eCP-FS"],
            rounds=rounds, runs=runs, load_s=load_s,
        )
    rows.append(r.row())

    # --- baselines: RestartQuery re-searches with k + k*round internally
    if not baselines:
        return rows
    for name, searcher in (("IVF", s.ivf), ("HNSW", s.hnsw), ("DiskANN-lite", s.vamana)):
        rr = incremental_workload(
            s.ds, name, searcher, k=k, b=p["b"][name], rounds=rounds, runs=runs
        )
        rows.append(rr.row())
    return rows
