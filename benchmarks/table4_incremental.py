"""Table 4: incremental search workload — top-100 then 10 x '100 more'.

eCP-FS resumes from its query state (Algorithms 1-3); the baselines have no
continuation so each round re-searches with k + k*round (the paper's
protocol), which is exactly why eCP-FS dominates this table."""
from __future__ import annotations

import time

import numpy as np

from .indexes import get_suite
from .mmir import incremental_workload


def run(rounds: int = 10, runs: int = 2) -> list[dict]:
    s = get_suite()
    p = s.params
    rows = []

    # --- eCP-FS: native continuation via query states
    t0 = time.perf_counter()
    ecp = s.fresh_ecp()
    load_s = time.perf_counter() - t0

    def ecp_new(q, k):
        res, qid = ecp.new_search(q, k, b=p["b"])
        return qid

    def ecp_next(qid, q, k, rd):
        return ecp.get_next_k(qid, k)

    r = incremental_workload(
        s.ds, "eCP-FS", ecp_new, ecp_next, k=p["k"], rounds=rounds, runs=runs, load_s=load_s
    )
    rows.append(r.row())

    # --- baselines: restart with k + k*rd
    def mk(name, fn):
        def new(q, k):
            fn(q, k)
            return None

        def nxt(_h, q, k, rd):
            fn(q, k + k * (rd + 1))

        rr = incremental_workload(s.ds, name, new, nxt, k=p["k"], rounds=rounds, runs=runs)
        rows.append(rr.row())

    mk("IVF", lambda q, k: s.ivf.search(q, k, nprobe=p["nprobe"]))
    mk("HNSW", lambda q, k: s.hnsw.search(q, k, ef=max(p["ef"], 100)))
    mk("DiskANN-lite", lambda q, k: s.vamana.search(q, k, complexity=max(p["complexity"], 100)))
    return rows
