"""Benchmark entry point — one section per paper table + roofline summary.

  PYTHONPATH=src python -m benchmarks.run [--fast]

Emits, per the harness contract, ``name,us_per_call,derived`` CSV lines in
the SUMMARY section (latencies from the tables; derived = context such as
tasks solved or speedup), after printing each table in full.

With ``--bench-json PATH`` also writes a machine-readable summary: every
scenario's us_per_call plus, where measured, its cold-pass IOStats — so
the perf trajectory is tracked across PRs (the committed
``BENCH_search.json`` comes from the CI bench-smoke invocation,
``--fast --backend all --bench-json BENCH_search.json``).
The search-engine section enforces bit-identical parity
between the legacy and vectorized traversal engines and fails the run on
any mismatch (CI's bench-smoke gate).
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _print_table(title: str, rows: list[dict]) -> None:
    print(f"\n=== {title} ===")
    if not rows:
        print("(empty)")
        return
    cols = list(rows[0].keys())
    widths = {c: max(len(c), *(len(str(r.get(c, ''))) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller dataset / fewer runs")
    ap.add_argument("--n-items", type=int, default=None)
    ap.add_argument(
        "--backend",
        choices=("fstore", "blob", "blob+prefetch", "all"),
        default="fstore",
        help="eCP-FS node-storage backend for tables 2/4; the backend-"
        "comparison section always reports every backend ('all' repeats "
        "tables 2/4 per backend)",
    )
    ap.add_argument(
        "--bench-json",
        default="",
        help="where to write the machine-readable per-scenario summary "
        "(us_per_call + IOStats).  Off by default so ad-hoc runs don't "
        "clobber the committed artifact; the committed BENCH_search.json "
        "is regenerated with '--fast --backend all --bench-json "
        "BENCH_search.json' (the CI bench-smoke invocation)",
    )
    args = ap.parse_args()

    from . import (
        backends,
        federation,
        indexes,
        lifecycle,
        recall,
        roofline,
        search_engine,
        serving,
        table2_single_query,
        table3_tasks,
        table4_incremental,
    )

    n_items = args.n_items or (6000 if args.fast else 20000)
    runs = 2 if args.fast else 4
    t0 = time.time()
    suite = indexes.get_suite(n_items=n_items, dim=32, n_tasks=24 if args.fast else 40)
    print(
        f"[bench] suite: {len(suite.ds.data)} items, {len(suite.ds.tasks)} tasks; "
        f"builds: eCP {suite.ecp_build_s:.1f}s IVF {suite.ivf_build_s:.1f}s "
        f"HNSW {suite.hnsw_build_s:.1f}s Vamana {suite.vamana_build_s:.1f}s "
        f"(total {time.time()-t0:.1f}s)"
    )

    ecp_backends = list(indexes.BACKENDS) if args.backend == "all" else [args.backend]

    t2 = []
    for i, be in enumerate(ecp_backends):
        t2.extend(table2_single_query.run(runs=runs, backend=be, baselines=i == 0))
    _print_table("Table 2 — load time + single-query latency (disk/memory) + workload", t2)

    t3 = table3_tasks.run()
    _print_table("Table 3 — tasks completed (target in top-100) + recall@100", t3)

    t4 = []
    for i, be in enumerate(ecp_backends):
        t4.extend(
            table4_incremental.run(rounds=10, runs=max(2, runs // 2), backend=be, baselines=i == 0)
        )
    _print_table("Table 4 — incremental workload: top-100 then 10 x '100 more'", t4)

    tb = backends.run(runs=runs)
    _print_table(
        "Backend comparison — same queries, byte-budgeted cache "
        "(cold-pass IOStats: the file-vs-serialized axis)",
        tb,
    )

    # parity-enforcing: raises on any legacy-vs-vectorized mismatch
    se = search_engine.run(runs=runs)
    _print_table(
        "Search-engine comparison — legacy vs vectorized single-query vs "
        "batch-dedup traversal (bit-identical parity enforced)",
        se,
    )

    # recall/latency frontier over the effort knob b: what the quantized
    # scan buys (or costs) at each recall point vs the plain blob path
    fr = search_engine.run_frontier(runs=runs)
    _print_table(
        "Recall/latency frontier — quantized scan+rerank vs plain blob "
        "batch path per effort b (recall@k vs exact top-k)",
        fr,
    )

    # recall knobs: multi-probe traversal + build-time spill vs the strict
    # best-first baseline (probe_m=1 parity gate enforced inside)
    rk = recall.run(runs=runs)
    _print_table(
        "Recall knobs — probe_m (multi-probe traversal) and spill_s "
        "(build-time replication) vs strict best-first at equal effort b "
        "(recall@10 vs exact)",
        rk,
    )

    lc = lifecycle.run(runs=runs, n_insert=256 if args.fast else 512)
    _print_table(
        "Index lifecycle — build / insert-while-search / delete / compact "
        "throughput (write path)",
        lc,
    )

    # shard federation: the same collection split 4 ways behind one
    # router, compared to the single blob at equal total effort b
    fd = federation.run(fast=args.fast, runs=runs)
    _print_table(
        "Shard federation — scatter-gather over 4 blob shards vs the "
        "single-file index at equal total b (recall@10 vs exact)",
        fd,
    )

    # closed-loop concurrent serving: snapshot-isolated reads vs the
    # single-threaded insert-while-search numbers in the lifecycle section
    sv = serving.run(fast=args.fast)
    _print_table(
        "Concurrent serving — closed-loop QPS/latency, readonly vs "
        "mixed-with-writer (scheduler row: avg queue-wait in p99_ms col, "
        "degraded/misses in inserts/deletes cols)",
        sv,
    )

    print("\n=== Roofline (single-pod 16x16, from dry-run artifacts) ===")
    roofline.print_table("single")
    print("\n=== Roofline (multi-pod 2x16x16) ===")
    roofline.print_table("multi")

    # ----------------------------------------------------------- summary CSV
    scenarios: list[dict] = []

    def emit(name: str, us: float, derived: str, io: dict | None = None) -> None:
        print(f"{name},{us:.1f},{derived}")
        row = {"name": name, "us_per_call": round(float(us), 1), "derived": derived}
        if io is not None:
            row["io"] = io
        scenarios.append(row)

    print("\nname,us_per_call,derived")
    for r in t2:
        emit(
            f"table2/{r['index']}/mem",
            r["lat_mem_s"] * 1e6,
            f"disk_us={r['lat_disk_s']*1e6:.1f}",
        )
    for r in t3:
        emit(f"table3/{r['index']}", 0, f"tasks={r['tasks']};recall={r['recall@100']}")
    ecp_wl = next(r for r in t4 if r["index"].startswith("eCP-FS"))["workload_s"]
    for r in t4:
        sp = r["workload_s"] / ecp_wl if ecp_wl else 0.0
        emit(
            f"table4/{r['index']}",
            r["lat_mem_s"] * 1e6,
            f"workload_s={r['workload_s']};vs_ecp={sp:.1f}x",
        )
    for r in tb:
        emit(
            f"backend/{r['backend']}",
            r["lat_cold_s"] * 1e6,
            f"warm_us={r['lat_warm_s']*1e6:.1f};bytes={r['bytes_read']};"
            f"files={r['files_opened']};reads={r['reads_issued']};"
            f"pf={r['prefetch_hits']}/{r['prefetch_issued']}",
            io={
                "bytes_read": r["bytes_read"],
                "files_opened": r["files_opened"],
                "reads_issued": r["reads_issued"],
                "prefetch_issued": r["prefetch_issued"],
                "prefetch_hits": r["prefetch_hits"],
                "prefetch_wasted": r["prefetch_wasted"],
            },
        )
    for r in se:
        # quantized-pipeline rows live under quant/* so the perf
        # trajectory of the compressed scan is trackable on its own
        name = r["scenario"] if r["scenario"].startswith("quant/") else (
            f"search-engine/{r['scenario']}"
        )
        emit(
            name,
            r["us_per_call"],
            f"cold_us={r['cold_us_per_call']};vs_legacy={r['speedup_vs_legacy']}x;"
            f"rounds={r['rounds']};dedup_hits={r['dedup_hits']};"
            f"kernel_launches={r['kernel_launches']}",
            io={
                "bytes_read": r["bytes_read"],
                "files_opened": r["files_opened"],
                "reads_issued": r["reads_issued"],
            },
        )
    for r in fr + rk:
        emit(
            f"frontier/{r['scenario']}",
            r["us_per_call"],
            f"recall={r['recall']};bytes={r['bytes_read']}",
            io={"bytes_read": r["bytes_read"], "reads_issued": r["reads_issued"]},
        )
    for r in lc:
        # us_per_call = per-vector cost of the lifecycle stage
        emit(
            f"lifecycle/{r['scenario']}",
            1e6 / r["vectors_per_s"] if r["vectors_per_s"] else 0.0,
            f"vectors_per_s={r['vectors_per_s']};n={r['n']};{r['extra']}",
        )
    fd_single = next(r for r in fd if r["config"] == "single")
    for r in fd:
        emit(
            f"federation/{r['config']}",
            r["lat_s"] * 1e6,
            f"recall@10={r['recall@10']};b_total={r['b_total']};"
            f"shards={r['shards']};probed={r['probed']};"
            f"recall_gap={fd_single['recall@10'] - r['recall@10']:+.4f}",
            io={"bytes_read": r["bytes"], "reads_issued": r["reads"],
                "leaves_opened": r["leaves"]},
        )
    sv_ro = next(r for r in sv if r["phase"] == "readonly")
    for r in sv:
        if r["phase"] == "scheduler":
            continue
        ratio = r["p99_ms"] / sv_ro["p99_ms"] if sv_ro["p99_ms"] else 0.0
        emit(
            f"serving/{r['phase']}",
            r["p99_ms"] * 1e3,  # us_per_call = p99 latency
            f"p50_ms={r['p50_ms']};qps={r['qps']};completed={r['completed']};"
            f"rejected={r['rejected']};inserts={r['inserts']};"
            f"p99_vs_readonly={ratio:.2f}x",
        )

    if args.bench_json:
        bench = {
            "schema": 1,
            "fast": bool(args.fast),
            "backend": args.backend,
            "n_items": n_items,
            "parity": "ok",  # search_engine.run raised otherwise
            "scenarios": scenarios,
        }
        with open(args.bench_json, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=False)
            f.write("\n")
        print(f"\n[bench] wrote {args.bench_json} ({len(scenarios)} scenarios)")
    sys.stdout.flush()


if __name__ == "__main__":
    main()
