"""Table 2: index load times, single-query latencies (disk vs memory),
and average workload time — eCP-FS vs IVF / HNSW / Vamana(DiskANN-lite).

All four run through the unified ``Searcher`` API; eCP-FS gets a
``reset_fn`` so its first run starts with a cold node cache (the paper's
"disk" column)."""
from __future__ import annotations

import time

from .indexes import get_suite
from .mmir import single_query_workload


def run(runs: int = 4, backend: str = "fstore", *, baselines: bool = True) -> list[dict]:
    s = get_suite()
    p = s.params
    k = p["k"]
    rows = []

    # --- eCP-FS: fresh instance => lazy, node-loading "disk" first run;
    #     ``backend`` picks its node storage (fstore | blob | blob+prefetch)
    t0 = time.perf_counter()
    ecp = s.fresh_ecp(backend)
    load_s = time.perf_counter() - t0

    r = single_query_workload(
        s.ds, f"eCP-FS[{backend}]", ecp, k=k, b=p["b"]["eCP-FS"], runs=runs,
        load_s=load_s, reset_fn=lambda: s.fresh_ecp(backend),
    )
    row = r.row()
    row["build_s"] = round(s.ecp_build_s, 2)
    rows.append(row)

    # --- in-memory baselines (skippable when sweeping eCP backends)
    if not baselines:
        return rows
    for name, searcher, build_s in (
        ("IVF", s.ivf, s.ivf_build_s),
        ("HNSW", s.hnsw, s.hnsw_build_s),
        ("DiskANN-lite", s.vamana, s.vamana_build_s),
    ):
        r = single_query_workload(s.ds, name, searcher, k=k, b=p["b"][name], runs=runs)
        row = r.row()
        row["build_s"] = round(build_s, 2)
        rows.append(row)
    return rows
