"""Table 2: index load times, single-query latencies (disk vs memory),
and average workload time — eCP-FS vs IVF / HNSW / Vamana(DiskANN-lite)."""
from __future__ import annotations

import time

import numpy as np

from .indexes import get_suite
from .mmir import single_query_workload


def run(runs: int = 4) -> list[dict]:
    s = get_suite()
    p = s.params
    rows = []

    # --- eCP-FS: fresh instance => lazy, node-loading "disk" first run
    t0 = time.perf_counter()
    ecp = s.fresh_ecp()
    load_s = time.perf_counter() - t0

    holder = {"idx": ecp}

    def ecp_search(q, k):
        res, qid = holder["idx"].new_search(q, k, b=p["b"])
        holder["idx"].drop_query(qid)
        return (np.asarray([d for d, _ in res]), np.asarray([i for _, i in res]))

    def ecp_reset():
        holder["idx"] = s.fresh_ecp()   # cold cache: every node re-read

    r = single_query_workload(
        s.ds, "eCP-FS", ecp_search, k=p["k"], runs=runs, load_s=load_s, reset_fn=ecp_reset
    )
    row = r.row()
    row["build_s"] = round(s.ecp_build_s, 2)
    rows.append(row)

    # --- IVF (in-memory)
    r = single_query_workload(
        s.ds, "IVF", lambda q, k: s.ivf.search(q, k, nprobe=p["nprobe"]),
        k=p["k"], runs=runs, load_s=s.ivf_build_s * 0,
    )
    row = r.row()
    row["build_s"] = round(s.ivf_build_s, 2)
    rows.append(row)

    # --- HNSW (in-memory)
    r = single_query_workload(
        s.ds, "HNSW", lambda q, k: s.hnsw.search(q, k, ef=p["ef"]),
        k=p["k"], runs=runs,
    )
    row = r.row()
    row["build_s"] = round(s.hnsw_build_s, 2)
    rows.append(row)

    # --- Vamana / DiskANN-lite
    r = single_query_workload(
        s.ds, "DiskANN-lite", lambda q, k: s.vamana.search(q, k, complexity=p["complexity"]),
        k=p["k"], runs=runs,
    )
    row = r.row()
    row["build_s"] = round(s.vamana_build_s, 2)
    rows.append(row)
    return rows
