"""Recall-knob frontier — what the two recall knobs buy over the paper's
strict best-first traversal at equal (or lower) effort b:

  * ``probe_m`` (query time): descend through the top-m frontier nodes
    per traversal step instead of only the single best.
  * ``spill_s`` (build time): replicate border vectors into up to s
    additional leaves whose leaders are nearly as close as the primary.

The sweep builds a base index and a spill twin over the same collection
(fixed seed, fixed scale — the rows are deterministic and comparable
across runs regardless of the bench suite's --fast flag), converts both
to blobs, and measures recall@k against the exact top-k along a
``(b, probe_m, spill_s)`` grid.  ``run()`` feeds the rows to
benchmarks/run.py (they land in BENCH_search.json as ``frontier/*``
scenarios); ``smoke()`` is the CI recall-smoke gate:

  1. parity    — flat-batch at probe_m=1 over the base blob is
                 bit-identical to the legacy oracle (per query),
  2. monotonic — recall@k never drops as probe_m widens or spill lands,
  3. improved  — some widened setting beats the probe_m=1 baseline
                 strictly at equal or lower b,
  4. baseline  — recall at the reference (b, probe_m=1) setting has not
                 dropped below the committed BENCH_search.json row.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

# (b, probe_m, spill_s); (REF_B, 1, 0) is the committed-baseline reference
REF_B = 16
GRID = (
    (8, 1, 0),
    (8, 2, 0),
    (8, 2, 1),
    (16, 1, 0),
    (16, 2, 0),
    (16, 4, 0),
    (16, 1, 1),
    (16, 2, 1),
)


def _build_suite(td: str, *, n: int, dim: int, spill_levels=(1,)):
    """Base + spill indexes over one clustered collection -> (data,
    queries, {spill_s: blob_path})."""
    from repro.core import ECPBuildConfig, build_index, convert
    from repro.data import clustered_vectors

    data, _ = clustered_vectors(0, n=n, dim=dim, n_clusters=48)
    rng = np.random.default_rng(17)
    queries = (
        data[rng.integers(0, n, 32)] + rng.normal(0, 0.05, (32, dim))
    ).astype(np.float32)
    blobs = {}
    for s in (0, *spill_levels):
        p = f"{td}/idx_s{s}"
        build_index(
            data, p,
            ECPBuildConfig(levels=2, cluster_cap=max(64, n // 256), spill_s=s),
        )
        blobs[s] = str(convert(p, f"{td}/idx_s{s}.blob"))
    return data, queries, blobs


def _exact_topk(data: np.ndarray, queries: np.ndarray, k: int) -> np.ndarray:
    """Brute-force top-k positions (== default item ids) per query."""
    from repro.core.distances import np_distances

    d = np_distances(queries, np.asarray(data, np.float32), "l2")
    return np.argsort(d, axis=1, kind="stable")[:, :k]


def _assert_probe1_parity(blob: str, queries: np.ndarray, k: int, b: int) -> None:
    """probe_m=1 must be bit-identical to the legacy oracle — the gate
    that multi-probe stays a pure superset feature."""
    from repro.core import open_index

    flat = open_index(blob, mode="file", backend="blob")
    leg = open_index(blob, mode="file", backend="blob", engine="legacy")
    try:
        rs = flat.search(queries, k, b=b, probe_m=1)
        for r, q in enumerate(queries):
            ref = leg.search(q, k, b=b)
            np.testing.assert_array_equal(
                rs.ids[r], ref.ids, err_msg=f"probe_m=1 parity break, query {r}"
            )
            np.testing.assert_array_equal(
                rs.dists[r], ref.dists, err_msg=f"probe_m=1 parity break, query {r}"
            )
    finally:
        flat.close()
        leg.close()


def sweep(
    *,
    blobs: dict[int, str],
    queries: np.ndarray,
    exact: np.ndarray,
    k: int = 10,
    grid=GRID,
    runs: int = 1,
) -> list[dict]:
    """One row per (b, probe_m, spill_s) grid point: recall@k vs the
    exact top-k, warm us_per_call, cold-pass IOStats."""
    from repro.core import open_index

    B = len(queries)
    exact_sets = [set(map(int, row)) for row in exact]
    rows = []
    for b, m, s in grid:
        idx = open_index(blobs[s], mode="file", backend="blob")
        try:
            io0 = idx.store.io.snapshot()
            res = idx.search(queries, k, b=b, probe_m=m)
            cold_io = idx.store.io.delta(io0)
            warm = []
            for _ in range(runs):
                t0 = time.perf_counter()
                idx.search(queries, k, b=b, probe_m=m)
                warm.append(time.perf_counter() - t0)
            hits = sum(
                len(exact_sets[r] & {int(x) for x in res.ids[r] if x >= 0})
                for r in range(B)
            )
            rows.append(
                {
                    "scenario": f"recall/b={b}/m={m}/s={s}",
                    "us_per_call": round(float(np.mean(warm)) / B * 1e6, 1),
                    "recall": round(hits / (B * k), 4),
                    "bytes_read": cold_io.bytes_read,
                    "reads_issued": cold_io.reads_issued,
                }
            )
        finally:
            idx.close()
    return rows


def run(*, n: int = 6000, dim: int = 32, k: int = 10, runs: int = 1) -> list[dict]:
    """The run.py section: the deterministic fixed-scale sweep (+ the
    probe_m=1 parity gate).  Scale is intentionally NOT tied to --fast so
    the committed frontier rows stay comparable."""
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        data, queries, blobs = _build_suite(td, n=n, dim=dim)
        exact = _exact_topk(data, queries, k)
        _assert_probe1_parity(blobs[0], queries, k, REF_B)
        return sweep(blobs=blobs, queries=queries, exact=exact, k=k, runs=runs)


def _recall_of(rows: list[dict], b: int, m: int, s: int) -> float:
    return next(
        r["recall"] for r in rows if r["scenario"] == f"recall/b={b}/m={m}/s={s}"
    )


def smoke(bench_json: str | None = "BENCH_search.json") -> None:
    """CI recall-smoke: run the sweep and enforce the four gates (see
    module docstring).  ``bench_json`` points at the committed baseline
    artifact; a missing file or missing frontier rows skips gate 4 (first
    commit of the artifact) rather than failing."""
    rows = run()
    for r in rows:
        print(r)

    base = _recall_of(rows, REF_B, 1, 0)
    # gate 2: monotone along the widening axes (non-strict)
    assert _recall_of(rows, REF_B, 2, 0) >= base, "recall dropped at probe_m=2"
    assert _recall_of(rows, REF_B, 4, 0) >= _recall_of(rows, REF_B, 2, 0), (
        "recall dropped from probe_m=2 to probe_m=4"
    )
    assert _recall_of(rows, REF_B, 1, 1) >= base, "recall dropped with spill_s=1"
    # gate 3: something widened must strictly beat the baseline at <= b
    widened = [
        r["recall"]
        for r in rows
        if r["scenario"] != f"recall/b={REF_B}/m=1/s=0"
        and int(r["scenario"].split("/")[1][2:]) <= REF_B
    ]
    assert max(widened) > base, (
        f"no widened setting beats the probe_m=1 baseline (recall@10={base})"
    )
    # gate 4: no regression vs the committed baseline row
    ref_name = f"frontier/recall/b={REF_B}/m=1/s=0"
    p = Path(bench_json) if bench_json else None
    if p is not None and p.exists():
        committed = json.loads(p.read_text())
        row = next(
            (x for x in committed.get("scenarios", []) if x["name"] == ref_name),
            None,
        )
        if row is not None:
            want = float(row["derived"].split("recall=")[1].split(";")[0])
            assert base >= want - 1e-6, (
                f"recall@10 regression at the reference setting: "
                f"{base} < committed {want}"
            )
            print(f"recall smoke OK: baseline {base} vs committed {want}")
            return
    print(f"recall smoke OK: baseline {base} (no committed row to compare)")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="parity + monotonicity + improvement + committed-baseline gates",
    )
    ap.add_argument("--bench-json", default="BENCH_search.json")
    args = ap.parse_args()
    if args.smoke:
        smoke(args.bench_json)
    else:
        for row in run():
            print(row)
