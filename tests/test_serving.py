"""Concurrent serving subsystem: BlobStore pin/COW, ECPSnapshot parity
under writes, reader/writer stress, scheduler backpressure + deadlines,
session cap/TTL, bounded ServeStats, prefetch-accuracy counters."""
import threading
import time

import numpy as np
import pytest

from repro.core import (
    BlobSnapshot,
    BlobStore,
    ECPBuildConfig,
    ECPSnapshot,
    QueryClosedError,
    build_index,
    convert,
    open_index,
)
from repro.core import layout
from repro.launch.scheduler import (
    DeadlinePolicy,
    RequestScheduler,
    ServerOverloadedError,
    SnapshotManager,
)
from repro.launch.serve import LatencyRing, Server, ServeStats


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    from repro.data import clustered_vectors

    data, _ = clustered_vectors(11, n=6000, dim=24, n_clusters=48)
    path = tmp_path_factory.mktemp("serve_idx") / "ecp"
    build_index(
        data, str(path), ECPBuildConfig(levels=2, metric="l2", cluster_cap=80, seed=4)
    )
    blob = convert(str(path), tmp_path_factory.mktemp("serve_blob") / "idx.blob")
    return data, str(path), str(blob)


def _fresh_blob(built, tmp_path):
    import shutil

    _, _, blob = built
    dst = tmp_path / "idx.blob"
    shutil.copy(blob, dst)
    return str(dst)


# ------------------------------------------------------------ BlobStore MVCC
def test_blob_pin_snapshot_reads_survive_overwrite(built, tmp_path):
    blob = _fresh_blob(built, tmp_path)
    bs = BlobStore(blob)
    emb0, ids0 = bs.get_node(1, 0)
    snap = bs.pin()
    assert isinstance(snap, BlobSnapshot) and snap.backend == "blob+snapshot"
    # overwrite the node in the LIVE store (COW because a pin exists);
    # doubling is exact in the blob's f16 storage dtype
    bs.write_node(1, 0, emb0 * 2.0, ids0 + 1000)
    e_live, i_live = bs.get_node(1, 0)
    e_snap, i_snap = snap.get_node(1, 0)
    np.testing.assert_array_equal(e_snap, emb0)
    np.testing.assert_array_equal(i_snap, ids0)
    np.testing.assert_array_equal(e_live, emb0 * 2.0)
    np.testing.assert_array_equal(i_live, ids0 + 1000)
    snap.close()
    bs.close()


def test_blob_snapshot_is_read_only_and_idempotent_close(built, tmp_path):
    bs = BlobStore(_fresh_blob(built, tmp_path))
    snap = bs.pin()
    with pytest.raises(PermissionError):
        snap.write_node(1, 0, np.zeros((1, 24), np.float32), np.zeros(1, np.int64))
    with pytest.raises(PermissionError):
        snap.write_attrs(layout.INFO, {})
    with pytest.raises(PermissionError):
        snap.free_slot(1, 0)
    assert not snap.closed
    snap.close()
    snap.close()  # idempotent
    assert snap.closed
    bs.close()


def test_blob_retired_slots_recycle_after_release(built, tmp_path):
    bs = BlobStore(_fresh_blob(built, tmp_path))
    emb, ids = bs.get_node(1, 0)
    snap = bs.pin()
    bs.write_node(1, 0, emb + 1, ids)  # COW -> old slot retired, not freed
    assert bs._retired, "overwrite under a pin must retire the old slot"
    snap.close()
    assert not bs._retired, "releasing the last pin recycles retired slots"
    bs.close()


def test_blob_free_slot_retires_while_pinned(built, tmp_path):
    bs = BlobStore(_fresh_blob(built, tmp_path))
    snap = bs.pin()
    emb, ids = snap.get_node(1, 1)
    bs.free_slot(1, 1)
    # the snapshot still reads the freed node's bytes
    e2, i2 = snap.get_node(1, 1)
    np.testing.assert_array_equal(e2, emb)
    np.testing.assert_array_equal(i2, ids)
    snap.close()
    bs.close()


def test_blob_snapshot_survives_compact_replace(built, tmp_path):
    """os.replace of the blob file must not invalidate a pinned snapshot
    (it holds its own dup'd fd)."""
    blob = _fresh_blob(built, tmp_path)
    idx = open_index(blob, mode="file", backend="blob")
    emb, ids = idx.store.get_node(1, 0)
    snap_store = idx.store.pin()
    idx.insert(np.random.default_rng(0).normal(size=(32, 24)).astype(np.float32))
    idx.compact()  # rewrites the file via os.replace
    e2, i2 = snap_store.get_node(1, 0)
    np.testing.assert_array_equal(e2, emb)
    np.testing.assert_array_equal(i2, ids)
    snap_store.close()
    idx.close()


# ------------------------------------------------------------- ECPSnapshot
def test_ecp_snapshot_bit_identical_under_mutation(built, tmp_path):
    data, _, _ = built
    blob = _fresh_blob(built, tmp_path)
    idx = open_index(blob, mode="file", backend="blob")
    rng = np.random.default_rng(2)
    qs = data[rng.integers(0, len(data), 12)]
    snap = idx.snapshot()
    assert isinstance(snap, ECPSnapshot)
    before = [snap.search(q, k=20, b=8) for q in qs]
    # mutate the live index heavily past the pinned generation
    base = int(idx.info.next_id)
    idx.insert(
        data[:200] + 0.01 * rng.normal(size=(200, 24)).astype(np.float32),
        np.arange(base, base + 200),
    )
    idx.delete(np.arange(0, 300, 5))
    idx.compact()
    after = [snap.search(q, k=20, b=8) for q in qs]
    for rs0, rs1 in zip(before, after):
        np.testing.assert_array_equal(rs0.ids, rs1.ids)
        np.testing.assert_array_equal(rs0.dists, rs1.dists)
    # live index sees the mutations; snapshot-vs-live may differ
    live = idx.search(qs[0], k=20, b=8)
    assert 0 not in live.row_ids(0) or 0 not in set(np.arange(0, 300, 5))
    snap.close()
    idx.close()


def test_ecp_snapshot_continuation_survives_compact(built, tmp_path):
    data, _, _ = built
    idx = open_index(_fresh_blob(built, tmp_path), mode="file", backend="blob")
    snap = idx.snapshot()
    rs = snap.search(data[0], k=10, b=4)
    idx.compact()  # live queries would now raise StaleQueryError
    more = rs.query.next(10)  # snapshot continuation keeps its generation
    assert more.ids.shape[-1] == 10
    rs.query.close()
    snap.close()
    idx.close()


def test_ecp_snapshot_refuses_writes(built, tmp_path):
    idx = open_index(_fresh_blob(built, tmp_path), mode="file", backend="blob")
    snap = idx.snapshot()
    with pytest.raises(PermissionError):
        snap.insert(np.zeros((1, 24), np.float32))
    with pytest.raises(PermissionError):
        snap.delete([0])
    with pytest.raises(PermissionError):
        snap.compact()
    snap.close()
    idx.close()


def test_ecp_snapshot_unsupported_on_fstore(built):
    _, path, _ = built
    idx = open_index(path, mode="file", backend="fstore")
    with pytest.raises(NotImplementedError):
        idx.snapshot()
    idx.close()


# ------------------------------------------- concurrent reader/writer stress
def test_concurrent_readers_one_writer_stress(built, tmp_path):
    """Reader threads search pinned snapshots while a writer inserts,
    deletes, and compacts: no torn reads (every search returns k valid
    rows), no StaleQueryError, and a snapshot re-query is bit-identical."""
    data, _, _ = built
    idx = open_index(_fresh_blob(built, tmp_path), mode="file", backend="blob")
    mgr = SnapshotManager(idx)
    rng = np.random.default_rng(5)
    qs = data[rng.integers(0, len(data), 8)]
    errors: list = []
    stop = threading.Event()

    def reader(tid):
        r = np.random.default_rng(tid)
        try:
            while not stop.is_set():
                lease = mgr.lease()
                try:
                    q = qs[r.integers(0, len(qs))]
                    rs1 = lease.search(q, k=10, b=6)
                    rs2 = lease.search(q, k=10, b=6)  # same pin -> identical
                    np.testing.assert_array_equal(rs1.ids, rs2.ids)
                    np.testing.assert_array_equal(rs1.dists, rs2.dists)
                    assert rs1.ids.shape[-1] == 10
                finally:
                    lease.release()
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    def writer():
        r = np.random.default_rng(77)
        try:
            for i in range(6):
                base = int(idx.info.next_id)
                idx.insert(
                    r.normal(size=(48, 24)).astype(np.float32),
                    np.arange(base, base + 48),
                )
                mgr.refresh()
                if i == 2:
                    idx.delete(np.arange(0, 120, 7))
                    mgr.refresh()
                if i == 4:
                    idx.compact()
                    mgr.refresh()
        except Exception as e:  # pragma: no cover
            errors.append(e)

    readers = [threading.Thread(target=reader, args=(t,)) for t in range(4)]
    wt = threading.Thread(target=writer)
    for t in readers:
        t.start()
    wt.start()
    wt.join()
    stop.set()
    for t in readers:
        t.join()
    mgr.close()
    idx.close()
    assert not errors, errors


# ---------------------------------------------------------------- scheduler
class _StubRS:
    def __init__(self, k):
        self.ids = np.zeros(k, np.int64)
        self.dists = np.zeros(k, np.float32)
        self.query = type("Q", (), {"close": lambda s: None, "next": lambda s, k: None})()


class _SlowSearcher:
    def __init__(self, delay_s=0.05):
        self.delay_s = delay_s
        self.bs: list = []

    def search(self, q, k, b=None, **opts):
        self.bs.append(b)
        time.sleep(self.delay_s)
        return _StubRS(k)


def test_scheduler_backpressure_rejects_when_full():
    sched = RequestScheduler(_SlowSearcher(0.05), workers=1, queue_depth=1)
    futs, rejected = [], 0
    for _ in range(12):
        try:
            futs.append(sched.submit(np.zeros(4), 5))
        except ServerOverloadedError:
            rejected += 1
    assert rejected > 0
    for f in futs:
        f.result()
    st = sched.stats.as_dict()
    assert st["submitted"] == st["completed"] + st["rejected"] + st["failed"]
    assert st["rejected"] == rejected
    sched.shutdown()


def test_scheduler_deadline_shrinks_b():
    s = _SlowSearcher(0.01)
    sched = RequestScheduler(s, workers=1, queue_depth=8)
    for _ in range(4):  # warm the EWMA with generous deadlines
        sched.search(np.zeros(4), 5, b=64, deadline_ms=10_000)
    r = sched.search(np.zeros(4), 5, b=64, deadline_ms=0.01)
    assert r.b_effective == sched.policy.b_min
    assert s.bs[-1] == sched.policy.b_min  # the searcher really saw it
    assert r.b_requested == 64
    assert sched.stats.as_dict()["degraded"] >= 1
    sched.shutdown()


def test_deadline_policy_ewma_and_clamp():
    p = DeadlinePolicy(b_min=2, alpha=0.5, safety=1.0, init_s_per_b=1e-3)
    assert p.choose_b(100, remaining_s=-1) == 2  # already past deadline
    assert p.choose_b(100, remaining_s=10.0) == 100  # plenty of time
    assert p.choose_b(100, remaining_s=0.01) == 10  # 0.01s / 1e-3 = 10
    p.observe(10, 0.1)  # 0.01 s/b observed -> ewma moves toward it
    assert p.s_per_b == pytest.approx(0.5 * 1e-3 + 0.5 * 0.01)
    p.observe(0, 1.0)  # ignored
    p.observe(10, -1.0)  # ignored
    assert p.s_per_b == pytest.approx(0.5 * 1e-3 + 0.5 * 0.01)


def test_scheduler_worker_error_propagates():
    class Boom:
        def search(self, q, k, b=None, **o):
            raise RuntimeError("kaboom")

    sched = RequestScheduler(Boom(), workers=1, queue_depth=4)
    with pytest.raises(RuntimeError, match="kaboom"):
        sched.submit(np.zeros(4), 5).result()
    st = sched.stats.as_dict()
    assert st["failed"] == 1
    assert st["submitted"] == st["completed"] + st["rejected"] + st["failed"]
    sched.shutdown()


def test_scheduler_mutate_serializes_with_rwlock_reads():
    """Non-pinning searcher: mutate() must be exclusive with in-flight
    reads (the fstore fallback path)."""
    events = []
    lock = threading.Lock()

    class Tracked:
        def search(self, q, k, b=None, **o):
            with lock:
                events.append("r+")
            time.sleep(0.02)
            with lock:
                events.append("r-")
            return _StubRS(k)

    sched = RequestScheduler(Tracked(), workers=2, queue_depth=8)
    assert sched.snapshots is None
    futs = [sched.submit(np.zeros(4), 5) for _ in range(2)]
    time.sleep(0.005)  # let reads start

    def mut():
        with lock:
            events.append("w+")
        time.sleep(0.01)
        with lock:
            events.append("w-")

    sched.mutate(mut)
    for f in futs:
        f.result()
    sched.shutdown()
    i_w = events.index("w+")
    assert "r+" not in events[i_w : events.index("w-")], events


# ---------------------------------------------------------------- Server
def test_server_sync_mode_unchanged(built):
    _, path, _ = built
    idx = open_index(path, mode="file", backend="fstore")
    with Server(idx) as srv:
        rs, sid = srv.search(np.zeros(24, np.float32), k=5, b=4)
        assert rs.ids.shape[-1] == 5
        srv.more(sid, k=5)
        srv.close(sid)
        with pytest.raises(QueryClosedError):
            srv.more(sid, k=5)
        s = srv.stats.summary()
        assert s["queries"] == 1 and s["continuations"] == 1
        assert s["p50_ms"] is not None


def test_server_concurrent_blob_uses_snapshots(built, tmp_path):
    data, _, _ = built
    idx = open_index(_fresh_blob(built, tmp_path), mode="file", backend="blob")
    with Server(idx, workers=2, queue_depth=8) as srv:
        assert srv.scheduler is not None and srv.scheduler.snapshots is not None
        rs, sid = srv.search(data[0], k=10, b=6)
        base = int(idx.info.next_id)
        srv.insert(
            np.random.default_rng(0).normal(size=(32, 24)).astype(np.float32),
            np.arange(base, base + 32),
        )
        srv.compact()
        # snapshot-backed continuation is immune to the compact
        more = srv.more(sid, k=10)
        assert more.ids.shape[-1] == 10
        srv.close(sid)


def test_server_session_cap_evicts_lru(built):
    _, path, _ = built
    idx = open_index(path, mode="file", backend="fstore")
    with Server(idx, session_cap=3) as srv:
        sids = [srv.search(np.zeros(24, np.float32), k=5, b=4)[1] for _ in range(5)]
        assert srv.open_sessions == 3
        for sid in sids[:2]:  # the two oldest were evicted
            with pytest.raises(QueryClosedError):
                srv.more(sid, k=5)
        srv.more(sids[-1], k=5)  # newest still live
        assert srv.stats.summary()["evicted_sessions"] == 2


def test_server_session_ttl_evicts_idle(built):
    _, path, _ = built
    idx = open_index(path, mode="file", backend="fstore")
    now = [0.0]
    with Server(idx, session_ttl_s=10.0, clock=lambda: now[0]) as srv:
        sid_old = srv.search(np.zeros(24, np.float32), k=5, b=4)[1]
        now[0] = 5.0
        sid_new = srv.search(np.zeros(24, np.float32), k=5, b=4)[1]
        now[0] = 11.0  # old idle 11s > ttl, new idle 6s
        srv.search(np.zeros(24, np.float32), k=5, b=4)  # triggers sweep
        with pytest.raises(QueryClosedError):
            srv.more(sid_old, k=5)
        srv.more(sid_new, k=5)


def test_serve_stats_bounded_and_threadsafe():
    stats = ServeStats(ring_capacity=64)
    threads = [
        threading.Thread(
            target=lambda: [stats.record("search", 1.0) for _ in range(500)]
        )
        for _ in range(4)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ring = stats.ring("search")
    assert ring.count == 2000
    assert len(ring.values()) == 64  # memory stays bounded
    assert stats.summary()["search_p99_ms"] == 1.0


def test_latency_ring_percentiles():
    r = LatencyRing(capacity=8)
    assert r.percentile(50) is None
    for v in [1.0, 2.0, 3.0, 4.0]:
        r.record(v)
    assert r.percentile(50) == pytest.approx(2.5)
    for v in range(100):  # wrap: only the last 8 remain
        r.record(float(v))
    assert r.values().min() == 92.0


# ------------------------------------------------------- prefetch accuracy
def test_prefetch_accuracy_counters(built):
    data, _, blob = built
    idx = open_index(blob, mode="file", backend="blob", prefetch=True, cache_max_nodes=256)
    rng = np.random.default_rng(9)
    for q in data[rng.integers(0, len(data), 8)]:
        idx.search(q, k=20, b=8)
    drain = getattr(idx.store, "drain", None)
    if drain is not None:
        drain()
    idx.flush_prefetch_stats()
    io = idx.store.io
    assert io.prefetch_issued > 0
    assert io.prefetch_hits <= io.prefetch_issued
    d = io.as_dict()
    assert {"prefetch_issued", "prefetch_hits", "prefetch_wasted_bytes"} <= set(d)
    idx.close()


def test_prefetch_counters_absent_without_prefetch(built):
    data, _, blob = built
    idx = open_index(blob, mode="file", backend="blob")
    idx.search(data[0], k=10, b=6)
    assert idx.store.io.prefetch_issued == 0
    assert idx.store.io.prefetch_hits == 0
    idx.close()
