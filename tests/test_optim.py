"""Optimizer, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim import (
    adamw,
    apply_updates,
    compress_decompress,
    constant,
    global_norm,
    init_ef_state,
    warmup_cosine,
)


def test_adamw_converges_quadratic():
    opt = adamw(0.1, wd=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    target = jnp.asarray([1.0, 2.0])
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_adamw_weight_decay_skips_1d():
    opt = adamw(0.1, wd=0.5)
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    state = opt.init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    upd, _ = opt.update(zeros, state, params)
    assert float(jnp.max(jnp.abs(upd["b"]))) == 0.0
    assert float(jnp.max(jnp.abs(upd["w"]))) > 0.0


def test_clipping_bounds_update():
    opt = adamw(1.0, clip=1.0, wd=0.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    g = {"w": jnp.asarray([1e6, -1e6, 1e6])}
    upd, _ = opt.update(g, state, params)
    assert np.isfinite(np.asarray(upd["w"])).all()


def test_bf16_moments_still_converge():
    opt = adamw(0.1, wd=0.0, moment_dtype=jnp.bfloat16)
    params = {"w": jnp.asarray([4.0])}
    state = opt.init(params)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert abs(float(params["w"][0])) < 0.1


def test_schedule_shapes():
    f = warmup_cosine(1.0, 10, 100)
    assert float(f(jnp.asarray(0))) == 0.0
    assert abs(float(f(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(f(jnp.asarray(100))) < float(f(jnp.asarray(50)))
    assert float(constant(0.5)(jnp.asarray(7))) == 0.5


def test_compression_error_feedback_unbiased_over_steps():
    """EF property: accumulated compressed grads track accumulated true
    grads (residual stays bounded), even though each step is lossy."""
    rng = np.random.default_rng(0)
    g_true = [{"w": jnp.asarray(rng.normal(size=64), jnp.float32)} for _ in range(50)]
    ef = init_ef_state(g_true[0])
    total_c = jnp.zeros(64)
    total_t = jnp.zeros(64)
    for g in g_true:
        dec, ef = compress_decompress(g, ef)
        total_c = total_c + dec["w"]
        total_t = total_t + g["w"]
    resid = float(jnp.max(jnp.abs(total_c - total_t)))
    # residual is bounded by one quantization step, not O(n_steps)
    assert resid < 0.2, resid


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31))
def test_quantize_roundtrip_bounded(seed):
    from repro.optim.compress import dequantize_int8, quantize_int8

    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=32) * r.uniform(0.01, 100), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) / 2 + 1e-6
