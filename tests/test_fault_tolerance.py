"""Fault tolerance: checkpoint round trips, restart determinism, elasticity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_tree, save_tree
from repro.data import StepLoader, lm_batch
from repro.distributed import FailureInjector, TrainSupervisor, reshard_tree
from repro.launch.cells import make_train_step
from repro.models import transformer as T
from repro.models.base import init_params, param_pspecs
from repro.optim import adamw

CFG = T.LMConfig(
    name="ft", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
    vocab=128, d_head=16, max_seq=32, dtype=jnp.float32, attn_chunk=16,
)


def _setup(tmp_path, ckpt_every=5):
    opt = adamw(1e-2)
    loss_fn = lambda p, b: T.lm_loss(p, b, CFG)
    raw = jax.jit(make_train_step(loss_fn, opt))

    def step_fn(state, batch, i):
        p, o = state
        p, o, m = raw(p, o, {"tokens": jnp.asarray(batch["tokens"])})
        return (p, o), m

    params = init_params(T.param_specs(CFG), jax.random.key(0))
    state = (params, opt.init(params))
    loader = StepLoader(make=lambda seed, step, shard=0: lm_batch(seed, step, batch=4, seq=32, vocab=128, shard=shard))
    ckpt = CheckpointManager(tmp_path / "ck", keep_n=2, async_save=False)
    sup = TrainSupervisor(step_fn=step_fn, loader=loader, ckpt=ckpt, ckpt_every=ckpt_every)
    return sup, state


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b": [np.ones(4, np.int64), (np.zeros(2, np.float16), np.asarray(3))],
    }
    save_tree(str(tmp_path / "t"), tree, attrs={"step": 9})
    back, meta = load_tree(str(tmp_path / "t"))
    assert meta["step"] == 9
    np.testing.assert_array_equal(back["a"], tree["a"])
    assert isinstance(back["b"][1], tuple)
    np.testing.assert_array_equal(back["b"][1][0], tree["b"][1][0])


def test_restart_is_bit_identical(tmp_path):
    """A run with two injected failures equals the failure-free run."""
    sup1, s1 = _setup(tmp_path / "clean")
    clean, stats1 = sup1.run(s1, 20)
    sup2, s2 = _setup(tmp_path / "faulty")
    inj = FailureInjector(fail_at={7: 1, 13: 1})
    faulty, stats2 = sup2.run(s2, 20, injector=inj)
    assert stats2["restarts"] == 2
    for a, b in zip(jax.tree.leaves(clean), jax.tree.leaves(faulty)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_keeps_n(tmp_path):
    sup, s = _setup(tmp_path / "r", ckpt_every=2)
    sup.run(s, 10)
    assert len(sup.ckpt.steps()) <= 2


def test_too_many_failures_raises(tmp_path):
    """Retries reset on progress, so only failures with NO successful step
    in between (here: the very first step keeps dying) exhaust the budget."""
    sup, s = _setup(tmp_path / "x")
    sup.max_retries = 2
    inj = FailureInjector(fail_at={0: 99})
    with pytest.raises(RuntimeError):
        sup.run(s, 10, injector=inj)


def test_elastic_reshard_roundtrip(tmp_path):
    """Save under one layout, restore onto a (1, n)-mesh — elastic restart."""
    params = init_params(T.param_specs(CFG), jax.random.key(1))
    save_tree(str(tmp_path / "e"), params, attrs={"step": 0})
    back, _ = load_tree(str(tmp_path / "e"))
    mesh = jax.make_mesh((1, len(jax.devices())), ("data", "model"))
    pspecs = param_pspecs(T.param_specs(CFG))
    placed = reshard_tree(back, mesh, pspecs)
    for a, b in zip(jax.tree.leaves(placed), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_loader_is_pure_in_step(tmp_path):
    loader = StepLoader(make=lambda seed, step, shard=0: lm_batch(seed, step, batch=2, seq=8, vocab=10, shard=shard))
    a = loader.global_batch(3)["tokens"]
    b = loader.global_batch(3)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, loader.global_batch(4)["tokens"])
