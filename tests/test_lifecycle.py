"""Index lifecycle (core/lifecycle.py): streaming out-of-core build,
incremental insert/delete with leaf splits, tombstone filtering in both
traversal engines, and compaction bit-identity against a fresh rebuild —
on both storage backends."""
import gc
import shutil
import weakref

import numpy as np
import pytest

from repro.core import (
    ECPBuildConfig,
    MultiIndexSession,
    MutableIndex,
    StaleQueryError,
    build_index,
    build_index_streaming,
    convert,
    load_packed,
    open_index,
    reservoir_sample,
)
from repro.core import layout
from repro.data import clustered_vectors

N, DIM, CAP = 3000, 16, 64
CFG = ECPBuildConfig(levels=2, cluster_cap=CAP, seed=3, insert_batch=1024)


@pytest.fixture(scope="module")
def base(tmp_path_factory):
    """A built index (fstore + blob) that tests copy before mutating."""
    data, _ = clustered_vectors(0, n=N, dim=DIM, n_clusters=24)
    root = tmp_path_factory.mktemp("lifecycle")
    build_index(data, str(root / "idx"), CFG)
    blob = convert(root / "idx", root / "idx.blob")
    return data, root, str(root / "idx"), str(blob)


def _copy(base, tmp_path, backend):
    """A private mutable copy of the base index for one test."""
    _, _, fpath, bpath = base
    if backend == "fstore":
        dst = tmp_path / "idx"
        shutil.copytree(fpath, dst)
        return str(dst)
    dst = tmp_path / "idx.blob"
    shutil.copyfile(bpath, dst)
    return str(dst)


# ------------------------------------------------------------ streaming build
def test_streaming_build_bit_identical_to_one_shot(base, tmp_path):
    data, _, fpath, _ = base

    def chunks():  # odd chunk size on purpose: boundaries must not matter
        for lo in range(0, N, 517):
            yield data[lo : lo + 517]

    s2 = build_index_streaming(chunks, str(tmp_path / "st"), CFG)
    s1 = open_index(fpath, mode="file").store
    info = layout.IndexInfo.from_attrs(s1.read_attrs(layout.INFO))
    assert info == layout.IndexInfo.from_attrs(s2.read_attrs(layout.INFO))
    keys = [(0, 0)] + [
        (lv, nd)
        for lv in range(1, info.levels + 1)
        for nd in range(info.nodes_per_level[lv - 1])
    ]
    for k in keys:
        e1, i1 = s1.get_node(*k)
        e2, i2 = s2.get_node(*k)
        np.testing.assert_array_equal(e1, e2, err_msg=str(k))
        np.testing.assert_array_equal(i1, i2, err_msg=str(k))
    np.testing.assert_array_equal(
        s1.read_array(layout.REP_EMB), s2.read_array(layout.REP_EMB)
    )
    np.testing.assert_array_equal(
        s1.read_array(layout.REP_IDS), s2.read_array(layout.REP_IDS)
    )


def test_streaming_build_spools_one_shot_iterators(base, tmp_path):
    data, _, fpath, _ = base
    gen = (data[lo : lo + 700] for lo in range(0, N, 700))  # single-pass
    s2 = build_index_streaming(gen, str(tmp_path / "sp"), CFG)
    s1 = open_index(fpath, mode="file").store
    info = layout.IndexInfo.from_attrs(s1.read_attrs(layout.INFO))
    for j in range(info.nodes_per_level[-1]):
        e1, i1 = s1.get_node(info.levels, j)
        e2, i2 = s2.get_node(info.levels, j)
        np.testing.assert_array_equal(e1, e2)
        np.testing.assert_array_equal(i1, i2)


def test_streaming_build_does_not_retain_chunks(tmp_path):
    """Peak memory is O(chunk + leaders): consumed chunk arrays must be
    collectable immediately, never all resident."""
    data, _ = clustered_vectors(1, n=2000, dim=DIM, n_clusters=16)
    refs = []

    def chunks():
        for lo in range(0, len(data), 250):
            c = data[lo : lo + 250].copy()
            refs.append(weakref.ref(c))
            yield c

    build_index_streaming(chunks, str(tmp_path / "mem"), CFG)
    gc.collect()
    alive = sum(r() is not None for r in refs)
    assert alive <= 2, f"{alive}/{len(refs)} chunks still resident after the build"


def test_streaming_build_explicit_ids_and_pair_chunks(tmp_path):
    data, _ = clustered_vectors(2, n=800, dim=DIM, n_clusters=8)
    ids = np.arange(800) * 7 + 3

    def pair_chunks():
        for lo in range(0, 800, 190):
            yield data[lo : lo + 190], ids[lo : lo + 190]

    store = build_index_streaming(pair_chunks, str(tmp_path / "pairs"), CFG)
    info = layout.IndexInfo.from_attrs(store.read_attrs(layout.INFO))
    seen = []
    for j in range(info.nodes_per_level[-1]):
        seen.extend(store.get_node(info.levels, j)[1].tolist())
    assert sorted(seen) == sorted(ids.tolist())


def test_reservoir_sample_uniform_without_replacement():
    data = np.arange(400, dtype=np.float32).reshape(100, 4)
    samp, pos, n = reservoir_sample((data[lo : lo + 17] for lo in range(0, 100, 17)), 20, seed=1)
    assert n == 100 and samp.shape == (20, 4)
    assert len(np.unique(pos)) == 20
    np.testing.assert_array_equal(samp, data[pos])
    # k > N degrades to the whole collection
    samp, pos, n = reservoir_sample([data[:5]], 20, seed=1)
    assert n == 5 and len(pos) == 5
    with pytest.raises(ValueError):
        reservoir_sample(iter([]), 4)


def test_streaming_build_reservoir_mode(tmp_path):
    data, _ = clustered_vectors(3, n=1500, dim=DIM, n_clusters=12)
    store = build_index_streaming(
        lambda: (data[lo : lo + 400] for lo in range(0, 1500, 400)),
        str(tmp_path / "resv"),
        CFG,
        n_leaders=24,
    )
    info = layout.IndexInfo.from_attrs(store.read_attrs(layout.INFO))
    assert info.n_leaders == 24
    seen = []
    for j in range(24):
        seen.extend(store.get_node(info.levels, j)[1].tolist())
    assert sorted(seen) == list(range(1500))


# ----------------------------------------------------------- build edge cases
def test_build_empty_collection_raises_clearly(tmp_path):
    with pytest.raises(ValueError, match="empty collection"):
        build_index(np.zeros((0, 8), np.float32), str(tmp_path / "e"), CFG)
    with pytest.raises(ValueError, match="empty collection"):
        build_index_streaming(iter([]), str(tmp_path / "e2"), CFG)


def test_build_rejects_non_2d_and_bad_ids(tmp_path):
    with pytest.raises(ValueError, match=r"\[N, D\]"):
        build_index(np.zeros(8, np.float32), str(tmp_path / "x"))
    data = np.random.default_rng(0).normal(size=(10, 8)).astype(np.float32)
    with pytest.raises(ValueError, match="item_ids length"):
        build_index(data, str(tmp_path / "y"), CFG, item_ids=np.arange(3))


def test_build_cluster_cap_one_and_tiny_collections(tmp_path):
    data = np.random.default_rng(0).normal(size=(20, 8)).astype(np.float32)
    cfg = ECPBuildConfig(levels=2, cluster_cap=1, seed=0)
    store = build_index(data, str(tmp_path / "cap1"), cfg)
    info = layout.IndexInfo.from_attrs(store.read_attrs(layout.INFO))
    assert info.n_leaders == 20
    with open_index(str(tmp_path / "cap1"), mode="file") as idx:
        assert idx.search(data[5], k=1, b=4).ids[0] == 5
    # one item, cap larger than the collection
    one = build_index(data[:1], str(tmp_path / "one"), ECPBuildConfig(levels=2, cluster_cap=100))
    assert layout.IndexInfo.from_attrs(one.read_attrs(layout.INFO)).n_leaders == 1
    with pytest.raises(ValueError, match="smaller than the requested leader count"):
        build_index_streaming([data], str(tmp_path / "over"), cfg, n_leaders=50)


# ------------------------------------------------------------------- inserts
@pytest.mark.parametrize("backend", ["fstore", "blob"])
def test_insert_findable_and_exactly_once(base, tmp_path, backend):
    data, _, _, _ = base
    path = _copy(base, tmp_path, backend)
    rng = np.random.default_rng(8)
    new = (data[rng.integers(0, N, 100)] + 0.05 * rng.normal(size=(100, DIM))).astype(np.float32)
    with open_index(path, mode="file", backend=backend) as idx:
        assert isinstance(idx, MutableIndex)
        gen0 = idx.generation
        r = idx.insert(new, np.arange(N, N + 100))
        assert r["inserted"] == 100
        assert idx.generation == gen0 + 1
        assert idx.info.n_items == N + 100
        for i in (0, 50, 99):
            rs = idx.search(new[i], k=3, b=8)
            assert N + i in rs.row_ids(0)
        # the whole collection is present exactly once across leaves
        info = idx.info
        seen = []
        for j in range(info.nodes_per_level[-1]):
            seen.extend(idx.store.get_node(info.levels, j)[1].tolist())
        assert sorted(seen) == list(range(N + 100))
        # splits kept every touched leaf within cap
        if r["splits"]:
            rows = idx.store.node_rows(
                [(info.levels, j) for j in range(info.nodes_per_level[-1])]
            )
            assert max(rows) <= max(
                CAP, max(idx.store.node_rows([(info.levels, j) for j in range(24)]))
            )


def test_insert_splits_register_with_parent(base, tmp_path):
    data, _, _, _ = base
    path = _copy(base, tmp_path, "fstore")
    with open_index(path, mode="file") as idx:
        info0 = idx.info
        # overfill one leaf deliberately: clone one stored vector cap times
        leaf_emb, leaf_ids = idx.store.get_node(info0.levels, 0)
        target = np.asarray(leaf_emb[0], np.float32)
        n_add = CAP + 10
        new = np.tile(target, (n_add, 1)) + 0.001 * np.random.default_rng(1).normal(
            size=(n_add, DIM)
        ).astype(np.float32)
        r = idx.insert(new, np.arange(N, N + n_add))
        assert r["splits"] >= 1
        info1 = idx.info
        assert info1.n_leaders > info0.n_leaders
        assert info1.nodes_per_level[-1] == info1.n_leaders
        # every leaf is reachable from exactly one parent, including the new ones
        child_ids = []
        for p in range(info1.nodes_per_level[0]):
            child_ids.extend(idx.store.get_node(1, p)[1].tolist())
        assert sorted(child_ids) == list(range(info1.n_leaders))
        # nothing lost
        seen = []
        for j in range(info1.n_leaders):
            seen.extend(idx.store.get_node(info1.levels, j)[1].tolist())
        assert sorted(seen) == list(range(N + n_add))


def test_insert_validation(base, tmp_path):
    path = _copy(base, tmp_path, "fstore")
    with open_index(path, mode="file") as idx:
        with pytest.raises(ValueError, match="vectors must be"):
            idx.insert(np.zeros((2, DIM + 1), np.float32))
        with pytest.raises(ValueError, match="unique"):
            idx.insert(np.zeros((2, DIM), np.float32), np.array([5, 5]))
        r = idx.insert(np.zeros((0, DIM), np.float32))
        assert r["inserted"] == 0


# -------------------------------------------------------------------- deletes
@pytest.mark.parametrize("backend", ["fstore", "blob"])
@pytest.mark.parametrize("engine", ["flat", "legacy"])
def test_delete_tombstones_filtered(base, tmp_path, backend, engine):
    data, _, _, _ = base
    path = _copy(base, tmp_path, backend)
    del_ids = np.arange(0, N, 13)
    with open_index(path, mode="file", backend=backend, engine=engine) as idx:
        before = set(idx.search(data[13], k=30, b=16).row_ids(0))
        assert before & set(del_ids.tolist())
        n = idx.delete(del_ids)
        assert n == len(del_ids)
        assert idx.delete(del_ids) == 0  # idempotent
        got = set(idx.search(data[13], k=30, b=16).row_ids(0))
        assert not (got & set(del_ids.tolist())), f"{backend}/{engine} leaked a tombstone"
    # tombstones persist: a fresh open still filters
    with open_index(path, mode="file", backend=backend, engine=engine) as idx:
        assert idx.tombstones == set(del_ids.tolist())
        got = set(idx.search(data[13], k=30, b=16).row_ids(0))
        assert not (got & set(del_ids.tolist()))


def test_insert_resurrects_tombstoned_id(base, tmp_path):
    data, _, _, _ = base
    path = _copy(base, tmp_path, "fstore")
    with open_index(path, mode="file") as idx:
        idx.delete([N + 1, 42])
        idx.insert(data[:2] + 0.3, np.array([N, N + 1]))
        assert idx.tombstones == {42}
        assert N + 1 in idx.search(data[1] + 0.3, k=3, b=8).row_ids(0)


def test_resurrect_purges_old_row_and_compacts(base, tmp_path):
    """Regression: delete(id) then insert(new_vec, id) must purge the OLD
    physical row — otherwise the id exists twice (stale row searchable,
    compact() rejects the duplicate forever)."""
    data, _, _, _ = base
    path = _copy(base, tmp_path, "fstore")
    far = np.full(DIM, 40.0, np.float32)  # nowhere near data[5]
    with open_index(path, mode="file") as idx:
        idx.delete([5])
        idx.insert(far[None, :], [5])
        # the old embedding for id 5 must be gone: searching AT it misses
        got = idx.search(data[5], k=10, b=64)
        assert 5 not in got.row_ids(0), "stale pre-delete row still live"
        assert 5 in idx.search(far, k=3, b=8).row_ids(0)
        # exactly one physical row carries the id
        count = sum(
            int((idx.store.get_node(idx.info.levels, j)[1] == 5).sum())
            for j in range(idx.info.nodes_per_level[-1])
        )
        assert count == 1
        idx.compact()  # used to raise 'duplicate item ids'
        assert 5 in idx.search(far, k=3, b=8).row_ids(0)


def test_default_ids_never_collide_after_compact(base, tmp_path):
    """Regression: default insert ids come from a monotonic next_id, not
    n_items — compact() shrinks n_items but must never reissue live ids."""
    data, _, _, _ = base
    path = _copy(base, tmp_path, "fstore")
    with open_index(path, mode="file") as idx:
        idx.delete([3])
        idx.compact()                      # n_items: N -> N-1; id N-1 lives
        r = idx.insert(data[:1] + 0.5)     # default id must NOT be N-1
        assert r["inserted"] == 1
        assert idx.info.next_id == N + 1
        seen = []
        for j in range(idx.info.nodes_per_level[-1]):
            seen.extend(idx.store.get_node(idx.info.levels, j)[1].tolist())
        assert len(seen) == len(set(seen)), "default id collided with a live id"
        idx.compact()                      # and the index stays compactable


def test_load_packed_refuses_tombstoned_index(base, tmp_path):
    path = _copy(base, tmp_path, "fstore")
    with open_index(path, mode="file") as idx:
        idx.delete([1, 2, 3])
    with pytest.raises(ValueError, match="compact"):
        load_packed(path)


# ----------------------------------------------------------------- compaction
@pytest.mark.parametrize("backend", ["fstore", "blob"])
def test_compact_bit_identical_to_fresh_rebuild(base, tmp_path, backend):
    """The acceptance criterion: streamed build + inserts + deletes +
    compact() == one-shot build of the logical collection, bit for bit,
    for both engines."""
    data, _, _, _ = base
    path = _copy(base, tmp_path, backend)
    rng = np.random.default_rng(4)
    n_ins = 150
    new = (data[rng.integers(0, N, n_ins)] + 0.05 * rng.normal(size=(n_ins, DIM))).astype(
        np.float32
    )
    new_ids = np.arange(N, N + n_ins)
    del_ids = np.concatenate([rng.choice(N, 100, replace=False), new_ids[:20]])
    with open_index(path, mode="file", backend=backend) as idx:
        idx.insert(new, new_ids)
        idx.delete(del_ids)
        r = idx.compact()
        assert r["purged"] == len(set(del_ids.tolist()))
        assert idx.tombstones == set()
        assert idx.info.n_items == r["live"]

    # the logical collection: live (id, stored-f16 vector) pairs, id order
    live = np.ones(N + n_ins, bool)
    live[del_ids] = False
    stored = np.concatenate([data, new]).astype(np.float16).astype(np.float32)
    fresh_f = str(tmp_path / "fresh")
    build_index(stored[live], fresh_f, CFG, item_ids=np.flatnonzero(live))
    fresh = fresh_f if backend == "fstore" else str(convert(fresh_f, tmp_path / "fresh.blob"))

    queries = data[rng.integers(0, N, 15)] + 0.01
    for engine in ("flat", "legacy"):
        with open_index(path, mode="file", backend=backend, engine=engine) as a, \
             open_index(fresh, mode="file", backend=backend, engine=engine) as b:
            for q in queries:
                ra = a.search(q, k=20, b=8)
                rb = b.search(q, k=20, b=8)
                np.testing.assert_array_equal(ra.ids, rb.ids, err_msg=f"{backend}/{engine}")
                np.testing.assert_array_equal(ra.dists, rb.dists, err_msg=f"{backend}/{engine}")


def test_compact_of_everything_deleted_raises(base, tmp_path):
    path = _copy(base, tmp_path, "fstore")
    with open_index(path, mode="file") as idx:
        idx.delete(np.arange(N))
        with pytest.raises(ValueError, match="empty index"):
            idx.compact()


def test_compact_stales_open_queries_but_inserts_do_not(base, tmp_path):
    data, _, _, _ = base
    path = _copy(base, tmp_path, "fstore")
    with open_index(path, mode="file") as idx:
        rs = idx.search(data[7], k=10, b=4)
        idx.insert(data[:1] + 0.2, [N])      # append-only: handle stays valid
        idx.delete([3])                       # tombstone-only: still valid
        assert len(rs.query.next(10)) > 0
        idx.compact()
        with pytest.raises(StaleQueryError):
            rs.query.next(10)
        # a new search works and reflects the compacted tree
        rs2 = idx.search(data[7], k=10, b=4)
        assert 3 not in rs2.row_ids(0)


def test_insert_of_live_id_raises_before_writing(base, tmp_path):
    """Regression: inserting an id that is already live must raise (not
    silently create a duplicate that bricks compact())."""
    data, _, _, _ = base
    path = _copy(base, tmp_path, "fstore")
    with open_index(path, mode="file") as idx:
        with pytest.raises(ValueError, match="already live"):
            idx.insert(data[:1] + 0.5, [5])
        # nothing was written: the index still compacts and id 5 is unique
        idx.compact()
        count = sum(
            int((idx.store.get_node(idx.info.levels, j)[1] == 5).sum())
            for j in range(idx.info.nodes_per_level[-1])
        )
        assert count == 1


def test_phantom_tombstone_does_not_skew_n_items(base, tmp_path):
    """Regression: delete(absent id) then insert(that id) must count
    n_items by rows actually purged (none), not tombstone membership."""
    data, _, _, _ = base
    path = _copy(base, tmp_path, "fstore")
    with open_index(path, mode="file") as idx:
        idx.delete([999_999])                # phantom: never existed
        idx.insert(data[:1] + 0.5, [999_999])
        assert idx.info.n_items == N + 1     # used to stay N
        rows = sum(
            len(idx.store.get_node(idx.info.levels, j)[1])
            for j in range(idx.info.nodes_per_level[-1])
        )
        assert rows == idx.info.n_items


def test_blob_split_refuses_cleanly_when_parent_block_full(base, tmp_path):
    """Regression: on the blob backend a split whose parent registration
    cannot fit the fixed block must raise BEFORE any write — previously
    it stranded the already-written new leaves outside the tree."""
    import repro.core.lifecycle as lifecycle

    data, _, _, _ = base
    path = _copy(base, tmp_path, "blob")
    with open_index(path, mode="file", backend="blob") as idx:
        all_before = []
        for j in range(idx.info.nodes_per_level[-1]):
            all_before.extend(idx.store.get_node(idx.info.levels, j)[1].tolist())
        target = idx.store.get_node(idx.info.levels, 0)[0][0]
        new = np.tile(np.asarray(target, np.float32), (CAP + 10, 1))
        orig = type(idx.store).capacity_rows
        try:  # make the parent look full so the pre-flight must trip
            type(idx.store).capacity_rows = property(lambda self: 8)
            with pytest.raises(ValueError, match="compact"):
                idx.insert(new, np.arange(N, N + CAP + 10))
        finally:
            type(idx.store).capacity_rows = orig
        # no rows orphaned, no metadata half-applied
        all_after = []
        for j in range(idx.info.nodes_per_level[-1]):
            all_after.extend(idx.store.get_node(idx.info.levels, j)[1].tolist())
        assert sorted(all_after) == sorted(all_before)
        assert idx.info.n_items == N


def test_v1_blob_split_header_overflow_raises_before_any_write(tmp_path):
    """Regression: a split that needs new slots on a v1 blob whose single
    reserved header page cannot hold the upgraded slot map must raise
    BEFORE the leaf is overwritten — previously it lost the leaf's rows
    past part 0."""
    data, _ = clustered_vectors(9, n=12_000, dim=16, n_clusters=64)
    cfg = ECPBuildConfig(levels=2, cluster_cap=8, seed=0)  # ~1500 leaves
    build_index(data, str(tmp_path / "big"), cfg)
    blob = convert(tmp_path / "big", tmp_path / "big.blob", format=1)
    with open_index(str(blob), mode="file", backend="blob") as idx:
        assert idx.store.format == 1
        target = idx.store.get_node(2, 0)[0][0]
        new = np.tile(np.asarray(target, np.float32), (20, 1))
        with pytest.raises(ValueError, match="header grew past"):
            idx.insert(new, np.arange(12_000, 12_020))
        # nothing was lost: every original row is still in exactly one leaf
        seen: list = []
        for lo in range(0, idx.info.nodes_per_level[-1], 256):
            keys = [(2, j) for j in range(lo, min(lo + 256, idx.info.nodes_per_level[-1]))]
            for _e, nids in idx.store.get_nodes(keys):
                seen.extend(nids.tolist())
        assert sorted(seen) == list(range(12_000))


def test_refresh_resyncs_after_external_writer(base, tmp_path):
    """session.invalidate / ECPIndex.refresh must pick up metadata, root,
    and tombstones written by ANOTHER index handle on the same files."""
    data, _, _, _ = base
    path = _copy(base, tmp_path, "fstore")
    with MultiIndexSession(cache_bytes=8 << 20) as sess:
        reader = sess.open(path, name="r")
        reader.search(data[1], k=5, b=8)  # warm caches + in-memory state
        with open_index(path, mode="file") as writer:  # "another process"
            writer.insert(data[:1] + 0.4, [N])
            writer.delete([7])
            writer.compact()
        sess.invalidate("r")
        assert reader.info.n_items == N  # N + 1 inserted - 1 deleted
        assert N in reader.search(data[0] + 0.4, k=3, b=8).row_ids(0)
        assert 7 not in reader.search(data[7], k=10, b=32).row_ids(0)


# --------------------------------------------------- sessions, context mgmt
def test_context_manager_closes_pool_and_store(base):
    _, _, fpath, _ = base
    with open_index(fpath, mode="file") as idx:
        idx.prefetch(up_to_level=1)
        assert idx._pool is not None
        pool = idx._pool
    assert idx._pool is None
    assert pool._shutdown


def test_session_shared_cache_invalidated_on_write(base, tmp_path):
    data, _, _, _ = base
    path = _copy(base, tmp_path, "fstore")
    with MultiIndexSession(cache_bytes=8 << 20) as sess:
        idx = sess.open(path, name="a")
        rs0 = idx.search(data[5], k=5, b=8)
        resident0 = sess.cache.n_resident
        assert resident0 > 0
        vec = data[5] + 0.02
        idx.insert(vec[None, :], [N])
        # rewritten nodes were dropped from the SHARED cache...
        rs = idx.search(vec, k=3, b=8)
        assert N in rs.row_ids(0), "stale shared cache hid the inserted item"
        # ...and compaction clears the whole namespace
        idx.compact()
        assert not any(k[0] == "a" for k in sess.cache._d)
        assert N in idx.search(vec, k=3, b=8).row_ids(0)
