"""The two recall knobs — multi-probe traversal (``probe_m``) and
build-time spill replication (``spill_s``) — plus the engine bugfixes
that rode along:

  * probe_m=1 / spill_s=0 stays bit-identical across {fstore, blob} x
    {flat-single, flat-batch, legacy} including ``next(k)`` continuation
    and mid-stream save/load,
  * every engine agrees bit-identically at probe_m >= 2 as well (the
    probe group is popped BEFORE expansion in all of them),
  * a spill-built index never emits a duplicate id — search, ``next(k)``,
    after delete, after insert, after compact,
  * recall@10 is monotone in probe_m and improved by spill,
  * query ``b`` stays pinned at the configured base across b-doubling
    (and across save/load),
  * the node-norm cache serves cosine (not just l2) bit-identically,
  * ``allocate_effort`` clamps/fails by the documented budget-floor rule.
"""
import numpy as np
import pytest

from repro.core import ECPBuildConfig, build_index, convert, open_index
from repro.core.distances import np_distances

N, DIM = 5000, 24
BACKENDS = ("fstore", "blob")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    from repro.data import clustered_vectors

    data, _ = clustered_vectors(0, n=N, dim=DIM, n_clusters=48)
    root = tmp_path_factory.mktemp("knobs")
    paths = {}
    for s in (0, 1, 2):
        p = str(root / f"ecp_s{s}")
        build_index(
            data, p, ECPBuildConfig(levels=2, metric="l2", cluster_cap=64, seed=0, spill_s=s)
        )
        paths[("fstore", s)] = p
        paths[("blob", s)] = str(convert(p, root / f"ecp_s{s}.blob"))
    rng = np.random.default_rng(7)
    queries = (
        data[rng.integers(0, N, 12)]
        + 0.05 * rng.normal(size=(12, DIM)).astype(np.float32)
    ).astype(np.float32)
    return data, paths, queries


def _open(paths, backend, spill=0, **kw):
    return open_index(paths[(backend, spill)], mode="file", backend=backend, **kw)


def _assert_same(a, b, msg=""):
    np.testing.assert_array_equal(a.ids, b.ids, err_msg=f"{msg}: ids")
    np.testing.assert_array_equal(a.dists, b.dists, err_msg=f"{msg}: dists")


# --------------------------------------------------------- probe_m parity
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("m", (1, 2, 3))
def test_engines_bit_identical_at_any_probe_m(built, backend, m):
    """flat-single, flat-batch and legacy agree bit-identically at every
    probe width — probe_m=1 is the historical strict best-first gate."""
    _, paths, queries = built
    flat = _open(paths, backend)
    leg = _open(paths, backend, engine="legacy")
    rb = flat.search(queries, k=20, b=4, probe_m=m)
    for r, q in enumerate(queries):
        rl = leg.search(q, k=20, b=4, probe_m=m)
        rs = flat.search(q, k=20, b=4, probe_m=m)
        np.testing.assert_array_equal(rs.ids, rl.ids, err_msg=f"single m={m} row {r}")
        np.testing.assert_array_equal(rb.ids[r], rl.ids, err_msg=f"batch m={m} row {r}")
        np.testing.assert_array_equal(rb.dists[r], rl.dists, err_msg=f"batch m={m} row {r}")


def test_quantized_engine_bit_identical_at_probe_m(built):
    _, paths, queries = built
    import pathlib

    blob = paths[("blob", 0)]
    qblob = str(pathlib.Path(blob).parent / "ecp_s0.qblob")
    convert(paths[("fstore", 0)], qblob, quant="int8")
    quant = open_index(qblob, mode="file", backend="blob", quantized=True)
    leg = _open(paths, "blob", engine="legacy")
    for m in (1, 2):
        rq = quant.search(queries, k=20, b=4, probe_m=m)
        for r, q in enumerate(queries):
            rl = leg.search(q, k=20, b=4, probe_m=m)
            np.testing.assert_array_equal(rq.ids[r], rl.ids, err_msg=f"quant m={m} row {r}")
    quant.close()
    leg.close()


def test_probe_m_default_flows_from_open_index(built):
    """open_index(probe_m=2) sets the index default; per-call override wins."""
    _, paths, queries = built
    wide = _open(paths, "blob", probe_m=2)
    narrow = _open(paths, "blob")
    q = queries[0]
    _assert_same(
        wide.search(q, k=20, b=4), narrow.search(q, k=20, b=4, probe_m=2), "default"
    )
    _assert_same(
        wide.search(q, k=20, b=4, probe_m=1), narrow.search(q, k=20, b=4), "override"
    )
    wide.close()
    narrow.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_probe_m_continuation_and_save_load(built, backend):
    """next(k) continuation at probe_m=2, with a save/load mid-stream,
    stays bit-identical to the uninterrupted legacy stream."""
    _, paths, queries = built
    flat = _open(paths, backend)
    leg = _open(paths, backend, engine="legacy")
    rf = flat.search(queries[1], k=10, b=4, probe_m=2)
    rl = leg.search(queries[1], k=10, b=4, probe_m=2)
    _assert_same(rf, rl, backend)
    if backend == "fstore":  # blob has no query-state persistence
        rf.query.save("knob_q")
        flat2 = _open(paths, backend)
        qf = flat2.load_query("knob_q")
    else:
        flat2, qf = None, rf.query
    for i in range(3):
        _assert_same(qf.next(15), rl.query.next(15), f"{backend} next#{i}")
    flat.close()
    if flat2 is not None:
        flat2.close()
    leg.close()


# ----------------------------------------------------------- spill parity
@pytest.mark.parametrize("backend", BACKENDS)
def test_spill_engines_agree_and_never_duplicate(built, backend):
    _, paths, queries = built
    flat = _open(paths, backend, spill=1)
    leg = _open(paths, backend, spill=1, engine="legacy")
    rb = flat.search(queries, k=20, b=4)
    for r, q in enumerate(queries):
        rl = leg.search(q, k=20, b=4)
        np.testing.assert_array_equal(rb.ids[r], rl.ids, err_msg=f"spill row {r}")
        live = [int(x) for x in rb.ids[r] if x >= 0]
        assert len(live) == len(set(live)), f"duplicate id emitted, row {r}"
    flat.close()
    leg.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_spill_next_k_never_duplicates_across_stream(built, backend):
    """No id may repeat across the WHOLE emission stream, including after
    a mid-stream save/load (the seen-set must persist; fstore only —
    blob has no query-state persistence)."""
    _, paths, queries = built
    for kw in ({}, {"engine": "legacy"}):
        idx = _open(paths, backend, spill=2, **kw)
        rs = idx.search(queries[2], k=8, b=4)
        seen = [int(x) for x in rs.ids if x >= 0]
        if backend == "fstore":
            rs.query.save("spill_q")
            idx2 = _open(paths, backend, spill=2, **kw)
            qh = idx2.load_query("spill_q")
        else:
            idx2, qh = None, rs.query
        for _ in range(4):
            nxt = qh.next(8)
            seen += [int(x) for x in nxt.ids if x >= 0]
        assert len(seen) == len(set(seen)), f"duplicate across stream ({kw})"
        idx.close()
        if idx2 is not None:
            idx2.close()


def test_spill_build_streaming_matches_oneshot(built, tmp_path):
    """Streamed spill build produces the same logical leaves as one-shot."""
    from repro.core import layout
    from repro.core.lifecycle import build_index_streaming
    from repro.core.store import open_store

    data, paths, _ = built
    cfg = ECPBuildConfig(levels=2, metric="l2", cluster_cap=64, seed=0, spill_s=1)

    def chunks():
        return iter(
            (data[i : i + 1100], np.arange(i, min(i + 1100, N), dtype=np.int64))
            for i in range(0, N, 1100)
        )

    build_index_streaming(chunks, str(tmp_path / "s1s"), cfg)
    s1 = open_store(paths[("fstore", 1)])
    s2 = open_store(str(tmp_path / "s1s"))
    info = layout.IndexInfo.from_attrs(s1.read_attrs(layout.INFO))
    assert info.spill_s == 1 and info.spill_eps > 0
    for j in range(info.nodes_per_level[-1]):
        e1, i1 = s1.get_node(2, j)
        e2, i2 = s2.get_node(2, j)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))


def test_spill_under_delete_insert_compact(built, tmp_path):
    """Mutations on a spill-built index: deletes filter every replica,
    inserts place best-effort replicas, compact dedups + rebuilds spill,
    and n_items stays the logical live count throughout."""
    data, _, queries = built
    path = str(tmp_path / "mut")
    build_index(
        data, path, ECPBuildConfig(levels=2, metric="l2", cluster_cap=64, seed=0, spill_s=1)
    )
    idx = open_index(path, mode="file")
    assert idx.info.n_items == N  # replicas not counted
    rng = np.random.default_rng(5)
    newv = (data[rng.integers(0, N, 30)] + 0.02 * rng.normal(size=(30, DIM))).astype(
        np.float32
    )
    r = idx.insert(newv)
    assert r["inserted"] == 30 and idx.info.n_items == N + 30
    assert "spilled" in r  # replica placement is reported
    idx.delete(np.arange(50, 90))
    rs = idx.search(data[60], k=30, b=16)
    live = [int(x) for x in rs.ids if x >= 0]
    assert not (set(live) & set(range(50, 90))), "tombstoned replica emitted"
    assert len(live) == len(set(live))
    c = idx.compact()
    assert c["live"] == N + 30 - 40 == idx.info.n_items
    assert idx.info.spill_s == 1  # spill metadata survives the rebuild
    rs = idx.search(queries[0], k=20, b=8)
    live = [int(x) for x in rs.ids if x >= 0]
    assert len(live) == len(set(live))
    idx.close()


# ---------------------------------------------------------------- recall
def test_recall_monotone_in_probe_m_and_spill(built):
    data, paths, queries = built
    exact = np.argsort(np_distances(queries, data, "l2"), axis=1, kind="stable")[:, :10]
    exact_sets = [set(map(int, row)) for row in exact]

    def recall(spill, m):
        idx = _open(paths, "blob", spill=spill)
        try:
            res = idx.search(queries, k=10, b=8, probe_m=m)
        finally:
            idx.close()
        hits = sum(
            len(exact_sets[r] & {int(x) for x in res.ids[r] if x >= 0})
            for r in range(len(queries))
        )
        return hits / (len(queries) * 10)

    r1, r2, r4 = recall(0, 1), recall(0, 2), recall(0, 4)
    assert r1 <= r2 <= r4, f"recall not monotone in probe_m: {r1} {r2} {r4}"
    assert recall(1, 1) >= r1, "spill_s=1 dropped recall at probe_m=1"
    assert max(r2, r4, recall(1, 1), recall(2, 1)) > r1, (
        "no knob setting improves on strict best-first at equal b"
    )


# ----------------------------------------------------- bugfix regressions
def test_query_b_pinned_across_doubling_and_save(built):
    """qs.b is the configured base budget: b-doubling happens on a
    transient copy, so continuations and save/load see the base value."""
    _, paths, queries = built
    for kw in ({}, {"engine": "legacy"}):
        idx = _open(paths, "fstore", **kw)
        rs = idx.search(queries[0], k=4000, b=2, mx_inc=5)  # forces doubling
        assert rs.query.stats.increments > 0, "test needs b-doubling to trigger"
        assert rs.query.b == 2, f"b mutated to {rs.query.b} ({kw})"
        rs.query.save("pinned_q")
        idx2 = _open(paths, "fstore", **kw)
        qh = idx2.load_query("pinned_q")
        assert qh.b == 2, f"saved b drifted to {qh.b} ({kw})"
        idx.close()
        idx2.close()


def test_saved_query_after_doubling_continues_identically(built):
    """Continuation after save/load == uninterrupted continuation, even
    when the saved increment had already doubled b (the transient b_cur
    is reset per increment, not persisted)."""
    _, paths, queries = built
    a = _open(paths, "fstore")
    ra = a.search(queries[3], k=200, b=2, mx_inc=3)
    ref = [ra.query.next(50) for _ in range(2)]
    b = _open(paths, "fstore")
    rb = b.search(queries[3], k=200, b=2, mx_inc=3)
    rb.query.save("doubled_q")
    c = _open(paths, "fstore")
    qh = c.load_query("doubled_q")
    for i, want in enumerate(ref):
        _assert_same(qh.next(50), want, f"next#{i}")
    a.close()
    b.close()
    c.close()


def test_cosine_norm_cache_parity_and_hit(tmp_path):
    """The per-node sqnorm cache now serves cosine: results bit-identical
    to the uncached legacy path AND the cache actually populates."""
    from repro.data import clustered_vectors

    data, _ = clustered_vectors(0, n=2000, dim=16, n_clusters=24)
    path = str(tmp_path / "cos")
    build_index(data, path, ECPBuildConfig(levels=2, metric="cosine", cluster_cap=64))
    idx = open_index(path, mode="file")
    leg = open_index(path, mode="file", engine="legacy")
    assert idx._norms is not None, "norm cache disabled for cosine"
    rs = idx.search(data[:6], k=10, b=6)
    assert len(idx._norms._d) > 0, "cosine search never populated the norm cache"
    for r in range(6):
        rl = leg.search(data[r], k=10, b=6)
        np.testing.assert_array_equal(rs.ids[r], rl.ids, err_msg=f"cosine row {r}")
        np.testing.assert_array_equal(rs.dists[r], rl.dists, err_msg=f"cosine row {r}")
    # the cached-path contract: sqrt(sum(c*c)) is bitwise what linalg.norm computes
    c = np.asarray(data[:100], np.float32)
    np.testing.assert_array_equal(
        np.sqrt((c * c).sum(-1)), np.linalg.norm(c, axis=-1)
    )
    idx.close()
    leg.close()


# ----------------------------------------------- allocate_effort edge rule
def test_allocate_effort_budget_floor_rule():
    from repro.core.federation import allocate_effort

    d = np.array([0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8])
    owner = np.array([0, 1, 2, 3, 0, 1, 2, 3])
    # clamp: b=4 cannot fund 4 shards at b_min=2 -> probe count drops to 2
    probe, alloc = allocate_effort(d, owner, 4, n_shards=4, b_min=2)
    assert len(probe) == 2 and alloc.sum() == 4 and (alloc >= 2).all()
    # b smaller than the shard count: still conserves b on fewer shards
    probe, alloc = allocate_effort(d, owner, 3, n_shards=4, b_min=1)
    assert len(probe) == 3 and alloc.sum() == 3
    # b_min=0 is "no floor" (effective 1), not an error
    probe, alloc = allocate_effort(d, owner, 8, n_shards=4, b_min=0)
    assert alloc.sum() == 8 and (alloc >= 1).all()
    # one shard takes the whole budget regardless of floors
    probe, alloc = allocate_effort(
        np.array([0.1, 0.2]), np.array([0, 0]), 5, n_shards=1, b_min=3
    )
    assert list(probe) == [0] and list(alloc) == [5]
    # probe_m widens the per-shard floor -> fewer shards funded
    probe_w, alloc_w = allocate_effort(d, owner, 8, n_shards=4, b_min=2, probe_m=2)
    assert len(probe_w) == 2 and alloc_w.sum() == 8 and (alloc_w >= 4).all()
    # negative floors are refused
    with pytest.raises(ValueError):
        allocate_effort(d, owner, 8, n_shards=4, b_min=-1)


def test_federation_probe_m_threading(tmp_path):
    """FederatedIndex(probe_m=...) forwards the knob to every shard and
    conserves total b; probe_m=1 matches the explicit per-call default."""
    from repro.core import build_federation
    from repro.data import clustered_vectors

    data, _ = clustered_vectors(0, n=2400, dim=16, n_clusters=24)
    root = build_federation(
        data, tmp_path / "fed", n_shards=3,
        cfg=ECPBuildConfig(levels=2, cluster_cap=64, seed=0),
    )
    fed = open_index(root, probe_m=2)
    try:
        assert fed.probe_m == 2
        q = data[5]
        r_def = fed.search(q, k=10, b=9)
        r_exp = fed.search(q, k=10, b=9, probe_m=2)
        np.testing.assert_array_equal(r_def.ids, r_exp.ids)
        total = sum(fed.search(q, k=10, b=9).query.allocation.values())
        assert total == 9, "federated probe_m must conserve total b"
    finally:
        fed.close()
