"""Quantized leaf blocks + device-resident scoring pipeline (blob v3).

Covers the quant seam end to end: encode/decode error bounds, the v3
on-disk format (header, persisted companions, v2 upgrade), companion
maintenance across insert/split/delete/compact, the fstore
encode-on-the-fly fallback, bit-parity of the quantized engine against
the fp32 engines, the one-launch-per-round contract, scorer shape
bucketing, and hot-level pinning.
"""
import os

import numpy as np
import pytest

from repro.core import build_index
from repro.core.api import open_index
from repro.core.distances import np_distances
from repro.core.lifecycle import ECPBuildConfig
from repro.core.quant import (
    QFORMATS,
    decode_codes,
    distance_bounds,
    encode_node,
    reconstruction_radius,
)
from repro.core.search import ECPIndex, make_kernel_scorer
from repro.core.store import BlobStore, convert

RNG = np.random.default_rng(42)


# ------------------------------------------------------------- encode/decode
@pytest.mark.parametrize("qformat", QFORMATS)
@pytest.mark.parametrize("scale_pow", [-3, 0, 4])
def test_encode_decode_error_bound(qformat, scale_pow):
    emb = (RNG.standard_normal((96, 24)) * 10.0**scale_pow).astype(np.float32)
    qn = encode_node(emb, qformat)
    dec = qn.decode()
    # per-row L2 reconstruction error is bounded by the node radius
    err = np.linalg.norm(dec.astype(np.float64) - emb.astype(np.float64), axis=1)
    assert float(err.max()) <= qn.radius
    assert qn.radius == reconstruction_radius(qn.scale, emb.shape[1])
    if qformat == "int8":
        assert qn.codes.dtype == np.int8
        assert qn.codes.min() >= -127 and qn.codes.max() <= 127


def test_encode_f16_storage_is_exact():
    # storage dtype is f16: rows arriving at encode are already f16-rounded,
    # so the f16 tier is bit-exact and advertises radius 0
    emb = RNG.standard_normal((32, 16)).astype(np.float16).astype(np.float32)
    qn = encode_node(emb, "float16")
    assert qn.scale == 0.0 and qn.radius == 0.0
    np.testing.assert_array_equal(qn.decode(), emb)


def test_encode_constant_node_exact():
    emb = np.full((8, 12), 3.25, np.float32)
    qn = encode_node(emb, "int8")
    assert qn.scale == 0.0
    np.testing.assert_array_equal(qn.decode(), emb)


def test_encode_deterministic_vs_f32_params():
    # codes must be computed from the f32-rounded scale/offset the blob
    # persists, so blob-persisted and on-the-fly codes agree bit-for-bit
    emb = RNG.standard_normal((64, 20)).astype(np.float32)
    a, b = encode_node(emb, "int8"), encode_node(emb.copy(), "int8")
    np.testing.assert_array_equal(a.codes, b.codes)
    assert a.scale == np.float32(a.scale) and a.offset == np.float32(a.offset)
    np.testing.assert_array_equal(
        decode_codes(a.codes, a.scale, a.offset, "int8"), b.decode()
    )


@pytest.mark.parametrize("metric", ["l2", "ip", "cosine"])
def test_distance_bounds_sound(metric):
    q = RNG.standard_normal(24).astype(np.float32)
    emb = RNG.standard_normal((128, 24)).astype(np.float32)
    qn = encode_node(emb, "int8")
    d_approx = np_distances(q, qn.decode(), metric)
    d_exact = np_distances(q, emb, metric)
    lb, ub = distance_bounds(
        d_approx, qn.radius, metric, q_norm=float(np.linalg.norm(q))
    )
    assert np.all(lb <= d_exact + 1e-9) and np.all(d_exact <= ub + 1e-9)


# ------------------------------------------------------------ on-disk format
@pytest.fixture(scope="module")
def small_index(tmp_path_factory):
    td = tmp_path_factory.mktemp("quant_idx")
    n, dim = 900, 16
    data = np.random.default_rng(3).standard_normal((n, dim)).astype(np.float32)
    fs = os.path.join(td, "fs")
    build_index(data, fs, ECPBuildConfig(levels=2, metric="l2", cluster_cap=48))
    v2 = os.path.join(td, "v2.bin")
    v3 = os.path.join(td, "v3.bin")
    convert(fs, v2, format=2)
    convert(fs, v3, format=2, quant="int8")
    return {"fs": fs, "v2": v2, "v3": v3, "data": data, "dim": dim}


def _leaf_keys(store):
    from repro.core import layout

    info = layout.IndexInfo.from_attrs(store.read_attrs(layout.INFO))
    return [(info.levels, j) for j in range(info.nodes_per_level[-1])]


def _assert_quant_matches_fp(store, qformat):
    """Persisted companions must equal a fresh encode of the fp rows."""
    for lv, nd in _leaf_keys(store):
        emb, _ = store.get_node(lv, nd)
        ref = encode_node(emb, qformat)
        got = store.get_quantized(lv, nd)
        assert got.qformat == qformat
        np.testing.assert_array_equal(got.codes, ref.codes)
        assert got.scale == ref.scale and got.offset == ref.offset


def test_blob_v3_header_and_companions(small_index):
    s = BlobStore(small_index["v3"])
    assert s.format == 3
    assert s.quant_format == "int8"
    assert s.q_block_bytes > 0
    _assert_quant_matches_fp(s, "int8")


def test_blob_v3_partial_row_reads(small_index):
    s = BlobStore(small_index["v3"])
    for lv, nd in _leaf_keys(s)[:4]:
        emb, ids = s.get_node(lv, nd)
        n = len(emb)
        rows = np.unique(RNG.integers(0, n, size=max(1, n // 3)))
        pe, pi = s.get_node_rows(lv, nd, rows)
        np.testing.assert_array_equal(pe, emb[rows])
        np.testing.assert_array_equal(pi, ids[rows])
        np.testing.assert_array_equal(s.get_node_ids(lv, nd), ids)


def test_blob_v2_reads_and_upgrade(small_index, tmp_path):
    v2 = BlobStore(small_index["v2"])
    assert v2.format == 2 and v2.quant_format is None
    # v2 has no companions: get_quantized encodes on the fly
    lv, nd = _leaf_keys(v2)[0]
    emb, _ = v2.get_node(lv, nd)
    got = v2.get_quantized(lv, nd, "int8")
    np.testing.assert_array_equal(got.codes, encode_node(emb, "int8").codes)
    # upgrade: convert(v2 blob) with quant writes a v3 blob, fp payload intact
    up = tmp_path / "up.bin"
    convert(small_index["v2"], up, quant="int8")
    v3 = BlobStore(up)
    assert v3.format == 3 and v3.quant_format == "int8"
    for key in _leaf_keys(v2):
        e2, i2 = v2.get_node(*key)
        e3, i3 = v3.get_node(*key)
        np.testing.assert_array_equal(e2, e3)
        np.testing.assert_array_equal(i2, i3)
    _assert_quant_matches_fp(v3, "int8")


def test_fstore_quantized_fallback(small_index):
    ix = open_index(small_index["fs"])
    s = ix.store
    assert s.quant_format is None
    lv, nd = _leaf_keys(s)[0]
    emb, _ = s.get_node(lv, nd)
    got = s.get_quantized(lv, nd, "int8")
    np.testing.assert_array_equal(got.codes, encode_node(emb, "int8").codes)
    (gn,) = s.get_nodes_quantized([(lv, nd)], "float16")
    np.testing.assert_array_equal(gn.decode(), emb)


# --------------------------------------------------- survival under mutation
def test_quant_blocks_survive_mutations(small_index, tmp_path):
    import shutil

    blob = tmp_path / "mut.bin"
    shutil.copy(small_index["v3"], blob)
    dim = small_index["dim"]
    ix = open_index(str(blob))
    rng = np.random.default_rng(9)

    # insert enough rows to force leaf splits (cluster_cap=48)
    res = ix.insert(rng.standard_normal((300, dim)).astype(np.float32))
    assert res["inserted"] == 300
    _assert_quant_matches_fp(ix.store, "int8")

    # delete a third of the original ids (tombstones; fp rows untouched)
    ids0 = np.concatenate([ix.store.get_node(lv, nd)[1] for lv, nd in _leaf_keys(ix.store)])
    victims = ids0[:: 3][:200]
    assert ix.delete(victims) > 0
    _assert_quant_matches_fp(ix.store, "int8")

    # compact rewrites the blob; the quant section must ride along
    ix.compact()
    s = ix.store
    assert s.format == 3 and s.quant_format == "int8"
    _assert_quant_matches_fp(s, "int8")

    # and the index still answers quantized queries bit-identically
    q = rng.standard_normal((4, dim)).astype(np.float32)
    ref = open_index(str(blob)).search(q, 20, b=6)
    got = open_index(str(blob), quantized=True).search(q, 20, b=6)
    np.testing.assert_array_equal(ref.ids, got.ids)
    np.testing.assert_array_equal(ref.dists, got.dists)


# ------------------------------------------------------------- engine parity
@pytest.mark.parametrize("backend", ["fs", "v3"])
def test_quant_bit_parity(small_index, backend):
    dim = small_index["dim"]
    Q = np.random.default_rng(11).standard_normal((12, dim)).astype(np.float32)
    flat = open_index(small_index[backend], engine="flat")
    leg = open_index(small_index[backend], engine="legacy")
    qi = open_index(small_index[backend], engine="flat", quantized=True)
    for k, b in [(10, 4), (50, 8)]:
        r_flat = flat.search(Q, k, b=b)
        r_q = qi.search(Q, k, b=b)
        np.testing.assert_array_equal(r_flat.ids, r_q.ids)
        np.testing.assert_array_equal(r_flat.dists, r_q.dists)
        # warm repeat (row caches promoted to full nodes must not drift)
        r_q2 = qi.search(Q, k, b=b)
        np.testing.assert_array_equal(r_flat.ids, r_q2.ids)
        np.testing.assert_array_equal(r_flat.dists, r_q2.dists)
        # the legacy oracle agrees per-row
        for row in range(len(Q)):
            r_leg = leg.search(Q[row], k, b=b)
            np.testing.assert_array_equal(r_leg.ids, r_q.ids[row])
            np.testing.assert_array_equal(r_leg.dists, r_q.dists[row])


def test_quant_parity_excludes_and_continuation(small_index):
    dim = small_index["dim"]
    rng = np.random.default_rng(13)
    Q = rng.standard_normal((6, dim)).astype(np.float32)
    flat = open_index(small_index["v3"], engine="flat")
    qi = open_index(small_index["v3"], quantized=True, rerank_depth=60)
    probe = flat.search(Q, 10, b=4)
    excl = set(int(i) for i in probe.ids[:, :5].ravel() if i >= 0)
    ra = flat.search(Q, 30, b=6, exclude=excl)
    rz = qi.search(Q, 30, b=6, exclude=excl)
    np.testing.assert_array_equal(ra.ids, rz.ids)
    np.testing.assert_array_equal(ra.dists, rz.dists)
    # continuation drains further increments through the same rerank seam
    na, nz = ra.query.next(30), rz.query.next(30)
    np.testing.assert_array_equal(na.ids, nz.ids)
    np.testing.assert_array_equal(na.dists, nz.dists)


def test_quant_f16_tier_parity(small_index, tmp_path):
    blob = tmp_path / "f16.bin"
    convert(small_index["fs"], blob, quant="float16")
    s = BlobStore(blob)
    assert s.format == 3 and s.quant_format == "float16"
    dim = small_index["dim"]
    Q = np.random.default_rng(17).standard_normal((8, dim)).astype(np.float32)
    ra = open_index(small_index["v2"]).search(Q, 25, b=6)
    rz = open_index(str(blob), quantized=True).search(Q, 25, b=6)
    np.testing.assert_array_equal(ra.ids, rz.ids)
    np.testing.assert_array_equal(ra.dists, rz.dists)


# ----------------------------------------------------- one launch per round
def test_one_device_launch_per_round(small_index, monkeypatch):
    from repro.kernels.distance_topk import ops

    calls = {"n": 0}
    orig = ops.grouped_distance_topk

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(ops, "grouped_distance_topk", counting)
    dim = small_index["dim"]
    Q = np.random.default_rng(19).standard_normal((8, dim)).astype(np.float32)
    qi = open_index(small_index["v3"], quantized=True)
    r = qi.search(Q, 40, b=8)
    st = r.query.batch_stats
    assert calls["n"] >= 1
    # THE acceptance contract: one grouped launch per leaf-bearing round
    assert calls["n"] == st.kernel_launches
    assert st.kernel_launches <= st.rounds


# ------------------------------------------------------- scorer + pinning
def test_kernel_scorer_shape_bucketing():
    scorer = make_kernel_scorer(min_rows=1, impl="ref", bucket=128)
    q = RNG.standard_normal(16).astype(np.float32)
    for n in (40, 77, 100, 128):  # heterogeneous leaves, one bucket
        emb = RNG.standard_normal((n, 16)).astype(np.float32)
        d = scorer(q, emb, "l2")
        np.testing.assert_allclose(d, np_distances(q, emb, "l2"), rtol=1e-5, atol=1e-5)
    assert scorer.compile_shapes == {(128, 128)}
    scorer(q, RNG.standard_normal((200, 16)).astype(np.float32), "l2")
    assert scorer.compile_shapes == {(128, 128), (256, 256)}


def test_pin_internal_zero_warm_internal_reads(small_index):
    ix = open_index(small_index["v3"], quantized=True, pin_internal=True)
    assert ix.cache.n_pinned > 0
    Q = np.random.default_rng(23).standard_normal((6, small_index["dim"]))
    Q = Q.astype(np.float32)
    ix.search(Q, 20, b=6)
    before = ix.store.io.internal_reads
    ix.search(Q, 20, b=6)
    assert ix.store.io.internal_reads == before


def test_quantized_rejects_legacy_engine(small_index):
    with pytest.raises(ValueError):
        ECPIndex(small_index["v3"], engine="legacy", quantized=True)
