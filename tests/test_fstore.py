"""fstore: zarr-v2 layout round trips, partial reads, transparency."""
import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.fstore import FStore


@pytest.fixture()
def store(tmp_path):
    return FStore(tmp_path / "s", create=True)


def test_roundtrip_basic(store):
    a = np.arange(100, dtype=np.float32).reshape(20, 5)
    store.write_array("x/y", a, chunk_rows=7)
    b = store.read_array("x/y")
    np.testing.assert_array_equal(a, b)


def test_layout_is_transparent(store, tmp_path):
    """The on-disk layout is plain JSON + raw chunks (the paper's point)."""
    a = np.arange(12, dtype="<i4").reshape(3, 4)
    store.write_array("arr", a, chunk_rows=2)
    meta = json.loads((tmp_path / "s/arr/.zarray").read_text())
    assert meta["zarr_format"] == 2
    assert meta["compressor"] is None
    assert meta["shape"] == [3, 4]
    assert meta["chunks"] == [2, 4]
    raw = (tmp_path / "s/arr/0.0").read_bytes()
    assert np.frombuffer(raw, "<i4").reshape(2, 4).tolist() == a[:2].tolist()


def test_partial_read(store):
    a = np.random.default_rng(0).normal(size=(100, 8)).astype(np.float32)
    store.write_array("a", a, chunk_rows=16)
    np.testing.assert_array_equal(store.read_rows("a", 10, 35), a[10:35])
    np.testing.assert_array_equal(store.read_rows("a", 96, 200), a[96:])


def test_empty_and_scalar(store):
    store.write_array("e", np.zeros((0, 4), np.float16))
    assert store.read_array("e").shape == (0, 4)
    store.write_array("s", np.asarray([7], np.int64))
    assert store.read_array("s")[0] == 7


def test_zero_row_roundtrip_all_shapes(store):
    """rows == 0 exercises the final-chunk padding path: the writer still
    emits one (padded) chunk and the reader must slice back to 0 rows."""
    for name, arr in (
        ("z1", np.zeros((0,), np.int32)),
        ("z2", np.zeros((0, 3), np.float32)),
        ("z3", np.zeros((0, 2, 5), np.float16)),
    ):
        store.write_array(name, arr, chunk_rows=4)
        back = store.read_array(name)
        assert back.shape == arr.shape and back.dtype == arr.dtype
        meta = store.array_meta(name)
        assert meta["shape"][0] == 0 and meta["chunks"][0] == 1
        # partial reads of an empty array are empty, not an error
        assert store.read_rows(name, 0, 10).shape[0] == 0


def test_read_rows_reads_only_needed_bytes(store):
    """A 2-row read from a large chunked array must not materialize whole
    chunks (satellite: slice at the file level, not post-concatenate)."""
    from repro.core.store import IOStats

    a = np.random.default_rng(1).normal(size=(10_000, 16)).astype(np.float32)
    store.write_array("big", a, chunk_rows=5_000)
    store.io = IOStats()
    got = store.read_rows("big", 4_998, 5_000)  # 2 rows, last rows of chunk 0
    np.testing.assert_array_equal(got, a[4_998:5_000])
    row_bytes = 16 * 4
    # json metadata + exactly 2 rows — far below one 5000-row chunk
    assert store.io.bytes_read < 2 * row_bytes + 4_096
    store.io = IOStats()
    got = store.read_rows("big", 4_999, 5_001)  # straddles the chunk boundary
    np.testing.assert_array_equal(got, a[4_999:5_001])
    assert store.io.bytes_read < 2 * row_bytes + 4_096


def test_attrs_groups(store):
    store.create_group("g", attrs={"metric": "l2", "levels": 3})
    assert store.read_attrs("g")["levels"] == 3
    assert store.is_group("g") and not store.is_array("g")


def test_escape_rejected(store):
    with pytest.raises(ValueError):
        store.read_array("../../etc/passwd")


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 50),
    cols=st.integers(1, 8),
    chunk=st.integers(1, 60),
    dt=st.sampled_from(["float32", "float16", "int32", "int64"]),
)
def test_roundtrip_property(tmp_path_factory, rows, cols, chunk, dt):
    store = FStore(tmp_path_factory.mktemp("fs") / "s", create=True)
    rng = np.random.default_rng(rows * 100 + cols)
    a = (rng.normal(size=(rows, cols)) * 100).astype(dt)
    store.write_array("a", a, chunk_rows=chunk)
    np.testing.assert_array_equal(store.read_array("a"), a)
    lo = min(rows - 1, chunk)
    np.testing.assert_array_equal(store.read_rows("a", lo, rows), a[lo:])
