"""Data pipeline: determinism, sampler correctness."""
import numpy as np

from repro.data import CSRGraph, ctr_batch, lm_batch, random_graph, sample_hops


def test_lm_batch_deterministic():
    a = lm_batch(0, 5, batch=4, seq=16, vocab=100)
    b = lm_batch(0, 5, batch=4, seq=16, vocab=100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm_batch(0, 6, batch=4, seq=16, vocab=100)
    assert not np.array_equal(a["tokens"], c["tokens"])
    d = lm_batch(0, 5, batch=4, seq=16, vocab=100, shard=1)
    assert not np.array_equal(a["tokens"], d["tokens"])


def test_ctr_batch_fields_in_vocab():
    b = ctr_batch(0, 0, batch=32, field_vocabs=(50, 20, 10), n_dense=3, seq_len=5, seq_fields=1)
    assert b["cat"].shape == (32, 2)
    assert b["cat"][:, 0].max() < 20 and b["cat"][:, 1].max() < 10
    assert b["seq"].max() < 50
    assert b["seq_mask"].sum(axis=1).min() >= 1


def test_csr_and_sampler():
    g = random_graph(0, n_nodes=100, n_edges=500, d_feat=8, n_classes=4)
    csr = CSRGraph(100, g["edge_src"], g["edge_dst"])
    assert csr.ptr[-1] == 500
    # neighbors of v are exactly the srcs of edges into v
    v = int(g["edge_dst"][0])
    expect = sorted(g["edge_src"][g["edge_dst"] == v].tolist())
    assert sorted(csr.neighbors(v).tolist()) == expect
    rng = np.random.default_rng(0)
    seeds = np.arange(10)
    hops = sample_hops(csr, g["feats"], seeds, (4, 3), rng)
    assert hops[0].shape == (10, 4, 3, 8)
    assert hops[1].shape == (10, 4, 8)
    assert hops[2].shape == (10, 8)
    np.testing.assert_array_equal(hops[2], g["feats"][seeds])


def test_sampled_neighbors_are_real_neighbors():
    g = random_graph(1, n_nodes=50, n_edges=300, d_feat=4, n_classes=2)
    csr = CSRGraph(50, g["edge_src"], g["edge_dst"])
    rng = np.random.default_rng(1)
    seeds = np.asarray([int(g["edge_dst"][0])])
    from repro.data.graph import _sample_neighbors

    nbrs = _sample_neighbors(csr, seeds, 8, rng)
    real = set(csr.neighbors(seeds[0]).tolist())
    assert set(nbrs[0].tolist()) <= real | {seeds[0]}
