"""Unified Searcher API: query-handle lifecycle, persistence roundtrip,
file-vs-batched parity, and the shared byte-budget MultiIndexSession."""
import numpy as np
import pytest

from repro.core import (
    ECPBuildConfig,
    ECPIndex,
    MultiIndexSession,
    NodeCache,
    QueryClosedError,
    ResultSet,
    Searcher,
    build_index,
    open_index,
)
from repro.data import clustered_vectors


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    data, _ = clustered_vectors(3, n=6000, dim=32, n_clusters=48)
    path = tmp_path_factory.mktemp("api_idx") / "ecp"
    build_index(data, str(path), ECPBuildConfig(levels=2, metric="l2", cluster_cap=64, seed=0))
    return data, str(path)


# ------------------------------------------------------------ protocol shape
def test_every_searcher_speaks_the_protocol(built):
    data, path = built
    from repro.core.baselines import BruteForce, IVFIndex

    for s in (
        open_index(path, mode="file"),
        open_index(path, mode="packed"),
        BruteForce(data),
        IVFIndex(data, n_lists=16, train_iters=3),
    ):
        assert isinstance(s, Searcher)
        rs = s.search(data[5], k=4, b=8)
        assert isinstance(rs, ResultSet)
        assert rs.ids.shape == (4,) and rs.dists.shape == (4,)
        assert rs.query is not None
        rs2 = s.search(data[:3], k=4, b=8)
        assert rs2.ids.shape == (3, 4)


def test_open_index_auto_and_bad_mode(built):
    _, path = built
    s = open_index(path, mode="auto")  # cpu test env -> file mode
    assert isinstance(s, ECPIndex)
    with pytest.raises(ValueError):
        open_index(path, mode="nope")


# ------------------------------------------------------- handle lifecycle
def test_query_lifecycle_next_close_closed_error(built):
    data, path = built
    idx = open_index(path, mode="file")
    rs = idx.search(data[10], k=8, b=4)
    first = set(rs.row_ids(0))
    more = rs.query.next(8)
    assert not (first & set(more.row_ids(0))), "next() re-emitted items"
    rs.query.close()
    assert rs.query.closed
    with pytest.raises(QueryClosedError):
        rs.query.next(8)
    with pytest.raises(QueryClosedError):
        rs.query.save()
    # closing twice is fine; state is gone, not a None hole
    rs.query.close()


def test_batched_query_lifecycle(built):
    data, path = built
    bs = open_index(path, mode="packed")
    rs = bs.search(data[:4], k=5, b=16)
    more = rs.query.next(5)
    for r in range(4):
        assert not (set(rs.row_ids(r)) & set(more.row_ids(r)))
    rs.query.close()
    with pytest.raises(QueryClosedError):
        rs.query.next(5)


# ---------------------------------------------------------- persistence
def test_save_load_roundtrip_preserves_frontier(built):
    data, path = built
    idx = open_index(path, mode="file")
    rs = idx.search(data[21], k=10, b=4)
    rs.query.next(10)                      # advance the frontier a bit
    token = rs.query.save(name="roundtrip")
    fresh = open_index(path, mode="file")  # completely fresh instance
    resumed = fresh.load_query(token)
    a = rs.query.next(10).pairs()
    b = resumed.next(10).pairs()
    assert [i for _, i in a] == [i for _, i in b]
    # loaded state carries the same b/emitted bookkeeping
    assert resumed.state.b == rs.query.state.b
    assert resumed.state.emitted == rs.query.state.emitted


def test_save_batch_roundtrip(built):
    data, path = built
    idx = open_index(path, mode="file")
    rs = idx.search(data[:3], k=6, b=4)
    token = rs.query.save()
    resumed = open_index(path, mode="file").load_query(token)
    a = rs.query.next(6)
    b = resumed.next(6)
    np.testing.assert_array_equal(a.ids, b.ids)


# --------------------------------------------------------------- parity
def test_file_vs_batched_parity(built):
    """Same dataset, same queries: file mode and packed mode agree on k-NN."""
    data, path = built
    idx = open_index(path, mode="file")
    bs = open_index(path, mode="packed")
    rng = np.random.default_rng(11)
    Q = data[rng.integers(0, len(data), 6)]
    w = idx.info.nodes_per_level[0]
    rsb = bs.search(Q, k=5, b=64, b_internal=w)
    for r in range(len(Q)):
        host = idx.search(Q[r], k=5, b=64)
        assert host.row_ids(0) == list(rsb.ids[r]), f"row {r}"


# ------------------------------------------------------- shared cache
def test_node_cache_byte_budget():
    c = NodeCache(max_bytes=10_000)
    for j in range(20):
        c.put(("ns", 1, j), (np.zeros((10, 32), np.float32), np.zeros((10,), np.int64)))
    assert c.resident_bytes <= 10_000
    assert c.evictions > 0
    c.resize(max_bytes=2_000)
    assert c.resident_bytes <= 2_000
    c.resize(max_bytes=0)


def _entry(rows=10, dim=32):
    return (np.zeros((rows, dim), np.float32), np.zeros((rows,), np.int64))


def test_node_cache_zero_and_negative_budgets():
    """Budget <= 0 means caching off: puts are dropped, nothing wedges."""
    for budget in (0, -1, -10_000):
        c = NodeCache(max_bytes=budget)
        c.put(("ns", 1, 0), _entry())
        assert c.n_resident == 0 and c.resident_bytes == 0
        assert c.get(("ns", 1, 0)) is None  # miss, not a crash
    c = NodeCache(max_nodes=-3)
    c.put(("ns", 1, 0), _entry())
    assert c.n_resident == 0
    # resizing to a negative budget behaves like 0 (evict all, caching off)
    c2 = NodeCache(max_bytes=10_000)
    c2.put(("ns", 1, 0), _entry())
    c2.resize(max_bytes=-5)
    assert c2.n_resident == 0
    c2.put(("ns", 1, 1), _entry())
    assert c2.n_resident == 0


def test_node_cache_entry_larger_than_whole_budget():
    """An entry that alone exceeds the budget must not wedge the cache: it
    is evicted immediately and later puts still work."""
    c = NodeCache(max_bytes=1_000)
    c.put(("ns", 1, 0), _entry(rows=100))         # ~40 KB >> 1 KB budget
    assert c.n_resident == 0 and c.resident_bytes == 0
    c.put(("ns", 1, 1), _entry(rows=2))           # small entry fits
    assert c.n_resident == 1
    assert c.get(("ns", 1, 1)) is not None


def test_node_cache_resize_below_residency_evicts():
    c = NodeCache(max_bytes=1 << 20)
    for j in range(8):
        c.put(("ns", 1, j), _entry())
    full = c.resident_bytes
    assert c.n_resident == 8 and full > 0
    c.resize(max_bytes=full // 4)                  # shrink below residency
    assert c.resident_bytes <= full // 4
    assert c.evictions > 0
    # LRU: the survivors are the most recently inserted keys
    assert c.contains(("ns", 1, 7))
    assert not c.contains(("ns", 1, 0))
    # still fully functional after the shrink
    c.put(("ns", 1, 99), _entry(rows=1))
    assert c.get(("ns", 1, 99)) is not None


def test_multi_index_session_respects_shared_budget(built, tmp_path_factory):
    data, path = built
    data2, _ = clustered_vectors(9, n=6000, dim=32, n_clusters=48)
    path2 = str(tmp_path_factory.mktemp("api_idx2") / "ecp2")
    build_index(data2, path2, ECPBuildConfig(levels=2, metric="l2", cluster_cap=64, seed=1))

    budget = 200_000
    sess = MultiIndexSession(cache_bytes=budget)
    a = sess.open(path, name="a")
    b = sess.open(path2, name="b")
    assert a.cache is sess.cache and b.cache is sess.cache
    rng = np.random.default_rng(4)
    for t in range(12):
        ra = a.search(data[rng.integers(0, len(data))], k=5, b=8)
        rb = b.search(data2[rng.integers(0, len(data2))], k=5, b=8)
        assert len(ra.row_ids(0)) == 5 and len(rb.row_ids(0)) == 5
        assert sess.cache.resident_bytes <= budget
    st = sess.stats()
    assert st["evictions"] > 0, "budget never forced an eviction"
    assert set(st["per_index"]) == {"a", "b"}
    assert st["resident_bytes"] <= budget

    # fleet-wide live resize (paper §4.2 knob)
    sess.resize(cache_bytes=budget // 4)
    assert sess.cache.resident_bytes <= budget // 4
    # both indexes still answer correctly under the tighter budget
    assert a.search(data[42], k=1, b=8).ids[0] == 42
    assert b.search(data2[7], k=1, b=8).ids[0] == 7
    sess.close()
    assert sess.cache.n_resident == 0


def test_session_name_collision_and_lookup(built):
    _, path = built
    sess = MultiIndexSession(cache_bytes=1 << 20)
    sess.open(path, name="x")
    assert "x" in sess and sess.names() == ["x"]
    assert sess["x"] is sess._indexes["x"]
    with pytest.raises(ValueError):
        sess.open(path, name="x")
