"""Per-(arch x shape) smoke: every one of the 40 assigned cells at reduced
scale runs a REAL step on CPU (same cell-builder code path the dry-run
lowers) with finite outputs and correct shapes."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_CELLS, ALL_ARCHS, arch_shapes
from repro.launch.cells import build_cell, example_inputs


def test_cell_coverage_is_40():
    assert len(ALL_CELLS) == 40
    assert len(ALL_ARCHS) == 10


@pytest.mark.parametrize("arch,shape", ALL_CELLS, ids=[f"{a}-{s}" for a, s in ALL_CELLS])
def test_reduced_cell_runs_finite(arch, shape):
    cell = build_cell(arch, shape, mesh_axes=None, reduced=True)
    args = example_inputs(cell)
    out = cell.fn(*args)
    for leaf in jax.tree.leaves(out):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.all(jnp.isfinite(leaf))), f"non-finite in {arch}/{shape}"
    if cell.kind == "train":
        params, opt_state, metrics = out
        assert float(metrics["loss"]) > 0
        assert int(opt_state["step"]) == 1
        # params actually moved
        before = jax.tree.leaves(args[0])
        after = jax.tree.leaves(params)
        moved = any(bool(jnp.any(a != b)) for a, b in zip(after, before))
        assert moved, "optimizer produced a no-op update"
