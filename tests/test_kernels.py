"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.distance_topk import (
    distance_topk_pallas,
    distance_topk_ref,
    grouped_distance_topk_pallas,
    grouped_distance_topk_ref,
)
from repro.kernels.flash_attention import flash_attention_pallas, mha_ref

RNG = np.random.default_rng(0)


# ------------------------------------------------------------ distance_topk
@pytest.mark.parametrize("metric", ["l2", "ip", "cosine"])
@pytest.mark.parametrize(
    "B,N,D,k,bq,bn",
    [
        (4, 256, 64, 8, 64, 128),
        (130, 1000, 128, 16, 128, 128),   # non-divisible B and N
        (1, 64, 32, 64, 8, 64),           # k == N
        (16, 512, 256, 32, 64, 256),
    ],
)
def test_distance_topk_matches_ref(metric, B, N, D, k, bq, bn):
    q = jnp.asarray(RNG.normal(size=(B, D)), jnp.float32)
    c = jnp.asarray(RNG.normal(size=(N, D)), jnp.float32)
    d1, i1 = distance_topk_pallas(q, c, k, metric, bq=bq, bn=bn, interpret=True)
    d0, i0 = distance_topk_ref(q, c, k, metric)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_distance_topk_dtypes(dtype):
    q = jnp.asarray(RNG.normal(size=(8, 64)), dtype)
    c = jnp.asarray(RNG.normal(size=(300, 64)), dtype)
    d1, i1 = distance_topk_pallas(q, c, 10, "l2", bq=8, bn=128, interpret=True)
    d0, i0 = distance_topk_ref(q.astype(jnp.float32), c.astype(jnp.float32), 10, "l2")
    # low precision inputs: compare distances loosely, ids by recall
    rec = np.mean(
        [len(set(np.asarray(i0)[r]) & set(np.asarray(i1)[r])) / 10 for r in range(8)]
    )
    assert rec >= 0.9


@settings(max_examples=15, deadline=None)
@given(
    B=st.integers(1, 17),
    N=st.integers(8, 300),
    D=st.integers(4, 96),
    metric=st.sampled_from(["l2", "ip"]),
    data=st.data(),
)
def test_distance_topk_property(B, N, D, metric, data):
    k = data.draw(st.integers(1, min(N, 32)))
    seed = data.draw(st.integers(0, 2**31))
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(size=(B, D)), jnp.float32)
    c = jnp.asarray(r.normal(size=(N, D)), jnp.float32)
    d1, i1 = distance_topk_pallas(q, c, k, metric, bq=8, bn=64, interpret=True)
    d0, i0 = distance_topk_ref(q, c, k, metric)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-3, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


# -------------------------------------------------- grouped quantized top-k
def _make_groups(G, N, D, qformat, seed=0, short=False):
    r = np.random.default_rng(seed)
    from repro.core.quant import encode_node, qdtype

    codes = np.zeros((G, N, D), qdtype(qformat))
    scales = np.zeros(G, np.float32)
    offsets = np.zeros(G, np.float32)
    n_rows = r.integers(1, N + 1, size=G) if short else np.full(G, N)
    for g in range(G):
        emb = r.normal(size=(int(n_rows[g]), D)).astype(np.float32)
        qn = encode_node(emb, qformat)
        codes[g, : qn.n_rows] = qn.codes
        scales[g], offsets[g] = qn.scale, qn.offset
    q = r.normal(size=(G, D)).astype(np.float32)
    return q, codes, scales, offsets, n_rows.astype(np.int32)


@pytest.mark.parametrize("metric", ["l2", "ip", "cosine"])
@pytest.mark.parametrize("qformat", ["int8", "float16"])
def test_grouped_topk_matches_ref(metric, qformat):
    q, codes, scales, offsets, nr = _make_groups(7, 96, 24, qformat, seed=5)
    k = 16
    d0, i0 = grouped_distance_topk_ref(q, codes, scales, offsets, nr, k, metric, qformat)
    d1, i1 = grouped_distance_topk_pallas(
        q, codes, scales, offsets, nr, k, metric, qformat, bn=32, interpret=True
    )
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_grouped_topk_short_groups_and_nondivisible_bn():
    # ragged valid counts, k larger than some groups, N not a bn multiple
    q, codes, scales, offsets, nr = _make_groups(9, 70, 16, "int8", seed=6, short=True)
    k = 48
    d0, i0 = grouped_distance_topk_ref(q, codes, scales, offsets, nr, k, "l2")
    d1, i1 = grouped_distance_topk_pallas(
        q, codes, scales, offsets, nr, k, "l2", bn=32, interpret=True
    )
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    # groups with fewer than k valid rows pad with (inf, -1)
    for g in range(len(nr)):
        assert np.all(np.asarray(i1)[g, int(nr[g]) :] == -1)
        assert np.all(np.isinf(np.asarray(d1)[g, int(nr[g]) :]))


def test_grouped_topk_empty_and_zero_rows():
    d, i = grouped_distance_topk_ref(
        np.zeros((0, 8), np.float32),
        np.zeros((0, 16, 8), np.int8),
        np.zeros(0, np.float32),
        np.zeros(0, np.float32),
        np.zeros(0, np.int32),
        4,
        "l2",
    )
    assert d.shape == (0, 4) and i.shape == (0, 4)
    # a group whose leaf is entirely past n_rows comes back all-invalid
    q, codes, scales, offsets, nr = _make_groups(3, 32, 8, "int8", seed=7)
    nr = nr.copy()
    nr[1] = 0
    d0, i0 = grouped_distance_topk_ref(q, codes, scales, offsets, nr, 8, "l2")
    d1, i1 = grouped_distance_topk_pallas(
        q, codes, scales, offsets, nr, 8, "l2", bn=32, interpret=True
    )
    assert np.all(i0[1] == -1) and np.all(np.isinf(d0[1]))
    np.testing.assert_array_equal(i0, np.asarray(i1))


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Skv,d,causal,lens",
    [
        (2, 4, 2, 128, 128, 64, True, None),
        (2, 4, 4, 128, 128, 64, False, None),
        (1, 8, 2, 64, 256, 32, True, None),      # chunked prefill
        (2, 4, 2, 1, 192, 64, True, (100, 192)),  # ragged decode
        (2, 2, 1, 100, 100, 64, True, None),      # non-divisible seq
        (1, 2, 2, 256, 256, 128, True, None),     # MXU-aligned d
    ],
)
def test_flash_attention_matches_ref(B, Hq, Hkv, Sq, Skv, d, causal, lens):
    q = jnp.asarray(RNG.normal(size=(B, Hq, Sq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Skv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Skv, d)), jnp.float32)
    kv_lens = None if lens is None else jnp.asarray(lens, jnp.int32)
    o1 = flash_attention_pallas(q, k, v, kv_lens=kv_lens, causal=causal, bq=64, bk=64, interpret=True)
    o0 = mha_ref(q, k, v, causal=causal, kv_lens=kv_lens)
    np.testing.assert_allclose(np.asarray(o0), np.asarray(o1), rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    o1 = flash_attention_pallas(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    o0 = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o0), np.asarray(o1), rtol=2e-2, atol=2e-2)


def test_flash_attention_numerics_extreme():
    """Large logits must not overflow the online softmax."""
    q = 30.0 * jnp.ones((1, 1, 64, 32), jnp.float32)
    k = 30.0 * jnp.ones((1, 1, 64, 32), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 1, 64, 32)), jnp.float32)
    o1 = flash_attention_pallas(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    assert bool(jnp.all(jnp.isfinite(o1)))
