"""eCP index: build invariants, cost model, incremental search semantics —
all through the unified Searcher/ResultSet/Query API."""
import numpy as np
import pytest

from repro.core import (
    ECPBuildConfig,
    build_index,
    derive_shape,
    load_packed,
    open_index,
)
from repro.core import layout
from repro.core.baselines import BruteForce
from repro.data import clustered_vectors


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    data, _ = clustered_vectors(0, n=8000, dim=32, n_clusters=64)
    path = tmp_path_factory.mktemp("idx") / "ecp"
    cfg = ECPBuildConfig(levels=2, metric="l2", cluster_cap=64, seed=0)
    store = build_index(data, str(path), cfg)
    return data, str(path), store


def test_cost_model_paper_example():
    """Paper §3: N=1M, V=2304B, C=128KB -> l~17544, w~26."""
    cap = 131072 // 2304  # 56 vectors
    l, w, nodes = derive_shape(1_000_000, cap, 3)
    assert l == -(-1_000_000 // cap)
    assert w == 27  # ceil(17858^(1/3)); paper rounds to 26 with l=17544
    assert nodes[-1] == l


def test_all_items_indexed_exactly_once(built):
    data, path, store = built
    info = layout.IndexInfo.from_attrs(store.read_attrs(layout.INFO))
    seen = []
    for j in range(info.n_leaders):
        ids = store.read_array(layout.node_ids(info.levels, j))
        seen.extend(ids.tolist())
    assert sorted(seen) == list(range(len(data)))


def test_leaf_embeddings_match_items(built):
    data, path, store = built
    info = layout.IndexInfo.from_attrs(store.read_attrs(layout.INFO))
    j = 0
    ids = store.read_array(layout.node_ids(info.levels, j))
    emb = store.read_array(layout.node_emb(info.levels, j))
    np.testing.assert_allclose(
        emb.astype(np.float32), data[ids].astype(np.float16).astype(np.float32)
    )


def test_internal_children_partition_leaders(built):
    _, _, store = built
    info = layout.IndexInfo.from_attrs(store.read_attrs(layout.INFO))
    for lv in range(1, info.levels):
        child_ids = []
        for j in range(info.nodes_per_level[lv - 1]):
            child_ids.extend(store.read_array(layout.node_ids(lv, j)).tolist())
        assert sorted(child_ids) == list(range(info.nodes_per_level[lv]))


def test_search_exact_hit(built):
    data, path, _ = built
    idx = open_index(path, mode="file")
    rs = idx.search(data[42], k=5, b=8)
    assert rs.ids[0] == 42
    assert rs.dists[0] < 1e-2


def test_incremental_no_duplicates_and_sorted(built):
    data, path, _ = built
    idx = open_index(path, mode="file")
    rs = idx.search(data[7], k=50, b=4)
    pairs = rs.pairs()
    all_items = [i for _, i in pairs]
    all_d = [d for d, _ in pairs]
    for _ in range(5):
        more = rs.query.next(50).pairs()
        if not more:
            break
        all_items.extend(i for _, i in more)
        all_d.extend(d for d, _ in more)
    assert len(all_items) == len(set(all_items)), "incremental emitted a duplicate"
    # distances non-decreasing within each emission batch by construction;
    # the concatenated stream is globally sorted because I stays sorted
    assert all_d == sorted(all_d)


def test_incremental_matches_single_big_search(built):
    """Query.next continuation == one big search (same b schedule)."""
    data, path, _ = built
    q = data[3] + 0.01
    rs1 = open_index(path, mode="file").search(q, k=30, b=64, mx_inc=0)
    rs2 = open_index(path, mode="file").search(q, k=10, b=64, mx_inc=0)
    stream = list(rs2.pairs())
    while len(stream) < 30:
        nxt = rs2.query.next(10).pairs()
        if not nxt:
            break
        stream.extend(nxt)
    assert [i for _, i in rs1.pairs()] == [i for _, i in stream[:30]]


def test_recall_reasonable_on_clustered_data(built):
    data, path, _ = built
    idx = open_index(path, mode="file")
    bf = BruteForce(data, "l2")
    rng = np.random.default_rng(5)
    qs = data[rng.integers(0, len(data), 20)] + 0.01 * rng.normal(size=(20, 32)).astype(np.float32)
    recalls = []
    for q in qs:
        got = set(idx.search(q, k=10, b=16).row_ids(0))
        gt = set(bf.search(q, 10).row_ids(0))
        recalls.append(len(gt & got) / 10)
    assert np.mean(recalls) >= 0.6, f"recall {np.mean(recalls)}"


def test_filter_exclude_triggers_expansion(built):
    """Paper §4.3 'Internal' case: filters shrink results; b doubles."""
    data, path, _ = built
    idx = open_index(path, mode="file")
    rs0 = idx.search(data[9], k=20, b=2, mx_inc=0)
    exclude = set(rs0.row_ids(0))
    rs = idx.search(data[9], k=20, b=2, mx_inc=4, exclude=exclude)
    got = set(rs.row_ids(0))
    assert not (got & exclude)
    assert rs.query.stats.increments > 0 or len(rs) == 20


def test_lru_cache_bound(built):
    data, path, _ = built
    idx = open_index(path, mode="file", cache_max_nodes=4)
    for i in range(10):
        idx.search(data[i * 100], k=10, b=8)
    assert idx.cache.n_resident <= 4
    assert idx.cache.evictions > 0


def test_cache_off_frees_everything(built):
    data, path, _ = built
    idx = open_index(path, mode="file", cache_max_nodes=0)
    idx.search(data[0], k=10, b=4)
    assert idx.cache.n_resident == 0


def test_prefetch_warms_cache(built):
    data, path, _ = built
    idx = open_index(path, mode="file")
    idx.prefetch(up_to_level=1)
    assert idx.cache.n_resident == idx.info.nodes_per_level[0]
    loads_before = idx.load_node_count
    rs = idx.search(data[1], k=5, b=2)
    # level-1 nodes already resident: only leaf loads remain
    assert idx.load_node_count - loads_before <= rs.query.stats.leaves_opened + 2


def test_query_state_persistence(built):
    data, path, _ = built
    idx = open_index(path, mode="file")
    rs = idx.search(data[11], k=10, b=4)
    token = rs.query.save()
    idx2 = open_index(path, mode="file")
    q2 = idx2.load_query(token)
    more2 = q2.next(10)
    more1 = rs.query.next(10)
    assert [i for _, i in more1.pairs()] == [i for _, i in more2.pairs()]


def test_batched_matches_host_on_first_k(built):
    data, path, store = built
    packed = load_packed(store)
    bs = open_index(path, mode="packed")
    rng = np.random.default_rng(3)
    Q = data[rng.integers(0, len(data), 8)]
    rsb = bs.search(Q, k=5, b=64, b_internal=packed.info.nodes_per_level[0])
    idx = open_index(path, mode="file")
    for r in range(8):
        host = idx.search(Q[r], k=5, b=64)
        assert host.row_ids(0) == list(rsb.ids[r]), f"row {r}"


def test_distance_calc_cost_model(built):
    """Expanded-search cost (paper §3): w + (L-1)*b*w + b*cap, within 2x."""
    data, path, _ = built
    idx = open_index(path, mode="file")
    b = 4
    rs = idx.search(data[77], k=5, b=b, mx_inc=0)
    st = rs.query.stats
    info = idx.info
    w = info.nodes_per_level[0]
    cap = info.cluster_cap
    predicted = w + (info.levels - 1) * b * w + b * cap
    assert st.distance_calcs <= 2 * predicted + info.fanout * 4
