"""Vectorized traversal engine vs the legacy reference: bit-identical
results on both storage backends, single and batch, with exclude,
continuation, and mid-traversal persistence (core/search.py parity suite)."""
import numpy as np
import pytest

from repro.core import (
    ECPBuildConfig,
    build_index,
    convert,
    make_kernel_scorer,
    open_index,
)

N, DIM = 6000, 24
BACKENDS = ("fstore", "blob")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    from repro.data import clustered_vectors

    data, _ = clustered_vectors(0, n=N, dim=DIM, n_clusters=48)
    root = tmp_path_factory.mktemp("parity")
    path = str(root / "ecp")
    build_index(data, path, ECPBuildConfig(levels=2, metric="l2", cluster_cap=64, seed=0))
    blob = str(convert(path, root / "ecp.blob"))
    rng = np.random.default_rng(7)
    queries = (
        data[rng.integers(0, N, 16)]
        + 0.01 * rng.normal(size=(16, DIM)).astype(np.float32)
    ).astype(np.float32)
    return data, {"fstore": path, "blob": blob}, queries


def _open(paths, backend, **kw):
    return open_index(paths[backend], mode="file", backend=backend, **kw)


def _assert_same(a, b, msg=""):
    np.testing.assert_array_equal(a.ids, b.ids, err_msg=f"{msg}: ids")
    np.testing.assert_array_equal(a.dists, b.dists, err_msg=f"{msg}: dists")


@pytest.mark.parametrize("backend", BACKENDS)
def test_single_query_bit_identical(built, backend):
    _, paths, queries = built
    flat = _open(paths, backend)
    leg = _open(paths, backend, engine="legacy")
    for q in queries[:8]:
        _assert_same(flat.search(q, k=20, b=4), leg.search(q, k=20, b=4), backend)


@pytest.mark.parametrize("backend", BACKENDS)
def test_batch_bit_identical_to_independent_rows(built, backend):
    _, paths, queries = built
    flat = _open(paths, backend)
    leg = _open(paths, backend, engine="legacy")
    rb = flat.search(queries, k=25, b=4)
    assert rb.batched and rb.ids.shape == (len(queries), 25)
    for r, q in enumerate(queries):
        rl = leg.search(q, k=25, b=4)
        np.testing.assert_array_equal(rb.ids[r], rl.ids, err_msg=f"{backend} row {r}")
        np.testing.assert_array_equal(rb.dists[r], rl.dists, err_msg=f"{backend} row {r}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_continuation_stream_bit_identical(built, backend):
    _, paths, queries = built
    flat = _open(paths, backend)
    leg = _open(paths, backend, engine="legacy")
    rf = flat.search(queries[0], k=10, b=4)
    rl = leg.search(queries[0], k=10, b=4)
    _assert_same(rf, rl, backend)
    for i in range(4):
        _assert_same(rf.query.next(15), rl.query.next(15), f"{backend} next#{i}")


def test_batch_continuation_bit_identical(built):
    _, paths, queries = built
    flat = _open(paths, "blob")
    leg = _open(paths, "blob", engine="legacy")
    rb = flat.search(queries, k=10, b=4)
    nb = rb.query.next(20)
    for r, q in enumerate(queries):
        rl = leg.search(q, k=10, b=4)
        nl = rl.query.next(20)
        np.testing.assert_array_equal(nb.ids[r], nl.ids, err_msg=f"row {r}")
        np.testing.assert_array_equal(nb.dists[r], nl.dists, err_msg=f"row {r}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_exclude_bit_identical(built, backend):
    _, paths, queries = built
    flat = _open(paths, backend)
    leg = _open(paths, backend, engine="legacy")
    q = queries[1]
    exclude = set(flat.search(q, k=20, b=2, mx_inc=0).row_ids(0))
    rf = flat.search(q, k=20, b=2, mx_inc=4, exclude=exclude)
    rl = leg.search(q, k=20, b=2, mx_inc=4, exclude=exclude)
    _assert_same(rf, rl, backend)
    assert not (set(rf.row_ids(0)) & exclude)


def test_save_load_roundtrip_mid_traversal(built):
    """fstore only: state persistence requires the writable hierarchy."""
    _, paths, queries = built
    flat = _open(paths, "fstore")
    rf = flat.search(queries[2], k=10, b=4)
    token = rf.query.save()
    resumed = _open(paths, "fstore").load_query(token)
    a = rf.query.next(12)
    b = resumed.next(12)
    _assert_same(a, b, "resumed")
    # and both match the legacy engine's continuation of the same query
    rl = _open(paths, "fstore", engine="legacy").search(queries[2], k=10, b=4)
    _assert_same(a, rl.query.next(12), "vs legacy")


def test_save_load_batch_roundtrip(built):
    _, paths, queries = built
    flat = _open(paths, "fstore")
    rb = flat.search(queries[:4], k=8, b=4)
    token = rb.query.save()
    resumed = _open(paths, "fstore").load_query(token)
    a = rb.query.next(10)
    b = resumed.next(10)
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(a.dists, b.dists)


def test_single_stats_parity(built):
    _, paths, queries = built
    sf = _open(paths, "fstore").search(queries[3], k=10, b=4).query.stats
    sl = _open(paths, "fstore", engine="legacy").search(queries[3], k=10, b=4).query.stats
    assert (sf.nodes_opened, sf.leaves_opened, sf.distance_calcs, sf.increments) == (
        sl.nodes_opened,
        sl.leaves_opened,
        sl.distance_calcs,
        sl.increments,
    )


def test_batch_dedup_fewer_reads_than_singles(built):
    """Cross-query fetch dedup: one batch call issues fewer blob reads
    than B independent single-query searches (both from a cold cache)."""
    _, paths, queries = built
    singles = _open(paths, "blob")
    io0 = singles.store.io.snapshot()
    for q in queries:
        singles.search(q, k=25, b=8)
    single_reads = singles.store.io.delta(io0).reads_issued

    batch = _open(paths, "blob")
    io0 = batch.store.io.snapshot()
    rb = batch.search(queries, k=25, b=8)
    batch_io = batch.store.io.delta(io0)
    assert batch_io.reads_issued < single_reads

    bs = rb.query.batch_stats
    assert bs is not None and bs.rounds > 0
    assert bs.dedup_hits > 0  # 16 co-located queries must share some nodes
    assert bs.io.reads_issued == batch_io.reads_issued
    # per-row solo-equivalent loads sum to actual loads + dedup savings
    assert sum(s.node_loads for s in rb.query.stats) == bs.node_loads + bs.dedup_hits
    assert all(s.rounds > 0 for s in rb.query.stats)


def test_kernel_scorer_hook(built):
    """The leaf scorer hook: a custom scorer is actually consulted, and
    the distance_topk-backed scorer reproduces the default results (values
    allclose; ids equal on this well-separated data)."""
    _, paths, queries = built
    calls = {"n": 0}

    def counting_scorer(q, emb, metric, sqnorms=None):
        from repro.core.distances import np_distances

        calls["n"] += 1
        return np_distances(q, emb, metric, c_sqnorms=sqnorms)

    idx = _open(paths, "blob", scorer=counting_scorer)
    base = _open(paths, "blob")
    r1 = idx.search(queries[4], k=10, b=4)
    r2 = base.search(queries[4], k=10, b=4)
    assert calls["n"] > 0
    _assert_same(r1, r2, "counting scorer")

    kidx = _open(paths, "blob", scorer=make_kernel_scorer(min_rows=1, impl="ref"))
    rk = kidx.search(queries[4], k=10, b=4)
    np.testing.assert_array_equal(rk.ids, r2.ids)
    np.testing.assert_allclose(rk.dists, r2.dists, rtol=1e-4, atol=1e-4)


def test_batch_matrix_mode_matches_ranking(built):
    """Opt-in dense [B', N] scoring: not bit-exact, but the returned
    neighbor ids and distances must agree to float tolerance."""
    _, paths, queries = built
    exact = _open(paths, "blob").search(queries, k=20, b=8)
    dense = _open(paths, "blob", batch_matrix=True).search(queries, k=20, b=8)
    np.testing.assert_allclose(dense.dists, exact.dists, rtol=1e-4, atol=1e-4)
    same = (dense.ids == exact.ids).mean()
    assert same > 0.95  # ulp-level reordering of near-ties only


def test_exclude_mutation_between_increments_honored(built):
    """The legacy engine reads the live exclude set per item; the flat
    engine must honor between-call mutations the same way."""
    _, paths, queries = built
    q = queries[5]
    flat = _open(paths, "blob")
    leg = _open(paths, "blob", engine="legacy")
    rf = flat.search(q, k=10, b=2, mx_inc=0)
    rl = leg.search(q, k=10, b=2, mx_inc=0)
    _assert_same(rf, rl, "pre-mutation")
    poison = set(int(i) for i in rf.ids[5:8] if i >= 0)
    rf.query.state.exclude.update(poison)
    rl.query.state.exclude.update(poison)
    nf, nl = rf.query.next(15), rl.query.next(15)
    _assert_same(nf, nl, "post-mutation")
    assert not (set(nf.row_ids(0)) & poison)


def test_norm_cache_fresh_after_node_rewrite(built):
    """An in-place node rewrite must not serve stale cached norms: the
    weakref tie to the payload array forces recomputation on reload."""
    _, paths, queries = built
    idx = _open(paths, "fstore")
    q = queries[6]
    idx.search(q, k=10, b=4)  # warms node + norm caches
    info = idx.info
    # rewrite one leaf with shifted embeddings (same row count)
    emb, ids = idx.store.get_node(info.levels, 0)
    idx.store.write_node(info.levels, 0, (emb + 1.0).astype(np.float16), ids)
    idx.cache.clear()  # payload coherence is the caller's contract
    got = idx.search(q, k=10, b=4)
    ref = _open(paths, "fstore", engine="legacy").search(q, k=10, b=4)
    _assert_same(got, ref, "after rewrite")
    # restore the original node for any later test using the fixture
    idx.store.write_node(info.levels, 0, emb.astype(np.float16), ids)
    idx.cache.clear()


def test_norm_cache_populated_and_bounded(built):
    _, paths, queries = built
    idx = _open(paths, "blob", norm_cache_entries=8)
    idx.search(queries, k=20, b=8)
    assert idx._norms is not None
    assert 0 < len(idx._norms) <= 8


def test_prefetch_pool_reused(built):
    _, paths, _ = built
    idx = _open(paths, "fstore")
    idx.prefetch(up_to_level=1)
    pool1 = idx._pool
    idx.prefetch(up_to_level=1)
    assert idx._pool is pool1  # same executor, not a fresh one per call
    idx.close()
    assert idx._pool is None
    idx.close()  # idempotent
