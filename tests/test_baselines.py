"""Baseline indexes (IVF / HNSW-lite / Vamana-lite) sanity vs brute force,
through the unified Searcher API."""
import numpy as np
import pytest

from repro.core import QueryClosedError
from repro.core.baselines import BruteForce, HNSWLite, IVFIndex, VamanaLite, kmeans
from repro.data import clustered_vectors


@pytest.fixture(scope="module")
def dataset():
    data, _ = clustered_vectors(1, n=3000, dim=24, n_clusters=32)
    bf = BruteForce(data)
    rng = np.random.default_rng(2)
    qs = data[rng.integers(0, len(data), 15)] + 0.005 * rng.normal(size=(15, 24)).astype(np.float32)
    gt = [set(bf.search(q, 10).row_ids(0)) for q in qs]
    return data, qs, gt


def _recall(searcher, qs, gt, *, b=None):
    rec = []
    for q, g in zip(qs, gt):
        ids = searcher.search(q, 10, b=b).row_ids(0)
        rec.append(len(g & set(ids)) / 10)
    return float(np.mean(rec))


def test_kmeans_partitions(dataset):
    data, _, _ = dataset
    cent, assign = kmeans(data, 16, iters=5)
    assert cent.shape == (16, 24)
    assert assign.min() >= 0 and assign.max() < 16
    # every cluster non-trivially used on clustered data
    assert (np.bincount(assign, minlength=16) > 0).sum() >= 12


def test_ivf_recall(dataset):
    data, qs, gt = dataset
    ivf = IVFIndex(data, n_lists=32, train_iters=5)
    assert _recall(ivf, qs, gt, b=8) >= 0.8


def test_hnsw_recall(dataset):
    data, qs, gt = dataset
    h = HNSWLite(data, M=12, ef_construction=48)
    assert _recall(h, qs, gt, b=64) >= 0.8


def test_vamana_recall(dataset):
    data, qs, gt = dataset
    v = VamanaLite(data, R=16, L_build=48)
    assert _recall(v, qs, gt, b=64) >= 0.8


def test_bruteforce_batch_matches_single(dataset):
    data, qs, _ = dataset
    bf = BruteForce(data)
    rs_b = bf.search(qs[:4], 5)
    assert rs_b.ids.shape == (4, 5)
    for r in range(4):
        rs_s = bf.search(qs[r], 5)
        np.testing.assert_array_equal(rs_b.ids[r], rs_s.ids)


def test_restart_query_matches_tail(dataset):
    """Baseline continuation == the tail of one bigger search (Table 4)."""
    data, qs, _ = dataset
    ivf = IVFIndex(data, n_lists=32, train_iters=5)
    rs = ivf.search(qs[0], 10, b=8)
    more = rs.query.next(10)
    big = ivf.search(qs[0], 20, b=8)
    np.testing.assert_array_equal(
        np.concatenate([rs.ids, more.ids]), big.ids
    )
    rs.query.close()
    with pytest.raises(QueryClosedError):
        rs.query.next(5)
