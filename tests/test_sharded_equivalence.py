"""Distributed-path numerical equivalence (the §Perf optimizations).

These run in a SUBPROCESS with 8 forced host devices (the main pytest
process must stay single-device), asserting that the optimized sharded
implementations match the single-logic references:

  * pure-FSDP / Megatron-SP LM train loss+grads  == reference
  * token-replicated expert-parallel MoE          == global dispatch (no-drop)
  * sequence-parallel eCP retrieval attention     == reference gather version
"""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, r"%SRC%")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import set_mesh
from repro.models import transformer as T
from repro.models.base import init_params, param_pspecs
from repro.models.moe import MoEConfig
from repro.models.retrieval_attention import (
    retrieval_decode_attention, retrieval_decode_attention_sharded)

mesh = jax.make_mesh((2, 4), ("data", "model"))

def put(params, pspecs):
    return jax.device_put(params, jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), pspecs,
        is_leaf=lambda x: isinstance(x, P)))

# --- 1) dense SP train path
cfg = T.LMConfig(name="t", n_layers=2, d_model=32, n_heads=8, n_kv_heads=2,
                 d_ff=64, vocab=64, d_head=8, max_seq=32, dtype=jnp.float32,
                 attn_chunk=16)
specs = T.param_specs(cfg)
params = init_params(specs, jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (4, 32), 0, 64)
ref, _ = T.lm_loss(params, {"tokens": toks}, cfg)
rules = T.ShardingRules(batch=("data",), model="model", seq="model")
with set_mesh(mesh):
    pp = put(params, param_pspecs(specs))
    tt = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
    sp, _ = jax.jit(lambda p, b: T.lm_loss(p, b, cfg, rules))(pp, {"tokens": tt})
    g_ref = jax.grad(lambda p: T.lm_loss(p, {"tokens": toks}, cfg)[0])(params)
    g_sp = jax.jit(jax.grad(lambda p: T.lm_loss(p, {"tokens": tt}, cfg, rules)[0]))(pp)
gerr = max(float(jnp.max(jnp.abs(a - b))) for a, b in
           zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_sp)))
assert abs(float(ref - sp)) < 1e-5, ("sp loss", float(ref), float(sp))
assert gerr < 1e-5, ("sp grads", gerr)

# --- 2) EP MoE under no-drop capacity
cfg = T.LMConfig(name="t", n_layers=2, d_model=32, n_heads=8, n_kv_heads=2,
                 d_ff=64, vocab=64, d_head=8, max_seq=32, dtype=jnp.float32,
                 moe=MoEConfig(n_experts=8, d_ff=64, capacity_factor=16.0),
                 attn_chunk=16)
specs = T.param_specs(cfg)
params = init_params(specs, jax.random.key(0))
ref, _ = T.lm_loss(params, {"tokens": toks}, cfg)
with set_mesh(mesh):
    pp = put(params, param_pspecs(specs))
    tt = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
    sp, _ = jax.jit(lambda p, b: T.lm_loss(p, b, cfg, rules))(pp, {"tokens": tt})
assert abs(float(ref - sp)) < 1e-5, ("ep loss", float(ref), float(sp))

# --- 3) sharded retrieval attention
rng = np.random.default_rng(0)
B, Hq, Hkv, nC, cs, d = 1, 8, 2, 16, 8, 32
q = jnp.asarray(rng.normal(size=(B, Hq, d)), jnp.float32)
kc = jnp.asarray(rng.normal(size=(B, Hkv, nC, cs, d)), jnp.float32)
vc = jnp.asarray(rng.normal(size=(B, Hkv, nC, cs, d)), jnp.float32)
cent = jnp.asarray(kc.mean(3), jnp.float32)
for pos in (5, 37, 128):
    ref = retrieval_decode_attention(q, kc, vc, cent, jnp.asarray(pos), cs=cs, top_b=4)
    with set_mesh(mesh):
        sh = lambda *a: NamedSharding(mesh, P(*a))
        out = jax.jit(lambda q, k, v, c, p: retrieval_decode_attention_sharded(
            q, k, v, c, p, cs=cs, top_b=4, seq_axes=("data", "model")))(
            q,
            jax.device_put(kc, sh(None, None, ("data", "model"), None, None)),
            jax.device_put(vc, sh(None, None, ("data", "model"), None, None)),
            jax.device_put(cent, sh(None, None, ("data", "model"), None)),
            jnp.asarray(pos),
        )
    err = float(jnp.max(jnp.abs(np.asarray(ref) - np.asarray(out))))
    assert err < 1e-5, ("retrieval", pos, err)

# --- 4) fused owner-local cache write + attend (iteration 4)
from repro.models.retrieval_attention import (
    clustered_cache_update, retrieval_update_and_attend_sharded)
kn = jnp.asarray(rng.normal(size=(B, Hkv, d)), jnp.float32)
vn = jnp.asarray(rng.normal(size=(B, Hkv, d)), jnp.float32)
for pos in (0, 36, 99):
    kc2, vc2, cent2 = clustered_cache_update(kc, vc, cent, kn, vn, jnp.asarray(pos), cs)
    ref = retrieval_decode_attention(q, kc2, vc2, cent2, jnp.asarray(pos + 1), cs=cs, top_b=4)
    with set_mesh(mesh):
        sh = lambda *a: NamedSharding(mesh, P(*a))
        out, ks, vs, cs_o = jax.jit(lambda *a: retrieval_update_and_attend_sharded(
            *a, cs=cs, top_b=4, seq_axes=("data", "model")))(
            q,
            jax.device_put(kc, sh(None, None, ("data", "model"), None, None)),
            jax.device_put(vc, sh(None, None, ("data", "model"), None, None)),
            jax.device_put(cent, sh(None, None, ("data", "model"), None)),
            kn, vn, jnp.asarray(pos))
    assert float(jnp.max(jnp.abs(np.asarray(ref) - np.asarray(out)))) < 1e-5, ("fused out", pos)
    assert float(jnp.max(jnp.abs(np.asarray(kc2) - np.asarray(ks)))) < 1e-6, ("fused cache", pos)
    assert float(jnp.max(jnp.abs(np.asarray(cent2) - np.asarray(cs_o)))) < 1e-6, ("fused cent", pos)
print("SHARDED_EQUIVALENCE_OK")
"""


def test_sharded_paths_match_reference():
    script = _SCRIPT.replace("%SRC%", str(ROOT / "src"))
    res = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert "SHARDED_EQUIVALENCE_OK" in res.stdout, res.stdout[-2000:] + res.stderr[-2000:]
