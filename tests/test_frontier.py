"""Flat-array frontier + candidate buffer: exact ordering parity with the
tuple-heap / list-sort structures they replace (core/frontier.py)."""
import heapq
import itertools

import numpy as np
import pytest

from repro.core import CandidateBuffer, Frontier


def _ref_push(heap, tie, d, nodes, is_leaf, level):
    """The old per-child heappush loop (tie assigned in nodes order)."""
    for nd, dist in zip(nodes, d):
        heapq.heappush(heap, (float(dist), next(tie), int(is_leaf), int(level), int(nd)))


def _drain_equal(f: Frontier, heap: list):
    while heap:
        d, _, leaf, level, node = heapq.heappop(heap)
        assert f.pop() == (d, leaf, level, node)
    assert len(f) == 0
    with pytest.raises(IndexError):
        f.pop()


def test_pop_order_matches_tuple_heap_random():
    rng = np.random.default_rng(0)
    f = Frontier(capacity=4)  # force several growths
    heap, tie = [], itertools.count()
    for _ in range(30):
        w = int(rng.integers(1, 40))
        # coarse grid => many exact distance ties across and within batches
        d = (rng.integers(0, 6, w) / 3.0).astype(np.float32)
        nodes = rng.integers(0, 1000, w).astype(np.int64)
        level = int(rng.integers(1, 4))
        is_leaf = int(rng.integers(0, 2))
        f.push_batch(d, nodes, is_leaf, level)
        _ref_push(heap, tie, d, nodes, is_leaf, level)
        for _ in range(int(rng.integers(0, w + 3))):
            if not heap:
                break
            ref = heapq.heappop(heap)
            assert f.pop() == (ref[0], ref[2], ref[3], ref[4])
    _drain_equal(f, heap)


def test_tie_break_is_insertion_order():
    f = Frontier()
    f.push_batch(np.zeros(3, np.float32), [10, 11, 12], 0, 1)
    f.push_batch(np.zeros(2, np.float32), [20, 21], 1, 2)
    got = [f.pop()[3] for _ in range(5)]
    assert got == [10, 11, 12, 20, 21]


def test_peek_does_not_consume():
    f = Frontier()
    f.push_batch(np.asarray([3.0, 1.0], np.float32), [7, 8], 0, 1)
    assert f.peek() == f.peek() == (1.0, 0, 1, 8)
    assert len(f) == 2
    assert f.pop() == (1.0, 0, 1, 8)


def test_export_import_roundtrip_mid_stream():
    rng = np.random.default_rng(1)
    f = Frontier()
    for lv in (1, 2, 3):
        f.push_batch(rng.random(8).astype(np.float32), rng.integers(0, 99, 8), lv == 3, lv)
    for _ in range(5):
        f.pop()
    rows = f.export_rows()
    assert rows.shape == (len(f), 4) and rows.dtype == np.float64
    g = Frontier.from_rows(rows)
    # distances pop in the same global order (ties re-keyed by row order,
    # matching the old loader's sequential heappush)
    a = [f.pop() for _ in range(len(f))]
    b = [g.pop() for _ in range(len(g))]
    assert [x[0] for x in a] == [x[0] for x in b]
    assert sorted(a) == sorted(b)


def test_from_rows_empty():
    g = Frontier.from_rows(np.zeros((0, 4), np.float64))
    assert len(g) == 0 and not g


def test_candidate_buffer_matches_list_sort_protocol():
    """stage/commit/take must replay the old append + stable-sort + slice
    list protocol exactly, including distance ties."""
    rng = np.random.default_rng(2)
    buf = CandidateBuffer()
    ref: list[tuple[float, int]] = []
    next_id = itertools.count()
    for _ in range(12):
        # one "increment": a few staged leaves, then commit (== list sort)
        for _ in range(int(rng.integers(1, 5))):
            w = int(rng.integers(0, 20))
            d = (rng.integers(0, 5, w) / 2.0).astype(np.float32)
            ids = np.asarray([next(next_id) for _ in range(w)], np.int64)
            buf.stage(d, ids)
            ref.extend((float(x), int(y)) for x, y in zip(d, ids))
        buf.commit()
        ref.sort(key=lambda t: t[0])
        assert len(buf) == len(ref)
        # one "next(k)": emit from the front
        k = int(rng.integers(1, 9))
        dd, ii = buf.take(k)
        out, ref = ref[: len(dd)], ref[len(dd) :]
        assert [x[0] for x in out] == list(dd)
        assert [x[1] for x in out] == list(ii)


def test_candidate_buffer_export_items():
    buf = CandidateBuffer()
    buf.stage(np.asarray([2.0, 1.0], np.float32), np.asarray([5, 6], np.int64))
    buf.commit()
    buf.take(1)
    buf.stage(np.asarray([0.5], np.float32), np.asarray([7], np.int64))
    d, i = buf.export_items()  # commits staged items first
    assert list(i) == [7, 5] and list(d) == [0.5, 2.0]
    rt = CandidateBuffer.from_items(d, i)
    assert len(rt) == 2
    dd, ii = rt.take(5)
    assert list(ii) == [7, 5]
