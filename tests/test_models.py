"""Model-level behaviour: LM consistency, MoE, retrieval attention, GNN, CTR."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gnn as G
from repro.models import recsys as R
from repro.models import transformer as T
from repro.models.attention import attention
from repro.models.base import init_params, param_count
from repro.models.moe import MoEConfig, moe_ffn
from repro.models.retrieval_attention import init_clustered_cache
from repro.kernels.flash_attention import mha_ref

KEY = jax.random.key(0)
RNG = np.random.default_rng(0)


def tiny_cfg(**kw):
    base = dict(
        name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, d_head=16, max_seq=64, dtype=jnp.float32, attn_chunk=32,
    )
    base.update(kw)
    return T.LMConfig(**base)


# ------------------------------------------------------------------- LM
def test_lm_decode_matches_forward():
    cfg = tiny_cfg(qkv_bias=True)
    p = init_params(T.param_specs(cfg), KEY)
    toks = jax.random.randint(jax.random.key(1), (2, 33), 0, cfg.vocab)
    _, cache = T.prefill(p, toks[:, :16], cfg, max_seq=40)
    lg = None
    for t in range(16, 20):
        lg, cache = T.decode_step(p, cache, toks[:, t], cfg)
    # after consuming tokens 0..19 the logits condition on toks[:, :20]
    full, _ = T.forward(p, toks[:, :20], cfg)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


def test_lm_moe_block_mode_runs_and_grads():
    cfg = tiny_cfg(moe=MoEConfig(n_experts=4, d_ff=96), moe_every=2, n_layers=4)
    p = init_params(T.param_specs(cfg), KEY)
    toks = jax.random.randint(jax.random.key(2), (2, 24), 0, cfg.vocab)
    loss, m = T.lm_loss(p, {"tokens": toks}, cfg)
    assert np.isfinite(float(loss)) and float(m["aux"]) > 0
    g = jax.grad(lambda pp: T.lm_loss(pp, {"tokens": toks}, cfg)[0])(p)
    gn = float(jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32)**2) for x in jax.tree.leaves(g))))
    assert np.isfinite(gn) and gn > 0


def test_lm_loss_decreases_under_sgd():
    cfg = tiny_cfg(n_layers=1, vocab=64)
    p = init_params(T.param_specs(cfg), KEY)
    toks = jax.random.randint(jax.random.key(3), (4, 32), 0, 64)
    loss_fn = lambda pp: T.lm_loss(pp, {"tokens": toks}, cfg)[0]
    l0 = float(loss_fn(p))
    step = jax.jit(lambda pp: jax.tree.map(lambda a, g: a - 0.5 * g, pp, jax.grad(loss_fn)(pp)))
    for _ in range(10):
        p = step(p)
    assert float(loss_fn(p)) < l0 - 0.3


def test_retrieval_decode_approximates_full_attention():
    """With top_b covering ALL clusters, retrieval decode == exact decode."""
    cfg = tiny_cfg(retrieval=T.RetrievalAttnConfig(cluster_size=8, top_clusters=8))
    p = init_params(T.param_specs(cfg), KEY)
    toks = jax.random.randint(jax.random.key(4), (2, 40), 0, cfg.vocab)
    cc = init_clustered_cache(cfg.n_layers, 2, cfg.n_kv_heads, 64, 8, cfg.d_head, jnp.float32)
    kc = T.init_cache(cfg, 2, 64)
    for t in range(33):
        lg_r, cc = T.retrieval_decode_step(p, cc, toks[:, t], cfg)
        lg_f, kc = T.decode_step(p, kc, toks[:, t], cfg)
    np.testing.assert_allclose(np.asarray(lg_r), np.asarray(lg_f), rtol=5e-3, atol=5e-3)


def test_retrieval_decode_subquadratic_selects_fewer():
    cfg = tiny_cfg(retrieval=T.RetrievalAttnConfig(cluster_size=8, top_clusters=1))
    p = init_params(T.param_specs(cfg), KEY)
    toks = jax.random.randint(jax.random.key(5), (1, 50), 0, cfg.vocab)
    cc = init_clustered_cache(cfg.n_layers, 1, cfg.n_kv_heads, 64, 8, cfg.d_head, jnp.float32)
    for t in range(45):
        lg, cc = T.retrieval_decode_step(p, cc, toks[:, t], cfg)
    assert bool(jnp.all(jnp.isfinite(lg)))


def test_attention_impls_agree():
    q = jnp.asarray(RNG.normal(size=(2, 4, 64, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 2, 64, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 2, 64, 32)), jnp.float32)
    o_full = attention(q, k, v, impl="full")
    o_chunk = attention(q, k, v, impl="chunked", chunk=16)
    o_flash = attention(q, k, v, impl="flash_interpret")
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_chunk), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_flash), rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ MoE
def test_moe_top1_routes_and_balances():
    cfg = MoEConfig(n_experts=8, d_ff=32, capacity_factor=2.0)
    x = jnp.asarray(RNG.normal(size=(64, 16)), jnp.float32)
    router = jnp.asarray(RNG.normal(size=(16, 8)), jnp.float32)
    wg = jnp.asarray(RNG.normal(size=(8, 16, 32)) * 0.1, jnp.float32)
    wu = jnp.asarray(RNG.normal(size=(8, 16, 32)) * 0.1, jnp.float32)
    wd = jnp.asarray(RNG.normal(size=(8, 32, 16)) * 0.1, jnp.float32)
    y, aux = moe_ffn(x, router, wg, wu, wd, cfg)
    assert y.shape == x.shape and np.isfinite(float(aux))
    # capacity sanity: with factor 2 almost nothing drops; output nonzero
    assert float(jnp.mean(jnp.abs(y))) > 0


def test_moe_dropped_tokens_zeroed():
    cfg = MoEConfig(n_experts=2, d_ff=8, capacity_factor=0.01)  # capacity 1
    x = jnp.asarray(RNG.normal(size=(32, 8)), jnp.float32)
    router = jnp.zeros((8, 2), jnp.float32)  # all tokens to expert 0 (argmax tie)
    wg = jnp.ones((2, 8, 8), jnp.float32)
    wu = jnp.ones((2, 8, 8), jnp.float32)
    wd = jnp.ones((2, 8, 8), jnp.float32)
    y, _ = moe_ffn(x, router, wg, wu, wd, cfg)
    # capacity 1: at most 1 token per expert got processed; rest exactly 0
    nonzero_rows = int(jnp.sum(jnp.any(y != 0, axis=1)))
    assert nonzero_rows <= 2


# ------------------------------------------------------------------ GNN
def test_gnn_full_batch_equals_manual():
    cfg = G.GraphSAGEConfig(name="t", d_in=4, n_classes=3, n_layers=1, d_hidden=8)
    p = init_params(G.param_specs(cfg), KEY)
    feats = jnp.asarray(RNG.normal(size=(5, 4)), jnp.float32)
    src = jnp.asarray([0, 1, 2], jnp.int32)
    dst = jnp.asarray([1, 1, 3], jnp.int32)
    out = G.full_batch_forward(p, feats, src, dst, cfg)
    agg = np.zeros((5, 4), np.float32)
    agg[1] = (feats[0] + feats[1]) / 2
    agg[3] = feats[2]
    h = np.maximum(
        feats @ p["layers"][0]["w_self"] + agg @ p["layers"][0]["w_neigh"] + p["layers"][0]["b"], 0
    )
    expected = h @ p["w_out"] + p["b_out"]
    np.testing.assert_allclose(np.asarray(out), expected, rtol=1e-5, atol=1e-5)


def test_gnn_edge_weight_padding_is_neutral():
    cfg = G.GraphSAGEConfig(name="t", d_in=4, n_classes=3, n_layers=2, d_hidden=8)
    p = init_params(G.param_specs(cfg), KEY)
    feats = jnp.asarray(RNG.normal(size=(6, 4)), jnp.float32)
    src = jnp.asarray([0, 1, 2, 4], jnp.int32)
    dst = jnp.asarray([1, 2, 3, 5], jnp.int32)
    out1 = G.full_batch_forward(p, feats, src, dst, cfg)
    # pad with zero-weight junk edges: output must be identical
    src_p = jnp.concatenate([src, jnp.zeros(4, jnp.int32)])
    dst_p = jnp.concatenate([dst, jnp.zeros(4, jnp.int32)])
    w = jnp.concatenate([jnp.ones(4), jnp.zeros(4)])
    out2 = G.full_batch_forward(p, feats, src_p, dst_p, cfg, edge_weight=w)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5, atol=1e-5)


def test_gnn_sampled_shapes():
    cfg = G.GraphSAGEConfig(name="t", d_in=8, n_classes=4, d_hidden=16, fanouts=(3, 2))
    p = init_params(G.param_specs(cfg), KEY)
    hops = (
        jnp.asarray(RNG.normal(size=(5, 3, 2, 8)), jnp.float32),
        jnp.asarray(RNG.normal(size=(5, 3, 8)), jnp.float32),
        jnp.asarray(RNG.normal(size=(5, 8)), jnp.float32),
    )
    out = G.sampled_forward(p, hops, cfg)
    assert out.shape == (5, 4)


# ---------------------------------------------------------------- recsys
@pytest.mark.parametrize("name", ["bst", "dien", "autoint", "dcn-v2"])
def test_recsys_models_train_one_sgd_step(name):
    from repro.configs import get_arch

    _, cfg = get_arch(name, reduced=True)
    p = init_params(R.param_specs(cfg), KEY)
    B = 8
    batch = {"label": jnp.asarray(RNG.integers(0, 2, B), jnp.float32)}
    n_plain = cfg.n_fields - cfg.seq_fields
    batch["cat"] = jnp.asarray(
        np.stack([RNG.integers(0, v, B) for v in cfg.field_vocabs[cfg.seq_fields:]], 1)
        if n_plain else np.zeros((B, 0)), jnp.int32)
    if cfg.n_dense:
        batch["dense"] = jnp.asarray(RNG.normal(size=(B, cfg.n_dense)), jnp.float32)
    if cfg.seq_len:
        batch["seq"] = jnp.asarray(
            RNG.integers(0, min(cfg.field_vocabs[:cfg.seq_fields]), (B, cfg.seq_len, cfg.seq_fields)), jnp.int32)
        batch["seq_mask"] = jnp.ones((B, cfg.seq_len), jnp.float32)
        batch["target"] = jnp.asarray(
            RNG.integers(0, min(cfg.field_vocabs[:cfg.seq_fields]), (B, cfg.seq_fields)), jnp.int32)
    loss_fn = lambda pp: R.recsys_loss(pp, batch, cfg)[0]
    l0 = float(loss_fn(p))
    g = jax.grad(loss_fn)(p)
    p2 = jax.tree.map(lambda a, b: a - 0.1 * b, p, g)
    assert float(loss_fn(p2)) < l0


def test_embedding_bag_modes_match_ragged():
    table = jnp.asarray(RNG.normal(size=(20, 4)), jnp.float32)
    ids = jnp.asarray([[1, 2, 3], [4, 5, 0]], jnp.int32)
    mask = jnp.asarray([[1, 1, 0], [1, 0, 0]], jnp.float32)
    dense = R.embedding_bag(table, ids, mask, mode="sum")
    flat = jnp.asarray([1, 2, 4], jnp.int32)
    seg = jnp.asarray([0, 0, 1], jnp.int32)
    ragged = R.embedding_bag_ragged(table, flat, seg, 2, mode="sum")
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ragged), rtol=1e-6)
