import os
import sys
from pathlib import Path

# benchmarks package (repo root) importable from tests
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

# Tests must see ONE device (the dry-run owns the 512-device flag).
os.environ.setdefault("JAX_PLATFORMS", "cpu")


# --------------------------------------------------------------------------
# hypothesis fallback shim: the property tests (test_fstore / test_kernels /
# test_optim) must stay collectable when hypothesis isn't installed.  The
# shim runs each @given test as a small deterministic example sweep instead
# of failing at import.  Real hypothesis, when present, wins untouched.
try:  # pragma: no cover - trivially true when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    import inspect
    import random
    import types

    _N_FALLBACK_EXAMPLES = 10  # bounded sweep; real hypothesis does 15-25

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _DataObject:
        """Stand-in for st.data()'s interactive draw object."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.draw(self._rng)

    _DATA_SENTINEL = object()

    def _integers(min_value=0, max_value=2**31):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value=-1e6, max_value=1e6, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def _data():
        s = _Strategy(lambda rng: _DataObject(rng))
        s._is_data = _DATA_SENTINEL
        return s

    def _given(*pos_strategies, **kw_strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters)
            # positional strategies bind to the RIGHTMOST params (hypothesis
            # semantics); remaining leading params stay pytest fixtures
            kw = dict(kw_strategies)
            for name, strat in zip(params[len(params) - len(pos_strategies):], pos_strategies):
                kw[name] = strat
            fixture_params = [p for p in params if p not in kw]

            def runner(*args, **fixtures):
                n = getattr(runner, "_hyp_max_examples", _N_FALLBACK_EXAMPLES)
                n = min(n, _N_FALLBACK_EXAMPLES)
                for ex in range(n):
                    rng = random.Random(0xECF5 + 7919 * ex)
                    drawn = {name: strat.draw(rng) for name, strat in kw.items()}
                    fn(*args, **fixtures, **drawn)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            runner.__signature__ = sig.replace(
                parameters=[sig.parameters[p] for p in fixture_params]
            )
            return runner

        return deco

    def _settings(max_examples=None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._hyp_max_examples = max_examples
            return fn

        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.data = _data
    _hyp.strategies = _st
    _hyp.__is_repro_shim__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
