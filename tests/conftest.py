import os
import sys
from pathlib import Path

# benchmarks package (repo root) importable from tests
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

# Tests must see ONE device (the dry-run owns the 512-device flag).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
