"""Store protocol: backend factory, blob format, fstore/blob/prefetch
parity (bit-identical), batched reads, IOStats threading, write_node."""
import json
import os

import numpy as np
import pytest

from repro.core import (
    AsyncPrefetchStore,
    BlobStore,
    ECPBuildConfig,
    FStoreBackend,
    Store,
    build_index,
    convert,
    open_index,
    open_store,
)
from repro.core import layout
from repro.core.store import BLOB_MAGIC
from repro.data import clustered_vectors


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    data, _ = clustered_vectors(5, n=5000, dim=24, n_clusters=40)
    path = tmp_path_factory.mktemp("store_idx") / "ecp"
    build_index(data, str(path), ECPBuildConfig(levels=2, metric="l2", cluster_cap=64, seed=2))
    blob = convert(str(path), tmp_path_factory.mktemp("store_blob") / "idx.blob")
    return data, str(path), str(blob)


# ----------------------------------------------------------------- factory
def test_open_store_returns_all_three_backends(built):
    _, path, blob = built
    fs = open_store(path, backend="fstore")
    bs = open_store(blob, backend="blob")
    ps = open_store(blob, backend="blob", prefetch=True)
    assert isinstance(fs, FStoreBackend) and fs.backend == "fstore"
    assert isinstance(bs, BlobStore) and bs.backend == "blob"
    assert isinstance(ps, AsyncPrefetchStore) and ps.backend == "blob+prefetch"
    for s in (fs, bs, ps):
        assert isinstance(s, Store)
    # the "<name>+prefetch" spelling is equivalent to prefetch=True
    ps2 = open_store(blob, backend="blob+prefetch")
    assert isinstance(ps2, AsyncPrefetchStore) and ps2.inner.backend == "blob"
    # a raw FStore still opens (wrapped into the protocol backend)
    from repro.core import FStore

    wrapped = open_store(FStore(path))
    assert isinstance(wrapped, FStoreBackend)
    with pytest.raises(ValueError):
        open_store(path, backend="nope")


def test_open_store_auto_detection(built, tmp_path):
    _, path, blob = built
    assert open_store(path, backend="auto").backend == "fstore"
    assert open_store(blob, backend="auto").backend == "blob"
    # a directory holding index.blob is detected as blob
    d = tmp_path / "blobdir"
    d.mkdir()
    (d / "index.blob").write_bytes((open(blob, "rb").read()))
    assert open_store(d, backend="auto").backend == "blob"


# ------------------------------------------------------------- blob format
def test_blob_on_disk_format(built):
    _, _, blob = built
    raw = open(blob, "rb").read(16)
    assert raw[:8] == BLOB_MAGIC
    hlen = int(np.frombuffer(raw[8:16], "<u8")[0])
    header = json.loads(open(blob, "rb").read()[16 : 16 + hlen])
    assert header["format"] == "ecp-blob/2"  # convert() default: mutable form
    page = header["page_size"]
    assert header["data_offset"] % page == 0
    assert header["block_bytes"] % page == 0
    # v2 carries the physical slot map; a fresh convert is exactly full
    n_slots = sum(len(lv) for lv in header["levels"])
    assert header["n_slots"] == n_slots
    assert header["free_slots"] == []
    assert sorted(s for lv in header["slots"] for s in lv) == list(range(n_slots))
    assert os.path.getsize(blob) == header["data_offset"] + n_slots * header["block_bytes"]
    # info in the header matches the fstore's info attrs
    bs = BlobStore(blob)
    assert bs.read_attrs(layout.INFO)["dim"] == header["info"]["dim"]
    assert bs.read_attrs("somewhere/else") == {}


def test_blob_rejects_garbage(tmp_path):
    p = tmp_path / "junk.blob"
    p.write_bytes(b"NOTABLOB" + b"\0" * 64)
    with pytest.raises(ValueError):
        BlobStore(p)
    with pytest.raises(FileNotFoundError):
        BlobStore(tmp_path / "missing.blob")


# ----------------------------------------------------------------- parity
def test_node_reads_bit_identical_across_backends(built):
    _, path, blob = built
    fs = open_store(path)
    bs = open_store(blob)
    info = fs.read_attrs(layout.INFO)
    keys = [(0, 0)] + [
        (lv, nd)
        for lv in range(1, int(info["levels"]) + 1)
        for nd in range(int(info["nodes_per_level"][lv - 1]))
    ]
    batched = bs.get_nodes(keys)
    for key, (be, bi) in zip(keys, batched):
        fe, fi = fs.get_node(*key)
        np.testing.assert_array_equal(fe, be)
        np.testing.assert_array_equal(np.asarray(fi, np.int64), np.asarray(bi, np.int64))


def test_search_results_bit_identical_across_backends(built):
    data, path, blob = built
    fidx = open_index(path, mode="file", backend="fstore")
    bidx = open_index(blob, mode="file", backend="blob")
    pidx = open_index(blob, mode="file", backend="blob", prefetch=True)
    rng = np.random.default_rng(2)
    qs = data[rng.integers(0, len(data), 12)]
    for q in qs:
        rf = fidx.search(q, k=10, b=8)
        rb = bidx.search(q, k=10, b=8)
        rp = pidx.search(q, k=10, b=8)
        np.testing.assert_array_equal(rf.ids, rb.ids)
        np.testing.assert_array_equal(rf.dists, rb.dists)
        np.testing.assert_array_equal(rf.ids, rp.ids)
        np.testing.assert_array_equal(rf.dists, rp.dists)


def test_packed_load_identical_from_blob(built):
    from repro.core import load_packed

    _, path, blob = built
    p1 = load_packed(open_store(path))
    p2 = load_packed(open_store(blob))
    np.testing.assert_array_equal(p1.root_emb, p2.root_emb)
    assert len(p1.levels) == len(p2.levels)
    for a, b in zip(p1.levels, p2.levels):
        np.testing.assert_array_equal(a.emb, b.emb)
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.mask, b.mask)


# ------------------------------------------------------------ batched reads
def test_get_nodes_matches_get_node_and_coalesces(built):
    _, _, blob = built
    bs = open_store(blob)
    keys = [(2, j) for j in range(12)]
    singles = [bs.get_node(*k) for k in keys]
    bs2 = open_store(blob)
    before = bs2.io.snapshot()
    batched = bs2.get_nodes(keys)
    d = bs2.io.delta(before)
    assert d.reads_issued == 1, "adjacent blob slots should coalesce into one read"
    for (e1, i1), (e2, i2) in zip(singles, batched):
        np.testing.assert_array_equal(e1, e2)
        np.testing.assert_array_equal(i1, i2)
    # unordered / duplicate-free scattered keys still come back aligned
    scattered = [(2, 9), (1, 0), (2, 3), (0, 0)]
    got = bs.get_nodes(scattered)
    for key, (e, i) in zip(scattered, got):
        e1, i1 = bs.get_node(*key)
        np.testing.assert_array_equal(e, e1)
        np.testing.assert_array_equal(i, i1)


# ----------------------------------------------------------------- IOStats
def test_iostats_blob_fewer_reads_than_fstore(built):
    data, path, blob = built
    fidx = open_index(path, mode="file", backend="fstore")
    bidx = open_index(blob, mode="file", backend="blob")
    f0, b0 = fidx.store.io.snapshot(), bidx.store.io.snapshot()
    rng = np.random.default_rng(3)
    for q in data[rng.integers(0, len(data), 8)]:
        fidx.search(q, k=10, b=8)
        bidx.search(q, k=10, b=8)
    f_io = fidx.store.io.delta(f0)
    b_io = bidx.store.io.delta(b0)
    assert f_io.reads_issued > 0 and b_io.reads_issued > 0
    assert b_io.reads_issued < f_io.reads_issued
    assert b_io.files_opened < f_io.files_opened
    assert b_io.bytes_read <= f_io.bytes_read  # no JSON / chunk padding overhead


def test_iostats_threaded_into_search_stats(built):
    data, path, _ = built
    idx = open_index(path, mode="file", backend="fstore")
    rs = idx.search(data[0], k=10, b=8)
    st = rs.query.stats
    assert st.io.reads_issued > 0 and st.io.bytes_read > 0
    # warm repeat: everything cached, no new node I/O for the same query
    rs2 = idx.search(data[0], k=10, b=8)
    assert rs2.query.stats.io.reads_issued == 0


# ------------------------------------------------------------- write paths
def test_blob_write_node_roundtrip_and_overflow(built, tmp_path):
    _, path, _ = built
    blob = convert(path, tmp_path / "w.blob")
    bs = BlobStore(blob)
    emb, ids = bs.get_node(2, 1)
    # shrink the node in place
    new_emb, new_ids = emb[:3], np.asarray(ids[:3], np.int64)
    bs.write_node(2, 1, new_emb, new_ids)
    e2, i2 = bs.get_node(2, 1)
    np.testing.assert_array_equal(e2, new_emb.astype(np.float16).astype(np.float32))
    np.testing.assert_array_equal(i2, new_ids)
    # a reopened store sees the persisted header update
    e3, i3 = BlobStore(blob).get_node(2, 1)
    np.testing.assert_array_equal(i3, new_ids)
    # data larger than the fixed block must be rejected
    big = np.zeros((bs.block_bytes // bs._row_bytes + 1, bs.dim), np.float32)
    with pytest.raises(ValueError):
        bs.write_node(2, 1, big, np.zeros(len(big), np.int64))


def test_prefetch_store_hits_and_close(built):
    _, _, blob = built
    ps = open_store(blob, prefetch=True)
    keys = [(2, 0), (2, 1), (2, 2)]
    ps.prefetch(keys)
    direct = open_store(blob)
    for key in keys:
        e, i = ps.get_node(*key)
        e1, i1 = direct.get_node(*key)
        np.testing.assert_array_equal(e, e1)
        np.testing.assert_array_equal(i, i1)
    assert ps.prefetch_issued == 3 and ps.prefetch_hits == 3
    ps.close()
    ps.prefetch([(2, 3)])  # no-op after close, must not raise


def test_save_requires_fstore_backend(built):
    data, _, blob = built
    bidx = open_index(blob, mode="file", backend="blob")
    rs = bidx.search(data[1], k=5, b=8)
    with pytest.raises(NotImplementedError):
        rs.query.save()
    with pytest.raises(NotImplementedError):
        bidx.load_query("q_000000")


def test_node_rows_matches_data_without_reading_it(built):
    _, path, blob = built
    fs, bs = open_store(path), open_store(blob)
    keys = [(0, 0), (1, 0), (2, 0), (2, 5)]
    expect = [len(fs.get_node(*k)[1]) for k in keys]
    assert fs.node_rows(keys) == expect
    before = bs.io.snapshot()
    assert bs.node_rows(keys) == expect
    assert bs.io.delta(before).reads_issued == 0  # header-only, no I/O


def test_prefetch_on_node_sink_releases_futures(built):
    """With an on_node sink, completed prefetches flow to the caller (e.g.
    the byte-budgeted NodeCache) and do NOT pin buffers in the store."""
    _, _, blob = built
    ps = open_store(blob, prefetch=True)
    got = {}
    ps.prefetch([(2, j) for j in range(4)], on_node=lambda k, v: got.__setitem__(k, v))
    ps.drain()
    # done-callbacks fire just after waiters wake; give them a beat
    import time

    for _ in range(200):
        if len(got) == 4 and len(ps._futures) == 0:
            break
        time.sleep(0.005)
    assert set(got) == {(2, j) for j in range(4)}
    assert len(ps._futures) == 0, "sunk futures must not linger in-flight"
    direct = open_store(blob)
    for (lv, nd), (e, i) in got.items():
        e1, i1 = direct.get_node(lv, nd)
        np.testing.assert_array_equal(e, e1)
        np.testing.assert_array_equal(i, i1)


def test_prefetch_drain_settles_io(built):
    _, _, blob = built
    ps = open_store(blob, prefetch=True)
    ps.prefetch([(2, j) for j in range(6)])
    ps.drain()
    settled = ps.io.snapshot()
    assert settled.reads_issued >= 1
    # after drain, no background reads are still trickling in
    assert ps.io.delta(settled).reads_issued == 0


def test_build_returns_protocol_store(built):
    _, path, _ = built
    store = open_store(path)
    # root is node (0, 0); its ids enumerate the level-1 nodes
    emb, ids = store.get_node(0, 0)
    assert emb.dtype == np.float32
    info = store.read_attrs(layout.INFO)
    assert len(ids) == int(info["nodes_per_level"][0])


# -------------------------------------------------- mutation ops (lifecycle)
def _mutable_copy(built, tmp_path, backend):
    import shutil

    _, path, blob = built
    if backend == "fstore":
        dst = tmp_path / "m_idx"
        shutil.copytree(path, dst)
        return open_store(str(dst))
    dst = tmp_path / "m.blob"
    shutil.copyfile(blob, dst)
    return open_store(str(dst))


@pytest.mark.parametrize("backend", ["fstore", "blob"])
def test_append_rows_grows_a_node(built, tmp_path, backend):
    s = _mutable_copy(built, tmp_path, backend)
    e0, i0 = s.get_node(2, 3)
    add_e = np.full((3, e0.shape[1]), 0.5, np.float16)
    add_i = np.array([90001, 90002, 90003])
    s.append_rows(2, 3, add_e, add_i)
    e1, i1 = s.get_node(2, 3)
    assert len(i1) == len(i0) + 3
    np.testing.assert_array_equal(i1[: len(i0)], i0)
    np.testing.assert_array_equal(i1[-3:], add_i)
    np.testing.assert_array_equal(e1[: len(i0)], e0)
    assert s.node_rows([(2, 3)]) == [len(i0) + 3]


@pytest.mark.parametrize("backend", ["fstore", "blob"])
def test_delete_rows_removes_by_id(built, tmp_path, backend):
    s = _mutable_copy(built, tmp_path, backend)
    e0, i0 = s.get_node(2, 1)
    drop = i0[:2]
    assert s.delete_rows(2, 1, drop) == 2
    e1, i1 = s.get_node(2, 1)
    assert len(i1) == len(i0) - 2
    assert not set(drop.tolist()) & set(i1.tolist())
    np.testing.assert_array_equal(e1, e0[2:])
    assert s.delete_rows(2, 1, drop) == 0  # already gone


@pytest.mark.parametrize("backend", ["fstore", "blob"])
def test_free_slot_then_rewrite(built, tmp_path, backend):
    s = _mutable_copy(built, tmp_path, backend)
    dim = s.get_node(2, 2)[0].shape[1]
    s.free_slot(2, 2)
    e, i = s.get_node(2, 2)
    assert len(i) == 0 and s.node_rows([(2, 2)]) == [0]
    # batched reads skip the freed node cleanly
    out = s.get_nodes([(2, 1), (2, 2), (2, 3)])
    assert len(out[1][1]) == 0 and len(out[0][1]) > 0
    # a freed node can be written again
    s.write_node(2, 2, np.ones((2, dim), np.float16), np.array([7, 8]))
    np.testing.assert_array_equal(s.get_node(2, 2)[1], [7, 8])


def test_blob_new_node_allocation_and_free_list(built, tmp_path):
    s = _mutable_copy(built, tmp_path, "blob")
    n_leaf = len(s._n_rows[2])
    dim = s.dim
    # appending a node at the level's end grows the file
    s.write_node(2, n_leaf, np.full((2, dim), 2, np.float16), np.array([1, 2]))
    assert s.node_rows([(2, n_leaf)]) == [2]
    # non-dense node ids are rejected
    with pytest.raises(KeyError, match="dense"):
        s.write_node(2, n_leaf + 5, np.zeros((1, dim), np.float16), np.array([9]))
    # a freed slot is reused by the next allocation
    old_slot = s._slots[2][4]
    s.free_slot(2, 4)
    s.write_node(2, n_leaf + 1, np.full((1, dim), 3, np.float16), np.array([55]))
    assert s._slots[2][n_leaf + 1] == old_slot
    # all of it survives reopen
    s.close()
    r = open_store(s.path)
    assert r.format == 2
    assert r._slots[2][4] == -1 and r._slots[2][n_leaf + 1] == old_slot
    np.testing.assert_array_equal(r.get_node(2, n_leaf)[1], [1, 2])


def test_blob_v1_reads_and_upgrades_in_place(built, tmp_path):
    _, path, _ = built
    v1 = convert(path, tmp_path / "v1.blob", format=1)
    s = BlobStore(v1)
    assert s.format == 1
    e, i = s.get_node(2, 0)
    # a row rewrite keeps the file at v1
    s.write_node(2, 0, e[:4].astype(np.float16), i[:4])
    assert s.format == 1
    # first structural mutation upgrades the header to v2
    s.free_slot(2, 5)
    assert s.format == 2
    s.close()
    r = BlobStore(v1)
    assert r.format == 2 and r._n_rows[2][5] == 0
    np.testing.assert_array_equal(r.get_node(2, 0)[1], i[:4])


def test_blob_block_capacity_fits_cluster_cap(built):
    _, _, blob = built
    s = open_store(blob)
    cap = int(s.read_attrs(layout.INFO)["cluster_cap"])
    assert s.capacity_rows >= cap
    with pytest.raises(ValueError, match="exceeds the fixed block"):
        s.write_node(
            2, 0,
            np.zeros((s.capacity_rows + 1, s.dim), np.float16),
            np.zeros(s.capacity_rows + 1, np.int64),
        )


def test_blob_write_attrs_failure_leaves_state_consistent(built, tmp_path):
    """Regression: an oversized header must raise BEFORE anything mutates —
    read_attrs afterwards returns what is actually on disk."""
    s = _mutable_copy(built, tmp_path, "blob")
    before = s.read_attrs(layout.INFO)
    real_offset = s.data_offset
    s.data_offset = 64  # force the fit check to fail
    try:
        with pytest.raises(ValueError, match="header grew past"):
            s.write_attrs(layout.INFO, {**before, "deleted_ids": list(range(10_000))})
    finally:
        s.data_offset = real_offset
    assert s.read_attrs(layout.INFO) == before
    s.write_attrs(layout.INFO, {**before, "generation": 9})  # still writable
    assert s.read_attrs(layout.INFO)["generation"] == 9


def test_blob_header_reserves_room_for_tombstones(built):
    """convert(format=2) must budget header slack so delete() of a large
    fraction of the collection fits in-place."""
    data, path, _ = built
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        blob = convert(path, td + "/t.blob")
        s = open_store(blob)
        info = s.read_attrs(layout.INFO)
        n = int(info["n_items"])
        s.write_attrs(layout.INFO, {**info, "deleted_ids": list(range(n))})
        assert len(s.read_attrs(layout.INFO)["deleted_ids"]) == n
        s.close()


def test_fstore_get_node_invisible_torn_append(built, tmp_path):
    """A crash between the emb append and the ids append must leave the
    node readable with its OLD row count (emb trimmed to len(ids))."""
    s = _mutable_copy(built, tmp_path, "fstore")
    emb, ids = s.get_node(2, 7)
    # simulate the torn state: emb grown, ids not yet rewritten
    s.fstore.append_rows(f"{layout.node_group(2, 7)}/{layout.EMB}",
                         np.zeros((3, emb.shape[1]), np.float16))
    e2, i2 = s.get_node(2, 7)
    assert e2.shape[0] == len(i2) == len(ids)
    np.testing.assert_array_equal(i2, ids)


def test_prefetch_wrapper_invalidates_inflight_on_write(built, tmp_path):
    s = _mutable_copy(built, tmp_path, "blob")
    ps = AsyncPrefetchStore(s, workers=2)
    ps.prefetch([(2, 6)])
    ps.drain()
    e, i = s.get_node(2, 6)
    ps.append_rows(2, 6, np.zeros((1, s.dim), np.float16), np.array([90009]))
    e2, i2 = ps.get_node(2, 6)  # must NOT be the stale prefetched payload
    assert len(i2) == len(i) + 1 and 90009 in i2
    ps.close()


# ------------------------------------------------------- accuracy throttle
def test_prefetch_throttle_gate_lifecycle(built):
    """Issued-but-never-consumed prefetches must close the accuracy gate
    (suppressing whole batches), a probe trickle must keep measuring, and
    consuming the backlog must reopen the gate."""
    _, _, blob = built
    ps = AsyncPrefetchStore(
        open_store(blob, backend="blob"),
        warmup=4, hit_rate_threshold=0.75, probe_every=3,
    )
    assert ps.hit_rate == 1.0  # vacuously accurate before anything issued

    ps.prefetch([(2, j) for j in range(8)])  # past warmup, zero consumed
    ps.drain()
    assert ps.prefetch_issued == 8 and ps.hit_rate == 0.0

    # gate closed: whole batches suppressed, nothing new issued
    ps.prefetch([(2, 8), (2, 9)])
    ps.prefetch([(2, 10), (2, 11)])
    assert ps.prefetch_issued == 8
    assert ps.prefetch_suppressed == 4

    # 3rd suppressed batch is the probe: exactly ONE key admitted
    ps.prefetch([(2, 12), (2, 13), (2, 14)])
    ps.drain()
    assert ps.prefetch_issued == 9
    assert ps.prefetch_suppressed == 6
    assert (2, 12) in ps._futures and (2, 13) not in ps._futures

    # consume the backlog: rate recovers above threshold, gate reopens
    for key in [(2, j) for j in range(8)] + [(2, 12)]:
        ps.get_node(*key)
    assert ps.prefetch_hits == 9 and ps.hit_rate == 1.0
    ps.prefetch([(2, 15), (2, 16)])
    assert ps.prefetch_issued == 11
    ps.close()


def test_prefetch_throttle_byte_cap(built):
    """The in-flight byte budget bounds speculation even with the gate
    open: submissions stop (and count as suppressed) at the cap."""
    _, _, blob = built
    inner = open_store(blob, backend="blob")
    ps = AsyncPrefetchStore(inner, max_inflight_bytes=1)
    ps.prefetch([(2, j) for j in range(5)])
    assert ps.prefetch_issued == 0 and ps.prefetch_suppressed == 5
    # demand reads still work, they just pay the inner store directly
    e, i = ps.get_node(2, 0)
    e1, i1 = open_store(blob, backend="blob").get_node(2, 0)
    np.testing.assert_array_equal(e, e1)
    ps.close()


def test_prefetch_sink_delivery_not_double_counted(built):
    """Owner semantics: a payload delivered to the on_node sink must not
    ALSO count as a wrapper hit on a later demand read, nor be flushed as
    wasted — whoever pops the future owns (and counts) it exactly once."""
    import time

    _, _, blob = built
    ps = open_store(blob, prefetch=True)
    got = {}
    ps.prefetch([(2, 4)], on_node=lambda k, v: got.__setitem__(k, v))
    ps.drain()
    for _ in range(200):
        if got and not ps._futures:
            break
        time.sleep(0.005)
    assert set(got) == {(2, 4)} and not ps._futures
    io0 = ps.io.snapshot()
    e, i = ps.get_node(2, 4)  # demand read AFTER delivery: plain inner read
    assert ps.prefetch_hits == 0
    assert ps.io.delta(io0).prefetch_hits == 0
    assert ps.io.prefetch_wasted_bytes == 0
    np.testing.assert_array_equal(e, got[(2, 4)][0])
    ps.close()
    assert ps.io.prefetch_wasted_bytes == 0  # delivered payloads never turn wasted


def test_open_store_auto_shard_dir_error(built, tmp_path):
    """backend="auto" on a directory of per-shard indexes (no federation
    manifest) must say what it found and how to fix it, not fail deep in
    the fstore parser."""
    import shutil

    _, path, blob = built
    d = tmp_path / "shards"
    d.mkdir()
    shutil.copy(blob, d / "shard_0000.blob")
    shutil.copy(blob, d / "shard_0001.blob")
    with pytest.raises(ValueError) as ei:
        open_store(d, backend="auto")
    msg = str(ei.value)
    assert "federation" in msg and "shard_0000.blob" in msg
    # with the manifest present the same directory opens as a federation
    from repro.core import open_index
    from repro.core.federation import FederationManifest, discover_shards

    m = FederationManifest(metric="l2", dim=24, dtype="float16",
                          shards=discover_shards(d))
    m.save(d)
    with open_index(d) as fed:
        assert sorted(fed.shard_names) == ["shard_0000", "shard_0001"]
