"""Shard federation: one logical eCP index over many blob files.

Covers the subsystem's contract (core/federation.py): manifest
round-trip and discovery, ``open_index`` auto-detection, effort
conservation in ``allocate_effort``, scatter-gather search parity and
incremental continuation, routed inserts / fan-out deletes / per-shard
compaction, snapshot stability under live writes, live topology changes
(adopt/evict/refresh), serving-stack integration, and the
``MultiIndexSession`` at federation scale (many indexes under one tight
shared byte budget).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    ECPBuildConfig,
    FederatedIndex,
    MultiIndexSession,
    build_federation,
    build_index,
    convert,
    open_index,
)
from repro.core.federation import (
    MANIFEST_FILENAME,
    FederationManifest,
    allocate_effort,
    find_manifest,
)
from repro.data import clustered_vectors

DIM = 24
N = 3000
CFG = ECPBuildConfig(levels=2, cluster_cap=80, metric="l2")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """One 4-shard federation + the same data as a single blob index."""
    td = tmp_path_factory.mktemp("fed")
    data, _ = clustered_vectors(0, n=N, dim=DIM, n_clusters=24)
    root = build_federation(data, td / "fed", n_shards=4, cfg=CFG)
    build_index(data, str(td / "single"), CFG)
    blob = str(convert(str(td / "single"), td / "single.blob"))
    return {"td": td, "data": data, "root": root, "single_blob": blob}


@pytest.fixture()
def fed(built):
    f = FederatedIndex(built["root"])
    yield f
    f.close()


@pytest.fixture()
def mutable_root(built, tmp_path):
    """A throwaway copy of the federation for mutation tests."""
    import shutil

    root = tmp_path / "fed"
    shutil.copytree(built["root"], root)
    return root


# ---------------------------------------------------------------- manifest
def test_manifest_roundtrip(built, tmp_path):
    m = FederationManifest.load(built["root"])
    assert m.dim == DIM and m.metric == "l2" and len(m.shards) == 4
    m2 = FederationManifest.from_json(m.to_json())
    assert m2.to_json() == m.to_json()
    p = m2.save(tmp_path)
    assert p.name == MANIFEST_FILENAME
    assert FederationManifest.load(tmp_path).to_json() == m.to_json()
    # the on-disk form is plain JSON an external tool can read
    d = json.loads(p.read_text())
    assert {e["name"] for e in d["shards"]} == {f"shard_{i:04d}" for i in range(4)}


def test_find_manifest(built, tmp_path):
    root = built["root"]
    assert find_manifest(root) == root / MANIFEST_FILENAME
    assert find_manifest(root / MANIFEST_FILENAME) == root / MANIFEST_FILENAME
    assert find_manifest(tmp_path) is None
    assert find_manifest(built["single_blob"]) is None


def test_open_index_autodetects_federation(built):
    with open_index(built["root"]) as f:
        assert isinstance(f, FederatedIndex)
        assert len(f.shard_names) == 4
    with pytest.raises(ValueError, match="mode='file'"):
        open_index(built["root"], mode="packed")


def test_open_without_manifest_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match=MANIFEST_FILENAME):
        FederatedIndex(tmp_path)


def test_manifest_with_no_shards_raises(tmp_path):
    FederationManifest(metric="l2", dim=DIM, dtype="float32", shards=[]).save(tmp_path)
    with pytest.raises(ValueError, match="no shards"):
        FederatedIndex(tmp_path)


# ---------------------------------------------------------- effort splitting
def test_allocate_effort_conserves_exactly():
    rng = np.random.default_rng(0)
    d = rng.random(64)
    owner = rng.integers(0, 4, 64)
    for b in (1, 2, 3, 5, 8, 13, 24, 64, 100):
        probe, alloc = allocate_effort(d, owner, b, b_min=1)
        assert alloc.sum() == b
        assert (alloc >= 1).all()
        assert len(probe) == len(set(probe.tolist())) == len(alloc)


def test_allocate_effort_edge_cases():
    d = np.array([0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8])
    owner = np.array([0, 0, 1, 1, 2, 2, 3, 3])
    # b too small to fund 2 shards at b_min=2 -> single probed shard gets all
    probe, alloc = allocate_effort(d, owner, 3, b_min=2)
    assert alloc.tolist() == [3] and probe.tolist() == [0]
    probe, alloc = allocate_effort(d, owner, 1)
    assert alloc.tolist() == [1]
    # floor: every probed shard gets at least b_min
    probe, alloc = allocate_effort(d, owner, 16, b_min=4)
    assert alloc.sum() == 16 and (alloc >= 4).all() and len(alloc) == 4
    # top_m cap: at most m shards probed, still conserved and floored
    probe, alloc = allocate_effort(d, owner, 7, b_min=2, top_m=2)
    assert alloc.sum() == 7 and len(alloc) <= 2 and (alloc >= 2).all()
    with pytest.raises(ValueError):
        allocate_effort(np.array([]), np.array([]), 4)
    with pytest.raises(ValueError):
        allocate_effort(d, owner[:4], 4)


def test_allocate_effort_concentrates_on_semantic_signal():
    # shard 0 owns all the near centroids: it must get the lion's share
    d = np.array([0.01, 0.02, 0.03, 0.04, 5.0, 6.0, 7.0, 8.0])
    owner = np.array([0, 0, 0, 0, 1, 2, 3, 3])
    probe, alloc = allocate_effort(d, owner, 4)
    assert probe[0] == 0 and alloc[0] == alloc.max()
    assert alloc.sum() == 4


def test_search_effort_conserved_and_floored(fed, built):
    q = built["data"][7]
    for b in (3, 5, 8, 24):
        rs = fed.search(q, k=10, b=b)
        alloc = rs.query.allocation
        assert sum(alloc.values()) == b
        assert all(v >= fed.b_min for v in alloc.values())
        rs.query.close()


# ------------------------------------------------------------------ search
def test_single_shard_federation_matches_plain_index(built, tmp_path):
    data = built["data"]
    root = build_federation(data, tmp_path / "fed1", n_shards=1, cfg=CFG)
    with open_index(root) as f, open_index(
        built["single_blob"], mode="file", backend="blob"
    ) as single:
        assert len(f.shard_names) == 1
        for q in data[::700]:
            rs_f = f.search(q, k=10, b=12)
            rs_s = single.search(q, k=10, b=12)
            # one shard holds everything: scatter-gather must degenerate
            # to the plain traversal bit-for-bit
            np.testing.assert_array_equal(rs_f.ids, rs_s.ids)
            np.testing.assert_array_equal(rs_f.dists, rs_s.dists)
            rs_f.query.close()
            rs_s.query.close()


def test_results_sorted_and_ids_valid(fed, built):
    rs = fed.search(built["data"][42], k=20, b=16)
    dists = np.asarray(rs.dists).ravel()
    ids = np.asarray(rs.ids).ravel()
    assert (np.diff(dists) >= 0).all()
    assert len(set(ids.tolist())) == len(ids)
    assert ((ids >= 0) & (ids < fed.info.next_id)).all()
    rs.query.close()


def test_incremental_continuation_no_overlap(fed, built):
    rs1 = fed.search(built["data"][5], k=10, b=24)
    first = set(int(i) for i in np.asarray(rs1.ids).ravel())
    rs2 = rs1.query.next(10)
    second = set(int(i) for i in np.asarray(rs2.ids).ravel())
    assert second, "continuation returned nothing"
    assert not (first & second), "next(k) re-returned already-delivered ids"
    # continuation never returns anything closer than the first page's tail
    assert np.asarray(rs2.dists).ravel()[0] >= np.asarray(rs1.dists).ravel()[-1]
    rs1.query.close()


def test_batch_search(fed, built):
    Q = built["data"][:6]
    rs = fed.search(Q, k=8, b=12)
    assert np.asarray(rs.ids).shape == (6, 8)
    alloc = rs.query.allocation
    assert isinstance(alloc, list) and len(alloc) == 6
    assert all(sum(a.values()) == 12 for a in alloc)
    per = rs.query.shard_stats
    assert isinstance(per, list) and len(per) == 6
    rs.query.close()


def test_per_shard_stats_sum_to_aggregate(fed, built):
    rs = fed.search(built["data"][3], k=10, b=16)
    per = rs.query.shard_stats
    assert set(per) == set(rs.query.allocation)
    for field in ("leaves_opened", "distance_calcs", "node_loads"):
        assert getattr(rs.stats, field) == sum(
            getattr(st, field) for st in per.values()
        )
    assert rs.stats.io.bytes_read == sum(st.io.bytes_read for st in per.values())
    rs.query.close()


def test_federation_recall_close_to_single(fed, built):
    data = built["data"]
    rng = np.random.default_rng(11)
    queries = data[rng.integers(0, N, 32)]
    from repro.core.distances import np_distances

    gt = np.argsort(np_distances(queries, data, "l2"), axis=1, kind="stable")[:, :10]
    with open_index(built["single_blob"], mode="file", backend="blob") as single:
        def recall(idx):
            hits = 0
            for q, g in zip(queries, gt):
                rs = idx.search(q, k=10, b=24)
                hits += len(set(rs.row_ids(0)) & set(int(x) for x in g))
                rs.query.close()
            return hits / (len(queries) * 10)

        r_fed, r_single = recall(fed), recall(single)
    assert r_fed >= r_single - 0.05, (r_fed, r_single)


# --------------------------------------------------------------- mutation
def test_insert_routes_and_is_searchable(mutable_root):
    with FederatedIndex(mutable_root) as f:
        rng = np.random.default_rng(2)
        base = f.info.next_id
        gen0 = f.info.generation
        vecs = rng.normal(size=(32, DIM)).astype(np.float32)
        out = f.insert(vecs)
        assert out["inserted"] == 32
        assert sum(out["per_shard"].values()) == 32
        assert set(out["per_shard"]) <= set(f.shard_names)
        assert f.info.next_id == base + 32
        assert f.info.generation > gen0
        # every inserted vector findable at its exact location
        for i in (0, 13, 31):
            rs = f.search(vecs[i], k=1, b=8)
            assert int(np.asarray(rs.ids).ravel()[0]) == base + i
            rs.query.close()
    # the republished manifest names the new state for external readers
    m = FederationManifest.load(mutable_root)
    assert sum(e.get("n_items", 0) for e in m.shards) == N + 32


def test_insert_spills_off_overloaded_shard(mutable_root):
    with FederatedIndex(mutable_root, balance_factor=1.05) as f:
        rng = np.random.default_rng(3)
        # slam one region: without spill the nearest shard would absorb all
        q = rng.normal(size=DIM).astype(np.float32)
        vecs = np.repeat(q[None, :], 400, axis=0) + 0.01 * rng.normal(
            size=(400, DIM)
        ).astype(np.float32)
        out = f.insert(vecs)
        counts = [f.shard(n).info.n_items for n in f.shard_names]
        assert sum(out["per_shard"].values()) == 400
        # balance held: no shard exceeds the configured factor of the mean
        assert max(counts) <= 1.05 * (sum(counts) / len(counts)) + 1, counts


def test_insert_validates_shapes(fed):
    with pytest.raises(ValueError, match="vectors must be"):
        fed.insert(np.zeros((2, DIM + 1), np.float32))
    with pytest.raises(ValueError, match="ids must be"):
        fed.insert(np.zeros((2, DIM), np.float32), ids=np.arange(3))


def test_delete_fans_out_and_compact_purges(mutable_root):
    with FederatedIndex(mutable_root) as f:
        victim_ids = np.arange(0, 50, 5)
        n_live0 = f.info.n_items - len(f.tombstones)
        added = f.delete(victim_ids)
        assert added == len(victim_ids)
        assert set(int(i) for i in victim_ids) <= f.tombstones
        for v in victim_ids[:3]:
            rs = f.search(np.zeros(DIM, np.float32), k=50, b=24)
            assert int(v) not in set(rs.row_ids(0))
            rs.query.close()
        gen = f.info.generation
        out = f.compact()
        assert set(out["shards"]) == set(f.shard_names)
        assert not f.tombstones
        assert f.info.generation > gen
        assert f.info.n_items == n_live0 - len(victim_ids)
        # still searchable post-rewrite
        rs = f.search(np.zeros(DIM, np.float32), k=5, b=8)
        assert len(rs.row_ids(0)) == 5
        rs.query.close()


def test_compact_single_shard(mutable_root):
    with FederatedIndex(mutable_root) as f:
        name = f.shard_names[0]
        gen = f.shard(name).info.generation
        out = f.compact_shard(name)
        assert out["generation"] > gen or out["purged"] == 0
        with pytest.raises(KeyError):
            f.compact_shard("nope")


def test_snapshot_stable_under_live_writes(mutable_root):
    with FederatedIndex(mutable_root) as f:
        q = np.zeros(DIM, np.float32)
        snap = f.snapshot()
        rs0 = snap.search(q, k=10, b=16)
        ids0, d0 = np.asarray(rs0.ids).copy(), np.asarray(rs0.dists).copy()
        rs0.query.close()
        rng = np.random.default_rng(4)
        f.insert(0.01 * rng.normal(size=(64, DIM)).astype(np.float32))
        f.delete(np.asarray(ids0).ravel()[:3])
        # the pinned view must not move, bit for bit
        rs1 = snap.search(q, k=10, b=16)
        np.testing.assert_array_equal(rs1.ids, ids0)
        np.testing.assert_array_equal(rs1.dists, d0)
        rs1.query.close()
        snap.close()
        # the live view did move
        rs2 = f.search(q, k=10, b=16)
        assert set(np.asarray(rs2.ids).ravel()) != set(ids0.ravel())
        rs2.query.close()


# ---------------------------------------------------------------- topology
def test_adopt_and_evict_shard(mutable_root, tmp_path):
    rng = np.random.default_rng(5)
    extra = rng.normal(size=(300, DIM)).astype(np.float32)
    with FederatedIndex(mutable_root) as f:
        base = f.info.next_id
        build_index(
            extra, str(tmp_path / "x"), CFG,
            item_ids=np.arange(base, base + 300),
        )
        blob = convert(str(tmp_path / "x"), tmp_path / "extra.blob")
        name = f.adopt_shard(blob)
        assert name == "extra" and name in f.shard_names
        assert f.info.n_items >= N + 300
        # b large enough that the off-distribution shard wins router votes
        rs = f.search(extra[0], k=1, b=32)
        assert "extra" in rs.query.allocation
        assert int(np.asarray(rs.ids).ravel()[0]) == base
        rs.query.close()
        # the manifest on disk now names 5 shards
        assert len(FederationManifest.load(mutable_root).shards) == 5
        info = f.evict_shard(name)
        assert info.n_items == 300
        assert name not in f.shard_names
        assert len(FederationManifest.load(mutable_root).shards) == 4
        with pytest.raises(KeyError):
            f.evict_shard(name)


def test_adopt_rejects_dim_mismatch(fed, tmp_path):
    data, _ = clustered_vectors(9, n=200, dim=DIM + 8, n_clusters=4)
    build_index(data, str(tmp_path / "bad"), CFG)
    blob = convert(str(tmp_path / "bad"), tmp_path / "bad.blob")
    with pytest.raises(ValueError, match="dim"):
        fed.adopt_shard(blob)
    assert "bad" not in fed.shard_names


def test_evict_last_shard_refused(built, tmp_path):
    root = build_federation(built["data"][:500], tmp_path / "f1", n_shards=1, cfg=CFG)
    with FederatedIndex(root) as f:
        with pytest.raises(ValueError, match="last shard"):
            f.evict_shard(f.shard_names[0])


def test_refresh_sees_external_writer(mutable_root):
    reader = FederatedIndex(mutable_root)
    writer = FederatedIndex(mutable_root)
    try:
        gen0 = reader.info.generation
        rng = np.random.default_rng(6)
        vecs = rng.normal(size=(16, DIM)).astype(np.float32)
        base = writer.info.next_id
        writer.insert(vecs)
        # the reader is stale until it polls
        assert reader.info.generation == gen0
        reader.refresh()
        assert reader.info.generation > gen0
        assert reader.info.next_id == base + 16
        rs = reader.search(vecs[0], k=1, b=8)
        assert int(np.asarray(rs.ids).ravel()[0]) == base
        rs.query.close()
    finally:
        reader.close()
        writer.close()


# ------------------------------------------------------------- serving stack
def test_server_integration(mutable_root, built):
    from repro.launch.serve import Server

    fed = FederatedIndex(mutable_root)
    q = built["data"][1]
    with Server(fed, workers=2, queue_depth=16) as srv:
        rs, sid = srv.search(q, k=10, b=12)
        assert len(rs.row_ids(0)) == 10
        srv.close(sid)
        base = int(fed.info.next_id)
        srv.insert(
            np.random.default_rng(8).normal(size=(24, DIM)).astype(np.float32),
            np.arange(base, base + 24),
        )
        assert fed.info.next_id == base + 24
        fut = fed.compact_async(scheduler=srv.scheduler)
        out = fut.result(timeout=60)
        assert set(out["shards"]) == set(fed.shard_names)
        st = srv.scheduler.stats.as_dict()
        assert st["submitted"] == st["completed"] + st["rejected"] + st["failed"]


# ------------------------------------------- MultiIndexSession at fleet scale
def _open_fleet(sess, built, n=8):
    """>=8 file-mode indexes under ONE shared budget: each federation
    shard blob opened twice under distinct names."""
    shard_blobs = sorted(Path(built["root"]).glob("*.blob"))
    assert len(shard_blobs) == 4
    names = []
    for rep in range(n // len(shard_blobs)):
        for p in shard_blobs:
            name = f"{p.stem}@{rep}"
            sess.open(str(p), name, backend="blob")
            names.append(name)
    return names


def test_session_federation_scale_shared_budget(built):
    # budget fits ~2.5 indexes' working sets: the fleet must still serve
    # correct results while evicting globally-LRU across all 8 indexes
    sess = MultiIndexSession(cache_bytes=64 << 20)
    try:
        names = _open_fleet(sess, built, n=8)
        assert len(names) == 8 and sorted(sess.names()) == sorted(names)
        q = built["data"][0]
        sess.search(names[0], q, k=5, b=6).query.close()
        one = sess.stats()["per_index"][names[0]]["bytes"]
        assert one > 0
        sess.resize(cache_bytes=int(2.5 * one))
        for _ in range(3):  # round-robin: everyone churns the one cache
            for nm in names:
                rs = sess.search(nm, q, k=5, b=6)
                assert len(rs.row_ids(0)) == 5
                rs.query.close()
        st = sess.stats()
        assert st["resident_bytes"] <= st["budget_bytes"]
        assert st["evictions"] > 0, "tight budget never evicted"
        per = st["per_index"]
        assert set(per) == set(names)
        assert sum(v["bytes"] for v in per.values()) == st["resident_bytes"]
        # fairness: the budget is shared, not monopolized — with a
        # round-robin workload more than one index stays resident and
        # nobody holds the entire budget
        resident = [nm for nm, v in per.items() if v["nodes"] > 0]
        assert len(resident) >= 2, per
        assert max(v["bytes"] for v in per.values()) < st["budget_bytes"], per
    finally:
        sess.close()


def test_session_resize_shrinks_fleet_live(built):
    sess = MultiIndexSession(cache_bytes=64 << 20)
    try:
        names = _open_fleet(sess, built, n=8)
        q = built["data"][9]
        for nm in names:
            sess.search(nm, q, k=5, b=8).query.close()
        before = sess.stats()["resident_bytes"]
        assert before > 0
        shrunk = max(1, before // 8)
        sess.resize(cache_bytes=shrunk)
        st = sess.stats()
        assert st["resident_bytes"] <= shrunk < before
        for nm in names:  # fleet still serves after the shrink
            rs = sess.search(nm, q, k=5, b=6)
            assert len(rs.row_ids(0)) == 5
            rs.query.close()
    finally:
        sess.close()


def test_session_invalidate_after_external_writer(built, tmp_path):
    import shutil

    blob = tmp_path / "shared.blob"
    shutil.copy(sorted(Path(built["root"]).glob("*.blob"))[0], blob)
    sess = MultiIndexSession(cache_bytes=1 << 20)
    try:
        idx = sess.open(str(blob), "shared", backend="blob")
        sess.search("shared", built["data"][0], k=5, b=6).query.close()
        gen0 = idx.info.generation
        # an external process mutates the file behind the session's back
        with open_index(str(blob), mode="file", backend="blob") as writer:
            base = writer.info.next_id
            writer.insert(
                np.random.default_rng(10).normal(size=(8, DIM)).astype(np.float32),
                ids=np.arange(base, base + 8),
            )
        assert idx.info.generation == gen0  # stale until invalidated
        sess.invalidate("shared")
        assert idx.info.generation > gen0
        assert idx.info.next_id == base + 8
    finally:
        sess.close()


def test_session_close_releases_fds(built):
    def n_fds():
        return len(os.listdir("/proc/self/fd"))

    base = n_fds()
    sess = MultiIndexSession(cache_bytes=1 << 20)
    names = _open_fleet(sess, built, n=8)
    for nm in names:
        sess.search(nm, built["data"][2], k=3, b=4).query.close()
    assert n_fds() >= base + 8  # every blob holds an fd while open
    sess.close()
    assert n_fds() <= base + 1, "close() leaked store fds"


def test_session_opens_whole_federation(built):
    # a federation root opened through the session shares the budget too
    sess = MultiIndexSession(cache_bytes=2 << 20)
    try:
        f = sess.open(str(built["root"]), "fed", backend="blob")
        assert isinstance(f, FederatedIndex)
        rs = sess.search("fed", built["data"][4], k=10, b=12)
        assert len(rs.row_ids(0)) == 10
        rs.query.close()
        per = sess.stats()["per_index"]["fed"]
        assert per["bytes"] > 0, "federation shards bypassed the shared cache"
    finally:
        sess.close()


# ------------------------------------------------------------ replica demo
def test_replica_readers_demo_smoke():
    """The multi-process demo is itself a cross-process invariant check:
    run it CI-sized and require a clean exit."""
    repo = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run(
        [sys.executable, str(repo / "examples" / "replica_readers.py"), "--smoke"],
        env=env,
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "replica demo OK" in r.stdout
