"""Tiered shard federation — one logical eCP index over many blob files.

The paper's file structure has a hard ceiling: one index, one file.  This
module composes N per-shard ``ECPIndex`` files into a single logical
``Searcher``/``MutableIndex`` behind a small in-memory *master router*
built from each shard's top-level leader centroids (the root node every
shard already reads at open, §4.2) — the FusionANNS recipe of a cheap
top-level structure routing over disk-resident partitions with a per-
partition effort budget.

  * ``FederationManifest`` — a human-readable JSON file
    (``federation.json``) in the federation root, keeping the paper's
    file-structure idiom: shard names/paths/backends, per-shard
    generations and item counts, and the router centroids, so external
    tools can route (or audit) without opening a single shard.
  * ``FederatedIndex`` — scatter-gather search: the router scores each
    shard by its nearest leader centroid, ``allocate_effort`` splits the
    effort knob ``b`` across the top-m shards proportionally to router
    affinity (total conserved exactly, floor ``b_min`` per probed
    shard), per-shard ``ResultSet`` streams merge through one global
    top-k heap, and ``SearchStats``/``IOStats`` aggregate per shard and
    in total.  Inserts route to the nearest shard leader (spilling to
    the emptiest shard past a balance threshold), deletes fan out,
    ``compact`` runs shard-by-shard (``compact_async`` through the
    serving scheduler, so snapshot readers re-pin between shards and
    never block).
  * ``FederatedSnapshot`` — generation-pinned read-only view composed of
    per-shard ``ECPSnapshot``\\ s; the serving scheduler leases it like a
    single-file snapshot.
  * ``build_federation`` — split one collection into N shards, build +
    convert each, write the manifest.

Shards share one ``NodeCache`` (namespaced ``<fed>/<shard>``), so a
federation opened through ``MultiIndexSession`` draws from the session's
shared byte budget like any other index.  ``open_index`` auto-detects
``federation.json``.
"""
from __future__ import annotations

import json
import os
import threading
from concurrent.futures import Future
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from .api import NodeCache, Query, ResultSet, SearchStats, pack_rows
from .distances import np_distances
from .store import BLOB_FILENAME

MANIFEST_FILENAME = "federation.json"
MANIFEST_FORMAT = "ecp-federation/1"

__all__ = [
    "MANIFEST_FILENAME",
    "FederationManifest",
    "FederationInfo",
    "FederatedIndex",
    "FederatedSnapshot",
    "FederatedQuery",
    "allocate_effort",
    "build_federation",
    "find_manifest",
    "discover_shards",
]


# ----------------------------------------------------------------- manifest
def find_manifest(path) -> Path | None:
    """The federation manifest at/under ``path``, or None.  Accepts the
    manifest file itself or a directory containing one."""
    p = Path(path)
    if p.is_file() and p.name == MANIFEST_FILENAME:
        return p
    if p.is_dir() and (p / MANIFEST_FILENAME).is_file():
        return p / MANIFEST_FILENAME
    return None


def discover_shards(root) -> list[dict]:
    """Shard-looking entries directly under ``root``: blob files, blob
    directories, and fstore index roots.  Used by ``adopt_shard`` and the
    ``open_store`` auto-detection diagnostics."""
    out = []
    p = Path(root)
    if not p.is_dir():
        return out
    for child in sorted(p.iterdir()):
        if child.name == MANIFEST_FILENAME:
            continue
        if child.is_file() and child.suffix == ".blob":
            out.append({"name": child.stem, "path": child.name, "backend": "blob"})
        elif child.is_dir() and (child / BLOB_FILENAME).is_file():
            out.append({"name": child.name, "path": child.name, "backend": "blob"})
        elif child.is_dir() and (child / ".zgroup").is_file():
            out.append({"name": child.name, "path": child.name, "backend": "fstore"})
    return out


@dataclass
class FederationManifest:
    """The on-disk description of a federation (``federation.json``).

    ``shards`` entries are plain dicts — ``name``, ``path`` (relative to
    the manifest's directory), ``backend`` (``blob``/``fstore``),
    ``generation``, ``n_items``, and ``router`` (that shard's top-level
    leader centroids as nested lists) — so the file stays greppable and
    hand-editable, like every other file in the structure.
    """

    metric: str
    dim: int
    dtype: str = "float16"
    shards: list[dict] = field(default_factory=list)
    format: str = MANIFEST_FORMAT

    def to_json(self) -> dict:
        return {
            "format": self.format,
            "metric": self.metric,
            "dim": int(self.dim),
            "dtype": self.dtype,
            "shards": self.shards,
        }

    @staticmethod
    def from_json(d: dict) -> "FederationManifest":
        fmt = str(d.get("format", ""))
        if not fmt.startswith("ecp-federation/"):
            raise ValueError(f"not a federation manifest (format={fmt!r})")
        return FederationManifest(
            metric=d["metric"],
            dim=int(d["dim"]),
            dtype=d.get("dtype", "float16"),
            shards=list(d.get("shards", [])),
            format=fmt,
        )

    def save(self, root) -> Path:
        """Atomically (tmp + rename) write ``root/federation.json``."""
        root = Path(root)
        dst = root / MANIFEST_FILENAME if root.is_dir() or not root.suffix else root
        tmp = dst.with_name(dst.name + ".tmp")
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=2)
            f.write("\n")
        os.replace(tmp, dst)
        return dst

    @staticmethod
    def load(path) -> "FederationManifest":
        mp = find_manifest(path)
        if mp is None:
            raise FileNotFoundError(f"no {MANIFEST_FILENAME} at {path}")
        with open(mp) as f:
            return FederationManifest.from_json(json.load(f))


@dataclass
class FederationInfo:
    """The ``info`` shim the serving layer reads off any index: totals
    over the live shards (generation = sum of shard generations, so every
    shard mutation moves it monotonically; next_id = max, so federation-
    allocated ids never collide with any shard's)."""

    dim: int
    metric: str
    dtype: str
    n_items: int
    n_shards: int
    generation: int
    next_id: int
    version: str = MANIFEST_FORMAT


# ------------------------------------------------------------ effort split
def allocate_effort(
    d: np.ndarray,
    owner: np.ndarray,
    b: int,
    *,
    n_shards: int | None = None,
    b_min: int = 1,
    top_m: int | None = None,
    probe_m: int = 1,
) -> tuple[np.ndarray, np.ndarray]:
    """Split total effort ``b`` across shards by a global leader vote.

    ``d[j]``: the query's distance to router centroid ``j``; ``owner[j]``:
    which shard that centroid belongs to.  The ``b`` globally-nearest
    centroids each cast one vote for their shard — so a random split
    (every shard statistically identical) degrades to a near-uniform
    split, while a semantic split (one shard owns the query's region)
    concentrates effort there.  Effort goes to the ``top_m`` most-voted
    shards, proportionally to votes, floored at ``b_min``, and rounding
    is repaired so ``alloc.sum() == b`` EXACTLY — federating conserves
    total effort, never amplifies it.

    Budget-floor rule (the documented clamp): a probed shard must be
    fundable at its effective floor ``b_min_eff = max(1, b_min) *
    max(1, probe_m)`` — multi-probe traversal (``probe_m > 1``) widens
    each shard's per-increment leaf appetite, so the floor scales with
    it.  When ``b < m * b_min_eff`` the probe count is CLAMPED to
    ``max(1, b // b_min_eff)`` rather than thinning allocations below
    the floor; with ``b_min=0`` there is no caller floor (the effective
    floor is 1: a probed shard always gets at least one leaf).  Negative
    ``b_min`` raises ``ValueError``.

    Returns ``(probe, alloc)``: probed shard indices (most-voted first)
    and their integer ``b`` shares.
    """
    d = np.asarray(d, np.float64).reshape(-1)
    owner = np.asarray(owner, np.int64).reshape(-1)
    if len(d) == 0 or len(d) != len(owner):
        raise ValueError("allocate_effort: empty or mismatched router arrays")
    if int(b_min) < 0:
        raise ValueError(f"b_min must be >= 0, got {b_min}")
    S = int(owner.max()) + 1 if n_shards is None else int(n_shards)
    b = max(1, int(b))
    b_min_eff = max(1, int(b_min)) * max(1, int(probe_m))
    ranked = np.argsort(d, kind="stable")[: max(1, b)]
    votes = np.zeros(S, np.float64)
    np.add.at(votes, owner[ranked], 1.0)
    shard_min = np.full(S, np.inf)
    np.minimum.at(shard_min, owner, d)
    # most-voted first; ties break by nearest centroid, then shard index
    cand = sorted(
        (i for i in range(S) if np.isfinite(shard_min[i])),
        key=lambda i: (-votes[i], shard_min[i], i),
    )
    cand = [i for i in cand if votes[i] > 0] or cand[:1]
    m = len(cand) if top_m is None else max(1, min(int(top_m), len(cand)))
    m = min(m, max(1, b // b_min_eff))  # the documented clamp: never fund
    # more shards than b can cover at b_min_eff each
    probe = np.asarray(cand[:m], np.int64)
    if m == 1:
        return probe, np.array([b], np.int64)
    w = votes[probe]
    if w.sum() <= 0:
        w = np.ones(m)
    alloc = np.maximum(b_min_eff, np.floor(b * w / w.sum())).astype(np.int64)
    diff = b - int(alloc.sum())
    i = 0
    while diff > 0:  # hand out the remainder most-voted-first
        alloc[i % m] += 1
        diff -= 1
        i += 1
    while diff < 0:  # claw back overshoot least-voted-first, floor intact
        j = m - 1 - (i % m)
        if alloc[j] > b_min_eff:
            alloc[j] -= 1
            diff += 1
        i += 1
    return probe, alloc


def _sum_stats(per: list[SearchStats]) -> SearchStats:
    tot = SearchStats()
    for s in per:
        if s is None:
            continue
        tot.node_loads += s.node_loads
        tot.nodes_opened += s.nodes_opened
        tot.leaves_opened += s.leaves_opened
        tot.distance_calcs += s.distance_calcs
        tot.increments += s.increments
        tot.rounds += s.rounds
        tot.dedup_hits += s.dedup_hits
        tot.io.add(s.io)
    return tot


# ------------------------------------------------------------- query merge
class _ShardStream:
    """One probed shard's emission stream: the sorted pairs of its latest
    emission buffer, refilled from the underlying ``ECPQuery`` on demand."""

    __slots__ = ("name", "query", "buf", "pos", "exhausted")

    def __init__(self, name: str, rs: ResultSet):
        self.name = name
        self.query = rs.query
        self.buf: list[tuple[float, int]] = rs.pairs()
        self.pos = 0
        self.exhausted = not self.buf and rs.query is None

    def head(self) -> tuple[float, int] | None:
        return self.buf[self.pos] if self.pos < len(self.buf) else None

    def pop(self) -> tuple[float, int]:
        v = self.buf[self.pos]
        self.pos += 1
        return v

    def refill(self, k: int) -> None:
        """Ask the shard for its next emission (one more ``next(k)``)."""
        if self.exhausted or self.query is None or self.query.closed:
            self.exhausted = True
            return
        pairs = self.query.next(k).pairs()
        if pairs:
            self.buf = pairs
            self.pos = 0
        else:
            self.exhausted = True


class _RowState:
    """Per-query-row scatter state: probed shards, their allocations, and
    the merge streams."""

    __slots__ = ("streams", "allocation")

    def __init__(self, streams: list[_ShardStream], allocation: dict):
        self.streams = streams
        self.allocation = allocation

    def merge(self, k: int, *, refill: bool) -> tuple[list, list]:
        """Pop the next k globally-smallest pairs across the streams.
        With ``refill`` (continuations), an empty stream pulls its next
        emission; the initial emission merges only what the allotted
        per-shard ``b`` already bought."""
        if refill:
            for st in self.streams:
                if st.head() is None:
                    st.refill(k)
        dists: list[float] = []
        ids: list[int] = []
        while len(ids) < k:
            best = None
            for st in self.streams:
                h = st.head()
                if h is not None and (best is None or h < best.head()):
                    best = st
            if best is None:
                break
            d, i = best.pop()
            dists.append(d)
            ids.append(i)
            if refill and best.head() is None:
                best.refill(k)
        return dists, ids

    def stats(self) -> dict[str, SearchStats]:
        out = {}
        for st in self.streams:
            if st.query is not None:
                out[st.name] = st.query.stats
        return out

    def close(self) -> None:
        for st in self.streams:
            if st.query is not None and not st.query.closed:
                st.query.close()


class FederatedQuery(Query):
    """The incremental handle of a federated search: a k-way merge over
    the probed shards' own ``ECPQuery`` streams.  ``next(k)`` lets each
    underfull stream advance (the shards' Algorithm 2 continuations) and
    re-merges; per-shard effort stays observable via ``allocation`` and
    ``shard_stats``."""

    def __init__(self, rows: list[_RowState], *, single: bool):
        self._rows = rows
        self._single = single

    @property
    def allocation(self) -> dict | list[dict]:
        """Per-shard effort (``b``) granted to this query; a dict for a
        single-row query, a list of dicts for a batch."""
        if self._single:
            return dict(self._rows[0].allocation)
        return [dict(r.allocation) for r in self._rows]

    @property
    def shard_stats(self) -> dict | list[dict]:
        """Cumulative per-shard ``SearchStats`` (single: dict; batch:
        list of dicts)."""
        if self._single:
            return self._rows[0].stats()
        return [r.stats() for r in self._rows]

    @property
    def stats(self):
        """Aggregated total(s) across the probed shards."""
        if self._single:
            return _sum_stats(list(self._rows[0].stats().values()))
        return [_sum_stats(list(r.stats().values())) for r in self._rows]

    def next(self, k: int) -> ResultSet:
        self._ensure_open()
        rows = [r.merge(k, refill=True) for r in self._rows]
        d, i = pack_rows([r[0] for r in rows], [r[1] for r in rows], k)
        if self._single:
            return ResultSet(dists=d[0], ids=i[0], stats=self.stats, query=self)
        return ResultSet(dists=d, ids=i, stats=self.stats, query=self)

    def close(self) -> None:
        if not self._closed:
            for r in self._rows:
                r.close()
        super().close()


# -------------------------------------------------------- scatter-gather
class _ScatterGather:
    """Search core shared by ``FederatedIndex`` and ``FederatedSnapshot``.

    Hosts provide ``_shard_names`` / ``_shard_objs`` (parallel lists),
    ``_router_emb`` (stacked leader centroids), ``_router_owner`` (which
    shard each centroid belongs to), ``_router_slices`` (one ``(lo, hi)``
    per shard into the stack), ``metric``, ``b_min``, ``top_m`` and
    ``probe_m``."""

    _shard_names: list
    _shard_objs: list
    _router_emb: np.ndarray
    _router_owner: np.ndarray
    _router_slices: list
    metric: str
    b_min: int
    top_m: int | None
    probe_m: int = 1

    def shard_affinity(self, q: np.ndarray) -> np.ndarray:
        """Router score per shard: distance to its nearest top-level
        leader centroid.  ``q`` [D] -> [S] (or [B, D] -> [B, S])."""
        d = np_distances(q, self._router_emb, self.metric)
        lo_hi = self._router_slices
        if d.ndim == 1:
            return np.array([d[lo:hi].min() for lo, hi in lo_hi], np.float32)
        return np.stack([d[:, lo:hi].min(axis=1) for lo, hi in lo_hi], axis=1)

    def _search_row(
        self, q: np.ndarray, k: int, b: int, mx_inc: int, exclude, probe_m: int
    ) -> _RowState:
        probe, alloc = allocate_effort(
            np_distances(q, self._router_emb, self.metric),
            self._router_owner,
            b,
            n_shards=len(self._shard_objs),
            b_min=self.b_min,
            top_m=self.top_m,
            probe_m=probe_m,  # probing widens per-shard effort demand
        )
        streams, allocation = [], {}
        for si, bi in zip(probe, alloc):
            name = self._shard_names[int(si)]
            rs = self._shard_objs[int(si)].search(
                q, k, b=int(bi), mx_inc=mx_inc, exclude=exclude, probe_m=probe_m
            )
            allocation[name] = int(bi)
            streams.append(_ShardStream(name, rs))
        return _RowState(streams, allocation)

    def search(
        self,
        q: np.ndarray,
        k: int = 100,
        *,
        b: int | None = 8,
        mx_inc: int = 4,
        exclude: set | None = None,
        probe_m: int | None = None,
    ) -> ResultSet:
        """Scatter-gather search over one vector [D] or a batch [B, D]:
        route, split ``b``, search each probed shard, merge the emissions
        through one global top-k (shard id spaces are disjoint, so the
        merge never deduplicates).  ``probe_m`` (default: the federation's
        configured value) is forwarded to every probed shard's traversal
        and widens the allocator's per-shard funding floor."""
        if not self._shard_objs:
            raise ValueError("federation has no shards")
        b = 8 if b is None else int(b)
        pm = self.probe_m if probe_m is None else max(1, int(probe_m))
        q = np.asarray(q, np.float32)
        single = q.ndim == 1
        Q = q[None, :] if single else q
        states = [self._search_row(row, k, b, mx_inc, exclude, pm) for row in Q]
        rows = [st.merge(k, refill=False) for st in states]
        d, i = pack_rows([r[0] for r in rows], [r[1] for r in rows], k)
        query = FederatedQuery(states, single=single)
        if single:
            return ResultSet(dists=d[0], ids=i[0], stats=query.stats, query=query)
        return ResultSet(dists=d, ids=i, stats=query.stats, query=query)


# ------------------------------------------------------------------- index
class FederatedIndex(_ScatterGather):
    """One logical eCP index over N shard files (a ``Searcher`` and a
    ``MutableIndex``).  See the module docstring for the architecture;
    every mutation rewrites the manifest (tmp + rename) so the on-disk
    description always names the published per-shard generations."""

    def __init__(
        self,
        path,
        *,
        backend: str = "auto",
        prefetch: bool = False,
        cache: NodeCache | None = None,
        namespace: str | None = None,
        cache_max_nodes: int | None = None,
        cache_max_bytes: int | None = None,
        b_min: int = 1,
        top_m: int | None = None,
        probe_m: int = 1,
        balance_factor: float = 2.0,
        **shard_kw,
    ):
        mp = find_manifest(path)
        if mp is None:
            raise FileNotFoundError(f"no {MANIFEST_FILENAME} at {path}")
        self.root = mp.parent
        self.manifest = FederationManifest.load(mp)
        self.cache = (
            cache
            if cache is not None
            else NodeCache(cache_max_nodes, max_bytes=cache_max_bytes)
        )
        self._ns = namespace if namespace is not None else str(self.root)
        self._mut_lock = threading.RLock()
        self.b_min = max(1, int(b_min))
        self.top_m = top_m
        self.probe_m = max(1, int(probe_m))
        self.balance_factor = float(balance_factor)
        self._default_backend = backend
        self._shard_kw = dict(prefetch=prefetch, **shard_kw)
        self._shards: dict[str, object] = {}
        for entry in self.manifest.shards:
            self._open_shard(entry)
        if not self._shards:
            raise ValueError(f"federation manifest lists no shards: {mp}")
        self._rebuild_router()

    # ------------------------------------------------------------ plumbing
    def _open_shard(self, entry: dict):
        from .search import ECPIndex

        name = entry["name"]
        if name in self._shards:
            raise ValueError(f"duplicate shard name in manifest: {name!r}")
        idx = ECPIndex(
            str(self.root / entry["path"]),
            backend=entry.get("backend", self._default_backend),
            cache=self.cache,
            namespace=f"{self._ns}/{name}",
            **self._shard_kw,
        )
        if self._shards:
            first = next(iter(self._shards.values()))
            if idx.info.dim != first.info.dim or idx.info.metric != first.info.metric:
                idx.close()
                raise ValueError(
                    f"shard {name!r} is dim={idx.info.dim}/{idx.info.metric}, "
                    f"federation is dim={first.info.dim}/{first.info.metric}"
                )
        self._shards[name] = idx
        return idx

    def _rebuild_router(self) -> None:
        """Stack the shards' top-level leader centroids (each shard's root
        node, already memory-resident) into the router arrays."""
        names, objs, slices, blocks = [], [], [], []
        at = 0
        for name, idx in self._shards.items():
            emb = np.asarray(idx.root_emb, np.float32)
            names.append(name)
            objs.append(idx)
            slices.append((at, at + len(emb)))
            blocks.append(emb)
            at += len(emb)
        self._shard_names = names
        self._shard_objs = objs
        self._router_slices = slices
        self._router_emb = (
            np.concatenate(blocks, axis=0)
            if blocks
            else np.empty((0, self.manifest.dim), np.float32)
        )
        self._router_owner = np.concatenate(
            [np.full(hi - lo, i, np.int64) for i, (lo, hi) in enumerate(slices)]
        ) if slices else np.empty(0, np.int64)

    def _save_manifest(self) -> None:
        """Re-derive the manifest from the live shards and rewrite it."""
        entries = []
        for name, idx in self._shards.items():
            spath = Path(idx._reopen["path"]) if idx._reopen else Path(name)
            try:
                rel = spath.relative_to(self.root)
            except ValueError:
                rel = Path(os.path.relpath(spath, self.root))
            entries.append(
                {
                    "name": name,
                    "path": str(rel),
                    "backend": idx.store.backend.split("+")[0],
                    "generation": int(idx.info.generation),
                    "n_items": int(idx.info.n_items),
                    "router": [
                        [round(float(x), 6) for x in row]
                        for row in np.asarray(idx.root_emb, np.float32)
                    ],
                }
            )
        self.manifest.shards = entries
        self.manifest.save(self.root)

    # ------------------------------------------------------------- surface
    @property
    def metric(self) -> str:
        return self.manifest.metric

    @property
    def shard_names(self) -> list[str]:
        return list(self._shards)

    def shard(self, name: str):
        return self._shards[name]

    @property
    def info(self) -> FederationInfo:
        shards = list(self._shards.values())
        return FederationInfo(
            dim=shards[0].info.dim if shards else self.manifest.dim,
            metric=self.manifest.metric,
            dtype=self.manifest.dtype,
            n_items=sum(s.info.n_items for s in shards),
            n_shards=len(shards),
            generation=sum(s.info.generation for s in shards),
            next_id=max((s.info.next_id for s in shards), default=0),
        )

    @property
    def generation(self) -> int:
        return self.info.generation

    @property
    def tombstones(self) -> set:
        out: set = set()
        for s in self._shards.values():
            out |= s.tombstones
        return out

    @property
    def supports_snapshot(self) -> bool:
        """True when every shard's store pins generations (blob)."""
        return bool(self._shards) and all(
            getattr(s.store, "pin", None) is not None for s in self._shards.values()
        )

    # ----------------------------------------------------------- mutation
    def insert(self, vectors, ids=None) -> dict:
        """Route each vector to the shard whose leader is nearest; a shard
        already holding more than ``balance_factor`` times the mean load
        spills to the emptiest shard instead.  Ids default from the
        federation-wide allocator (max of the shards' ``next_id``), so
        they stay unique across every shard."""
        with self._mut_lock:
            Q = np.asarray(vectors, np.float32)
            if Q.ndim == 1:
                Q = Q[None, :]
            n = len(Q)
            dim = self.info.dim
            if Q.ndim != 2 or (n and Q.shape[1] != dim):
                raise ValueError(f"vectors must be [n, {dim}], got {list(Q.shape)}")
            if ids is None:
                base = self.info.next_id
                ids = np.arange(base, base + n, dtype=np.int64)
            else:
                ids = np.asarray(ids, np.int64)
                if ids.shape != (n,):
                    raise ValueError(f"ids must be [n]={n}, got {list(ids.shape)}")
            if n == 0:
                return {
                    "inserted": 0,
                    "splits": 0,
                    "leaves": 0,
                    "generation": self.info.generation,
                    "per_shard": {},
                }
            names = self._shard_names
            counts = {nm: self._shards[nm].info.n_items for nm in names}
            total = sum(counts.values()) + n
            threshold = self.balance_factor * max(1.0, total / len(names))
            nearest = np.argmin(self.shard_affinity(Q), axis=1)
            target: dict[str, list[int]] = {}
            for r in range(n):
                nm = names[int(nearest[r])]
                if counts[nm] + 1 > threshold:  # overloaded: spill
                    nm = min(counts, key=lambda x: (counts[x], names.index(x)))
                counts[nm] += 1
                target.setdefault(nm, []).append(r)
            out = {"inserted": n, "splits": 0, "leaves": 0, "per_shard": {}}
            for nm, rows in target.items():
                r = self._shards[nm].insert(Q[rows], ids[rows])
                out["splits"] += r["splits"]
                out["leaves"] += r["leaves"]
                out["per_shard"][nm] = len(rows)
            self._rebuild_router()  # splits can rewrite a shard's root
            self._save_manifest()
            out["generation"] = self.info.generation
            return out

    def delete(self, ids) -> int:
        """Fan the tombstones out to every shard (ids are not located
        first — a tombstone for an absent id is a harmless no-op, and
        per-shard ``compact`` clears them).  Returns the number of ids
        newly tombstoned federation-wide."""
        with self._mut_lock:
            added = max(s.delete(ids) for s in self._shards.values())
            self._save_manifest()
            return added

    def compact(self) -> dict:
        """Compact every shard in turn (each a deterministic rebuild of
        its live items).  Snapshot readers keep their pinned generations
        throughout; use ``compact_async`` to run this off-thread through
        the serving scheduler."""
        out = {}
        for name in list(self._shards):
            out[name] = self.compact_shard(name)
        return {"shards": out, "generation": self.info.generation}

    def compact_shard(self, name: str) -> dict:
        """Compact one shard and republish the manifest — the unit of
        background compaction (scheduler ``mutate`` granularity)."""
        with self._mut_lock:
            out = self._shards[name].compact()
            self._rebuild_router()
            self._save_manifest()
            return out

    def compact_async(self, scheduler=None) -> Future:
        """Background per-shard compaction.  With a ``RequestScheduler``,
        each shard goes through ``scheduler.mutate`` so readers re-pin to
        the fresh generation after every shard and never block mid-sweep;
        without one the shards compact directly.  Returns a ``Future``
        resolving to the per-shard result dict."""
        fut: Future = Future()

        def run() -> None:
            try:
                out = {}
                for name in list(self._shards):
                    step = lambda nm=name: self.compact_shard(nm)  # noqa: E731
                    out[name] = scheduler.mutate(step) if scheduler else step()
                fut.set_result({"shards": out, "generation": self.info.generation})
            except BaseException as e:  # surfaced via fut.result()
                fut.set_exception(e)

        threading.Thread(target=run, daemon=True, name="fed-compact").start()
        return fut

    # ---------------------------------------------------------- snapshots
    def snapshot(self) -> "FederatedSnapshot":
        """A generation-pinned read-only view: one ``ECPSnapshot`` per
        shard plus a frozen router, taken atomically under the mutation
        lock so the pinned per-shard generations are a published state."""
        if not self.supports_snapshot:
            raise NotImplementedError(
                "snapshot() needs every shard on a generation-pinning "
                "store (blob); serialize readers and writers externally "
                "instead (launch/scheduler.py does)"
            )
        with self._mut_lock:
            return FederatedSnapshot(self)

    # ------------------------------------------------------------ topology
    def adopt_shard(self, path, name: str | None = None) -> str:
        """Bring a shard discovered on disk into the federation live: open
        it, validate dim/metric, extend the router, republish the
        manifest."""
        with self._mut_lock:
            p = Path(path)
            if name is None:
                name = p.stem if p.is_file() else p.name
            entry = {"name": name, "path": str(p), "backend": self._default_backend}
            self._open_shard(entry)
            self._rebuild_router()
            self._save_manifest()
            return name

    def evict_shard(self, name: str):
        """Remove a shard from the federation (its files stay on disk);
        returns the closed shard's last ``IndexInfo``."""
        with self._mut_lock:
            if name not in self._shards:
                raise KeyError(f"no such shard: {name!r}")
            if len(self._shards) == 1:
                raise ValueError("cannot evict the last shard")
            idx = self._shards.pop(name)
            info = idx.info
            idx.close()
            self.cache.invalidate_namespace(f"{self._ns}/{name}")
            self._rebuild_router()
            self._save_manifest()
            return info

    def refresh(self) -> None:
        """Resynchronize with the files after an external writer changed
        them: re-read the manifest (adopting/evicting shards it gained or
        lost), refresh every remaining shard, rebuild the router."""
        with self._mut_lock:
            self.manifest = FederationManifest.load(self.root)
            listed = {e["name"]: e for e in self.manifest.shards}
            for name in [n for n in self._shards if n not in listed]:
                idx = self._shards.pop(name)
                idx.close()
                self.cache.invalidate_namespace(f"{self._ns}/{name}")
            for name, idx in self._shards.items():
                idx.refresh()
            for name, entry in listed.items():
                if name not in self._shards:
                    self._open_shard(entry)
            self._rebuild_router()

    def close(self) -> None:
        """Close every shard (store fds, prefetch executors).  Idempotent."""
        for idx in self._shards.values():
            idx.close()

    def __enter__(self) -> "FederatedIndex":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class FederatedSnapshot(_ScatterGather):
    """Read-only scatter-gather over per-shard ``ECPSnapshot``\\ s, pinned
    at one published federation generation.  Refcounted like
    ``ECPSnapshot`` (``acquire``/``release``) so the serving scheduler can
    lease it across concurrent requests."""

    def __init__(self, parent: FederatedIndex):
        taken = []
        try:
            for name in parent._shard_names:
                taken.append((name, parent._shards[name].snapshot()))
        except BaseException:
            for _, s in taken:
                s.close()
            raise
        self._shard_names = [n for n, _ in taken]
        self._shard_objs = [s for _, s in taken]
        self._router_emb = parent._router_emb.copy()
        self._router_owner = parent._router_owner.copy()
        self._router_slices = list(parent._router_slices)
        self.metric = parent.metric
        self.b_min = parent.b_min
        self.top_m = parent.top_m
        self.probe_m = parent.probe_m
        self.generation = sum(s.generation for s in self._shard_objs)
        self._refs = 1
        self._lock = threading.Lock()

    @property
    def supports_snapshot(self) -> bool:
        return False  # already one; snapshot-of-snapshot is not a thing

    def acquire(self) -> "FederatedSnapshot":
        with self._lock:
            if self._refs <= 0:
                raise RuntimeError("FederatedSnapshot already released")
            self._refs += 1
        return self

    def release(self) -> None:
        with self._lock:
            self._refs -= 1
            drop = self._refs == 0
        if drop:
            for s in self._shard_objs:
                s.close()

    def close(self) -> None:
        self.release()

    def __enter__(self) -> "FederatedSnapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ------------------------------------------------------------------- build
def build_federation(
    data: np.ndarray,
    root,
    *,
    n_shards: int,
    cfg,
    item_ids: np.ndarray | None = None,
    backend: str = "blob",
    keep_fstore: bool = False,
) -> Path:
    """Split ``data`` into ``n_shards`` contiguous slices, build each as
    its own eCP index under ``root`` (``shard_0000`` ...), convert to the
    single-file blob form when ``backend="blob"``, and write the
    federation manifest.  Returns the federation root.

    Contiguous slicing keeps ids globally unique and (for shuffled
    collections) statistically uniform; callers wanting semantic shards
    can pass pre-partitioned data per shard through repeated
    ``adopt_shard`` instead.
    """
    import shutil

    from .build import build_index
    from .store import convert

    data = np.asarray(data, np.float32)
    n = len(data)
    if n_shards < 1 or n_shards > n:
        raise ValueError(f"n_shards must be in [1, {n}], got {n_shards}")
    if item_ids is None:
        item_ids = np.arange(n, dtype=np.int64)
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    bounds = np.linspace(0, n, n_shards + 1).astype(np.int64)
    entries = []
    for i in range(n_shards):
        lo, hi = int(bounds[i]), int(bounds[i + 1])
        name = f"shard_{i:04d}"
        fdir = root / name
        store = build_index(data[lo:hi], str(fdir), cfg, item_ids=item_ids[lo:hi])
        store.close()
        if backend == "blob":
            blob = root / f"{name}.blob"
            convert(str(fdir), str(blob))
            if not keep_fstore:
                shutil.rmtree(fdir)
            entries.append({"name": name, "path": blob.name, "backend": "blob"})
        else:
            entries.append({"name": name, "path": name, "backend": "fstore"})
    manifest = FederationManifest(
        metric=cfg.metric, dim=int(data.shape[1]), dtype=cfg.storage_dtype, shards=entries
    )
    manifest.save(root)
    # one open/close pass fills in generations, counts, and router blocks
    fed = FederatedIndex(root)
    fed._save_manifest()
    fed.close()
    return root
