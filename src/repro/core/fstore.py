"""fstore — a minimal, zero-dependency, zarr-v2-compatible file structure.

The paper's point is that the index *is* a file structure: every node is a
directory, every array a set of raw chunk files plus JSON metadata, so the
index is readable from any language (and by humans with ``ls`` + ``xxd``).
Zarr itself is not installed in this environment, so we implement the v2
on-disk layout directly:

  group/            .zgroup   -> {"zarr_format": 2}
                    .zattrs   -> arbitrary JSON attributes
  array/            .zarray   -> shape/chunks/dtype/order metadata, compressor
                                 null (raw little-endian C-order bytes)
                    0.0, 1.0  -> chunk files (row-major chunk grid indices)

Arrays written here are readable by the real ``zarr`` library and vice versa
(for compressor=None arrays), which preserves the paper's language-agnostic
claim. Only the features the index needs are implemented: C-order raw chunks,
chunking along the leading axis, partial (chunk-aligned) reads.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Iterator

import numpy as np

__all__ = ["FStore", "dtype_to_zarr", "zarr_to_dtype"]

_ENDIAN = "<"  # little-endian on disk, always


def dtype_to_zarr(dt: np.dtype) -> str:
    dt = np.dtype(dt)
    kind = dt.kind
    if kind not in "fiub":
        raise TypeError(f"unsupported dtype for fstore: {dt}")
    if kind == "b":
        return "|b1"
    return f"{_ENDIAN}{kind}{dt.itemsize}"


def zarr_to_dtype(s: str) -> np.dtype:
    return np.dtype(s)


class FStore:
    """A root directory acting as a zarr-v2 style hierarchical store."""

    def __init__(self, root: str | os.PathLike, *, create: bool = False):
        self.root = Path(root)
        if create:
            self.root.mkdir(parents=True, exist_ok=True)
            self._write_json(self.root / ".zgroup", {"zarr_format": 2})
        if not self.root.is_dir():
            raise FileNotFoundError(f"fstore root does not exist: {self.root}")
        self._root = self.root.resolve()  # resolved ONCE; _p is on the hot path
        self._lock = threading.Lock()
        self.io = None  # optional IOStats sink (set by FStoreBackend)

    # ---------------------------------------------------------------- paths
    def _p(self, path: str) -> Path:
        # Fast path: relative, '..'-free paths join the pre-resolved root
        # without any syscalls (this is under every node read).  Only
        # lexical escapes (absolute paths, '..' segments) pay for a resolve
        # check; a symlink planted INSIDE the store pointing outside is
        # deliberately not re-checked per access — the store owns its tree.
        path = str(path)
        if (
            path.startswith(("/", "\\"))
            or ":" in path.split("/", 1)[0]  # windows drive-absolute
            or ".." in path.replace("\\", "/").split("/")
        ):
            p = (self._root / path).resolve()
            if self._root not in p.parents and p != self._root:
                raise ValueError(f"path escapes store root: {path}")
            return p
        return self._root / path

    def _count_io(self, nbytes: int, *, files: int = 1, reads: int = 1) -> None:
        if self.io is not None:
            self.io.count(nbytes, files=files, reads=reads)

    def exists(self, path: str) -> bool:
        return self._p(path).exists()

    def is_array(self, path: str) -> bool:
        return (self._p(path) / ".zarray").exists()

    def is_group(self, path: str) -> bool:
        return (self._p(path) / ".zgroup").exists()

    def listdir(self, path: str = "") -> list[str]:
        p = self._p(path)
        if not p.is_dir():
            return []
        return sorted(c.name for c in p.iterdir() if not c.name.startswith("."))

    def walk_arrays(self, path: str = "") -> Iterator[str]:
        base = self._p(path)
        for dirpath, dirnames, filenames in os.walk(base):
            if ".zarray" in filenames:
                yield str(Path(dirpath).relative_to(self.root))
                dirnames.clear()

    def delete(self, path: str) -> None:
        p = self._p(path)
        if p.exists():
            shutil.rmtree(p)

    # ---------------------------------------------------------------- json
    @staticmethod
    def _write_json(p: Path, obj: Any) -> None:
        tmp = p.with_suffix(p.suffix + ".tmp")
        tmp.write_text(json.dumps(obj, indent=2, sort_keys=True))
        os.replace(tmp, p)

    def _read_json(self, p: Path) -> Any:
        raw = p.read_bytes()
        self._count_io(len(raw))
        return json.loads(raw)

    # ---------------------------------------------------------------- groups
    def create_group(self, path: str, attrs: dict | None = None) -> None:
        p = self._p(path)
        p.mkdir(parents=True, exist_ok=True)
        self._write_json(p / ".zgroup", {"zarr_format": 2})
        if attrs:
            self.write_attrs(path, attrs)

    def write_attrs(self, path: str, attrs: dict) -> None:
        p = self._p(path)
        p.mkdir(parents=True, exist_ok=True)
        self._write_json(p / ".zattrs", attrs)

    def read_attrs(self, path: str) -> dict:
        p = self._p(path) / ".zattrs"
        if not p.exists():
            return {}
        return self._read_json(p)

    # ---------------------------------------------------------------- arrays
    def write_array(
        self,
        path: str,
        arr: np.ndarray,
        *,
        chunk_rows: int | None = None,
        attrs: dict | None = None,
    ) -> None:
        """Write ``arr`` as a raw-chunked zarr-v2 array (chunked on axis 0)."""
        arr = np.ascontiguousarray(arr)
        p = self._p(path)
        p.mkdir(parents=True, exist_ok=True)
        shape = list(arr.shape) if arr.ndim else [1]
        data = arr.reshape(shape)
        rows = shape[0]
        cr = rows if chunk_rows is None else max(1, min(int(chunk_rows), max(rows, 1)))
        if rows == 0:
            cr = 1
        chunks = [cr] + shape[1:]
        meta = {
            "zarr_format": 2,
            "shape": shape,
            "chunks": chunks,
            "dtype": dtype_to_zarr(data.dtype),
            "compressor": None,
            "fill_value": 0,
            "order": "C",
            "filters": None,
        }
        self._write_json(p / ".zarray", meta)
        if attrs:
            self._write_json(p / ".zattrs", attrs)
        n_chunks = max(1, -(-rows // cr))
        trailing_zeros = ".".join(["0"] * (len(shape) - 1))
        for ci in range(n_chunks):
            lo, hi = ci * cr, min((ci + 1) * cr, rows)
            block = data[lo:hi]
            if block.shape[0] < cr:  # zarr pads the final chunk to full size
                pad = np.zeros((cr - block.shape[0],) + block.shape[1:], data.dtype)
                block = np.concatenate([block, pad], axis=0)
            name = str(ci) if not trailing_zeros else f"{ci}.{trailing_zeros}"
            tmp = p / (name + ".tmp")
            tmp.write_bytes(np.ascontiguousarray(block).tobytes())
            os.replace(tmp, p / name)
        # overwriting a larger array leaves chunk files past the new grid;
        # reads honor the metadata, but stale chunks would shadow future
        # appends (and lie to anyone inspecting the files) — drop them
        for child in p.iterdir():
            if child.name.startswith("."):
                continue
            head = child.name.split(".", 1)[0]
            if head.isdigit() and int(head) >= n_chunks:
                child.unlink()

    def append_rows(self, path: str, arr: np.ndarray, *, chunk_rows: int | None = None) -> None:
        """Append rows to an axis-0-chunked array, touching only the
        trailing partial chunk plus the new chunks (the out-of-core build
        appends leaf blocks incrementally; rewriting the whole array per
        append would be quadratic).  Creates the array when missing
        (``chunk_rows`` then sets the chunk size).  The metadata's shape is
        rewritten *after* the chunk files, so a torn append leaves the old
        (consistent) view."""
        arr = np.ascontiguousarray(arr)
        if arr.shape[0] == 0:
            if not self.is_array(path):
                self.write_array(path, arr, chunk_rows=chunk_rows)
            return
        if not self.is_array(path):
            self.write_array(path, arr, chunk_rows=chunk_rows)
            return
        p = self._p(path)
        meta = self.array_meta(path)
        shape, chunks = meta["shape"], meta["chunks"]
        dt = zarr_to_dtype(meta["dtype"])
        if list(arr.shape[1:]) != shape[1:] or np.dtype(arr.dtype) != dt:
            raise ValueError(
                f"append_rows mismatch at {path}: array is {shape[1:]}/{dt}, "
                f"got {list(arr.shape[1:])}/{arr.dtype}"
            )
        rows, cr = shape[0], chunks[0]
        if rows == 0:
            # zero-row arrays carry a degenerate 1-row chunk grid; replace
            # wholesale so the appended array gets a sensible chunk size
            self.write_array(path, arr, chunk_rows=chunk_rows)
            return
        trailing_zeros = ".".join(["0"] * (len(shape) - 1))

        def chunk_name(ci: int) -> str:
            return str(ci) if not trailing_zeros else f"{ci}.{trailing_zeros}"

        new_rows = rows + arr.shape[0]
        at = 0  # rows of ``arr`` consumed
        # 1) fill the trailing partial chunk in place (tmp + replace)
        if rows % cr:
            ci = rows // cr
            fill = min(cr - rows % cr, arr.shape[0])
            cp = p / chunk_name(ci)
            block = np.frombuffer(cp.read_bytes(), dtype=dt).reshape([cr] + shape[1:]).copy()
            block[rows % cr : rows % cr + fill] = arr[:fill]
            tmp = p / (chunk_name(ci) + ".tmp")
            tmp.write_bytes(block.tobytes())
            os.replace(tmp, cp)
            at = fill
        # 2) whole new chunks
        ci = (rows + at) // cr
        while at < arr.shape[0]:
            block = arr[at : at + cr]
            if block.shape[0] < cr:
                pad = np.zeros((cr - block.shape[0],) + block.shape[1:], dt)
                block = np.concatenate([block, pad], axis=0)
            tmp = p / (chunk_name(ci) + ".tmp")
            tmp.write_bytes(np.ascontiguousarray(block).tobytes())
            os.replace(tmp, p / chunk_name(ci))
            at += cr
            ci += 1
        meta["shape"] = [new_rows] + shape[1:]
        self._write_json(p / ".zarray", meta)

    def array_meta(self, path: str) -> dict:
        return self._read_json(self._p(path) / ".zarray")

    def read_array(self, path: str) -> np.ndarray:
        meta = self.array_meta(path)
        shape = meta["shape"]
        chunks = meta["chunks"]
        dt = zarr_to_dtype(meta["dtype"])
        rows, cr = shape[0], chunks[0]
        n_chunks = max(1, -(-rows // cr))
        p = self._p(path)
        trailing_zeros = ".".join(["0"] * (len(shape) - 1))
        parts = []
        for ci in range(n_chunks):
            name = str(ci) if not trailing_zeros else f"{ci}.{trailing_zeros}"
            raw = (p / name).read_bytes()
            self._count_io(len(raw))
            block = np.frombuffer(raw, dtype=dt).reshape([cr] + shape[1:])
            parts.append(block)
        out = np.concatenate(parts, axis=0)[:rows] if parts else np.zeros(shape, dt)
        return np.ascontiguousarray(out.reshape(shape))

    def read_rows(self, path: str, lo: int, hi: int) -> np.ndarray:
        """Partial read of rows [lo, hi): reads only the BYTES covering the
        requested rows of each chunk file (chunks are raw C-order with
        leading-axis chunking, so a row range is contiguous in its chunk)."""
        meta = self.array_meta(path)
        shape, chunks = meta["shape"], meta["chunks"]
        dt = zarr_to_dtype(meta["dtype"])
        cr = chunks[0]
        hi = min(hi, shape[0])
        lo = max(0, lo)
        if hi <= lo:
            return np.zeros([0] + shape[1:], dt)
        c_lo, c_hi = lo // cr, -(-hi // cr)
        p = self._p(path)
        trailing_zeros = ".".join(["0"] * (len(shape) - 1))
        row_shape = shape[1:]
        row_nbytes = dt.itemsize * int(np.prod(row_shape, dtype=np.int64))
        parts = []
        for ci in range(c_lo, c_hi):
            name = str(ci) if not trailing_zeros else f"{ci}.{trailing_zeros}"
            r_lo = max(lo - ci * cr, 0)          # first needed row inside chunk
            r_hi = min(hi - ci * cr, cr)         # one past the last needed row
            with open(p / name, "rb") as f:
                if r_lo:
                    f.seek(r_lo * row_nbytes)
                raw = f.read((r_hi - r_lo) * row_nbytes)
            self._count_io(len(raw))
            parts.append(np.frombuffer(raw, dtype=dt).reshape([r_hi - r_lo] + row_shape))
        if len(parts) == 1:
            return np.ascontiguousarray(parts[0])
        return np.ascontiguousarray(np.concatenate(parts, axis=0))

    def array_nbytes(self, path: str) -> int:
        meta = self.array_meta(path)
        dt = zarr_to_dtype(meta["dtype"])
        n = 1
        for s in meta["shape"]:
            n *= s
        return n * dt.itemsize
