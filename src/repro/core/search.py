"""eCP-FS retrieval: lazy node loading, LRU cache, incremental search.

Faithful implementation of the paper's Algorithms 1-3:
  * ``NewSearch``       — create a query state (Q, T, I), run one increment,
                          return the first k items plus a query id.
  * ``GetNextKItems``   — pop k items from I, resuming the tree search via
                          ``IncrementalSearch`` when I underflows.
  * ``IncrementalSearch`` — single cross-level priority queue T: always open
                          the globally most promising node regardless of
                          level; leaves append scanned items to I; after b
                          leaves, either return (|I| >= k) or double b
                          (bounded by mx_inc) and continue.

Node data is loaded on first access and kept in a bounded LRU cache
(paper §4.2); prefetching up to a level runs on background threads.

Two deliberate fixes of apparent pseudocode typos (semantics follow the
paper's prose): (1) Algorithm 2 line 4 checks ``cnt = 0`` but the text says
"in case there is not enough [items] it resumes the search" — we resume when
``cnt < k``; (2) Algorithm 3 line 26 reads ``increments > mx_inc`` where the
prose caps doubling at mx_inc — we double while ``increments < mx_inc`` (or
mx_inc == -1 meaning unbounded).
"""
from __future__ import annotations

import heapq
import itertools
import threading
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np

from . import layout
from .distances import np_distances
from .fstore import FStore

__all__ = ["NodeCache", "ECPIndex", "QueryState", "SearchStats"]


class NodeCache:
    """LRU cache over (level, node) -> (embeddings f32, ids).

    ``max_nodes``: None = unbounded; 0 = caching off (free after use);
    n > 0 = keep at most n nodes resident. Tunable at runtime (paper §4.2).
    """

    def __init__(self, max_nodes: int | None = None):
        self.max_nodes = max_nodes
        self._d: OrderedDict[tuple[int, int], tuple[np.ndarray, np.ndarray]] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def resize(self, max_nodes: int | None) -> None:
        with self._lock:
            self.max_nodes = max_nodes
            self._evict_locked()

    def _evict_locked(self) -> None:
        if self.max_nodes is None:
            return
        while len(self._d) > self.max_nodes:
            self._d.popitem(last=False)
            self.evictions += 1

    def get(self, key):
        with self._lock:
            v = self._d.get(key)
            if v is not None:
                self._d.move_to_end(key)
                self.hits += 1
            else:
                self.misses += 1
            return v

    def put(self, key, value) -> None:
        if self.max_nodes == 0:
            return
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            self._evict_locked()

    @property
    def n_resident(self) -> int:
        return len(self._d)

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return sum(e.nbytes + i.nbytes for e, i in self._d.values())

    def clear(self) -> None:
        with self._lock:
            self._d.clear()


@dataclass
class SearchStats:
    node_loads: int = 0            # disk reads (cache misses served from files)
    nodes_opened: int = 0          # total nodes popped from T
    leaves_opened: int = 0
    distance_calcs: int = 0        # individual distance computations
    increments: int = 0            # b-doublings


@dataclass
class QueryState:
    """Persistent per-query state (paper §4.3): Q.q, Q.T, Q.I."""

    q: np.ndarray
    b: int
    mx_inc: int
    exclude: set = field(default_factory=set)
    T: list = field(default_factory=list)              # heap of (d, tie, is_leaf, level, node)
    I: list = field(default_factory=list)              # sorted [(d, item_id)]
    started: bool = False
    increments: int = 0
    emitted: int = 0
    stats: SearchStats = field(default_factory=SearchStats)
    _tie: "itertools.count" = field(default_factory=itertools.count)


class ECPIndex:
    """Open an eCP-FS file structure for retrieval."""

    def __init__(
        self,
        path: str | FStore,
        *,
        cache_max_nodes: int | None = None,
        prefetch_workers: int = 4,
    ):
        self.store = path if isinstance(path, FStore) else FStore(path)
        self.info = layout.IndexInfo.from_attrs(self.store.read_attrs(layout.INFO))
        # Loading the index = read info + index_root only (paper §4.2).
        self.root_emb = self.store.read_array(f"{layout.ROOT}/{layout.EMB}").astype(np.float32)
        self.root_ids = self.store.read_array(f"{layout.ROOT}/{layout.IDS}")
        self.cache = NodeCache(cache_max_nodes)
        self.QS: list[QueryState] = []
        self._prefetch_workers = prefetch_workers
        self.load_node_count = 0

    # ------------------------------------------------------------ node IO
    def get_node(self, level: int, node: int) -> tuple[np.ndarray, np.ndarray]:
        key = (level, node)
        v = self.cache.get(key)
        if v is not None:
            return v
        g = layout.node_group(level, node)
        emb_path = f"{g}/{layout.EMB}"
        if not self.store.exists(emb_path):
            v = (np.zeros((0, self.info.dim), np.float32), np.zeros((0,), np.int64))
        else:
            emb = self.store.read_array(emb_path).astype(np.float32)  # f16 -> f32 (paper)
            ids = self.store.read_array(f"{g}/{layout.IDS}")
            v = (emb, ids)
        self.load_node_count += 1
        self.cache.put(key, v)
        return v

    def prefetch(self, up_to_level: int) -> None:
        """Background-load all nodes at levels 1..up_to_level (paper §4.2)."""
        keys = [
            (lv, j)
            for lv in range(1, min(up_to_level, self.info.levels) + 1)
            for j in range(self.info.nodes_per_level[lv - 1])
        ]
        with ThreadPoolExecutor(max_workers=self._prefetch_workers) as ex:
            list(ex.map(lambda k: self.get_node(*k), keys))

    # ------------------------------------------------------- Algorithm 1
    def new_search(
        self,
        q: np.ndarray,
        k: int = 100,
        *,
        b: int = 8,
        mx_inc: int = 4,
        exclude: set | None = None,
    ) -> tuple[list[tuple[float, int]], int]:
        qs = QueryState(
            q=np.asarray(q, np.float32),
            b=b,
            mx_inc=mx_inc,
            exclude=set(exclude) if exclude else set(),
        )
        self.QS.append(qs)
        q_id = len(self.QS) - 1
        self._incremental_search(q_id, k)
        return self.get_next_k(q_id, k), q_id

    # ------------------------------------------------------- Algorithm 2
    def get_next_k(self, q_id: int, k: int) -> list[tuple[float, int]]:
        qs = self.QS[q_id]
        cnt = min(len(qs.I), k)
        if cnt < k and qs.T:
            self._incremental_search(q_id, k)
            cnt = min(len(qs.I), k)
        out, qs.I = qs.I[:cnt], qs.I[cnt:]
        qs.emitted += len(out)
        return out

    # ------------------------------------------------------- Algorithm 3
    def _incremental_search(self, q_id: int, k: int) -> None:
        qs = self.QS[q_id]
        info = self.info
        metric = info.metric
        leaf_cnt = 0
        loads_before = self.load_node_count

        if not qs.started:
            qs.started = True
            d = np_distances(qs.q, self.root_emb, metric)
            qs.stats.distance_calcs += len(self.root_emb)
            is_leaf = 1 if info.levels == 1 else 0
            for c, dist in zip(self.root_ids, d):
                heapq.heappush(qs.T, (float(dist), next(qs._tie), is_leaf, 1, int(c)))

        while qs.T:
            dist, _, is_leaf, level, node = heapq.heappop(qs.T)
            qs.stats.nodes_opened += 1
            emb, ids = self.get_node(level, node)
            if len(ids) == 0:
                continue
            d = np_distances(qs.q, emb, metric)
            qs.stats.distance_calcs += len(ids)
            if is_leaf:
                qs.stats.leaves_opened += 1
                for c, cd in zip(ids, d):
                    c = int(c)
                    if c not in qs.exclude:
                        qs.I.append((float(cd), c))
                leaf_cnt += 1
            else:
                next_is_leaf = 1 if (level + 1) == info.levels else 0
                for c, cd in zip(ids, d):
                    heapq.heappush(
                        qs.T, (float(cd), next(qs._tie), next_is_leaf, level + 1, int(c))
                    )
            if is_leaf and leaf_cnt >= qs.b:
                if len(qs.I) >= k:
                    break
                if qs.mx_inc == -1 or qs.increments < qs.mx_inc:
                    qs.increments += 1
                    qs.stats.increments += 1
                    qs.b *= 2
                else:
                    break
        qs.stats.node_loads += self.load_node_count - loads_before
        qs.I.sort(key=lambda t: t[0])

    # ------------------------------------------------------------- misc
    def drop_query(self, q_id: int) -> None:
        self.QS[q_id] = None  # type: ignore[assignment]

    def save_query_state(self, q_id: int, group: str = "query_states") -> None:
        """Persist a query state into the same file structure (paper §6.2)."""
        qs = self.QS[q_id]
        g = f"{group}/q_{q_id:06d}"
        self.store.create_group(g)
        self.store.write_array(f"{g}/query", qs.q)
        if qs.I:
            d = np.asarray([x[0] for x in qs.I], np.float32)
            i = np.asarray([x[1] for x in qs.I], np.int64)
        else:
            d = np.zeros((0,), np.float32)
            i = np.zeros((0,), np.int64)
        self.store.write_array(f"{g}/item_dists", d)
        self.store.write_array(f"{g}/item_ids", i)
        if qs.T:
            t = np.asarray(
                [(e[0], e[2], e[3], e[4]) for e in qs.T], np.float64
            )
        else:
            t = np.zeros((0, 4), np.float64)
        self.store.write_array(f"{g}/frontier", t)
        self.store.write_attrs(
            g,
            {
                "b": qs.b,
                "mx_inc": qs.mx_inc,
                "increments": qs.increments,
                "emitted": qs.emitted,
                "started": qs.started,
                "exclude": sorted(int(x) for x in qs.exclude),
            },
        )

    def load_query_state(self, q_id: int, group: str = "query_states") -> int:
        g = f"{group}/q_{q_id:06d}"
        a = self.store.read_attrs(g)
        qs = QueryState(
            q=self.store.read_array(f"{g}/query"),
            b=int(a["b"]),
            mx_inc=int(a["mx_inc"]),
            exclude=set(a.get("exclude", [])),
        )
        qs.increments = int(a["increments"])
        qs.emitted = int(a["emitted"])
        qs.started = bool(a["started"])
        d = self.store.read_array(f"{g}/item_dists")
        i = self.store.read_array(f"{g}/item_ids")
        qs.I = [(float(x), int(y)) for x, y in zip(d, i)]
        t = self.store.read_array(f"{g}/frontier")
        for row in t:
            heapq.heappush(
                qs.T, (float(row[0]), next(qs._tie), int(row[1]), int(row[2]), int(row[3]))
            )
        self.QS.append(qs)
        return len(self.QS) - 1
