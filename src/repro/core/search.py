"""eCP-FS retrieval: lazy node loading, LRU cache, incremental search.

Faithful implementation of the paper's Algorithms 1-3 behind the unified
``Searcher`` API (core/api.py):

  * ``ECPIndex.search(q, k, *, b)``  — Algorithm 1 (NewSearch): create the
    per-query state (Q, T, I), run one increment, return the first k items
    in a ``ResultSet`` whose ``.query`` handle owns the state.
  * ``ECPQuery.next(k)``             — Algorithm 2 (GetNextKItems): pop k
    items from I, resuming the tree search when I underflows.
  * ``_incremental_search``          — Algorithm 3: single cross-level
    priority queue T: always open the globally most promising node
    regardless of level; leaves append scanned items to I; after b leaves,
    either return (|I| >= k) or double b (bounded by mx_inc) and continue.

Node data is loaded on first access and kept in a bounded LRU cache
(paper §4.2) which may be private or shared across indexes
(``MultiIndexSession``); prefetching up to a level runs on background
threads.

Two deliberate fixes of apparent pseudocode typos (semantics follow the
paper's prose): (1) Algorithm 2 line 4 checks ``cnt = 0`` but the text says
"in case there is not enough [items] it resumes the search" — we resume when
``cnt < k``; (2) Algorithm 3 line 26 reads ``increments > mx_inc`` where the
prose caps doubling at mx_inc — we double while ``increments < mx_inc`` (or
mx_inc == -1 meaning unbounded).
"""
from __future__ import annotations

import heapq
import itertools
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np

from . import layout
from .api import NodeCache, Query, ResultSet, SearchStats, pack_rows
from .distances import np_distances
from .fstore import FStore

__all__ = ["ECPIndex", "ECPQuery", "QueryState", "NodeCache", "SearchStats"]


@dataclass
class QueryState:
    """Persistent per-query state (paper §4.3): Q.q, Q.T, Q.I."""

    q: np.ndarray
    b: int
    mx_inc: int
    exclude: set = field(default_factory=set)
    T: list = field(default_factory=list)              # heap of (d, tie, is_leaf, level, node)
    I: list = field(default_factory=list)              # sorted [(d, item_id)]
    started: bool = False
    increments: int = 0
    emitted: int = 0
    stats: SearchStats = field(default_factory=SearchStats)
    _tie: "itertools.count" = field(default_factory=itertools.count)


class ECPQuery(Query):
    """Handle over one ``ECPIndex.search`` call (single query or a batch).

    Owns one ``QueryState`` per query row; ``next(k)`` resumes the
    incremental search, ``save()`` persists the frontier into the index's
    own file structure (paper §6.2), ``close()`` frees the states — any
    later call raises ``QueryClosedError`` (no silent ``None`` holes).
    """

    def __init__(self, index: "ECPIndex", states: list[QueryState], *, single: bool):
        self._index = index
        self._states = states
        self._single = single

    # ------------------------------------------------------------- access
    @property
    def states(self) -> list[QueryState]:
        self._ensure_open()
        return self._states

    @property
    def state(self) -> QueryState:
        """The sole state of a single-query handle."""
        self._ensure_open()
        if len(self._states) != 1:
            raise ValueError("state is for single-query handles; use states")
        return self._states[0]

    @property
    def stats(self):
        self._ensure_open()
        if self._single:
            return self._states[0].stats
        return [s.stats for s in self._states]

    @property
    def b(self):
        self._ensure_open()
        if self._single:
            return self._states[0].b
        return [s.b for s in self._states]

    # -------------------------------------------------------- continuation
    def next(self, k: int) -> ResultSet:
        self._ensure_open()
        rows = [self._index._next_items(qs, k) for qs in self._states]
        return self._index._result(rows, self._states, k, self._single, self)

    # -------------------------------------------------------- persistence
    def save(self, name: str | None = None, *, group: str = "query_states") -> str:
        """Persist all row states; returns the token ``load_query`` takes."""
        self._ensure_open()
        store = self._index.store
        if name is None:
            existing = set(store.listdir(group)) if store.exists(group) else set()
            n = 0
            while f"q_{n:06d}" in existing:
                n += 1
            name = f"q_{n:06d}"
        g = f"{group}/{name}"
        store.create_group(g, attrs={"n_rows": len(self._states), "single": self._single})
        for r, qs in enumerate(self._states):
            rg = f"{g}/row_{r:06d}"
            store.create_group(rg)
            store.write_array(f"{rg}/query", qs.q)
            if qs.I:
                d = np.asarray([x[0] for x in qs.I], np.float32)
                i = np.asarray([x[1] for x in qs.I], np.int64)
            else:
                d = np.zeros((0,), np.float32)
                i = np.zeros((0,), np.int64)
            store.write_array(f"{rg}/item_dists", d)
            store.write_array(f"{rg}/item_ids", i)
            if qs.T:
                t = np.asarray([(e[0], e[2], e[3], e[4]) for e in qs.T], np.float64)
            else:
                t = np.zeros((0, 4), np.float64)
            store.write_array(f"{rg}/frontier", t)
            store.write_attrs(
                rg,
                {
                    "b": qs.b,
                    "mx_inc": qs.mx_inc,
                    "increments": qs.increments,
                    "emitted": qs.emitted,
                    "started": qs.started,
                    "exclude": sorted(int(x) for x in qs.exclude),
                },
            )
        return name

    def close(self) -> None:
        self._states = []
        super().close()


class ECPIndex:
    """Open an eCP-FS file structure for retrieval (the ``Searcher`` for
    file mode: bounded memory, true incremental continuation)."""

    def __init__(
        self,
        path: str | FStore,
        *,
        cache: NodeCache | None = None,
        namespace: str | None = None,
        cache_max_nodes: int | None = None,
        cache_max_bytes: int | None = None,
        prefetch_workers: int = 4,
    ):
        self.store = path if isinstance(path, FStore) else FStore(path)
        self.info = layout.IndexInfo.from_attrs(self.store.read_attrs(layout.INFO))
        # Loading the index = read info + index_root only (paper §4.2).
        self.root_emb = self.store.read_array(f"{layout.ROOT}/{layout.EMB}").astype(np.float32)
        self.root_ids = self.store.read_array(f"{layout.ROOT}/{layout.IDS}")
        self.cache = cache if cache is not None else NodeCache(
            cache_max_nodes, max_bytes=cache_max_bytes
        )
        # namespace tag keeps keys distinct inside a shared session cache
        self._ns = namespace if namespace is not None else str(self.store.root)
        self._prefetch_workers = prefetch_workers
        self.load_node_count = 0

    # ------------------------------------------------------------ node IO
    def get_node(self, level: int, node: int) -> tuple[np.ndarray, np.ndarray]:
        key = (self._ns, level, node)
        v = self.cache.get(key)
        if v is not None:
            return v
        g = layout.node_group(level, node)
        emb_path = f"{g}/{layout.EMB}"
        if not self.store.exists(emb_path):
            v = (np.zeros((0, self.info.dim), np.float32), np.zeros((0,), np.int64))
        else:
            emb = self.store.read_array(emb_path).astype(np.float32)  # f16 -> f32 (paper)
            ids = self.store.read_array(f"{g}/{layout.IDS}")
            v = (emb, ids)
        self.load_node_count += 1
        self.cache.put(key, v)
        return v

    def prefetch(self, up_to_level: int) -> None:
        """Background-load all nodes at levels 1..up_to_level (paper §4.2)."""
        keys = [
            (lv, j)
            for lv in range(1, min(up_to_level, self.info.levels) + 1)
            for j in range(self.info.nodes_per_level[lv - 1])
        ]
        with ThreadPoolExecutor(max_workers=self._prefetch_workers) as ex:
            list(ex.map(lambda k: self.get_node(*k), keys))

    # ------------------------------------------------------- Algorithm 1
    def search(
        self,
        q: np.ndarray,
        k: int = 100,
        *,
        b: int | None = 8,
        mx_inc: int = 4,
        exclude: set | None = None,
    ) -> ResultSet:
        """New search over one vector [D] or a batch [B, D].

        Returns a ``ResultSet``; ``.query`` is the ``ECPQuery`` handle for
        ``next(k)`` continuation, ``save()``, and ``close()``.
        """
        b = 8 if b is None else int(b)
        q = np.asarray(q, np.float32)
        single = q.ndim == 1
        Q = q[None, :] if single else q
        states = [
            QueryState(
                q=row,
                b=b,
                mx_inc=mx_inc,
                exclude=set(exclude) if exclude else set(),
            )
            for row in Q
        ]
        rows = []
        for qs in states:
            self._incremental_search(qs, k)
            rows.append(self._next_items(qs, k))
        return self._result(rows, states, k, single, ECPQuery(self, states, single=single))

    def _result(self, rows, states, k, single, query) -> ResultSet:
        d, i = pack_rows([[x[0] for x in r] for r in rows], [[x[1] for x in r] for r in rows], k)
        if single:
            return ResultSet(dists=d[0], ids=i[0], stats=states[0].stats, query=query)
        return ResultSet(dists=d, ids=i, stats=[s.stats for s in states], query=query)

    # ------------------------------------------------------- Algorithm 2
    def _next_items(self, qs: QueryState, k: int) -> list[tuple[float, int]]:
        cnt = min(len(qs.I), k)
        if cnt < k and qs.T:
            self._incremental_search(qs, k)
            cnt = min(len(qs.I), k)
        out, qs.I = qs.I[:cnt], qs.I[cnt:]
        qs.emitted += len(out)
        return out

    # ------------------------------------------------------- Algorithm 3
    def _incremental_search(self, qs: QueryState, k: int) -> None:
        info = self.info
        metric = info.metric
        leaf_cnt = 0
        loads_before = self.load_node_count

        if not qs.started:
            qs.started = True
            d = np_distances(qs.q, self.root_emb, metric)
            qs.stats.distance_calcs += len(self.root_emb)
            is_leaf = 1 if info.levels == 1 else 0
            for c, dist in zip(self.root_ids, d):
                heapq.heappush(qs.T, (float(dist), next(qs._tie), is_leaf, 1, int(c)))

        while qs.T:
            dist, _, is_leaf, level, node = heapq.heappop(qs.T)
            qs.stats.nodes_opened += 1
            emb, ids = self.get_node(level, node)
            if len(ids) == 0:
                continue
            d = np_distances(qs.q, emb, metric)
            qs.stats.distance_calcs += len(ids)
            if is_leaf:
                qs.stats.leaves_opened += 1
                for c, cd in zip(ids, d):
                    c = int(c)
                    if c not in qs.exclude:
                        qs.I.append((float(cd), c))
                leaf_cnt += 1
            else:
                next_is_leaf = 1 if (level + 1) == info.levels else 0
                for c, cd in zip(ids, d):
                    heapq.heappush(
                        qs.T, (float(cd), next(qs._tie), next_is_leaf, level + 1, int(c))
                    )
            if is_leaf and leaf_cnt >= qs.b:
                if len(qs.I) >= k:
                    break
                if qs.mx_inc == -1 or qs.increments < qs.mx_inc:
                    qs.increments += 1
                    qs.stats.increments += 1
                    qs.b *= 2
                else:
                    break
        qs.stats.node_loads += self.load_node_count - loads_before
        qs.I.sort(key=lambda t: t[0])

    # -------------------------------------------------------- persistence
    def load_query(self, name: str, *, group: str = "query_states") -> ECPQuery:
        """Rehydrate a saved ``ECPQuery`` (token from ``ECPQuery.save``)."""
        g = f"{group}/{name}"
        head = self.store.read_attrs(g)
        n_rows = int(head.get("n_rows", 1))
        single = bool(head.get("single", n_rows == 1))
        states = []
        for r in range(n_rows):
            rg = f"{g}/row_{r:06d}"
            a = self.store.read_attrs(rg)
            qs = QueryState(
                q=self.store.read_array(f"{rg}/query"),
                b=int(a["b"]),
                mx_inc=int(a["mx_inc"]),
                exclude=set(a.get("exclude", [])),
            )
            qs.increments = int(a["increments"])
            qs.emitted = int(a["emitted"])
            qs.started = bool(a["started"])
            d = self.store.read_array(f"{rg}/item_dists")
            i = self.store.read_array(f"{rg}/item_ids")
            qs.I = [(float(x), int(y)) for x, y in zip(d, i)]
            t = self.store.read_array(f"{rg}/frontier")
            for row in t:
                heapq.heappush(
                    qs.T, (float(row[0]), next(qs._tie), int(row[1]), int(row[2]), int(row[3]))
                )
            states.append(qs)
        return ECPQuery(self, states, single=single)
