"""eCP-FS retrieval: lazy node loading, LRU cache, incremental search.

Faithful implementation of the paper's Algorithms 1-3 behind the unified
``Searcher`` API (core/api.py):

  * ``ECPIndex.search(q, k, *, b)``  — Algorithm 1 (NewSearch): create the
    per-query state (Q, T, I), run one increment, return the first k items
    in a ``ResultSet`` whose ``.query`` handle owns the state.
  * ``ECPQuery.next(k)``             — Algorithm 2 (GetNextKItems): pop k
    items from I, resuming the tree search when I underflows.
  * ``_incremental_search``          — Algorithm 3: single cross-level
    priority queue T: always open the globally most promising node
    regardless of level; leaves append scanned items to I; after b leaves,
    either return (|I| >= k) or double b (bounded by mx_inc) and continue.

Node data is loaded on first access and kept in a bounded LRU cache
(paper §4.2) which may be private or shared across indexes
(``MultiIndexSession``); prefetching up to a level runs on background
threads.

Two deliberate fixes of apparent pseudocode typos (semantics follow the
paper's prose): (1) Algorithm 2 line 4 checks ``cnt = 0`` but the text says
"in case there is not enough [items] it resumes the search" — we resume when
``cnt < k``; (2) Algorithm 3 line 26 reads ``increments > mx_inc`` where the
prose caps doubling at mx_inc — we double while ``increments < mx_inc`` (or
mx_inc == -1 meaning unbounded).
"""
from __future__ import annotations

import heapq
import itertools
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np

from . import layout
from .api import NodeCache, Query, ResultSet, SearchStats, pack_rows
from .distances import np_distances
from .store import Store, open_store

__all__ = ["ECPIndex", "ECPQuery", "QueryState", "NodeCache", "SearchStats"]

# when expanding an internal node, asynchronously prefetch this many of its
# nearest not-yet-resident children (only with a prefetch-capable store)
PREFETCH_FANOUT = 8


@dataclass
class QueryState:
    """Persistent per-query state (paper §4.3): Q.q, Q.T, Q.I."""

    q: np.ndarray
    b: int
    mx_inc: int
    exclude: set = field(default_factory=set)
    T: list = field(default_factory=list)              # heap of (d, tie, is_leaf, level, node)
    I: list = field(default_factory=list)              # sorted [(d, item_id)]
    started: bool = False
    increments: int = 0
    emitted: int = 0
    stats: SearchStats = field(default_factory=SearchStats)
    _tie: "itertools.count" = field(default_factory=itertools.count)


class ECPQuery(Query):
    """Handle over one ``ECPIndex.search`` call (single query or a batch).

    Owns one ``QueryState`` per query row; ``next(k)`` resumes the
    incremental search, ``save()`` persists the frontier into the index's
    own file structure (paper §6.2), ``close()`` frees the states — any
    later call raises ``QueryClosedError`` (no silent ``None`` holes).
    """

    def __init__(self, index: "ECPIndex", states: list[QueryState], *, single: bool):
        self._index = index
        self._states = states
        self._single = single

    # ------------------------------------------------------------- access
    @property
    def states(self) -> list[QueryState]:
        self._ensure_open()
        return self._states

    @property
    def state(self) -> QueryState:
        """The sole state of a single-query handle."""
        self._ensure_open()
        if len(self._states) != 1:
            raise ValueError("state is for single-query handles; use states")
        return self._states[0]

    @property
    def stats(self):
        self._ensure_open()
        if self._single:
            return self._states[0].stats
        return [s.stats for s in self._states]

    @property
    def b(self):
        self._ensure_open()
        if self._single:
            return self._states[0].b
        return [s.b for s in self._states]

    # -------------------------------------------------------- continuation
    def next(self, k: int) -> ResultSet:
        self._ensure_open()
        rows = [self._index._next_items(qs, k) for qs in self._states]
        return self._index._result(rows, self._states, k, self._single, self)

    # -------------------------------------------------------- persistence
    def save(self, name: str | None = None, *, group: str = "query_states") -> str:
        """Persist all row states; returns the token ``load_query`` takes."""
        self._ensure_open()
        store = self._index.state_store
        if name is None:
            existing = set(store.listdir(group)) if store.exists(group) else set()
            n = 0
            while f"q_{n:06d}" in existing:
                n += 1
            name = f"q_{n:06d}"
        g = f"{group}/{name}"
        store.create_group(g, attrs={"n_rows": len(self._states), "single": self._single})
        for r, qs in enumerate(self._states):
            rg = f"{g}/row_{r:06d}"
            store.create_group(rg)
            store.write_array(f"{rg}/query", qs.q)
            if qs.I:
                d = np.asarray([x[0] for x in qs.I], np.float32)
                i = np.asarray([x[1] for x in qs.I], np.int64)
            else:
                d = np.zeros((0,), np.float32)
                i = np.zeros((0,), np.int64)
            store.write_array(f"{rg}/item_dists", d)
            store.write_array(f"{rg}/item_ids", i)
            if qs.T:
                t = np.asarray([(e[0], e[2], e[3], e[4]) for e in qs.T], np.float64)
            else:
                t = np.zeros((0, 4), np.float64)
            store.write_array(f"{rg}/frontier", t)
            store.write_attrs(
                rg,
                {
                    "b": qs.b,
                    "mx_inc": qs.mx_inc,
                    "increments": qs.increments,
                    "emitted": qs.emitted,
                    "started": qs.started,
                    "exclude": sorted(int(x) for x in qs.exclude),
                },
            )
        return name

    def close(self) -> None:
        self._states = []
        super().close()


class ECPIndex:
    """Open an eCP-FS file structure for retrieval (the ``Searcher`` for
    file mode: bounded memory, true incremental continuation)."""

    def __init__(
        self,
        path: "str | Store",
        *,
        backend: str = "auto",
        prefetch: bool = False,
        cache: NodeCache | None = None,
        namespace: str | None = None,
        cache_max_nodes: int | None = None,
        cache_max_bytes: int | None = None,
        prefetch_workers: int = 4,
    ):
        self.store = (
            path
            if isinstance(path, Store)
            else open_store(path, backend=backend, prefetch=prefetch,
                            prefetch_workers=prefetch_workers)
        )
        self.info = layout.IndexInfo.from_attrs(self.store.read_attrs(layout.INFO))
        # Loading the index = read info + the root node only (paper §4.2).
        self.root_emb, self.root_ids = self.store.get_node(0, 0)
        self.cache = cache if cache is not None else NodeCache(
            cache_max_nodes, max_bytes=cache_max_bytes
        )
        # namespace tag keeps keys distinct inside a shared session cache
        self._ns = namespace if namespace is not None else str(self.store.path)
        self._prefetch_workers = prefetch_workers
        # store-level async prefetch hook (AsyncPrefetchStore); None otherwise
        self._store_prefetch = getattr(self.store, "prefetch", None)
        self.load_node_count = 0

    @property
    def state_store(self):
        """The writable hierarchy store for query-state persistence (§6.2).

        Only the fstore backend can hold per-query groups; the blob form
        is a fixed-slot node file."""
        if getattr(self.store, "fstore", None) is None:
            raise NotImplementedError(
                "query-state persistence (save/load_query) requires the "
                f"fstore backend; this index uses {self.store.backend!r}"
            )
        return self.store

    # ------------------------------------------------------------ node IO
    def get_node(self, level: int, node: int) -> tuple[np.ndarray, np.ndarray]:
        key = (self._ns, level, node)
        v = self.cache.get(key)
        if v is not None:
            return v
        v = self.store.get_node(level, node)
        self.load_node_count += 1
        self.cache.put(key, v)
        return v

    def _on_prefetched(self, key, value) -> None:
        """Prefetch sink: completed background reads land straight in the
        (byte-budgeted) node cache instead of pinning store-side buffers."""
        self.cache.put((self._ns, key[0], key[1]), value)

    def get_nodes(self, keys: list) -> list:
        """Cache-aware batched node read (one ``Store.get_nodes`` for the
        misses, so a blob backend can coalesce adjacent blocks)."""
        out: list = [None] * len(keys)
        missing, missing_i = [], []
        for i, (lv, nd) in enumerate(keys):
            v = self.cache.get((self._ns, lv, nd))
            if v is not None:
                out[i] = v
            else:
                missing.append((lv, nd))
                missing_i.append(i)
        if missing:
            for (lv, nd), i, v in zip(missing, missing_i, self.store.get_nodes(missing)):
                self.load_node_count += 1
                self.cache.put((self._ns, lv, nd), v)
                out[i] = v
        return out

    def prefetch(self, up_to_level: int) -> None:
        """Background-load all nodes at levels 1..up_to_level (paper §4.2)."""
        keys = [
            (lv, j)
            for lv in range(1, min(up_to_level, self.info.levels) + 1)
            for j in range(self.info.nodes_per_level[lv - 1])
        ]
        chunk = 64
        batches = [keys[i : i + chunk] for i in range(0, len(keys), chunk)]
        with ThreadPoolExecutor(max_workers=self._prefetch_workers) as ex:
            list(ex.map(self.get_nodes, batches))

    # ------------------------------------------------------- Algorithm 1
    def search(
        self,
        q: np.ndarray,
        k: int = 100,
        *,
        b: int | None = 8,
        mx_inc: int = 4,
        exclude: set | None = None,
    ) -> ResultSet:
        """New search over one vector [D] or a batch [B, D].

        Returns a ``ResultSet``; ``.query`` is the ``ECPQuery`` handle for
        ``next(k)`` continuation, ``save()``, and ``close()``.
        """
        b = 8 if b is None else int(b)
        q = np.asarray(q, np.float32)
        single = q.ndim == 1
        Q = q[None, :] if single else q
        states = [
            QueryState(
                q=row,
                b=b,
                mx_inc=mx_inc,
                exclude=set(exclude) if exclude else set(),
            )
            for row in Q
        ]
        rows = []
        for qs in states:
            self._incremental_search(qs, k)
            rows.append(self._next_items(qs, k))
        return self._result(rows, states, k, single, ECPQuery(self, states, single=single))

    def _result(self, rows, states, k, single, query) -> ResultSet:
        d, i = pack_rows([[x[0] for x in r] for r in rows], [[x[1] for x in r] for r in rows], k)
        if single:
            return ResultSet(dists=d[0], ids=i[0], stats=states[0].stats, query=query)
        return ResultSet(dists=d, ids=i, stats=[s.stats for s in states], query=query)

    # ------------------------------------------------------- Algorithm 2
    def _next_items(self, qs: QueryState, k: int) -> list[tuple[float, int]]:
        cnt = min(len(qs.I), k)
        if cnt < k and qs.T:
            self._incremental_search(qs, k)
            cnt = min(len(qs.I), k)
        out, qs.I = qs.I[:cnt], qs.I[cnt:]
        qs.emitted += len(out)
        return out

    # ------------------------------------------------------- Algorithm 3
    def _incremental_search(self, qs: QueryState, k: int) -> None:
        info = self.info
        metric = info.metric
        leaf_cnt = 0
        loads_before = self.load_node_count
        io_before = self.store.io.snapshot()

        if not qs.started:
            qs.started = True
            d = np_distances(qs.q, self.root_emb, metric)
            qs.stats.distance_calcs += len(self.root_emb)
            is_leaf = 1 if info.levels == 1 else 0
            for c, dist in zip(self.root_ids, d):
                heapq.heappush(qs.T, (float(dist), next(qs._tie), is_leaf, 1, int(c)))

        while qs.T:
            dist, _, is_leaf, level, node = heapq.heappop(qs.T)
            qs.stats.nodes_opened += 1
            emb, ids = self.get_node(level, node)
            if len(ids) == 0:
                continue
            d = np_distances(qs.q, emb, metric)
            qs.stats.distance_calcs += len(ids)
            if is_leaf:
                qs.stats.leaves_opened += 1
                for c, cd in zip(ids, d):
                    c = int(c)
                    if c not in qs.exclude:
                        qs.I.append((float(cd), c))
                leaf_cnt += 1
            else:
                next_is_leaf = 1 if (level + 1) == info.levels else 0
                for c, cd in zip(ids, d):
                    heapq.heappush(
                        qs.T, (float(cd), next(qs._tie), next_is_leaf, level + 1, int(c))
                    )
                if self._store_prefetch is not None:
                    # async: start loading the nearest children while the
                    # traversal keeps scoring (frontier prefetch)
                    order = np.argsort(d)[:PREFETCH_FANOUT]
                    want = [
                        (level + 1, int(ids[j]))
                        for j in order
                        if not self.cache.contains((self._ns, level + 1, int(ids[j])))
                    ]
                    if want:
                        self._store_prefetch(want, on_node=self._on_prefetched)
            if is_leaf and leaf_cnt >= qs.b:
                if len(qs.I) >= k:
                    break
                if qs.mx_inc == -1 or qs.increments < qs.mx_inc:
                    qs.increments += 1
                    qs.stats.increments += 1
                    qs.b *= 2
                else:
                    break
        qs.stats.node_loads += self.load_node_count - loads_before
        # NOTE: with an AsyncPrefetchStore, background reads count when they
        # complete, so per-traversal io can lag slightly; store.drain() gives
        # exact attribution (benchmarks use it between passes)
        qs.stats.io.add(self.store.io.delta(io_before))
        qs.I.sort(key=lambda t: t[0])

    # -------------------------------------------------------- persistence
    def load_query(self, name: str, *, group: str = "query_states") -> ECPQuery:
        """Rehydrate a saved ``ECPQuery`` (token from ``ECPQuery.save``)."""
        store = self.state_store
        g = f"{group}/{name}"
        head = store.read_attrs(g)
        n_rows = int(head.get("n_rows", 1))
        single = bool(head.get("single", n_rows == 1))
        states = []
        for r in range(n_rows):
            rg = f"{g}/row_{r:06d}"
            a = store.read_attrs(rg)
            qs = QueryState(
                q=store.read_array(f"{rg}/query"),
                b=int(a["b"]),
                mx_inc=int(a["mx_inc"]),
                exclude=set(a.get("exclude", [])),
            )
            qs.increments = int(a["increments"])
            qs.emitted = int(a["emitted"])
            qs.started = bool(a["started"])
            d = store.read_array(f"{rg}/item_dists")
            i = store.read_array(f"{rg}/item_ids")
            qs.I = [(float(x), int(y)) for x, y in zip(d, i)]
            t = store.read_array(f"{rg}/frontier")
            for row in t:
                heapq.heappush(
                    qs.T, (float(row[0]), next(qs._tie), int(row[1]), int(row[2]), int(row[3]))
                )
            states.append(qs)
        return ECPQuery(self, states, single=single)
