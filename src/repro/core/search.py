"""eCP-FS retrieval: lazy node loading, LRU cache, vectorized incremental
search.

Faithful implementation of the paper's Algorithms 1-3 behind the unified
``Searcher`` API (core/api.py):

  * ``ECPIndex.search(q, k, *, b)``  — Algorithm 1 (NewSearch): create the
    per-query state (Q, T, I), run one increment, return the first k items
    in a ``ResultSet`` whose ``.query`` handle owns the state.
  * ``ECPQuery.next(k)``             — Algorithm 2 (GetNextKItems): pop k
    items from I, resuming the tree search when I underflows.
  * ``_increment``                   — Algorithm 3: single cross-level
    priority queue T: always open the globally most promising node
    regardless of level; leaves append scanned items to I; after b leaves,
    either return (|I| >= k) or double b (bounded by mx_inc) and continue.

The traversal engine is vectorized (the file-mode hot path used to be
interpreter overhead, not file I/O):

  * T is a flat-array ``Frontier`` and I a ``CandidateBuffer``
    (core/frontier.py) — batch pushes/merges instead of per-item tuples,
    with pop order bit-identical to the old tuple heap.
  * Batch queries ``[B, D]`` advance all rows in lockstep **rounds**: each
    round collects every row's next node demand, dedupes them, and issues
    ONE cache-aware ``get_nodes`` — a node needed by several queries is
    read once (and a blob backend coalesces adjacent blocks).  Per-row
    ranking semantics are untouched, so results equal B independent
    searches bit-for-bit.
  * Leaf scans route through a ``scorer`` hook (default: ``np_distances``
    with per-node cached squared norms, so l2 stops recomputing
    ``(c*c).sum(-1)`` on every visit; ``make_kernel_scorer`` swaps in the
    Pallas ``distance_topk`` kernel for large leaf blocks).
  * ``batch_matrix=True`` additionally scores a node's co-demanding rows
    as one dense ``[B', N]`` distance matrix.  BLAS GEMM results are not
    bit-identical across batch shapes, so this throughput mode is opt-in;
    the default scores each row through the exact same ``[1, D]`` call the
    reference engine makes.

``ECPIndex(engine="legacy")`` selects the original Python-object engine
(core/legacy.py) — the parity oracle and benchmark baseline.

``ECPIndex(quantized=True)`` turns on the device-resident scoring
pipeline: leaf scans read the blob's scalar-quantized companion blocks
(core/quant.py, blob format v3 — an fstore or v2 blob encodes on the fly)
and every traversal round launches ONE grouped ``distance_topk`` kernel
over all (query, leaf) scan units of the round
(kernels/distance_topk/grouped.py).  Survivor selection keeps every row
whose sound distance lower bound could still reach the query's rerank
depth ``R = max(rerank_depth, emitted + k)``; survivors are re-scored
against the full-precision rows (partial row reads where the store
supports them) and staged exactly like a plain scan.  Because dropped
rows provably rank strictly beyond R, emitted results are bit-identical
to the fp32 engines whenever cumulative emissions stay within R —
``rerank_depth=None`` (the default) guarantees this for every increment's
subsequent ``take`` — while the store reads shrink to the compressed
codes plus the few reranked rows.  Traversal control flow (leaf budgets,
b-doubling, resume) tracks the VIRTUAL candidate count the fp engine
would have seen (``QueryState.virtual_i``), so the tree walk is identical
too.  A custom leaf ``scorer`` does not apply to quantized scans.

Node data is loaded on first access and kept in a bounded LRU cache
(paper §4.2) which may be private or shared across indexes
(``MultiIndexSession``); prefetching up to a level runs on a reusable
background pool.

Two deliberate fixes of apparent pseudocode typos (semantics follow the
paper's prose): (1) Algorithm 2 line 4 checks ``cnt = 0`` but the text says
"in case there is not enough [items] it resumes the search" — we resume when
``cnt < k``; (2) Algorithm 3 line 26 reads ``increments > mx_inc`` where the
prose caps doubling at mx_inc — we double while ``increments < mx_inc`` (or
mx_inc == -1 meaning unbounded).
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np

from . import layout, legacy, lifecycle
from .api import NodeCache, Query, ResultSet, SearchStats, StaleQueryError, pack_rows
from .distances import np_distances
from .frontier import CandidateBuffer, Frontier
from .quant import QFORMATS, distance_bounds, encode_node, qdtype
from .store import NodeNormCache, Store, open_store

__all__ = [
    "ECPIndex",
    "ECPSnapshot",
    "ECPQuery",
    "QueryState",
    "NodeCache",
    "SearchStats",
    "make_kernel_scorer",
]

# when expanding an internal node, asynchronously prefetch this many of its
# nearest not-yet-resident children (only with a prefetch-capable store)
PREFETCH_FANOUT = 8

ENGINES = ("flat", "legacy")

# per-query cap on the exact-distance watermark array the quantized scan
# keeps for cross-leaf pruning (QueryState.best_d)
BEST_D_CAP = 4096


def _kernel_ops():
    """The grouped device top-k entry point, resolved lazily so plain
    (non-quantized) searches never import jax; late attribute lookup keeps
    ``repro.kernels.distance_topk.ops.grouped_distance_topk`` patchable
    (the launch-count tests count calls through here)."""
    from repro.kernels.distance_topk import ops

    return ops


@dataclass
class QueryState:
    """Persistent per-query state (paper §4.3): Q.q, Q.T, Q.I — T/I as the
    flat-array structures of core/frontier.py."""

    q: np.ndarray
    b: int                   # configured base leaf budget (never mutated)
    mx_inc: int
    exclude: set = field(default_factory=set)
    T: Frontier = field(default_factory=Frontier)
    I: CandidateBuffer = field(default_factory=CandidateBuffer)
    started: bool = False
    increments: int = 0
    emitted: int = 0
    probe_m: int = 1         # frontier pops per traversal step (multi-probe)
    b_cur: int = 0           # transient budget: reset to ``b`` at the start
                             # of every increment, doubled in place of the
                             # old in-place ``qs.b *= 2`` — so a saved or
                             # continued query never runs at an inflated b
    stats: SearchStats = field(default_factory=SearchStats)
    _excl_arr: np.ndarray | None = None
    # quantized-scan bookkeeping: virtual_i mirrors the candidate count
    # the fp32 engine's I would have (scanned live rows minus takes) so
    # control flow stays identical even though only reranked survivors are
    # staged; best_d is the sorted exact-distance watermark used to prune
    # later leaves (None until the first quantized increment)
    virtual_i: int | None = None
    best_d: np.ndarray | None = None
    _q_norm: float | None = None

    def q_norm(self) -> float:
        """||q|| in float64 (the ip metric's error-bound operand)."""
        if self._q_norm is None:
            self._q_norm = float(np.linalg.norm(np.asarray(self.q, np.float64)))
        return self._q_norm

    def excl(self) -> np.ndarray | None:
        """The exclude set as a cached int64 array (np.isin operand).
        The cache lives for one increment (the engine invalidates it on
        entry), so between-call mutations of ``exclude`` are honored just
        like the per-item membership test of the legacy engine."""
        if self._excl_arr is None and self.exclude:
            self._excl_arr = np.fromiter(self.exclude, np.int64, len(self.exclude))
        return self._excl_arr


class _LeafRowCache:
    """Accumulated full-precision rows of one leaf, filled lazily by the
    quantized rerank across rounds and queries.

    ``emb`` is a full-leaf-shaped buffer (rows never fetched stay zero)
    so every rerank GEMM has exactly the shape the fp engine's scan has —
    per-column GEMM results depend only on that column's data, which is
    what keeps staged distances bit-identical.  ``have`` marks which rows
    hold real data; each storage row is read from disk at most once per
    cache residency no matter how many (query, round) units demand it.
    Concurrent fills from snapshot readers write disjoint (or identical)
    rows, so sharing one instance through NodeCache is safe."""

    __slots__ = ("emb", "ids", "have", "born")

    def __init__(self, n_rows: int, dim: int, born: int = 0):
        self.emb = np.zeros((n_rows, dim), np.float32)
        self.ids = np.full(n_rows, -1, np.int64)
        self.have = np.zeros(n_rows, bool)
        self.born = born  # search-call sequence that first demanded rows

    @property
    def nbytes(self) -> int:
        return self.emb.nbytes + self.ids.nbytes + self.have.nbytes


def make_kernel_scorer(min_rows: int = 256, impl: str = "auto", bucket: int = 512):
    """A leaf ``scorer`` that runs large leaf blocks through the fused
    Pallas ``distance_topk`` kernel (kernels/distance_topk) and falls back
    to numpy below ``min_rows``.

    Full-N selection (k == N) recovers every item's distance, scattered
    back to storage order, so the traversal's candidate semantics are
    unchanged.  Leaf blocks are zero-padded up to the next multiple of
    ``bucket`` before the call, so the kernel's jit cache holds ONE
    compiled program per size bucket instead of one per distinct leaf
    size (k and N are static compile keys; pad rows are dropped at the
    scatter, so results are unchanged).  ``scorer.compile_shapes`` is the
    set of (N_pad, k) static keys issued so far — tests assert it stays
    at one entry across heterogeneous leaves.  Device math is NOT
    guaranteed bit-identical to the numpy path across backends — this is
    an opt-in throughput mode, excluded from the parity suite.
    """
    if bucket < 1:
        raise ValueError("bucket must be >= 1")
    compile_shapes: set = set()

    def scorer(q, emb, metric, sqnorms=None):
        n = emb.shape[0]
        if n < min_rows:
            return np_distances(q, emb, metric, c_sqnorms=sqnorms)
        from repro.kernels.distance_topk import distance_topk

        n_pad = -(-n // bucket) * bucket
        block = np.asarray(emb, np.float32)
        if n_pad != n:
            padded = np.zeros((n_pad, emb.shape[1]), np.float32)
            padded[:n] = block
            block = padded
        compile_shapes.add((n_pad, n_pad))
        d, idx = distance_topk(
            np.asarray(q, np.float32)[None, :], block, n_pad, metric, impl=impl
        )
        d, idx = np.asarray(d[0], np.float32), np.asarray(idx[0])
        keep = idx < n  # pad rows rank somewhere; full-N selection means
        out = np.empty(n, np.float32)  # every REAL row is present exactly once
        out[idx[keep]] = d[keep]
        return out

    scorer.compile_shapes = compile_shapes
    return scorer


class ECPQuery(Query):
    """Handle over one ``ECPIndex.search`` call (single query or a batch).

    Owns one per-row state; ``next(k)`` resumes the incremental search
    (batch handles resume underflowing rows together, through the same
    round-based dedup engine), ``save()`` persists the frontier into the
    index's own file structure (paper §6.2), ``close()`` frees the states —
    any later call raises ``QueryClosedError`` (no silent ``None`` holes).
    """

    def __init__(self, index: "ECPIndex", states: list, *, single: bool, batch_stats: SearchStats | None = None):
        self._index = index
        self._states = states
        self._single = single
        self._batch_stats = batch_stats
        # a structural rewrite (compact) renumbers nodes; frontiers made
        # before it must not resume over the new tree
        self._epoch = index._epoch

    def _ensure_open(self) -> None:
        super()._ensure_open()
        if self._epoch != self._index._epoch:
            raise StaleQueryError(
                "the index was compacted after this query started; node "
                "references in its frontier are stale — re-issue the search"
            )

    # ------------------------------------------------------------- access
    @property
    def states(self) -> list:
        self._ensure_open()
        return self._states

    @property
    def state(self):
        """The sole state of a single-query handle."""
        self._ensure_open()
        if len(self._states) != 1:
            raise ValueError("state is for single-query handles; use states")
        return self._states[0]

    @property
    def stats(self):
        self._ensure_open()
        if self._single:
            return self._states[0].stats
        return [s.stats for s in self._states]

    @property
    def batch_stats(self) -> SearchStats | None:
        """Aggregate counters of the round-based batch engine (None for
        single-query and legacy handles): ``rounds``, actual deduped
        ``node_loads``, ``dedup_hits`` (loads saved by cross-query
        sharing), and the store ``io`` delta of the whole batch."""
        self._ensure_open()
        return self._batch_stats

    @property
    def b(self):
        self._ensure_open()
        if self._single:
            return self._states[0].b
        return [s.b for s in self._states]

    # -------------------------------------------------------- continuation
    def next(self, k: int) -> ResultSet:
        self._ensure_open()
        rows = self._index._next_rows(self._states, k, self._batch_stats)
        return self._index._result(rows, self._states, k, self._single, self)

    # -------------------------------------------------------- persistence
    def save(self, name: str | None = None, *, group: str = "query_states") -> str:
        """Persist all row states; returns the token ``load_query`` takes."""
        self._ensure_open()
        store = self._index.state_store
        if name is None:
            existing = set(store.listdir(group)) if store.exists(group) else set()
            n = 0
            while f"q_{n:06d}" in existing:
                n += 1
            name = f"q_{n:06d}"
        g = f"{group}/{name}"
        store.create_group(g, attrs={"n_rows": len(self._states), "single": self._single})
        for r, qs in enumerate(self._states):
            rg = f"{g}/row_{r:06d}"
            store.create_group(rg)
            store.write_array(f"{rg}/query", qs.q)
            d, i, t = self._index._export_state(qs)
            store.write_array(f"{rg}/item_dists", d)
            store.write_array(f"{rg}/item_ids", i)
            store.write_array(f"{rg}/frontier", t)
            # spill dedup state: ids ever committed/emitted, so a restored
            # continuation can never re-emit a replica's id
            if isinstance(qs, legacy.LegacyQueryState):
                seen = np.asarray(sorted(qs.seen), np.int64)
            else:
                seen = qs.I.export_seen()
            if len(seen):
                store.write_array(f"{rg}/seen_ids", seen)
            store.write_attrs(
                rg,
                {
                    "b": qs.b,
                    "mx_inc": qs.mx_inc,
                    "increments": qs.increments,
                    "emitted": qs.emitted,
                    "started": qs.started,
                    "exclude": sorted(int(x) for x in qs.exclude),
                    "probe_m": qs.probe_m,
                },
            )
        return name

    def close(self) -> None:
        self._states = []
        super().close()


class ECPIndex:
    """Open an eCP-FS file structure for retrieval (the ``Searcher`` for
    file mode: bounded memory, true incremental continuation).

    ``engine`` picks the traversal implementation: ``"flat"`` (default —
    flat-array frontier, batched rounds, scorer hook) or ``"legacy"`` (the
    original tuple-heap engine, kept as parity oracle and benchmark
    baseline).  Both return bit-identical results.
    """

    prefetch_fanout = PREFETCH_FANOUT

    def __init__(
        self,
        path: "str | Store",
        *,
        backend: str = "auto",
        prefetch: bool = False,
        cache: NodeCache | None = None,
        namespace: str | None = None,
        cache_max_nodes: int | None = None,
        cache_max_bytes: int | None = None,
        prefetch_workers: int = 4,
        engine: str = "flat",
        scorer=None,
        batch_matrix: bool = False,
        norm_cache_entries: int = 16384,
        quantized: "bool | str" = False,
        rerank_depth: int | None = None,
        pin_internal: bool = False,
        probe_m: int = 1,
    ):
        if engine not in ENGINES:
            raise ValueError(f"unknown engine: {engine!r} ({'|'.join(ENGINES)})")
        if quantized and engine == "legacy":
            raise ValueError(
                "quantized scans run on the round-based flat engine only; "
                "engine='legacy' is the fp32 parity oracle"
            )
        if isinstance(quantized, str) and quantized not in QFORMATS:
            raise ValueError(
                f"unknown quant format: {quantized!r} ({'|'.join(QFORMATS)})"
            )
        self._owns_store = not isinstance(path, Store)
        self._reopen = (
            dict(path=path, backend=backend, prefetch=prefetch,
                 prefetch_workers=prefetch_workers)
            if self._owns_store
            else None
        )
        self.store = (
            path
            if isinstance(path, Store)
            else open_store(path, backend=backend, prefetch=prefetch,
                            prefetch_workers=prefetch_workers)
        )
        attrs = self.store.read_attrs(layout.INFO)
        self.info = layout.IndexInfo.from_attrs(attrs)
        self._tombstones: set = layout.read_tombstones(attrs)
        self._tomb_arr: np.ndarray | None = None
        self._epoch = 0  # bumped by structural rewrites (compact)
        # per-node version counters for the cache key (bumped on every
        # in-place rewrite) — a pinned ECPSnapshot copies this map, so a
        # shared NodeCache can never serve it bytes newer than its pin
        self._node_ver: dict[tuple[int, int], int] = {}
        # serializes insert/delete/compact/refresh against each other AND
        # against snapshot(): a snapshot is only ever taken at a published
        # generation, never mid-mutation
        self._mut_lock = threading.RLock()
        # prefetched-but-unconsumed payloads: (level, node) -> nbytes; a
        # later cache hit counts a prefetch_hit, a miss (evicted first) or
        # invalidation counts the bytes as wasted
        self._pf_pending: dict[tuple[int, int], int] = {}
        # Loading the index = read info + the root node only (paper §4.2).
        self.root_emb, self.root_ids = self.store.get_node(0, 0)
        self.cache = cache if cache is not None else NodeCache(
            cache_max_nodes, max_bytes=cache_max_bytes
        )
        # namespace tag keeps keys distinct inside a shared session cache
        self._ns = namespace if namespace is not None else str(self.store.path)
        self._prefetch_workers = prefetch_workers
        self._pool: ThreadPoolExecutor | None = None  # reusable prefetch pool
        # store-level async prefetch hook (AsyncPrefetchStore); None otherwise
        self._store_prefetch = getattr(self.store, "prefetch", None)
        self.load_node_count = 0
        self.engine = engine
        self._scorer = scorer
        self._batch_matrix = bool(batch_matrix)
        # per-node squared-norm cache: l2 reuses (c*c).sum(-1) directly and
        # cosine takes np.sqrt of it — bitwise what np.linalg.norm computes
        # — so both metrics stop recomputing norms on every leaf visit
        self._norms = (
            NodeNormCache(norm_cache_entries)
            if self.info.metric in ("l2", "cosine")
            else None
        )
        # device-resident scoring pipeline (quantized leaf scan + rerank):
        # qformat follows the blob's persisted companion tier; a string
        # ``quantized`` overrides it for on-the-fly encoding backends
        self._quantized = bool(quantized)
        self._rerank_depth = None if rerank_depth is None else max(1, int(rerank_depth))
        # monotone per-public-call counter: a leaf whose row cache was
        # born in an EARLIER call is under repeat demand, so later calls
        # read it whole and scan it on the cached fp fast path
        self._quant_seq = 0
        self._qformat = (
            quantized
            if isinstance(quantized, str)
            else (getattr(self.store, "quant_format", None) or "int8")
        )
        # hot-level pinning: park every internal level in the cache's
        # pinned (LRU-exempt) region at open so leaf churn never evicts
        # the navigation structure — warm internal_reads drop to zero
        # multi-probe traversal default: every search pops this many
        # frontier entries per step (per-call ``probe_m=`` overrides it);
        # 1 reproduces strict best-first traversal bit-identically
        self._probe_m = max(1, int(probe_m))
        self._pin_internal = bool(pin_internal)
        if self._pin_internal and self.info.levels > 1:
            self._preload_internal()

    @property
    def state_store(self):
        """The writable hierarchy store for query-state persistence (§6.2).

        Only the fstore backend can hold per-query groups; the blob form
        is a fixed-slot node file."""
        if getattr(self.store, "fstore", None) is None:
            raise NotImplementedError(
                "query-state persistence (save/load_query) requires the "
                f"fstore backend; this index uses {self.store.backend!r}"
            )
        return self.store

    # ------------------------------------------------------------ node IO
    def _key(self, level: int, node: int) -> tuple:
        """Versioned cache key: (namespace, epoch, node-version, level,
        node).  Mutations bump the node's version (or the epoch, for
        structural rewrites), so an ``ECPSnapshot`` pinned at an older
        (epoch, version) and the live index can share one ``NodeCache``
        without ever seeing each other's bytes."""
        return (self._ns, self._epoch, self._node_ver.get((level, node), 0), level, node)

    def _pf_consumed(self, level: int, node: int, *, hit: bool) -> None:
        """Prefetch-accuracy attribution: a cache hit on a pending
        prefetched node is a prefetch_hit; a miss means the payload was
        evicted before use — its bytes were read for nothing."""
        nb = self._pf_pending.pop((level, node), None)
        if nb is None:
            return
        if hit:
            self.store.io.count_prefetch(hits=1)
        else:
            self.store.io.count_prefetch(wasted_bytes=nb)

    def flush_prefetch_stats(self) -> None:
        """Charge every still-unconsumed prefetched payload as wasted (the
        end-of-pass accounting benchmarks use, after ``store.drain()``)."""
        while self._pf_pending:
            try:
                _, nb = self._pf_pending.popitem()
            except KeyError:  # racing consumer emptied it
                break
            self.store.io.count_prefetch(wasted_bytes=nb)

    def _store_miss(self, level: int, node: int, v) -> None:
        """Account + cache one node read the store just served: internal
        levels (1..L-1) bump ``io.internal_reads`` — the counter the
        hot-level pinning tests watch — and go to the pinned cache region
        when ``pin_internal`` is on."""
        self.load_node_count += 1
        key = self._key(level, node)
        if 0 < level < self.info.levels:
            self.store.io.count_internal(1)
            if self._pin_internal:
                self.cache.pin(key, v)
                return
        self.cache.put(key, v)

    def _preload_internal(self) -> None:
        """Load and pin every internal-level node (pin_internal=True):
        after this, a warm search's ``internal_reads`` delta is zero."""
        info = self.info
        keys = [
            (lv, j)
            for lv in range(1, info.levels)
            for j in range(info.nodes_per_level[lv - 1])
        ]
        chunk = 64
        for i in range(0, len(keys), chunk):
            batch = [
                kk for kk in keys[i : i + chunk]
                if not self.cache.contains(self._key(*kk))
            ]
            if not batch:
                continue
            for (lv, nd), v in zip(batch, self.store.get_nodes(batch)):
                self._store_miss(lv, nd, v)

    def get_node(self, level: int, node: int) -> tuple[np.ndarray, np.ndarray]:
        key = self._key(level, node)
        v = self.cache.get(key)
        if v is not None:
            if self._pf_pending:
                self._pf_consumed(level, node, hit=True)
            return v
        if self._pf_pending:
            self._pf_consumed(level, node, hit=False)
        v = self.store.get_node(level, node)
        self._store_miss(level, node, v)
        return v

    def _on_prefetched(self, key, value) -> None:
        """Prefetch sink: completed background reads land straight in the
        (byte-budgeted) node cache instead of pinning store-side buffers."""
        lv, nd = key[0], key[1]
        self.cache.put(self._key(lv, nd), value)
        self._pf_pending[(lv, nd)] = int(value[0].nbytes + value[1].nbytes)

    def get_nodes(self, keys: list) -> list:
        """Cache-aware batched node read (one ``Store.get_nodes`` for the
        misses, so a blob backend can coalesce adjacent blocks)."""
        out: list = [None] * len(keys)
        missing, missing_i = [], []
        for i, (lv, nd) in enumerate(keys):
            v = self.cache.get(self._key(lv, nd))
            if v is not None:
                if self._pf_pending:
                    self._pf_consumed(lv, nd, hit=True)
                out[i] = v
            else:
                if self._pf_pending:
                    self._pf_consumed(lv, nd, hit=False)
                missing.append((lv, nd))
                missing_i.append(i)
        if missing:
            for (lv, nd), i, v in zip(missing, missing_i, self.store.get_nodes(missing)):
                self._store_miss(lv, nd, v)
                out[i] = v
        return out

    def _get_quant_nodes(self, keys: list) -> list:
        """Cache-aware batched read of the leaves' quantized companion
        blocks (``QuantNode`` per key, cached under ``key + ('q',)``).
        A store without companions (fstore, v1/v2 blob) falls back to
        encoding the full-precision node on the fly — functionally
        identical, no byte savings."""
        out: list = [None] * len(keys)
        missing, missing_i = [], []
        for i, (lv, nd) in enumerate(keys):
            v = self.cache.get(self._key(lv, nd) + ("q",))
            if v is not None:
                out[i] = v
            else:
                missing.append((lv, nd))
                missing_i.append(i)
        if missing:
            getter = getattr(self.store, "get_nodes_quantized", None)
            if getter is not None:
                payloads = getter(missing, self._qformat)
            else:
                payloads = [
                    encode_node(self.store.get_node(lv, nd)[0], self._qformat)
                    for lv, nd in missing
                ]
            for (lv, nd), i, qn in zip(missing, missing_i, payloads):
                self.load_node_count += 1
                self.cache.put(self._key(lv, nd) + ("q",), qn)
                out[i] = qn
        return out

    def _get_leaf_ids(self, level: int, node: int) -> np.ndarray:
        """One leaf's item ids without its embeddings (tombstone/exclude
        filtering during the quantized scan): served from a cached full
        node when resident, else an ids-only store read cached under
        ``key + ('ids',)``."""
        full = self.cache.get(self._key(level, node))
        if full is not None:
            return full[1]
        ikey = self._key(level, node) + ("ids",)
        v = self.cache.get(ikey)
        if v is not None:
            return v
        getter = getattr(self.store, "get_node_ids", None)
        ids = getter(level, node) if getter is not None else self.store.get_node(level, node)[1]
        self.cache.put(ikey, ids)
        return ids


    def prefetch(self, up_to_level: int) -> None:
        """Background-load all nodes at levels 1..up_to_level (paper §4.2)
        on the index's reusable prefetch pool."""
        keys = [
            (lv, j)
            for lv in range(1, min(up_to_level, self.info.levels) + 1)
            for j in range(self.info.nodes_per_level[lv - 1])
        ]
        chunk = 64
        batches = [keys[i : i + chunk] for i in range(0, len(keys), chunk)]
        list(self._prefetch_pool().map(self.get_nodes, batches))

    def _prefetch_pool(self) -> ThreadPoolExecutor:
        """One executor per index, created lazily and reused across
        ``prefetch`` calls (no per-call pool spin-up/teardown)."""
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self._prefetch_workers, thread_name_prefix="ecp-prefetch"
            )
        return self._pool

    def close(self) -> None:
        """Shut down the prefetch pool and (if this index opened it) the
        underlying store.  Idempotent."""
        pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=False)
        if self._owns_store and self.store is not None:
            self.store.close()

    def __enter__(self) -> "ECPIndex":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------- mutation
    def insert(self, vectors, ids=None) -> dict:
        """Insert vectors into the live index (core/lifecycle.py): beam-1
        routing, leaf appends, 2-means splits past ``cluster_cap``.
        Mutations serialize on the index's mutation lock; concurrent
        readers go through ``snapshot()`` (or an external RW lock)."""
        with self._mut_lock:
            return lifecycle.insert_items(self, vectors, ids)

    def delete(self, ids) -> int:
        """Tombstone item ids; both engines filter them from results."""
        with self._mut_lock:
            return lifecycle.delete_items(self, ids)

    def compact(self) -> dict:
        """Purge tombstones + rebalance splits by rebuilding from the live
        items — bit-identical to a fresh build of the logical collection."""
        with self._mut_lock:
            return lifecycle.compact(self)

    def snapshot(self) -> "ECPSnapshot":
        """An isolated read-only view of the index at its current
        generation (requires a store with ``pin()`` — the blob backend).

        The snapshot answers ``search``/``next`` bit-identically to a
        fresh single-threaded search of this generation, forever: later
        ``insert``/``delete``/``compact`` on the live index cannot touch
        it (copy-on-write slots + a dup'd fd), and its query handles never
        raise ``StaleQueryError``.  Taken under the mutation lock, so it
        always captures a published generation.  ``close()`` (or
        ``release()``) drops the pin; ``acquire()``/``release()`` refcount
        it for sharing across concurrent requests."""
        pin = getattr(self.store, "pin", None)
        if pin is None:
            raise NotImplementedError(
                f"snapshot() needs a generation-pinning store (blob); this "
                f"index uses {self.store.backend!r} — serialize readers and "
                "writers externally instead (launch/scheduler.py does)"
            )
        with self._mut_lock:
            return ECPSnapshot(self, pin())

    @property
    def supports_snapshot(self) -> bool:
        """Whether ``snapshot()`` works here — i.e. the store pins
        generations (blob).  The serving scheduler keys its isolation
        strategy off this (uniform across ECPIndex/FederatedIndex)."""
        return getattr(self.store, "pin", None) is not None

    @property
    def tombstones(self) -> set:
        """Tombstoned item ids (a copy; mutate via ``delete``)."""
        return set(self._tombstones)

    @property
    def generation(self) -> int:
        return self.info.generation

    def _tomb_sorted(self) -> np.ndarray | None:
        """Tombstones as a cached sorted array (np.isin operand)."""
        if not self._tombstones:
            return None
        if self._tomb_arr is None or len(self._tomb_arr) != len(self._tombstones):
            self._tomb_arr = np.sort(
                np.fromiter(self._tombstones, np.int64, len(self._tombstones))
            )
        return self._tomb_arr

    def _apply_mutation(
        self, new_info, written, *, tombstones: set | None = None, structural: bool = False
    ) -> None:
        """Post-mutation bookkeeping (called by core/lifecycle.py): cache
        invalidation for rewritten nodes (covers a shared MultiIndexSession
        cache — keys are namespaced), metadata refresh, root reload.
        Rewritten nodes also bump their cache-key version so pinned
        snapshots keep resolving the old entries, never the new bytes."""
        if structural:
            self.cache.invalidate_namespace(self._ns)
            if self._norms is not None:
                self._norms.clear()
            self.flush_prefetch_stats()
            self._node_ver.clear()
            self._epoch += 1
        else:
            for key in written:
                self._pf_consumed(key[0], key[1], hit=False)
                self.cache.invalidate(self._key(*key))
                self._node_ver[key] = self._node_ver.get(key, 0) + 1
        if tombstones is not None:
            self._tombstones = set(tombstones)
            self._tomb_arr = None
        if new_info is not None:
            self.info = new_info
        if structural or (0, 0) in set(written):
            self.root_emb, self.root_ids = self.store.get_node(0, 0)

    def _reload_store(self) -> None:
        """Reopen the underlying store after its file was swapped (blob
        compaction); the old fd would keep serving the old file."""
        if self._reopen is None:
            raise ValueError(
                "cannot reopen a caller-provided Store; open the index "
                "from a path to use blob compaction"
            )
        self.store.close()
        self.store = open_store(**self._reopen)
        self._store_prefetch = getattr(self.store, "prefetch", None)

    def refresh(self) -> None:
        """Resynchronize with the files after they changed OUTSIDE this
        process (another writer mutated or compacted the index): reopen a
        swapped blob, re-read metadata/tombstones/root, drop every cached
        node.  Open query handles become stale (``StaleQueryError``)."""
        with self._mut_lock:
            if self.store.backend.startswith("blob") and self._reopen is not None:
                self._reload_store()  # an os.replace'd blob needs a fresh fd
            attrs = self.store.read_attrs(layout.INFO)
            self._apply_mutation(
                layout.IndexInfo.from_attrs(attrs),
                (),
                tombstones=layout.read_tombstones(attrs),
                structural=True,
            )

    # ------------------------------------------------------------ scoring
    def _sqnorms(self, level: int, node: int, emb: np.ndarray) -> np.ndarray | None:
        if self._norms is None or len(emb) == 0:
            return None
        return self._norms.get(level, node, emb)

    def _score_row(self, q: np.ndarray, emb: np.ndarray, sq, *, leaf: bool) -> np.ndarray:
        """One row's distances to one node — the exact ``[1, D]`` numpy
        call of the reference engine unless a custom leaf scorer is set."""
        if leaf and self._scorer is not None:
            return self._scorer(q, emb, self.info.metric, sq)
        return np_distances(q, emb, self.info.metric, c_sqnorms=sq)

    def _stage_leaf(
        self, qs: QueryState, d: np.ndarray, ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        tomb = self._tomb_sorted()
        if tomb is not None and len(ids):
            keep = ~np.isin(ids, tomb)
            if not keep.all():
                d, ids = d[keep], ids[keep]
        if qs.exclude:
            keep = ~np.isin(ids, qs.excl())
            if not keep.all():
                d, ids = d[keep], ids[keep]
        qs.I.stage(d, ids)
        return d, ids

    def _ilen(self, qs: QueryState) -> int:
        """The candidate count Algorithm 2/3 decisions key off: the fp32
        engines use ``len(I)`` directly; the quantized scan substitutes
        the virtual count (all scanned live rows, not just the reranked
        survivors it stages) so traversal control flow is identical."""
        return qs.virtual_i if qs.virtual_i is not None else len(qs.I)

    def _fp_leaf(self, key: tuple) -> bool:
        """Quantized-mode routing: scan this leaf full-precision when its
        fp node is already cached, or when its rerank row cache was born
        in an earlier public call (repeat demand across calls — one full
        read now converges the leaf to plain-scan speed)."""
        if self.cache.contains(self._key(*key)):
            return True
        rc = self.cache.get(self._key(*key) + ("rows",))
        return rc is not None and rc.born < self._quant_seq

    @staticmethod
    def _note_exact(qs: QueryState, d_live) -> None:
        """Fold freshly-staged exact live distances into the query's
        sorted cross-leaf watermark (``best_d``) used by the quantized
        scan's rank-R pruning threshold."""
        if not len(d_live):
            return
        add = np.asarray(d_live, np.float64)
        bd = qs.best_d
        merged = add if bd is None else np.concatenate([bd, add])
        merged.sort()
        qs.best_d = merged[:BEST_D_CAP]

    def _prefetch_hint(self, child_level: int, ids: np.ndarray, d: np.ndarray) -> list:
        """The nearest not-yet-resident children of one expansion —
        ``argpartition`` (no full sort) since prefetch order is moot."""
        f = min(self.prefetch_fanout, len(d))
        if f <= 0:
            return []
        sel = np.argpartition(d, f - 1)[:f] if f < len(d) else range(len(d))
        return [
            (child_level, int(ids[j]))
            for j in sel
            if not self.cache.contains(self._key(child_level, int(ids[j])))
        ]

    # ------------------------------------------------------- Algorithm 1
    def search(
        self,
        q: np.ndarray,
        k: int = 100,
        *,
        b: int | None = 8,
        mx_inc: int = 4,
        exclude: set | None = None,
        probe_m: int | None = None,
    ) -> ResultSet:
        """New search over one vector [D] or a batch [B, D].

        Returns a ``ResultSet``; ``.query`` is the ``ECPQuery`` handle for
        ``next(k)`` continuation, ``save()``, and ``close()``.  Batch
        queries traverse in lockstep rounds with cross-query node-fetch
        dedup (``.query.batch_stats``).

        ``probe_m`` overrides the index's multi-probe width for this
        query: each traversal step pops the top-``probe_m`` frontier
        entries instead of just the single best, widening descent (and,
        past the leaf-budget boundary, scanning up to ``probe_m - 1``
        extra leaves) for higher recall at the same ``b``.  ``probe_m=1``
        (the default) is bit-identical to strict best-first traversal.
        """
        b = 8 if b is None else int(b)
        pm = self._probe_m if probe_m is None else max(1, int(probe_m))
        q = np.asarray(q, np.float32)
        single = q.ndim == 1
        Q = q[None, :] if single else q
        excl = set(exclude) if exclude else set()
        if self.engine == "legacy":
            states = [
                legacy.LegacyQueryState(
                    q=row, b=b, mx_inc=mx_inc, exclude=set(excl), probe_m=pm
                )
                for row in Q
            ]
            rows = []
            for qs in states:
                legacy.incremental_search(self, qs, k)
                rows.append(legacy.next_items(self, qs, k))
            return self._result(rows, states, k, single, ECPQuery(self, states, single=single))
        states = [
            QueryState(q=row, b=b, mx_inc=mx_inc, exclude=set(excl), probe_m=pm)
            for row in Q
        ]
        if self.info.spill_s > 0:
            # spill-built index: a vector may live in several leaves —
            # id-level dedup at emission keeps next(k) duplicate-free
            for qs in states:
                qs.I.dedup = True
        self._quant_seq += 1
        if len(states) == 1:
            self._increment(states[0], k)
            rows = [self._next_items(states[0], k)]
            return self._result(rows, states, k, single, ECPQuery(self, states, single=single))
        # batch: initial increment, then one resume pass for underflowing
        # rows — the same two chances Algorithm 1 + 2 give a single query
        agg = SearchStats()
        self._batch_increment(states, k, agg)
        need = [qs for qs in states if self._ilen(qs) < k and qs.T]
        if need:
            self._batch_increment(need, k, agg)
        rows = [self._next_items(qs, k, resume=False) for qs in states]
        return self._result(
            rows, states, k, single, ECPQuery(self, states, single=single, batch_stats=agg)
        )

    def _result(self, rows, states, k, single, query) -> ResultSet:
        d, i = pack_rows([r[0] for r in rows], [r[1] for r in rows], k)
        if single:
            return ResultSet(dists=d[0], ids=i[0], stats=states[0].stats, query=query)
        return ResultSet(dists=d, ids=i, stats=[s.stats for s in states], query=query)

    # ------------------------------------------------------- Algorithm 2
    def _next_rows(self, states: list, k: int, batch_stats: SearchStats | None = None) -> list:
        if self.engine == "legacy":
            return [legacy.next_items(self, qs, k) for qs in states]
        self._quant_seq += 1
        if len(states) > 1:
            need = [qs for qs in states if self._ilen(qs) < k and qs.T]
            if need:
                agg = batch_stats if batch_stats is not None else SearchStats()
                self._batch_increment(need, k, agg)
            return [self._next_items(qs, k, resume=False) for qs in states]
        return [self._next_items(qs, k) for qs in states]

    def _next_items(self, qs: QueryState, k: int, *, resume: bool = True):
        if resume and self._ilen(qs) < k and qs.T:
            self._increment(qs, k)
        d, i = qs.I.take(k)
        qs.emitted += int(len(d))
        if qs.virtual_i is not None:
            qs.virtual_i = max(0, qs.virtual_i - int(len(d)))
        return d, i

    # ------------------------------------------------------- Algorithm 3
    def _start(self, qs: QueryState) -> None:
        qs.started = True
        d = np_distances(qs.q, self.root_emb, self.info.metric)
        qs.stats.distance_calcs += len(self.root_emb)
        qs.T.push_batch(d, self.root_ids, 1 if self.info.levels == 1 else 0, 1)

    def _increment(self, qs: QueryState, k: int) -> None:
        if self._quantized:
            # the quantized scan lives in the round engine (it is what
            # builds the per-round grouped kernel launch) — a single query
            # is a batch of one, with io/launches re-attributed to the row
            io_before = self.store.io.snapshot()
            agg = SearchStats()
            self._batch_increment([qs], k, agg)
            qs.stats.kernel_launches += agg.kernel_launches
            qs.stats.io.add(self.store.io.delta(io_before))
            return
        info = self.info
        leaf_cnt = 0
        qs.b_cur = qs.b  # each increment starts from the configured budget
        loads_before = self.load_node_count
        io_before = self.store.io.snapshot()
        qs._excl_arr = None  # re-read the (mutable) exclude set

        if not qs.started:
            self._start(qs)

        # Each step pops a probe group — the top-min(probe_m, |T|) frontier
        # entries taken BEFORE any of them is expanded (children pushed by
        # the group land in the next group, exactly one batch-engine round).
        # Budget checks stay inline per leaf but only break at the group
        # boundary, so a group may stage up to probe_m - 1 leaves past the
        # stopping point — that overshoot is the recall widening.
        # probe_m=1 is exactly the old single-pop loop.
        while qs.T:
            stop = False
            group = [qs.T.pop() for _ in range(min(qs.probe_m, len(qs.T)))]
            for dist, is_leaf, level, node in group:
                qs.stats.nodes_opened += 1
                emb, ids = self.get_node(level, node)
                if len(ids) == 0:
                    continue
                d = self._score_row(qs.q, emb, self._sqnorms(level, node, emb), leaf=bool(is_leaf))
                qs.stats.distance_calcs += len(ids)
                if is_leaf:
                    qs.stats.leaves_opened += 1
                    self._stage_leaf(qs, d, ids)
                    leaf_cnt += 1
                else:
                    qs.T.push_batch(d, ids, 1 if (level + 1) == info.levels else 0, level + 1)
                    if self._store_prefetch is not None:
                        # async: start loading the nearest children while
                        # the traversal keeps scoring (frontier prefetch)
                        want = self._prefetch_hint(level + 1, ids, d)
                        if want:
                            self._store_prefetch(want, on_node=self._on_prefetched)
                if is_leaf and leaf_cnt >= qs.b_cur:
                    if len(qs.I) >= k:
                        stop = True
                    elif qs.mx_inc == -1 or qs.increments < qs.mx_inc:
                        qs.increments += 1
                        qs.stats.increments += 1
                        qs.b_cur *= 2
                    else:
                        stop = True
            if stop:
                break
        qs.stats.node_loads += self.load_node_count - loads_before
        # NOTE: with an AsyncPrefetchStore, background reads count when they
        # complete, so per-traversal io can lag slightly; store.drain() gives
        # exact attribution (benchmarks use it between passes)
        qs.stats.io.add(self.store.io.delta(io_before))
        qs.I.commit()

    # --------------------------------------------- Algorithm 3, batch mode
    def _batch_increment(self, states: list, k: int, agg: SearchStats) -> None:
        """Advance every row's traversal in lockstep rounds.

        Each round pops one node demand per active row, dedupes the
        demands, and issues a single cache-aware ``get_nodes`` so the blob
        backend coalesces adjacent blocks and a node wanted by several
        rows is read once.  Per-row control flow (leaf budget, b-doubling,
        termination) is exactly Algorithm 3, so results are bit-identical
        to independent single-query traversals.

        Stats: each row keeps its own nodes_opened / distance_calcs /
        leaves_opened / increments / rounds, and counts ``node_loads`` as
        the misses *it* demanded (what a solo run would have read) with
        ``dedup_hits`` for demands served by another row's load in the
        same round.  ``agg`` gets the actual deduped loads, total rounds,
        total dedup savings, and the store io delta of the whole call
        (per-row ``stats.io`` stays zero in batch mode — coalesced reads
        have no per-row attribution).
        """
        info = self.info
        quant = self._quantized
        io_before = self.store.io.snapshot()
        for qs in states:
            qs._excl_arr = None  # re-read the (mutable) exclude set
            qs.b_cur = qs.b  # each increment starts from the configured budget
            if not qs.started:
                self._start(qs)
            if quant and qs.virtual_i is None:
                qs.virtual_i = len(qs.I)
        leaf_cnt = {id(qs): 0 for qs in states}
        pending: list = []  # quantized (query, leaf) units awaiting rerank
        active = [qs for qs in states if qs.T]
        while active:
            agg.rounds += 1
            pops = []
            for qs in active:
                # multi-probe: each round takes the row's top-probe_m
                # frontier entries (probe_m=1 = the old single pop), so
                # the round's dedup/coalescing window widens with m
                for _ in range(min(qs.probe_m, len(qs.T))):
                    d0, is_leaf, level, node = qs.T.pop()
                    qs.stats.nodes_opened += 1
                    pops.append((qs, is_leaf, level, node))
                qs.stats.rounds += 1
            # cross-query fetch dedup: unique (level, node) demands, one
            # batched read for all of them
            key_rows: dict[tuple, list] = {}
            for p in pops:
                key_rows.setdefault((p[2], p[3]), []).append(p)
            keys = list(key_rows)
            # quantized mode scans leaves from the compressed companion
            # blocks; only internal nodes go through the fp payload path.
            # A leaf whose full fp node is already cached (a prior rerank
            # fetched it), or whose row cache was born in an earlier call
            # (repeat demand — read it whole once, scan it cheap forever),
            # skips the kernel + rerank entirely and scans through the fp
            # path — the results are bit-identical either way, and the
            # warm path costs what the plain engine's does.
            if quant:
                leaf_keys = [
                    key
                    for key in keys
                    if key_rows[key][0][1] and not self._fp_leaf(key)
                ]
                lset = set(leaf_keys)
                fp_keys = [key for key in keys if key not in lset]
            else:
                leaf_keys, fp_keys = [], keys
            missing = {
                key for key in fp_keys if not self.cache.contains(self._key(*key))
            }
            missing |= {
                key
                for key in leaf_keys
                if not self.cache.contains(self._key(*key) + ("q",))
            }
            payloads = dict(zip(fp_keys, self.get_nodes(fp_keys))) if fp_keys else {}
            qpayloads = (
                dict(zip(leaf_keys, self._get_quant_nodes(leaf_keys)))
                if leaf_keys
                else {}
            )
            for key in keys:
                demanders = key_rows[key]
                if key in missing:
                    agg.node_loads += 1
                    agg.dedup_hits += len(demanders) - 1
                    for j, p in enumerate(demanders):
                        p[0].stats.node_loads += 1
                        if j:
                            p[0].stats.dedup_hits += 1
            hints: dict[tuple, None] = {}
            done: set[int] = set()
            if leaf_keys:
                self._quant_scan_round(
                    leaf_keys, key_rows, qpayloads, k, agg, leaf_cnt, done, pending
                )
            for key in fp_keys:
                emb, ids = payloads[key]
                if len(ids) == 0:
                    continue
                level, node = key
                demanders = key_rows[key]
                is_leaf = bool(demanders[0][1])
                sq = self._sqnorms(level, node, emb)
                D = None
                if self._batch_matrix and len(demanders) >= 4 and not (is_leaf and (self._scorer is not None or quant)):
                    # opt-in dense [B', N] block (not bit-exact across B');
                    # only pays off once enough rows co-demand the node
                    D = np_distances(
                        np.stack([p[0].q for p in demanders]), emb, info.metric, c_sqnorms=sq
                    )
                for r, (qs, _, _, _) in enumerate(demanders):
                    d = D[r] if D is not None else self._score_row(
                        qs.q, emb, sq, leaf=is_leaf and not quant
                    )
                    qs.stats.distance_calcs += len(ids)
                    if is_leaf:
                        qs.stats.leaves_opened += 1
                        d_f, _ = self._stage_leaf(qs, d, ids)
                        if qs.virtual_i is not None:
                            # a fully-staged leaf advances the virtual
                            # count by its live rows, and its exact
                            # distances tighten the cross-leaf watermark
                            qs.virtual_i += int(len(d_f))
                            self._note_exact(qs, d_f)
                        leaf_cnt[id(qs)] += 1
                        if leaf_cnt[id(qs)] >= qs.b_cur:
                            if self._ilen(qs) >= k:
                                done.add(id(qs))
                            elif qs.mx_inc == -1 or qs.increments < qs.mx_inc:
                                qs.increments += 1
                                qs.stats.increments += 1
                                qs.b_cur *= 2
                            else:
                                done.add(id(qs))
                    else:
                        qs.T.push_batch(d, ids, 1 if (level + 1) == info.levels else 0, level + 1)
                        if self._store_prefetch is not None:
                            for hk in self._prefetch_hint(level + 1, ids, d):
                                hints[hk] = None
            if hints:
                self._store_prefetch(list(hints), on_node=self._on_prefetched)
            active = [qs for qs in active if id(qs) not in done and qs.T]
        self._quant_finalize(pending)
        agg.io.add(self.store.io.delta(io_before))
        for qs in states:
            qs.I.commit()

    # ------------------------------------------- quantized leaf scan round
    def _quant_scan_round(
        self, leaf_keys, key_rows, qpayloads, k, agg, leaf_cnt, done, pending
    ) -> None:
        """Scan every (query, leaf) unit of one traversal round from the
        quantized companion blocks with ONE grouped device launch.

        Only the approximate results are produced here — they go on
        ``pending`` and are reranked once, at the end of the increment
        (``_quant_finalize``), when every scanned leaf's upper bounds have
        been seen and the per-query pruning watermark is as tight as it
        will get.  Traversal control flow never looks at staged leaf
        distances (only at the virtual candidate count and the internal
        levels), so deferring the rerank cannot change which nodes are
        visited."""
        info = self.info
        metric = info.metric
        tomb = self._tomb_sorted()
        units = []  # (qs, key, qn, R)
        for key in leaf_keys:
            qn = qpayloads[key]
            if qn.n_rows == 0:
                continue  # matches the fp engines: empty nodes cost nothing
            for qs, _leaf, _lv, _nd in key_rows[key]:
                units.append(
                    (qs, key, qn, max(self._rerank_depth or 0, qs.emitted + k))
                )
        if not units:
            return
        # ---- the round's single grouped kernel launch
        G = len(units)
        n_max = max(u[2].n_rows for u in units)
        r_max = max(u[3] for u in units)
        kop = min(n_max, -(-(r_max + 16) // 32) * 32)
        q_arr = np.stack([np.asarray(u[0].q, np.float32) for u in units])
        codes = np.zeros((G, n_max, info.dim), qdtype(self._qformat))
        scales = np.zeros(G, np.float32)
        offsets = np.zeros(G, np.float32)
        n_rows = np.zeros(G, np.int32)
        for g, (qs, key, qn, R) in enumerate(units):
            codes[g, : qn.n_rows] = qn.codes
            scales[g] = qn.scale
            offsets[g] = qn.offset
            n_rows[g] = qn.n_rows
        dists, idxs = _kernel_ops().grouped_distance_topk(
            q_arr, codes, scales, offsets, n_rows, kop, metric, self._qformat
        )
        agg.kernel_launches += 1
        # ---- record approximate results; advance per-query control flow
        for g, (qs, key, qn, R) in enumerate(units):
            dead_rows = None
            n_dead = 0
            if tomb is not None or qs.exclude:
                ids = self._get_leaf_ids(*key)
                dead = np.zeros(len(ids), bool)
                if tomb is not None:
                    dead |= np.isin(ids, tomb)
                if qs.exclude:
                    dead |= np.isin(ids, qs.excl())
                dead_rows = np.flatnonzero(dead)
                n_dead = len(dead_rows)
            valid = idxs[g] >= 0
            pending.append(
                (
                    qs,
                    key,
                    qn,
                    R,
                    dists[g][valid].astype(np.float64),
                    idxs[g][valid].astype(np.int64),
                    qn.n_rows > kop,
                    dead_rows,
                )
            )
            qs.stats.distance_calcs += qn.n_rows
            qs.stats.leaves_opened += 1
            # virtual candidate count advances by what the fp engine would
            # have staged: every live row of the leaf, survivors or not
            qs.virtual_i += qn.n_rows - n_dead
            leaf_cnt[id(qs)] += 1
            if leaf_cnt[id(qs)] >= qs.b_cur:
                if qs.virtual_i >= k:
                    done.add(id(qs))
                elif qs.mx_inc == -1 or qs.increments < qs.mx_inc:
                    qs.increments += 1
                    qs.stats.increments += 1
                    qs.b_cur *= 2
                else:
                    done.add(id(qs))

    def _quant_finalize(self, pending) -> None:
        """End-of-increment rerank of every pending (query, leaf) unit.

        Pass 1 live-filters each unit and pools its exact-distance upper
        bounds per query; the R-th smallest pooled value (together with
        ``best_d``, the exact distances staged by earlier increments) is a
        sound bound on the query's R-th best distance — at least R
        distinct rows provably score at or below it.  Pass 2 keeps only
        rows whose lower bound could still reach rank R under that final
        watermark, then fetches and scores the survivors.

        A fully-pruned leaf never touches its fp block — that is the
        scan's byte saving.  Already-cached or high-coverage leaves go
        through ONE coalescing ``get_nodes`` (which populates the node
        cache, so later increments scan them on the cached fp fast path);
        sparse survivor sets use partial row reads (I/O proportional to
        R, not the leaf size) accumulated in a per-leaf _LeafRowCache —
        each storage row is read from disk at most once no matter how
        many queries or increments demand it.  The row cache keeps the
        full leaf shape so every scoring GEMM below has exactly the shape
        the fp engine's has, and a GEMM's per-column results depend only
        on that column's data — so staged distances stay bit-identical (a
        subset-shaped GEMM would drift in the last ulp)."""
        if not pending:
            return
        info = self.info
        metric = info.metric
        # ---- pass 1: live-filter, bounds, per-query upper-bound pool
        prep = []
        pools: dict[int, list] = {}
        rank: dict[int, int] = {}
        for qs, key, qn, R, d_sorted, i_sorted, truncated, dead_rows in pending:
            if dead_rows is not None and len(dead_rows) and len(i_sorted):
                live = ~np.isin(i_sorted, dead_rows)
                d_live, i_live = d_sorted[live], i_sorted[live]
            else:
                d_live, i_live = d_sorted, i_sorted
            q_norm = qs.q_norm() if metric == "ip" else 0.0
            if len(d_live):
                lb, ub = distance_bounds(d_live, qn.radius, metric, q_norm)
                pools.setdefault(id(qs), []).append(ub)
            else:
                lb = ub = None
            rank[id(qs)] = max(rank.get(id(qs), 0), R)
            prep.append(
                (qs, key, qn, R, d_sorted, d_live, i_live, lb, ub, truncated, dead_rows)
            )
        tau_state: dict[int, float] = {}
        for qs, key, qn, R, *_ in prep:
            qid = id(qs)
            if qid in tau_state:
                continue
            vals = pools.get(qid, [])
            if qs.best_d is not None:
                vals = vals + [qs.best_d]
            R = rank[qid]
            if vals:
                u = np.concatenate(vals)
                u.sort()
                tau_state[qid] = float(u[R - 1]) if len(u) >= R else np.inf
            else:
                tau_state[qid] = np.inf
        # ---- pass 2: survivors per unit under the final watermark
        need_rows: dict[tuple, list] = {}
        selections = []  # (qs, key, qn, rows)
        for qs, key, qn, R, d_sorted, d_live, i_live, lb, ub, truncated, dead_rows in prep:
            q_norm = qs.q_norm() if metric == "ip" else 0.0
            rows, overflow = self._quant_survivors(
                d_live, i_live, lb, ub, d_sorted, truncated,
                qn.radius, R, tau_state[id(qs)], q_norm, metric,
            )
            if overflow:
                # rescore the whole leaf from the local codes on the host
                d_all = np_distances(qs.q, qn.decode(), metric).astype(np.float64)
                order = np.argsort(d_all, kind="stable").astype(np.int64)
                if dead_rows is not None and len(dead_rows):
                    live = ~np.isin(order, dead_rows)
                    d_l, i_l = d_all[order][live], order[live]
                else:
                    d_l, i_l = d_all[order], order
                lb2 = ub2 = None
                if len(d_l):
                    lb2, ub2 = distance_bounds(d_l, qn.radius, metric, q_norm)
                rows, _ = self._quant_survivors(
                    d_l, i_l, lb2, ub2, d_all, False,
                    qn.radius, R, tau_state[id(qs)], q_norm, metric,
                )
            selections.append((qs, key, qn, rows))
            if len(rows):
                need_rows.setdefault(key, []).append(rows)
        # ---- survivor fetch: one coalescing full read + row-cache top-ups
        partial_getter = getattr(self.store, "get_node_rows", None)
        unions: dict[tuple, np.ndarray] = {}
        full_keys: list = []
        plans: dict[tuple, tuple] = {}  # key -> (rkey, row_cache, missing)
        n_of = {key: qn.n_rows for _, key, qn, _ in selections}
        for key, row_lists in need_rows.items():
            union = (
                row_lists[0]
                if len(row_lists) == 1
                else np.unique(np.concatenate(row_lists))
            )
            unions[key] = union
            if partial_getter is None or self.cache.contains(self._key(*key)):
                full_keys.append(key)
                continue
            rkey = self._key(*key) + ("rows",)
            rc = self.cache.get(rkey)
            missing = union if rc is None else union[~rc.have[union]]
            # with contiguous-only run merging a partial fetch never reads
            # a byte it doesn't need, so a full-node read only wins (on
            # syscalls) when literally every row is demanded
            if rc is None and len(missing) >= n_of[key]:
                full_keys.append(key)
            else:
                plans[key] = (rkey, rc, missing)
        full_payloads = (
            dict(zip(full_keys, self.get_nodes(full_keys))) if full_keys else {}
        )
        fetched: dict[tuple, tuple] = {}
        for key, union in unions.items():
            if key in full_payloads:
                emb, ids = full_payloads[key]
                fetched[key] = (emb, self._sqnorms(*key, emb), ids)
            else:
                rkey, rc, need = plans[key]
                if rc is None:
                    rc = _LeafRowCache(n_of[key], info.dim, self._quant_seq)
                if len(need):
                    emb_rows, ids_rows = partial_getter(*key, need)
                    rc.emb[need] = emb_rows
                    rc.ids[need] = ids_rows
                    rc.have[need] = True
                    if rc.have.all():
                        # the accumulated rows ARE the node (same f32 cast
                        # as get_node) — promote to the node cache so the
                        # leaf scans on the fp fast path from now on
                        self.cache.put(self._key(*key), (rc.emb, rc.ids))
                    else:
                        self.cache.put(rkey, rc)
                fetched[key] = (rc.emb, None, rc.ids)
        # ---- exact scoring + staging, per unit
        for qs, key, qn, rows in selections:
            if not len(rows):
                continue
            emb, sq, ids = fetched[key]
            d_full = np_distances(qs.q, emb, metric, c_sqnorms=sq)
            d_live, _ = self._stage_leaf(qs, d_full[rows], ids[rows])
            self._note_exact(qs, d_live)

    @staticmethod
    def _quant_survivors(
        d_live, i_live, lb, ub, d_sorted, truncated, radius, R, tau_state,
        q_norm, metric,
    ) -> tuple[np.ndarray, bool]:
        """Rows of one scanned leaf that must be reranked: every live row
        whose exact-distance lower bound could still reach rank ``R``.

        ``d_live``/``i_live``/``lb``/``ub`` are the unit's live
        approximate distances (ascending), storage rows, and exact-
        distance bounds; ``d_sorted`` is the unfiltered approx list (its
        tail bounds the unseen rows); ``tau_state`` is the query's pooled
        cross-leaf watermark.  Returns (survivor rows ascending,
        overflow): overflow means pruning the unseen tail past a
        truncated kernel list could not be proven sound and the caller
        must rescore the whole leaf from the local codes (no extra
        I/O)."""
        if len(d_live) == 0:
            return i_live, bool(truncated)
        Rp = min(R, len(d_live))
        # ub is ascending (monotone in the approx distance), so the Rp-th
        # smallest live upper bound closes the leaf-local threshold; the
        # cross-leaf watermark can only tighten it
        tau = min(float(ub[Rp - 1]), tau_state)
        # slack absorbs device-vs-host float drift in approx distances
        # (f32 kernel vs f64 host bounds: relative error ~1e-6)
        tau_eff = tau + 1e-4 * abs(tau) + 1e-7
        rows = np.sort(i_live[lb <= tau_eff])
        if truncated:
            # unseen rows all score >= the largest seen approx distance;
            # prunable only if even that lower bound clears tau
            lb_tail = distance_bounds(d_sorted[-1:], radius, metric, q_norm)[0][0]
            if len(d_live) < R or lb_tail <= tau_eff:
                return rows, True
        return rows, False

    # -------------------------------------------------------- persistence
    def _export_state(self, qs) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(item_dists, item_ids, frontier_rows) in the §6.2 schema —
        identical on-disk layout for both engines."""
        if isinstance(qs, legacy.LegacyQueryState):
            return legacy.export_state(qs)
        d, i = qs.I.export_items()
        return d, i, qs.T.export_rows()

    def load_query(self, name: str, *, group: str = "query_states") -> ECPQuery:
        """Rehydrate a saved ``ECPQuery`` (token from ``ECPQuery.save``)."""
        store = self.state_store
        g = f"{group}/{name}"
        head = store.read_attrs(g)
        n_rows = int(head.get("n_rows", 1))
        single = bool(head.get("single", n_rows == 1))
        states = []
        for r in range(n_rows):
            rg = f"{g}/row_{r:06d}"
            a = store.read_attrs(rg)
            q = store.read_array(f"{rg}/query")
            d = store.read_array(f"{rg}/item_dists")
            i = store.read_array(f"{rg}/item_ids")
            t = store.read_array(f"{rg}/frontier")
            seen = (
                store.read_array(f"{rg}/seen_ids")
                if store.exists(f"{rg}/seen_ids")
                else None
            )
            if self.engine == "legacy":
                qs = legacy.load_state(q, a, d, i, t, seen_ids=seen)
            else:
                qs = QueryState(
                    q=q,
                    b=int(a["b"]),
                    mx_inc=int(a["mx_inc"]),
                    exclude=set(a.get("exclude", [])),
                    probe_m=int(a.get("probe_m", 1)),
                )
                qs.increments = int(a["increments"])
                qs.emitted = int(a["emitted"])
                qs.started = bool(a["started"])
                qs.I = CandidateBuffer.from_items(d, i)
                if self.info.spill_s > 0:
                    qs.I.dedup = True
                    if seen is not None:
                        qs.I.seed_seen(seen)
                qs.T = Frontier.from_rows(t)
            states.append(qs)
        batch_stats = (
            SearchStats() if (self.engine == "flat" and len(states) > 1) else None
        )
        return ECPQuery(self, states, single=single, batch_stats=batch_stats)


class ECPSnapshot(ECPIndex):
    """A generation-pinned, read-only ``ECPIndex`` view — the serving
    subsystem's unit of snapshot isolation.

    Created by ``ECPIndex.snapshot()`` under the mutation lock: the store
    is a pinned ``BlobSnapshot`` (own dup'd fd, copy-on-write protected
    slots) and the in-memory metadata (info, tombstones, root, cache-key
    versions, epoch) is frozen at the same instant, so every search —
    including ``next(k)`` continuations issued arbitrarily later — is
    bit-identical to a fresh single-threaded search of that generation.
    The node cache (and norm cache) is SHARED with the parent: versioned
    keys keep the pinned and live entries apart while still letting
    snapshot readers reuse everything the live index already loaded.

    Searches are thread-safe (no per-index mutable search state beyond
    locked caches), so N scheduler workers can serve from one snapshot.
    ``acquire()``/``release()`` refcount the pin across concurrent
    lease-holders; ``close()`` is an alias for ``release()``.  Mutations
    raise ``PermissionError``.
    """

    def __init__(self, parent: ECPIndex, view):
        # deliberately NOT calling ECPIndex.__init__: every field is
        # copied from the parent (or shared where immutable/lock-guarded)
        self._owns_store = True  # close() releases the pinned view
        self._reopen = None
        self.store = view
        self.info = parent.info
        self._tombstones = set(parent._tombstones)
        self._tomb_arr = parent._tomb_arr
        self._epoch = parent._epoch
        self._node_ver = dict(parent._node_ver)
        self._mut_lock = threading.RLock()  # uncontended; type uniformity
        self._pf_pending: dict = {}
        self.root_emb, self.root_ids = parent.root_emb, parent.root_ids
        self.cache = parent.cache
        self._ns = parent._ns
        self._prefetch_workers = 0
        self._pool = None
        self._store_prefetch = None  # snapshots never prefetch
        self.load_node_count = 0
        self.engine = parent.engine
        self._scorer = parent._scorer
        self._batch_matrix = parent._batch_matrix
        self._norms = parent._norms
        self._quantized = parent._quantized
        self._rerank_depth = parent._rerank_depth
        self._qformat = parent._qformat
        self._quant_seq = parent._quant_seq
        self._probe_m = parent._probe_m
        # never pin from a snapshot: its versioned keys outlive the pin's
        # usefulness once the snapshot closes (parent's pins stay shared)
        self._pin_internal = False
        self._refs = 1
        self._refs_lock = threading.Lock()

    # ------------------------------------------------------------ lifecycle
    def acquire(self) -> "ECPSnapshot":
        """Take one more reference (a scheduler lease); pair with
        ``release()``."""
        with self._refs_lock:
            if self._refs <= 0:
                raise ValueError("snapshot is closed")
            self._refs += 1
        return self

    def release(self) -> None:
        """Drop one reference; the last one releases the store pin."""
        with self._refs_lock:
            self._refs -= 1
            if self._refs != 0:
                return
        self.store.close()

    def close(self) -> None:
        self.release()

    # ------------------------------------------------------------- mutation
    def _read_only(self, *_a, **_k):
        raise PermissionError(
            "ECPSnapshot is a pinned read-only view; mutate the live index"
        )

    insert = delete = compact = refresh = prefetch = _read_only
    _apply_mutation = _reload_store = _read_only
