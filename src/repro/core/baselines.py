"""Baselines the paper evaluates against (§5): brute force, IVF, HNSW,
DiskANN. None of these ship as black boxes here — each is a small, readable
implementation (the paper's complaint about SOTA indexes being opaque is the
reason this module exists at all).

  * BruteForce   — exact scan; ground truth for recall.
  * IVFIndex     — k-means (Lloyd, on-device) + nprobe search (FAISS-style).
  * HNSWLite     — layered navigable-small-world graph, greedy + beam.
  * VamanaLite   — DiskANN's graph: randomized build with alpha-pruning,
                   greedy best-first beam search from a medoid.

All speak the unified ``Searcher`` API (core/api.py):
``search(q, k, *, b) -> ResultSet`` over one vector [D] or a batch [B, D],
where ``b`` is each index's search-effort knob (IVF nprobe, HNSW ef,
Vamana complexity; BruteForce ignores it).  None of them has native
incremental state, so the ``ResultSet.query`` handle is a ``RestartQuery``
that re-searches with ``emitted + k`` — the paper's Table 4 protocol.
These back benchmarks/table{2,3,4}_*.py.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .api import RestartQuery, ResultSet, pack_rows
from .distances import jnp_distances, np_distances

__all__ = ["BruteForce", "IVFIndex", "HNSWLite", "VamanaLite", "kmeans"]


def _as_result(searcher, q, k, b, rows_d, rows_i, *, single) -> ResultSet:
    d, i = pack_rows(rows_d, rows_i, k)
    query = RestartQuery(searcher, q, k, b=b)
    if single:
        return ResultSet(dists=d[0], ids=i[0], stats=None, query=query)
    return ResultSet(dists=d, ids=i, stats=None, query=query)


def _effort_search(searcher, q, k, b, default_effort) -> ResultSet:
    """Shared single/batch dispatch for the effort-knob baselines: resolve
    ``b`` against the index default, loop rows through ``_search_one``."""
    eff = int(b) if b is not None else default_effort
    q = np.asarray(q, np.float32)
    if q.ndim == 1:
        d, i = searcher._search_one(q, k, eff)
        return _as_result(searcher, q, k, b, [d], [i], single=True)
    rows = [searcher._search_one(row, k, eff) for row in q]
    return _as_result(searcher, q, k, b, [r[0] for r in rows], [r[1] for r in rows], single=False)


# --------------------------------------------------------------- brute force
class BruteForce:
    def __init__(self, data: np.ndarray, metric: str = "l2"):
        self.data = np.asarray(data, np.float32)
        self.metric = metric

    def _search_one(self, q: np.ndarray, k: int):
        d = np_distances(q, self.data, self.metric)
        idx = np.argpartition(d, min(k, len(d) - 1))[:k]
        idx = idx[np.argsort(d[idx])]
        return d[idx], idx

    def search(self, q: np.ndarray, k: int = 100, *, b=None) -> ResultSet:
        q = np.asarray(q, np.float32)
        if q.ndim == 1:
            d, i = self._search_one(q, k)
            return _as_result(self, q, k, b, [d], [i], single=True)
        # batch: one dense device distance block, argsorted per row
        d = np.asarray(jnp_distances(jnp.asarray(q), jnp.asarray(self.data), self.metric))
        idx = np.argsort(d, axis=-1)[:, :k]
        return _as_result(
            self, q, k, b,
            list(np.take_along_axis(d, idx, axis=-1)), list(idx), single=False,
        )


# ------------------------------------------------------------------- k-means
def kmeans(
    data: np.ndarray,
    n_clusters: int,
    *,
    iters: int = 10,
    metric: str = "l2",
    seed: int = 0,
    batch: int = 65536,
) -> tuple[np.ndarray, np.ndarray]:
    """Lloyd's k-means with on-device assignment. Returns (centroids, assign)."""
    rng = np.random.default_rng(seed)
    data = np.asarray(data, np.float32)
    n = len(data)
    cent = data[rng.choice(n, size=n_clusters, replace=False)].copy()

    @jax.jit
    def assign_fn(x, c):
        return jnp.argmin(jnp_distances(x, c, metric), axis=-1).astype(jnp.int32)

    assign = np.zeros(n, np.int32)
    for _ in range(iters):
        cj = jnp.asarray(cent)
        for lo in range(0, n, batch):
            hi = min(lo + batch, n)
            assign[lo:hi] = np.asarray(assign_fn(jnp.asarray(data[lo:hi]), cj))
        # host-side centroid update (segment mean)
        sums = np.zeros_like(cent)
        np.add.at(sums, assign, data)
        counts = np.bincount(assign, minlength=n_clusters).astype(np.float32)
        nonempty = counts > 0
        cent[nonempty] = sums[nonempty] / counts[nonempty, None]
        # re-seed empty clusters from random points
        n_empty = int((~nonempty).sum())
        if n_empty:
            cent[~nonempty] = data[rng.choice(n, size=n_empty, replace=False)]
    return cent, assign


# ----------------------------------------------------------------------- IVF
class IVFIndex:
    """Inverted file: k-means coarse quantizer + nprobe search."""

    def __init__(
        self,
        data: np.ndarray,
        n_lists: int,
        *,
        metric: str = "l2",
        train_iters: int = 10,
        seed: int = 0,
        nprobe: int = 8,
    ):
        self.data = np.asarray(data, np.float32)
        self.metric = metric
        self.nprobe = nprobe
        self.centroids, assign = kmeans(
            self.data, n_lists, iters=train_iters, metric=metric, seed=seed
        )
        order = np.argsort(assign, kind="stable")
        bounds = np.searchsorted(assign[order], np.arange(n_lists + 1))
        self.lists = [order[bounds[i] : bounds[i + 1]] for i in range(n_lists)]

    def _search_one(self, q: np.ndarray, k: int, nprobe: int):
        cd = np_distances(q, self.centroids, self.metric)
        probe = np.argsort(cd)[:nprobe]
        cand = np.concatenate([self.lists[p] for p in probe]) if len(probe) else np.zeros(0, np.int64)
        if len(cand) == 0:
            return np.zeros(0, np.float32), np.zeros(0, np.int64)
        d = np_distances(q, self.data[cand], self.metric)
        kk = min(k, len(cand))
        idx = np.argpartition(d, kk - 1)[:kk]
        idx = idx[np.argsort(d[idx])]
        return d[idx], cand[idx]

    def search(self, q: np.ndarray, k: int = 100, *, b=None) -> ResultSet:
        """b = nprobe (coarse lists visited)."""
        return _effort_search(self, q, k, b, self.nprobe)


# ---------------------------------------------------------------------- HNSW
class HNSWLite:
    """Hierarchical navigable small world (Malkov & Yashunin), readable form.

    Build: insert points one at a time; each gets a geometric random level;
    greedy-descend from the entry point, then at each level run a beam
    (ef_construction) and connect to the M closest results (simple pruning).
    """

    def __init__(
        self,
        data: np.ndarray,
        *,
        M: int = 16,
        ef_construction: int = 64,
        metric: str = "l2",
        seed: int = 0,
        ef: int = 100,
    ):
        self.data = np.asarray(data, np.float32)
        self.metric = metric
        self.ef = ef
        self.M = M
        self.ml = 1.0 / np.log(M)
        rng = np.random.default_rng(seed)
        n = len(self.data)
        self.levels = np.minimum(
            (-np.log(rng.uniform(1e-12, 1.0, n)) * self.ml).astype(np.int64), 8
        )
        self.max_level = int(self.levels.max()) if n else 0
        # adjacency: per level, dict node -> list of neighbours
        self.graph: list[dict[int, list[int]]] = [dict() for _ in range(self.max_level + 1)]
        self.entry = 0
        for i in range(n):
            self._insert(i, ef_construction)

    def _dist(self, a: int, q: np.ndarray) -> float:
        return float(np_distances(q, self.data[a][None], self.metric)[0])

    def _search_layer(self, q: np.ndarray, entry: int, ef: int, level: int):
        g = self.graph[level]
        dist0 = self._dist(entry, q)
        visited = {entry}
        cand = [(dist0, entry)]                 # min-heap
        best = [(-dist0, entry)]                # max-heap of current top-ef
        while cand:
            d, u = heapq.heappop(cand)
            if d > -best[0][0]:
                break
            for v in g.get(u, ()):  # explore neighbours
                if v in visited:
                    continue
                visited.add(v)
                dv = self._dist(v, q)
                if dv < -best[0][0] or len(best) < ef:
                    heapq.heappush(cand, (dv, v))
                    heapq.heappush(best, (-dv, v))
                    if len(best) > ef:
                        heapq.heappop(best)
        return sorted((-d, v) for d, v in best)

    def _insert(self, i: int, ef_c: int) -> None:
        lvl = int(self.levels[i])
        if i == 0:
            for lc in range(lvl + 1):
                self.graph[lc][i] = []
            self.entry = i
            self.entry_level = lvl
            return
        q = self.data[i]
        ep = self.entry
        for lc in range(self.max_level, lvl, -1):
            if self.graph[lc]:
                res = self._search_layer(q, ep, 1, lc) if ep in self.graph[lc] else None
                if res:
                    ep = res[0][1]
        for lc in range(min(lvl, self.max_level), -1, -1):
            if ep not in self.graph[lc]:
                self.graph[lc][i] = []
                continue
            res = self._search_layer(q, ep, ef_c, lc)
            neigh = [v for _, v in res[: self.M]]
            self.graph[lc][i] = list(neigh)
            for v in neigh:
                lst = self.graph[lc].setdefault(v, [])
                lst.append(i)
                if len(lst) > 2 * self.M:  # prune by distance to v
                    dv = np_distances(self.data[v], self.data[lst], self.metric)
                    keep = np.argsort(dv)[: self.M]
                    self.graph[lc][v] = [lst[j] for j in keep]
            ep = res[0][1] if res else ep
        if lvl > getattr(self, "entry_level", 0):
            self.entry = i
            self.entry_level = lvl

    def _search_one(self, q: np.ndarray, k: int, ef: int):
        ep = self.entry
        for lc in range(self.max_level, 0, -1):
            if self.graph[lc] and ep in self.graph[lc]:
                ep = self._search_layer(q, ep, 1, lc)[0][1]
        res = self._search_layer(q, ep, max(ef, k), 0)[:k]
        return (
            np.asarray([d for d, _ in res], np.float32),
            np.asarray([v for _, v in res], np.int64),
        )

    def search(self, q: np.ndarray, k: int = 100, *, b=None) -> ResultSet:
        """b = ef (beam width at layer 0)."""
        return _effort_search(self, q, k, b, self.ef)


# -------------------------------------------------------------------- Vamana
class VamanaLite:
    """DiskANN's Vamana graph (readable form): random init, two passes of
    greedy-search + alpha-pruned reconnection; search = best-first beam from
    the medoid ("complexity" = beam width, as DiskANN calls it)."""

    def __init__(
        self,
        data: np.ndarray,
        *,
        R: int = 24,
        L_build: int = 64,
        alpha: float = 1.2,
        metric: str = "l2",
        seed: int = 0,
        complexity: int = 100,
    ):
        self.data = np.asarray(data, np.float32)
        self.metric = metric
        self.complexity = complexity
        self.R = R
        n = len(self.data)
        rng = np.random.default_rng(seed)
        self.nbrs = [list(rng.choice(n, size=min(R, n - 1), replace=False)) for _ in range(n)]
        self.medoid = int(
            np.argmin(np_distances(self.data.mean(0), self.data, metric))
        )
        for _pass in range(2):
            for i in rng.permutation(n):
                _, visited = self._greedy(self.data[i], L_build, return_visited=True)
                self.nbrs[i] = self._robust_prune(i, visited, alpha)
                for j in self.nbrs[i]:
                    if i not in self.nbrs[j]:
                        self.nbrs[j].append(i)
                        if len(self.nbrs[j]) > R:
                            self.nbrs[j] = self._robust_prune(j, self.nbrs[j], alpha)

    def _robust_prune(self, i: int, cand: list[int], alpha: float) -> list[int]:
        cand = [c for c in dict.fromkeys(cand) if c != i]
        if not cand:
            return []
        d_i = np_distances(self.data[i], self.data[cand], self.metric)
        order = np.argsort(d_i)
        chosen: list[int] = []
        for oi in order:
            c = cand[oi]
            if len(chosen) >= self.R:
                break
            ok = True
            if chosen:
                d_cc = np_distances(self.data[c], self.data[chosen], self.metric)
                if np.any(alpha * d_cc < d_i[oi]):
                    ok = False
            if ok:
                chosen.append(c)
        return chosen

    def _greedy(self, q: np.ndarray, L: int, *, return_visited: bool = False):
        start = self.medoid
        d0 = float(np_distances(q, self.data[start][None], self.metric)[0])
        best = [(d0, start)]
        visited = {start}
        frontier = [(d0, start)]
        while frontier:
            d, u = heapq.heappop(frontier)
            if d > best[-1][0] and len(best) >= L:
                break
            new = [v for v in self.nbrs[u] if v not in visited]
            if not new:
                continue
            visited.update(new)
            dv = np_distances(q, self.data[new], self.metric)
            for v, dvv in zip(new, dv):
                heapq.heappush(frontier, (float(dvv), v))
                best.append((float(dvv), v))
            best = sorted(best)[:L]
        if return_visited:
            return best, list(visited)
        return best

    def _search_one(self, q: np.ndarray, k: int, complexity: int):
        best = self._greedy(q, max(complexity, k))
        best = best[:k]
        return (
            np.asarray([d for d, _ in best], np.float32),
            np.asarray([v for _, v in best], np.int64),
        )

    def search(self, q: np.ndarray, k: int = 100, *, b=None) -> ResultSet:
        """b = complexity (DiskANN's beam width)."""
        return _effort_search(self, q, k, b, self.complexity)
