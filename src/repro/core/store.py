"""Pluggable node-storage backends — the I/O seam under every searcher.

The paper's central question (verbose file structure vs compact serialized
index) is an *I/O-layer* question, so the I/O layer is a protocol rather
than a hard-wired ``FStore``:

  ``Store``
    * ``get_node(level, node)``          one node's (embeddings f32, ids)
    * ``get_nodes([(level, node), ..])`` batched node reads (backends may
                                         coalesce adjacent blocks)
    * ``read_attrs`` / ``write_attrs``   JSON metadata (``info`` group)
    * ``write_node(level, node, emb, ids)``
    * ``append_rows(level, node, emb, ids)``   grow a node in place (leaf
                                         appends of the streaming build and
                                         ``ECPIndex.insert``)
    * ``delete_rows(level, node, drop_ids)``   physically remove rows by id
    * ``free_slot(level, node)``         release a node's storage; the node
                                         id stays valid but empty (blob
                                         slots return to the free list)
    * ``io``                             an ``IOStats`` counter
    * level 0, node 0 is the index root (``index_root`` in the file layout)

  Backends (``open_store(path, backend=...)``):
    * ``FStoreBackend`` — the paper's human-readable zarr-v2 hierarchy:
      every node read opens JSON metadata plus raw chunk files.
    * ``BlobStore``     — a single page-aligned file: fixed-size node
      blocks after a small JSON header; one ``pread`` per node, adjacent
      nodes coalesce into one read.  Built from any other store with
      ``convert()``.
    * ``AsyncPrefetchStore`` — wraps either backend with a thread pool so
      the traversal can prefetch frontier children while scoring.

``IOStats`` counts bytes read / files opened / reads issued; searchers
snapshot it around each traversal and thread the delta into
``SearchStats.io`` so file-vs-blob becomes a measurable axis.

BlobStore on-disk format::

  [0:8)    magic b"ECPBLOB1"
  [8:16)   uint64 LE header length H
  [16:16+H) JSON header: page_size, block_bytes, data_offset, dim,
            emb_dtype, ids_dtype, info (index metadata), levels
            (levels[lv] = per-node row counts; levels[0] = [root rows])
  data_offset (page-aligned): one block per node.  A block is n_rows
            embeddings (emb_dtype) then n_rows ids (ids_dtype),
            zero-padded to block_bytes.

Two header formats share the magic; the JSON ``format`` field versions them:

  ``ecp-blob/1``  node -> physical slot is implicit: slots are ordered by
            (level, node) and the file is exactly full.  Read-only in
            structure: rows in an existing slot may be rewritten, but no
            node can be added or released.
  ``ecp-blob/2``  the mutable form (``convert()`` default): the header
            additionally carries ``slots`` (a per-node physical-slot map,
            -1 = released), ``free_slots`` (released physical slots,
            reused by the next allocation), and ``n_slots`` (slots ever
            allocated — the file's data region is n_slots blocks).  New
            nodes appended by leaf splits take a free slot or grow the
            file; ``block_bytes`` is sized so a full ``cluster_cap`` leaf
            always fits.  A v1 file is upgraded to v2 in place the first
            time a structural mutation needs the slot map (if its reserved
            header page can hold the map — otherwise rebuild).
  ``ecp-blob/3``  v2 plus a quantized companion block per slot
            (``convert(..., quant="int8"|"float16")``): the header adds
            ``quant = {"qformat", "q_block_bytes"}`` and every slot's
            stride becomes ``block_bytes + q_block_bytes`` — the
            full-precision block, then ``[scale f32][offset f32][codes
            n_rows*dim]``.  ``get_quantized``/``get_nodes_quantized``
            read only the (much smaller) companion; ``get_node_rows``
            reads a subset of full-precision rows for the rerank;
            ``write_node`` re-encodes the companion on every update so
            insert/delete/split/compact keep the two views coherent.
            Stores without a companion (v1/v2 blobs, fstore) serve
            ``get_quantized`` by encoding on the fly from the
            full-precision rows — same codes, no byte savings.

Snapshot isolation (the serving subsystem's read side): ``BlobStore.pin()``
returns a ``BlobSnapshot`` — a read-only view pinned to the header version
at pin time, on its own dup'd fd.  While pins are outstanding, in-place
node updates copy-on-write into fresh slots and the superseded slots are
retired (recycled once every older pin releases), so snapshot reads are
bit-identical to the pinned version forever and never take the store lock.
"""
from __future__ import annotations

import json
import os
import threading
import weakref
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Protocol, runtime_checkable

import numpy as np

from . import layout
from .fstore import FStore, dtype_to_zarr, zarr_to_dtype
from .quant import QFORMATS, QuantNode, encode_node, qdtype

__all__ = [
    "IOStats",
    "Store",
    "FStoreBackend",
    "BlobStore",
    "BlobSnapshot",
    "AsyncPrefetchStore",
    "NodeNormCache",
    "open_store",
    "convert",
    "BLOB_MAGIC",
    "BLOB_FILENAME",
]

BLOB_MAGIC = b"ECPBLOB1"
BLOB_FILENAME = "index.blob"


# ------------------------------------------------------------------- IOStats
class IOStats:
    """Thread-safe I/O counters: bytes read, files opened, reads issued.

    Prefetch accuracy rides along: ``prefetch_issued`` counts background
    reads scheduled, ``prefetch_hits`` counts prefetched payloads a demand
    read actually consumed (joined in flight, or served from the node
    cache before eviction), and ``prefetch_wasted_bytes`` counts bytes
    read ahead that were never used (evicted before demand, invalidated by
    a write, or still unconsumed when the pass flushed) — the axis that
    explains whether ``+prefetch`` pays for its extra reads.
    """

    __slots__ = (
        "bytes_read",
        "files_opened",
        "reads_issued",
        "prefetch_issued",
        "prefetch_hits",
        "prefetch_wasted_bytes",
        "internal_reads",
        "_lock",
    )

    def __init__(
        self,
        bytes_read: int = 0,
        files_opened: int = 0,
        reads_issued: int = 0,
        prefetch_issued: int = 0,
        prefetch_hits: int = 0,
        prefetch_wasted_bytes: int = 0,
        internal_reads: int = 0,
    ):
        self.bytes_read = bytes_read
        self.files_opened = files_opened
        self.reads_issued = reads_issued
        self.prefetch_issued = prefetch_issued
        self.prefetch_hits = prefetch_hits
        self.prefetch_wasted_bytes = prefetch_wasted_bytes
        self.internal_reads = internal_reads
        self._lock = threading.Lock()

    def count(self, nbytes: int, *, files: int = 0, reads: int = 1) -> None:
        with self._lock:
            self.bytes_read += int(nbytes)
            self.files_opened += files
            self.reads_issued += reads

    def count_internal(self, reads: int = 1) -> None:
        """Internal-level (non-leaf) node loads that missed the cache —
        incremented by the traversal, not the raw read path, because only
        the engine knows a key's level.  Hot-level pinning drives this to
        ~0 on warm queries; the counter is the proof."""
        with self._lock:
            self.internal_reads += reads

    def count_prefetch(self, *, issued: int = 0, hits: int = 0, wasted_bytes: int = 0) -> None:
        with self._lock:
            self.prefetch_issued += issued
            self.prefetch_hits += hits
            self.prefetch_wasted_bytes += int(wasted_bytes)

    def snapshot(self) -> "IOStats":
        with self._lock:
            return IOStats(
                self.bytes_read,
                self.files_opened,
                self.reads_issued,
                self.prefetch_issued,
                self.prefetch_hits,
                self.prefetch_wasted_bytes,
                self.internal_reads,
            )

    def delta(self, since: "IOStats") -> "IOStats":
        with self._lock:
            return IOStats(
                self.bytes_read - since.bytes_read,
                self.files_opened - since.files_opened,
                self.reads_issued - since.reads_issued,
                self.prefetch_issued - since.prefetch_issued,
                self.prefetch_hits - since.prefetch_hits,
                self.prefetch_wasted_bytes - since.prefetch_wasted_bytes,
                self.internal_reads - since.internal_reads,
            )

    def add(self, other: "IOStats") -> None:
        with self._lock:
            self.bytes_read += other.bytes_read
            self.files_opened += other.files_opened
            self.reads_issued += other.reads_issued
            self.prefetch_issued += other.prefetch_issued
            self.prefetch_hits += other.prefetch_hits
            self.prefetch_wasted_bytes += other.prefetch_wasted_bytes
            self.internal_reads += other.internal_reads

    def as_dict(self) -> dict:
        with self._lock:
            return {
                "bytes_read": self.bytes_read,
                "files_opened": self.files_opened,
                "reads_issued": self.reads_issued,
                "prefetch_issued": self.prefetch_issued,
                "prefetch_hits": self.prefetch_hits,
                "prefetch_wasted_bytes": self.prefetch_wasted_bytes,
                "internal_reads": self.internal_reads,
            }

    def __repr__(self) -> str:
        return (
            f"IOStats(bytes_read={self.bytes_read}, "
            f"files_opened={self.files_opened}, reads_issued={self.reads_issued}, "
            f"prefetch_issued={self.prefetch_issued}, "
            f"prefetch_hits={self.prefetch_hits}, "
            f"prefetch_wasted_bytes={self.prefetch_wasted_bytes})"
        )


# ------------------------------------------------------------------ protocol
@runtime_checkable
class Store(Protocol):
    """Node storage for an eCP index; level 0 node 0 is the root.

    Optional extensions (not required for isinstance checks, probed with
    ``getattr``): ``get_quantized(level, node, qformat)`` /
    ``get_nodes_quantized(keys, qformat)`` returning ``QuantNode``s,
    ``get_node_ids(level, node)`` (ids only), and
    ``get_node_rows(level, node, rows)`` (a sorted subset of fp rows) —
    the quantized-scan/rerank seam.  Backends without them still serve
    the quantized engine via the engine's encode-on-the-fly fallback."""

    backend: str
    io: IOStats

    def get_node(self, level: int, node: int) -> tuple[np.ndarray, np.ndarray]:
        ...

    def get_nodes(self, keys: list) -> list:
        ...

    def read_attrs(self, path: str) -> dict:
        ...

    def write_attrs(self, path: str, attrs: dict) -> None:
        ...

    def write_node(self, level: int, node: int, emb: np.ndarray, ids: np.ndarray) -> None:
        ...

    def append_rows(self, level: int, node: int, emb: np.ndarray, ids: np.ndarray) -> None:
        ...

    def delete_rows(self, level: int, node: int, drop_ids: np.ndarray) -> int:
        ...

    def free_slot(self, level: int, node: int) -> None:
        ...

    def close(self) -> None:
        ...


def _node_group(level: int, node: int) -> str:
    if level == 0:
        if node != 0:
            raise ValueError(f"level 0 has only the root node, got node {node}")
        return layout.ROOT
    return layout.node_group(level, node)


# ------------------------------------------------------------- fstore backend
class FStoreBackend:
    """The paper's mode: nodes as zarr-v2 groups in a directory hierarchy.

    Every hierarchy operation the index's persistence layer needs
    (``read_array``, ``create_group``, ``listdir`` …) delegates to the
    underlying ``FStore``, so this backend is a strict superset: it speaks
    the ``Store`` protocol *and* remains the writable human-readable file
    structure.
    """

    backend = "fstore"

    def __init__(self, path: str | os.PathLike | FStore, *, create: bool = False):
        self.fstore = path if isinstance(path, FStore) else FStore(path, create=create)
        self.io = IOStats()
        self.fstore.io = self.io  # FStore counts json/chunk reads into it
        self.path = self.fstore.root
        self._dim: int | None = None
        self._dtype: np.dtype | None = None

    def __getattr__(self, name):
        # hierarchy ops (read_array, create_group, listdir, exists, ...)
        if name == "fstore":  # pre-__init__ lookups must not recurse
            raise AttributeError(name)
        return getattr(self.fstore, name)

    def _node_dim(self) -> int:
        if self._dim is None:
            self._dim = int(self.read_attrs(layout.INFO).get("dim", 0))
        return self._dim

    def _node_dtype(self) -> np.dtype:
        if self._dtype is None:
            self._dtype = np.dtype(self.read_attrs(layout.INFO).get("dtype", "float16"))
        return self._dtype

    # -------------------------------------------------------------- protocol
    def get_node(self, level: int, node: int) -> tuple[np.ndarray, np.ndarray]:
        g = _node_group(level, node)
        emb_path = f"{g}/{layout.EMB}"
        if not self.fstore.exists(emb_path):
            return (
                np.zeros((0, self._node_dim()), np.float32),
                np.zeros((0,), np.int64),
            )
        emb = self.fstore.read_array(emb_path).astype(np.float32)  # f16 -> f32
        ids = self.fstore.read_array(f"{g}/{layout.IDS}")
        if emb.shape[0] > ids.shape[0]:
            # a torn append (emb grown, ids metadata not yet rewritten)
            # must stay invisible: the node's row count IS len(ids)
            emb = emb[: ids.shape[0]]
        return emb, ids

    def get_nodes(self, keys: list) -> list:
        # the file structure has no batched read primitive — that is the
        # paper's trade-off this seam makes measurable
        return [self.get_node(lv, nd) for lv, nd in keys]

    def node_rows(self, keys: list) -> list[int]:
        """Row counts without reading node data (one metadata read each)."""
        out = []
        for lv, nd in keys:
            ids_path = f"{_node_group(lv, nd)}/{layout.IDS}"
            if not self.fstore.exists(ids_path):
                out.append(0)
            else:
                out.append(int(self.fstore.array_meta(ids_path)["shape"][0]))
        return out

    # ---------------------------------------------- quantized-read fallback
    # the file structure has no quantized companion — codes are derived on
    # the fly from the full-precision rows (bit-identical to what a v3
    # blob persists, since both encode from the storage-dtype-rounded
    # rows), so the quantized engine path works unchanged, just without
    # the byte savings
    quant_format = None

    def get_quantized(self, level: int, node: int, qformat: str = "int8") -> QuantNode:
        emb, _ = self.get_node(level, node)
        return encode_node(emb, qformat)

    def get_nodes_quantized(self, keys: list, qformat: str = "int8") -> list:
        return [encode_node(emb, qformat) for emb, _ in self.get_nodes(keys)]

    def get_node_ids(self, level: int, node: int) -> np.ndarray:
        return self.get_node(level, node)[1]

    def get_node_rows(self, level: int, node: int, rows) -> tuple[np.ndarray, np.ndarray]:
        emb, ids = self.get_node(level, node)
        rows = np.asarray(rows, np.int64)
        return emb[rows], ids[rows]

    def read_attrs(self, path: str) -> dict:
        return self.fstore.read_attrs(path)

    def write_attrs(self, path: str, attrs: dict) -> None:
        self.fstore.write_attrs(path, attrs)

    def write_node(
        self,
        level: int,
        node: int,
        emb: np.ndarray,
        ids: np.ndarray,
        *,
        chunk_rows: int | None = None,
    ) -> None:
        g = _node_group(level, node)
        self.fstore.create_group(g)
        self.fstore.write_array(f"{g}/{layout.EMB}", np.asarray(emb), chunk_rows=chunk_rows)
        self.fstore.write_array(f"{g}/{layout.IDS}", np.asarray(ids))

    def append_rows(
        self,
        level: int,
        node: int,
        emb: np.ndarray,
        ids: np.ndarray,
        *,
        chunk_rows: int | None = None,
    ) -> None:
        """Grow a node in place; only the trailing chunk of each array is
        rewritten.  Creates the node when missing (the streaming build's
        first touch of a leaf)."""
        emb, ids = np.asarray(emb), np.asarray(ids)
        if emb.shape[0] != ids.shape[0]:
            raise ValueError(f"append_rows shape mismatch: emb {emb.shape} ids {ids.shape}")
        g = _node_group(level, node)
        if not self.fstore.is_group(g):
            self.fstore.create_group(g)
        # ids metadata is rewritten last: a torn append leaves extra emb
        # rows invisible to get_node (which sizes the node by its ids)
        self.fstore.append_rows(f"{g}/{layout.EMB}", emb, chunk_rows=chunk_rows)
        self.fstore.append_rows(f"{g}/{layout.IDS}", ids)

    def delete_rows(self, level: int, node: int, drop_ids: np.ndarray) -> int:
        """Physically remove the rows whose ids are in ``drop_ids``."""
        emb, ids = self.get_node(level, node)
        if len(ids) == 0:
            return 0
        keep = ~np.isin(ids, np.asarray(drop_ids, ids.dtype))
        removed = int((~keep).sum())
        if removed:
            self.write_node(level, node, emb[keep].astype(self._node_dtype()), ids[keep])
        return removed

    def free_slot(self, level: int, node: int) -> None:
        """Release a node's storage (the group vanishes from the
        hierarchy); the node id stays addressable and reads as empty."""
        self.fstore.delete(_node_group(level, node))

    def close(self) -> None:
        pass


# --------------------------------------------------------------- blob backend
def _align(n: int, page: int) -> int:
    return -(-n // page) * page


class BlobStore:
    """Page-aligned single-file backend: one ``pread`` per node.

    A v2 blob (``convert()`` default) is mutable: nodes can be rewritten,
    grown (``append_rows``), added (``write_node`` at the level's next
    index — leaf splits), or released (``free_slot``, slot returned to the
    header's free list).  A v1 blob allows only in-slot rewrites; the
    first structural mutation upgrades it to v2 in place when the reserved
    header page can hold the slot map.
    """

    backend = "blob"

    def __init__(self, path: str | os.PathLike):
        p = Path(path)
        if p.is_dir():
            p = p / BLOB_FILENAME
        if not p.is_file():
            raise FileNotFoundError(f"blob store does not exist: {p}")
        self.path = p
        self.io = IOStats()
        try:
            self._fd = os.open(p, os.O_RDWR)
            self._writable = True
        except OSError:  # EACCES, EROFS (read-only mounts), ...
            self._fd = os.open(p, os.O_RDONLY)
            self._writable = False
        head = os.pread(self._fd, 16, 0)
        if head[:8] != BLOB_MAGIC:
            os.close(self._fd)
            self._fd = -1
            raise ValueError(f"not an ecp-blob file (bad magic): {p}")
        (hlen,) = np.frombuffer(head[8:16], "<u8")
        raw = os.pread(self._fd, int(hlen), 16)
        self.io.count(16 + int(hlen), files=1, reads=2)
        self._header = json.loads(raw.decode("utf-8"))
        h = self._header
        fmt = str(h.get("format", "ecp-blob/1"))
        self.format = 3 if fmt.endswith("/3") else 2 if fmt.endswith("/2") else 1
        self.page_size = int(h["page_size"])
        self.block_bytes = int(h["block_bytes"])
        self.data_offset = int(h["data_offset"])
        self.dim = int(h["dim"])
        self.emb_dtype = zarr_to_dtype(h["emb_dtype"])
        self.ids_dtype = zarr_to_dtype(h["ids_dtype"])
        # v3: quantized companion block after each slot's fp block
        q = h.get("quant") or None
        self.quant_format: str | None = str(q["qformat"]) if q else None
        self.q_block_bytes = int(q["q_block_bytes"]) if q else 0
        self._q_dtype = qdtype(self.quant_format) if q else None
        self._stride = self.block_bytes + self.q_block_bytes
        # levels[lv] = list of per-node row counts; levels[0] = [root rows]
        self._n_rows: list[list[int]] = [list(map(int, lv)) for lv in h["levels"]]
        if self.format >= 2:
            self._slots: list[list[int]] = [list(map(int, lv)) for lv in h["slots"]]
            self._free: list[int] = sorted(int(s) for s in h.get("free_slots", []))
            self._n_slots = int(h["n_slots"])
        else:
            # v1: physical slots are implicitly (level, node)-ordered
            at = 0
            self._slots = []
            for lv in self._n_rows:
                self._slots.append(list(range(at, at + len(lv))))
                at += len(lv)
            self._free = []
            self._n_slots = at
        self._row_bytes = self.dim * self.emb_dtype.itemsize + self.ids_dtype.itemsize
        # re-entrant: append_rows/delete_rows hold it across their whole
        # read-modify-write, and call write_node (which takes it) inside
        self._lock = threading.RLock()
        # ---- MVCC: generation pinning for snapshot-isolated readers ----
        # every header install bumps _mvcc_seq; pin() records the current
        # seq and returns a BlobSnapshot whose reads see exactly that
        # header.  While pins exist, in-place updates copy-on-write into a
        # fresh slot and the old slot is RETIRED (kept out of the free
        # list) until every pin taken before the retirement is released.
        self._mvcc_seq = 0
        self._pins: dict[int, int] = {}  # pin id -> seq pinned at
        self._next_pin = 0
        self._retired: list[tuple[int, int]] = []  # (seq retired at, slot)

    # ---------------------------------------------------------------- layout
    @property
    def capacity_rows(self) -> int:
        """Rows one fixed-size block can hold (the hard per-node bound the
        lifecycle's split threshold must respect)."""
        return self.block_bytes // self._row_bytes

    def _check_key(self, level: int, node: int) -> None:
        if not (0 <= level < len(self._n_rows)):
            raise KeyError(f"no such level in blob: {level}")
        if not (0 <= node < len(self._n_rows[level])):
            raise KeyError(f"no such node in blob: lvl {level} node {node}")
        if level == 0 and node != 0:
            raise KeyError("level 0 has only the root node")

    def _slot(self, level: int, node: int) -> int:
        self._check_key(level, node)
        return self._slots[level][node]

    def _offset(self, slot: int) -> int:
        return self.data_offset + slot * self._stride

    def _parse_block(self, buf: bytes, n_rows: int) -> tuple[np.ndarray, np.ndarray]:
        eb = n_rows * self.dim * self.emb_dtype.itemsize
        emb = (
            np.frombuffer(buf, self.emb_dtype, count=n_rows * self.dim)
            .reshape(n_rows, self.dim)
            .astype(np.float32)
        )
        ids = np.frombuffer(buf, self.ids_dtype, count=n_rows, offset=eb).copy()
        return emb, ids

    def _empty(self) -> tuple[np.ndarray, np.ndarray]:
        return np.zeros((0, self.dim), np.float32), np.zeros((0,), self.ids_dtype)

    # ------------------------------------------------------------- raw reads
    # fd/slot-map/row-counts come in as parameters so a pinned
    # ``BlobSnapshot`` (own dup'd fd, frozen maps) shares the exact same
    # read + coalescing code as the live store
    def _read_one(self, fd: int, slot: int, n_rows: int, io: IOStats):
        need = n_rows * self._row_bytes
        buf = os.pread(fd, need, self._offset(slot))
        io.count(need, reads=1)
        return self._parse_block(buf, n_rows)

    def _read_batch(self, fd: int, entries: list, out: list, io: IOStats) -> None:
        """``entries``: (slot, n_rows, out_index) triples; runs of adjacent
        slots coalesce into one pread.  On a v3 blob adjacent fp blocks
        are separated by the quantized companions, so coalescing would
        read (and count) bytes the caller never asked for — each entry
        reads on its own there."""
        entries.sort()
        if self.q_block_bytes:
            for slot, n_rows, i in entries:
                out[i] = self._read_one(fd, slot, n_rows, io)
            return
        j = 0
        while j < len(entries):
            # grow a run of consecutive slots
            r = j
            while r + 1 < len(entries) and entries[r + 1][0] == entries[r][0] + 1:
                r += 1
            first_slot = entries[j][0]
            last_slot, last_rows, _ = entries[r]
            need = (last_slot - first_slot) * self.block_bytes + last_rows * self._row_bytes
            buf = os.pread(fd, need, self._offset(first_slot))
            io.count(need, reads=1)
            for s in range(j, r + 1):
                slot, n_rows, i = entries[s]
                rel = (slot - first_slot) * self.block_bytes
                out[i] = self._parse_block(buf[rel : rel + n_rows * self._row_bytes], n_rows)
            j = r + 1

    def _read_quant_one(self, fd: int, slot: int, n_rows: int, io: IOStats) -> QuantNode:
        """Read one slot's quantized companion: [scale f32][offset f32]
        [codes n_rows*dim] right after the fp block."""
        need = 8 + n_rows * self.dim * self._q_dtype.itemsize
        buf = os.pread(fd, need, self._offset(slot) + self.block_bytes)
        io.count(need, reads=1)
        scale, offset = np.frombuffer(buf, "<f4", count=2)
        codes = (
            np.frombuffer(buf, self._q_dtype, count=n_rows * self.dim, offset=8)
            .reshape(n_rows, self.dim)
            .copy()
        )
        return QuantNode(codes, float(scale), float(offset), self.quant_format)

    def _read_ids_one(self, fd: int, slot: int, n_rows: int, io: IOStats) -> np.ndarray:
        """Read only a block's ids segment (tombstone/exclude filtering of
        a quantized scan — the emb rows stay untouched on disk)."""
        eb = n_rows * self.dim * self.emb_dtype.itemsize
        need = n_rows * self.ids_dtype.itemsize
        buf = os.pread(fd, need, self._offset(slot) + eb)
        io.count(need, reads=1)
        return np.frombuffer(buf, self.ids_dtype, count=n_rows).copy()

    # runs of requested rows whose index difference is <= this merge into
    # one pread.  A difference of 1 is *adjacent* (merging is free), so 1
    # is the bytes-optimal floor for both spans: rerank reads sit on the
    # cold path where bytes_read is the contended budget, so neither span
    # trades bytes for syscalls
    _ROW_READ_GAP = 1
    _IDS_READ_GAP = 1

    def _read_rows_one(
        self, fd: int, slot: int, n_rows: int, rows: np.ndarray, io: IOStats
    ) -> tuple[np.ndarray, np.ndarray]:
        """Read a sorted subset of one block's rows: coalesced range
        preads over the emb rows and over the ids rows independently (the
        full-precision rerank's partial fetch)."""
        esz = self.dim * self.emb_dtype.itemsize
        base = self._offset(slot)
        emb = np.empty((len(rows), self.dim), self.emb_dtype)
        j = 0
        while j < len(rows):
            r = j
            while r + 1 < len(rows) and rows[r + 1] - rows[r] <= self._ROW_READ_GAP:
                r += 1
            a, b = int(rows[j]), int(rows[r])
            need = (b - a + 1) * esz
            buf = os.pread(fd, need, base + a * esz)
            io.count(need, reads=1)
            span = np.frombuffer(buf, self.emb_dtype, count=(b - a + 1) * self.dim)
            span = span.reshape(b - a + 1, self.dim)
            emb[j : r + 1] = span[rows[j : r + 1] - a]
            j = r + 1
        isz = self.ids_dtype.itemsize
        ibase = base + n_rows * esz
        ids = np.empty(len(rows), self.ids_dtype)
        j = 0
        while j < len(rows):
            r = j
            while r + 1 < len(rows) and rows[r + 1] - rows[r] <= self._IDS_READ_GAP:
                r += 1
            a, b = int(rows[j]), int(rows[r])
            need = (b - a + 1) * isz
            buf = os.pread(fd, need, ibase + a * isz)
            io.count(need, reads=1)
            span = np.frombuffer(buf, self.ids_dtype, count=b - a + 1)
            ids[j : r + 1] = span[rows[j : r + 1] - a]
            j = r + 1
        return emb.astype(np.float32), ids

    # -------------------------------------------------------------- protocol
    def get_node(self, level: int, node: int) -> tuple[np.ndarray, np.ndarray]:
        self._check_key(level, node)
        n_rows = self._n_rows[level][node]
        if n_rows == 0:
            return self._empty()
        return self._read_one(self._fd, self._slots[level][node], n_rows, self.io)

    def get_nodes(self, keys: list) -> list:
        """Batched read; runs of adjacent slots coalesce into one pread."""
        out: list = [None] * len(keys)
        entries = []
        for i, (lv, nd) in enumerate(keys):
            self._check_key(lv, nd)
            if self._n_rows[lv][nd] == 0:
                out[i] = self._empty()
            else:
                entries.append((self._slots[lv][nd], self._n_rows[lv][nd], i))
        self._read_batch(self._fd, entries, out, self.io)
        return out

    def node_rows(self, keys: list) -> list[int]:
        """Row counts straight from the in-memory header (no I/O)."""
        return [self._n_rows[lv][nd] for lv, nd in keys]

    # ------------------------------------------------------ quantized reads
    def _empty_quant(self, qformat: str) -> QuantNode:
        return QuantNode(np.zeros((0, self.dim), qdtype(qformat)), 0.0, 0.0, qformat)

    def get_quantized(self, level: int, node: int, qformat: str = "int8") -> QuantNode:
        """One node's quantized rows.  A v3 blob reads the persisted
        companion block (``qformat`` is ignored — the blob has one); a
        v1/v2 blob encodes on the fly from the fp rows (same codes, no
        byte savings)."""
        self._check_key(level, node)
        n_rows = self._n_rows[level][node]
        if self.quant_format is None:
            if n_rows == 0:
                return self._empty_quant(qformat)
            emb, _ = self.get_node(level, node)
            return encode_node(emb, qformat)
        if n_rows == 0:
            return self._empty_quant(self.quant_format)
        return self._read_quant_one(self._fd, self._slots[level][node], n_rows, self.io)

    def get_nodes_quantized(self, keys: list, qformat: str = "int8") -> list:
        return [self.get_quantized(lv, nd, qformat) for lv, nd in keys]

    def get_node_ids(self, level: int, node: int) -> np.ndarray:
        """Only a node's ids (the quantized scan needs them just for
        tombstone/exclude filtering)."""
        self._check_key(level, node)
        n_rows = self._n_rows[level][node]
        if n_rows == 0:
            return np.zeros((0,), self.ids_dtype)
        return self._read_ids_one(self._fd, self._slots[level][node], n_rows, self.io)

    def get_node_rows(self, level: int, node: int, rows) -> tuple[np.ndarray, np.ndarray]:
        """Read a subset of one node's full-precision rows (sorted row
        indices) — the rerank's partial fetch."""
        self._check_key(level, node)
        rows = np.asarray(rows, np.int64)
        n_rows = self._n_rows[level][node]
        if len(rows) == 0:
            return self._empty()
        if rows[0] < 0 or rows[-1] >= n_rows:
            raise IndexError(f"rows out of range for lvl {level} node {node}")
        return self._read_rows_one(self._fd, self._slots[level][node], n_rows, rows, self.io)

    def read_attrs(self, path: str) -> dict:
        if path == layout.INFO:
            return dict(self._header["info"])
        return {}

    def write_attrs(self, path: str, attrs: dict) -> None:
        if not self._writable:
            raise PermissionError(f"blob store opened read-only: {self.path}")
        if path != layout.INFO:
            raise ValueError(
                f"blob store only holds '{layout.INFO}' attributes, not {path!r}"
            )
        with self._lock:
            old = self._header
            self._header = dict(old)
            self._header["info"] = dict(attrs)
            try:
                self._rewrite_header_locked()
            except ValueError:
                # an oversized header (e.g. a huge tombstone list) raises
                # BEFORE any byte is written; in-memory state must agree
                # with the disk, so the old attrs come back
                self._header = old
                raise

    def _prep_rows(self, emb, ids) -> tuple[np.ndarray, np.ndarray, bytes]:
        emb = np.ascontiguousarray(np.asarray(emb), dtype=self.emb_dtype)
        ids = np.ascontiguousarray(np.asarray(ids), dtype=self.ids_dtype)
        if emb.ndim != 2 or emb.shape[1] != self.dim or emb.shape[0] != ids.shape[0]:
            raise ValueError(
                f"write_node shape mismatch: emb {emb.shape} ids {ids.shape} dim {self.dim}"
            )
        need = emb.shape[0] * self._row_bytes
        if need > self.block_bytes:
            raise ValueError(
                f"node data ({need} B) exceeds the fixed block size "
                f"({self.block_bytes} B = {self.capacity_rows} rows); split the "
                "node first or rebuild the blob with convert()"
            )
        block = emb.tobytes() + ids.tobytes()
        block += b"\0" * (self.block_bytes - len(block))
        if self.quant_format is not None:
            # re-encode the companion from the storage-dtype-rounded rows
            # so codes match what a reader would encode from get_node
            qn = encode_node(np.asarray(emb, np.float32), self.quant_format)
            qraw = (
                np.float32(qn.scale).tobytes()
                + np.float32(qn.offset).tobytes()
                + qn.codes.tobytes()
            )
            if len(qraw) > self.q_block_bytes:
                raise ValueError(
                    f"quantized node data ({len(qraw)} B) exceeds the quant "
                    f"block size ({self.q_block_bytes} B); rebuild with convert()"
                )
            block += qraw + b"\0" * (self.q_block_bytes - len(qraw))
        return emb, ids, block

    def write_node(self, level: int, node: int, emb: np.ndarray, ids: np.ndarray) -> None:
        """In-place node update; ``node == len(level)`` appends a new node
        (v2: slot from the free list, else the file grows by one block).

        NOT crash-atomic: the block and header are two in-place writes, so
        a crash between them can leave a stale row count over new bytes.
        The blob is a derived serving artifact — the writable source of
        truth is the fstore hierarchy (every write there goes through
        tmp + os.replace); rebuild a torn blob with ``convert()``.
        """
        if not self._writable:
            raise PermissionError(f"blob store opened read-only: {self.path}")
        emb, ids, block = self._prep_rows(emb, ids)
        n_rows = emb.shape[0]
        with self._lock:
            if not (0 <= level < len(self._n_rows)):
                raise KeyError(f"no such level in blob: {level}")
            n_level = len(self._n_rows[level])
            if level == 0 and node != 0:
                raise KeyError("level 0 has only the root node")
            if node == n_level:
                # structural append: nodes are numbered densely per level
                slot, commit = self._alloc_slot_locked(level, node, n_rows)
            elif 0 <= node < n_level:
                slot = self._slots[level][node]
                if slot < 0:  # rewriting a released node re-allocates storage
                    slot, commit = self._alloc_slot_locked(level, node, n_rows)
                elif self._pins:
                    # copy-on-write: a pinned snapshot may still read the
                    # old block, so the update lands in a fresh slot and
                    # the old one is retired until those pins release
                    slot, commit = self._alloc_slot_locked(
                        level, node, n_rows, retire=slot
                    )
                else:
                    def commit() -> None:
                        self._n_rows[level][node] = n_rows
                        self._rewrite_header_locked()
            else:
                raise KeyError(
                    f"blob nodes are dense per level: next node of lvl {level} "
                    f"is {n_level}, got {node}"
                )
            os.pwrite(self._fd, block, self._offset(slot))
            commit()

    def _v2_candidate_locked(self, rows, slots, free, n_slots) -> tuple[bytes, dict]:
        """Serialize a CANDIDATE v2 header (nothing mutates; an oversized
        header raises here with file and in-memory maps untouched).  Both
        structural mutators build their candidates through this one place
        so the header schema cannot diverge between them."""
        header = dict(self._header)
        # the mutable form: /3 when this blob carries quantized companions
        # (the "quant" section rides along in the header copy), else /2
        header["format"] = "ecp-blob/3" if self.quant_format else "ecp-blob/2"
        header["levels"] = rows
        header["slots"] = slots
        header["free_slots"] = free
        header["n_slots"] = n_slots
        raw = self._check_fits(json.dumps(header, sort_keys=True).encode("utf-8"))
        return raw, header

    def _install_v2_locked(self, raw: bytes, header: dict) -> None:
        """Adopt a candidate header (in memory + on disk)."""
        self.format = max(2, self.format)
        self._header = header
        self._n_rows = header["levels"]
        self._slots = header["slots"]
        self._free = header["free_slots"]
        self._n_slots = header["n_slots"]
        self._pwrite_header_locked(raw)

    def ensure_capacity(self, level: int, new_nodes: int) -> None:
        """Raise — without writing or mutating anything — if appending
        ``new_nodes`` nodes at ``level`` could not fit the reserved header
        region (covers the v1→v2 upgrade too).  Multi-node mutations
        (leaf splits) pre-flight through this so a mid-sequence header
        overflow can never strand already-written nodes."""
        if new_nodes <= 0:
            return
        with self._lock:
            if not (0 <= level < len(self._n_rows)):
                raise KeyError(f"no such level in blob: {level}")
            cand_slots = [list(lv) for lv in self._slots]
            cand_rows = [list(lv) for lv in self._n_rows]
            free = list(self._free)
            n_slots = self._n_slots
            for _ in range(new_nodes):
                slot = free.pop(0) if free else n_slots
                n_slots = max(n_slots, slot + 1)
                cand_slots[level].append(slot)
                cand_rows[level].append(0)
            self._v2_candidate_locked(cand_rows, cand_slots, free, n_slots)

    def _alloc_slot_locked(self, level: int, node: int, n_rows: int, *, retire: int | None = None):
        """Pick a physical slot for a new/re-allocated node; the returned
        commit closure installs the pre-serialized candidate header after
        the block write succeeds.  ``retire`` is the node's previous slot
        when this allocation is a copy-on-write around pinned snapshots:
        it is dropped from the slot map but NOT freed — it joins the
        retired list until every pin older than the install releases.
        (Any slot already on the free list is safe to hand out: it was
        unreferenced in every header a current pin could have pinned.)"""
        new_node = node == len(self._n_rows[level])
        slot = self._free[0] if self._free else self._n_slots
        cand_slots = [list(lv) for lv in self._slots]
        cand_rows = [list(lv) for lv in self._n_rows]
        if new_node:
            cand_slots[level].append(slot)
            cand_rows[level].append(n_rows)
        else:
            cand_slots[level][node] = slot
            cand_rows[level][node] = n_rows
        raw, header = self._v2_candidate_locked(
            cand_rows,
            cand_slots,
            [s for s in self._free if s != slot],
            max(self._n_slots, slot + 1),
        )

        def commit() -> None:
            self._install_v2_locked(raw, header)
            if retire is not None and retire >= 0:
                self._retired.append((self._mvcc_seq, retire))

        return slot, commit

    def append_rows(self, level: int, node: int, emb: np.ndarray, ids: np.ndarray) -> None:
        """Grow a node in place.  The block layout is emb-rows-then-ids, so
        growing rewrites the whole block (one pread + one pwrite); the
        lock is held across the read-modify-write so concurrent appends
        cannot lose each other's rows."""
        with self._lock:
            old_emb, old_ids = self.get_node(level, node)
            emb = np.concatenate(
                [old_emb.astype(self.emb_dtype), np.asarray(emb, self.emb_dtype)]
            )
            ids = np.concatenate([old_ids, np.asarray(ids, self.ids_dtype)])
            self.write_node(level, node, emb, ids)

    def delete_rows(self, level: int, node: int, drop_ids: np.ndarray) -> int:
        with self._lock:
            emb, ids = self.get_node(level, node)
            if len(ids) == 0:
                return 0
            keep = ~np.isin(ids, np.asarray(drop_ids, ids.dtype))
            removed = int((~keep).sum())
            if removed:
                self.write_node(level, node, emb[keep], ids[keep])
            return removed

    def free_slot(self, level: int, node: int) -> None:
        """Release a node's block back to the free list; the node id stays
        valid and reads as empty until something is written to it again.
        With pinned snapshots outstanding the slot is retired instead of
        freed (a pin taken before the release may still read it)."""
        if not self._writable:
            raise PermissionError(f"blob store opened read-only: {self.path}")
        with self._lock:
            self._check_key(level, node)
            slot = self._slots[level][node]
            if slot < 0 and self._n_rows[level][node] == 0:
                return
            retire = bool(self._pins) and slot >= 0
            cand_slots = [list(lv) for lv in self._slots]
            cand_rows = [list(lv) for lv in self._n_rows]
            cand_slots[level][node] = -1
            cand_rows[level][node] = 0
            free = set(self._free)
            if slot >= 0 and not retire:
                free.add(slot)
            raw, header = self._v2_candidate_locked(
                cand_rows, cand_slots, sorted(free), self._n_slots
            )
            self._install_v2_locked(raw, header)
            if retire:
                self._retired.append((self._mvcc_seq, slot))

    def _check_fits(self, raw: bytes) -> bytes:
        if 16 + len(raw) > self.data_offset:
            raise ValueError(
                "blob header grew past the data region (more tombstones or "
                "nodes than the reserved header pages can hold); compact() "
                "the index or rebuild the blob with convert()"
            )
        return raw

    def _pwrite_header_locked(self, raw: bytes) -> None:
        """THE header write: every path (row updates, slot allocation,
        free_slot, attrs) funnels through here so padding/length framing
        can never diverge — and every install is a new MVCC version."""
        self._mvcc_seq += 1
        pad = b" " * (self.data_offset - 16 - len(raw))
        os.pwrite(self._fd, BLOB_MAGIC + len(raw).to_bytes(8, "little") + raw + pad, 0)

    # ------------------------------------------------- snapshot pinning (MVCC)
    def pin(self) -> "BlobSnapshot":
        """Pin the current header and return a read-only ``BlobSnapshot``
        whose every read sees exactly this version of the index, no matter
        what the writer does afterwards (in-place updates copy-on-write
        around pinned slots; a compaction's ``os.replace`` cannot touch
        the snapshot's dup'd fd).  Release with ``BlobSnapshot.close()``.

        Retired-but-pinned slots live only in memory: a crash while pins
        are outstanding leaks them from the persisted free list (harmless
        — ``compact()`` rebuilds the file and reclaims everything)."""
        with self._lock:
            pin_id = self._next_pin
            self._next_pin += 1
            self._pins[pin_id] = self._mvcc_seq
            return BlobSnapshot(self, pin_id)

    def _release_pin(self, pin_id: int) -> None:
        with self._lock:
            self._pins.pop(pin_id, None)
            self._recycle_locked()

    def _recycle_locked(self) -> None:
        """Return retired slots to the (in-memory) free list once no pin
        predates their retirement; the persisted free list catches up on
        the next header write."""
        if not self._retired:
            return
        floor = min(self._pins.values()) if self._pins else None
        still, freed = [], []
        for seq, slot in self._retired:
            # a pin at seq P sees the header as of P; the slot became
            # unreferenced at seq > P only for pins with P < seq
            if floor is None or seq <= floor:
                freed.append(slot)
            else:
                still.append((seq, slot))
        if freed:
            self._retired = still
            self._free = sorted(set(self._free) | set(freed))

    def _serialize_header_locked(self) -> bytes:
        self._header["levels"] = self._n_rows
        if self.format >= 2:
            self._header["format"] = "ecp-blob/3" if self.quant_format else "ecp-blob/2"
            self._header["slots"] = self._slots
            self._header["free_slots"] = self._free
            self._header["n_slots"] = self._n_slots
        return self._check_fits(json.dumps(self._header, sort_keys=True).encode("utf-8"))

    def _rewrite_header_locked(self) -> None:
        self._pwrite_header_locked(self._serialize_header_locked())

    def close(self) -> None:
        if getattr(self, "_fd", -1) >= 0:
            os.close(self._fd)
            self._fd = -1

    def __del__(self):  # best-effort; close() is the real API
        try:
            self.close()
        except OSError:
            pass


# ------------------------------------------------------------- blob snapshot
class BlobSnapshot:
    """A pinned, read-only view of one ``BlobStore`` version (the
    ``SnapshotView`` of the serving subsystem).

    Created by ``BlobStore.pin()`` under the store lock: it copies the
    row-count/slot maps and info of the pinned header and dups the file
    descriptor, so

      * reads are lock-free and bit-identical to what the live store
        would have returned at pin time — writers copy-on-write around
        pinned slots, so the bytes under this view never change;
      * it survives a blob compaction's ``os.replace`` (the dup'd fd
        keeps the replaced file alive until the snapshot closes);
      * N snapshot readers share one physical file with a single writer.

    It speaks the read side of the ``Store`` protocol (``get_node``,
    ``get_nodes``, ``node_rows``, ``read_attrs``, ``io``); every write
    raises ``PermissionError``.  ``close()`` releases the pin (idempotent)
    so the parent can recycle retired slots.
    """

    backend = "blob+snapshot"

    def __init__(self, parent: BlobStore, pin_id: int):
        # runs under the parent's (re-entrant) lock, inside pin()
        self._parent = parent
        self._pin_id = pin_id
        self._fd = os.dup(parent._fd)
        self.path = parent.path
        self.io = IOStats()
        self.pinned_seq = parent._mvcc_seq
        self._n_rows = [list(lv) for lv in parent._n_rows]
        self._slots = [list(lv) for lv in parent._slots]
        self._info = dict(parent._header.get("info", {}))
        self.generation = int(self._info.get(layout.GENERATION, 0))

    # ------------------------------------------------------------ read side
    def _check_key(self, level: int, node: int) -> None:
        if not (0 <= level < len(self._n_rows)):
            raise KeyError(f"no such level in blob snapshot: {level}")
        if not (0 <= node < len(self._n_rows[level])):
            raise KeyError(f"no such node in blob snapshot: lvl {level} node {node}")

    def get_node(self, level: int, node: int) -> tuple[np.ndarray, np.ndarray]:
        self._check_key(level, node)
        n_rows = self._n_rows[level][node]
        if n_rows == 0:
            return self._parent._empty()
        return self._parent._read_one(self._fd, self._slots[level][node], n_rows, self.io)

    def get_nodes(self, keys: list) -> list:
        out: list = [None] * len(keys)
        entries = []
        for i, (lv, nd) in enumerate(keys):
            self._check_key(lv, nd)
            if self._n_rows[lv][nd] == 0:
                out[i] = self._parent._empty()
            else:
                entries.append((self._slots[lv][nd], self._n_rows[lv][nd], i))
        self._parent._read_batch(self._fd, entries, out, self.io)
        return out

    def node_rows(self, keys: list) -> list[int]:
        return [self._n_rows[lv][nd] for lv, nd in keys]

    @property
    def quant_format(self):
        return self._parent.quant_format

    def get_quantized(self, level: int, node: int, qformat: str = "int8") -> QuantNode:
        self._check_key(level, node)
        p = self._parent
        n_rows = self._n_rows[level][node]
        if p.quant_format is None:
            if n_rows == 0:
                return p._empty_quant(qformat)
            emb, _ = self.get_node(level, node)
            return encode_node(emb, qformat)
        if n_rows == 0:
            return p._empty_quant(p.quant_format)
        return p._read_quant_one(self._fd, self._slots[level][node], n_rows, self.io)

    def get_nodes_quantized(self, keys: list, qformat: str = "int8") -> list:
        return [self.get_quantized(lv, nd, qformat) for lv, nd in keys]

    def get_node_ids(self, level: int, node: int) -> np.ndarray:
        self._check_key(level, node)
        p = self._parent
        n_rows = self._n_rows[level][node]
        if n_rows == 0:
            return np.zeros((0,), p.ids_dtype)
        return p._read_ids_one(self._fd, self._slots[level][node], n_rows, self.io)

    def get_node_rows(self, level: int, node: int, rows) -> tuple[np.ndarray, np.ndarray]:
        self._check_key(level, node)
        p = self._parent
        rows = np.asarray(rows, np.int64)
        n_rows = self._n_rows[level][node]
        if len(rows) == 0:
            return p._empty()
        if rows[0] < 0 or rows[-1] >= n_rows:
            raise IndexError(f"rows out of range for lvl {level} node {node}")
        return p._read_rows_one(self._fd, self._slots[level][node], n_rows, rows, self.io)

    def read_attrs(self, path: str) -> dict:
        if path == layout.INFO:
            return dict(self._info)
        return {}

    # ----------------------------------------------------------- write side
    def _read_only(self, *_a, **_k):
        raise PermissionError(
            "blob snapshot is a pinned read-only view; mutate the live store"
        )

    write_attrs = write_node = append_rows = delete_rows = free_slot = _read_only

    # ------------------------------------------------------------ lifecycle
    @property
    def closed(self) -> bool:
        return self._fd < 0

    def close(self) -> None:
        if self._fd >= 0:
            os.close(self._fd)
            self._fd = -1
            self._parent._release_pin(self._pin_id)

    def __enter__(self) -> "BlobSnapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # best-effort; close() is the real API
        try:
            self.close()
        except OSError:
            pass


def convert(
    src: "Store | str | os.PathLike",
    dst: str | os.PathLike,
    *,
    page_size: int = 4096,
    format: int = 2,
    quant: str | None = None,
) -> Path:
    """Serialize any ``Store``'s index into a page-aligned blob file.

    Returns the path of the written blob.  Embeddings are stored in the
    index's own storage dtype (``info['dtype']``, e.g. float16) so reads
    are bit-identical with the source backend's ``get_node``.

    ``format=2`` (default) writes the mutable header (slot map + free
    list) and sizes blocks so a full ``cluster_cap`` leaf fits — the form
    ``ECPIndex.insert``/``delete``/``compact`` require.  ``format=1``
    writes the legacy fixed-layout header.

    ``quant="int8"|"float16"`` additionally writes a quantized companion
    block per slot (blob format v3, mutable): the compressed-scan input
    of the device-resident scoring pipeline.  Converting an existing v2
    blob with ``quant=`` set is the v2->v3 upgrade path.
    """
    if format not in (1, 2):
        raise ValueError(f"unknown blob format: {format!r} (1|2)")
    if quant is not None:
        if quant not in QFORMATS:
            raise ValueError(f"unknown quant format: {quant!r} {QFORMATS}")
        if format == 1:
            raise ValueError("quantized companions need the mutable format (format=2)")
    store = src if isinstance(src, Store) else open_store(src)
    info = store.read_attrs(layout.INFO)
    if not info:
        raise ValueError("source store has no index info; not an eCP index?")
    dim = int(info["dim"])
    emb_dt = np.dtype(info.get("dtype", "float16"))
    ids_dt = np.dtype(np.int64)
    levels = int(info["levels"])
    nodes_per_level = [int(x) for x in info["nodes_per_level"]]

    keys = [(0, 0)] + [
        (lv, nd) for lv in range(1, levels + 1) for nd in range(nodes_per_level[lv - 1])
    ]
    n_rows: list[list[int]] = [[] for _ in range(levels + 1)]
    row_bytes = dim * emb_dt.itemsize + ids_dt.itemsize
    max_block = page_size
    if format >= 2:
        # a mutable blob must fit any legal leaf: inserts grow a leaf up to
        # cluster_cap rows before the lifecycle splits it
        max_block = max(max_block, int(info.get("cluster_cap", 0)) * row_bytes)

    dst = Path(dst)
    if dst.is_dir():
        dst = dst / BLOB_FILENAME
    dst.parent.mkdir(parents=True, exist_ok=True)

    # pass 1: row counts to size the fixed blocks — metadata only where the
    # backend supports it (node_rows), never the embedding bytes themselves
    batch = 512
    rows_fn = getattr(store, "node_rows", None)
    if rows_fn is not None:
        counts = rows_fn(keys)
    else:
        counts = []
        for lo in range(0, len(keys), batch):
            counts.extend(len(ids) for _, ids in store.get_nodes(keys[lo : lo + batch]))
    for (lv, nd), n in zip(keys, counts):
        n_rows[lv].append(int(n))
        max_block = max(max_block, int(n) * row_bytes)
    block_bytes = _align(max_block, page_size)
    q_block_bytes = 0
    if quant is not None:
        # the companion must hold any node the fp block can: size it for
        # capacity_rows so in-place updates never outgrow it
        q_row = dim * qdtype(quant).itemsize
        q_block_bytes = _align(8 + (block_bytes // row_bytes) * q_row, page_size)

    header = {
        "format": "ecp-blob/3" if quant else f"ecp-blob/{format}",
        "page_size": page_size,
        "block_bytes": block_bytes,
        "dim": dim,
        "emb_dtype": dtype_to_zarr(emb_dt),
        "ids_dtype": dtype_to_zarr(ids_dt),
        "info": dict(info),
        "levels": n_rows,
    }
    if quant is not None:
        header["quant"] = {"qformat": quant, "q_block_bytes": q_block_bytes}
    if format >= 2:
        at = 0
        slots = []
        for lv in n_rows:
            slots.append(list(range(at, at + len(lv))))
            at += len(lv)
        header["slots"] = slots
        header["free_slots"] = []
        header["n_slots"] = at
    # reserve spare pages so in-place header rewrites never collide with
    # the data region: one page for row-count churn (v1) plus, for the
    # mutable format, room for the slot map / free list to grow as splits
    # append nodes AND for the tombstone list (info.deleted_ids) — budgeted
    # at every item deleted at once, ~12 JSON bytes per id.  Deleting past
    # that budget raises cleanly (compact() shrinks the list to zero).
    raw = json.dumps(header, sort_keys=True).encode("utf-8")
    slack = page_size
    if format >= 2:
        slack += _align(len(keys) * 16 + page_size, page_size)
        slack += _align(int(info.get("n_items", 0)) * 12 + page_size, page_size)
    data_offset = _align(16 + len(raw), page_size) + slack
    header["data_offset"] = data_offset
    raw = json.dumps(header, sort_keys=True).encode("utf-8")

    tmp = dst.with_suffix(dst.suffix + ".tmp")
    with open(tmp, "wb") as f:
        f.write(BLOB_MAGIC)
        f.write(len(raw).to_bytes(8, "little"))
        f.write(raw)
        f.write(b" " * (data_offset - 16 - len(raw)))
        for lo in range(0, len(keys), batch):
            for emb, ids in store.get_nodes(keys[lo : lo + batch]):
                emb = np.ascontiguousarray(emb, dtype=emb_dt)
                b = emb.tobytes() + np.ascontiguousarray(ids, dtype=ids_dt).tobytes()
                f.write(b)
                f.write(b"\0" * (block_bytes - len(b)))
                if quant is not None:
                    # encode from the storage-dtype-rounded rows: a reader
                    # quantizing get_node's output lands on the same codes
                    qn = encode_node(np.asarray(emb, np.float32), quant)
                    qb = (
                        np.float32(qn.scale).tobytes()
                        + np.float32(qn.offset).tobytes()
                        + qn.codes.tobytes()
                    )
                    f.write(qb)
                    f.write(b"\0" * (q_block_bytes - len(qb)))
    os.replace(tmp, dst)
    return dst


# --------------------------------------------------------- norm-aware payloads
class NodeNormCache:
    """Bounded LRU of per-node squared-norm vectors, keyed ``(level, node)``.

    l2 scoring decomposes as ``|q|^2 + |c|^2 - 2 q.c``; the ``|c|^2`` term
    depends only on the node's stored embeddings, yet the traversal used
    to recompute ``(c * c).sum(-1)`` on every visit of every query.  The
    search engine attaches this cache next to its ``NodeCache`` so a
    node's norms are computed once per residency and shared across
    queries (``np_distances(..., c_sqnorms=...)`` — bit-identical by
    construction since the cached value IS that exact expression).

    Entries are one float32 per node row (~1/(dim) of the node payload);
    ``max_entries`` bounds residency with LRU eviction.  Each entry holds
    a weakref to the exact embedding array it was computed from and is
    only served for that same array — so the norms are never fresher or
    staler than the node payload the caller is scoring (an in-place
    ``Store.write_node`` rewrite produces a new array and transparently
    recomputes, without pinning evicted payloads alive).
    """

    def __init__(self, max_entries: int = 16384):
        self.max_entries = max(1, int(max_entries))
        # key -> (weakref-to-emb, sqnorms)
        self._d: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._lock = threading.Lock()

    def get(self, level: int, node: int, emb: np.ndarray) -> np.ndarray:
        key = (level, node)
        with self._lock:
            v = self._d.get(key)
            if v is not None and v[0]() is emb:
                self._d.move_to_end(key)
                return v[1]
        sq = (emb * emb).sum(-1)
        with self._lock:
            self._d[key] = (weakref.ref(emb), sq)
            self._d.move_to_end(key)
            while len(self._d) > self.max_entries:
                self._d.popitem(last=False)
        return sq

    def __len__(self) -> int:
        return len(self._d)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()


# ------------------------------------------------------------ async prefetch
class AsyncPrefetchStore:
    """Wrap any ``Store`` with a thread pool for asynchronous node reads.

    ``prefetch(keys)`` schedules background ``get_node`` calls; a later
    ``get_node``/``get_nodes`` for the same key joins the in-flight future
    instead of touching the disk again.  The traversal uses this to load
    the frontier's children while distance math runs.

    Speculation is throttled by its own measured accuracy: once the
    ``IOStats`` hit rate (``prefetch_hits / prefetch_issued``, which
    includes the cache-level was-it-ever-used attribution) drops below
    ``hit_rate_threshold`` after a ``warmup`` of issues, new batches are
    suppressed — except an occasional probe (1 in ``probe_every``) so the
    rate can recover when the access pattern changes.  Independently,
    in-flight speculative bytes are capped at ``max_inflight_bytes`` so a
    burst of never-consumed reads cannot queue unbounded wasted I/O.
    """

    def __init__(
        self,
        inner,
        *,
        workers: int = 4,
        max_inflight: int = 128,
        hit_rate_threshold: float = 0.75,
        warmup: int = 16,
        probe_every: int = 32,
        max_inflight_bytes: int = 4 << 20,
    ):
        self.inner = inner
        self.backend = f"{inner.backend}+prefetch"
        self._ex = ThreadPoolExecutor(max_workers=workers, thread_name_prefix="store-prefetch")
        self._futures: dict = {}
        self._lock = threading.Lock()
        self.prefetch_issued = 0
        self.prefetch_hits = 0
        self._max_inflight = max_inflight
        self.hit_rate_threshold = float(hit_rate_threshold)
        self.warmup = int(warmup)
        self.probe_every = int(probe_every)
        self.max_inflight_bytes = int(max_inflight_bytes)
        self.prefetch_suppressed = 0  # keys skipped by the accuracy gate
        self._suppressed_batches = 0
        self._inflight_bytes = 0
        self._charged: dict = {}  # key -> bytes charged against the in-flight cap
        # per-node size estimate for the byte cap; refined from completed reads
        self._node_bytes_est = int(getattr(inner, "block_bytes", 0) or 16384)

    @property
    def io(self) -> IOStats:
        return self.inner.io

    def __getattr__(self, name):
        if name == "inner":  # pre-__init__ lookups must not recurse
            raise AttributeError(name)
        return getattr(self.inner, name)

    @property
    def hit_rate(self) -> float:
        """Measured prefetch accuracy so far (1.0 before anything issued)."""
        io = self.inner.io
        return io.prefetch_hits / io.prefetch_issued if io.prefetch_issued else 1.0

    def _gate(self) -> str:
        """Accuracy gate, lock held: ``open`` | ``probe`` | ``closed``.

        ``probe`` (1 in ``probe_every`` suppressed batches) admits only the
        nearest key, keeping a trickle of measurements alive so the rate
        can recover when the access pattern changes."""
        io = self.inner.io
        if io.prefetch_issued < self.warmup:
            return "open"
        if io.prefetch_hits >= self.hit_rate_threshold * io.prefetch_issued:
            return "open"
        self._suppressed_batches += 1
        if self._suppressed_batches >= self.probe_every:
            self._suppressed_batches = 0
            return "probe"
        return "closed"

    def _drop(self, key) -> None:
        """Forget a future's in-flight byte charge, lock held."""
        self._inflight_bytes -= self._charged.pop(key, 0)

    def prefetch(self, keys: list, on_node=None) -> None:
        """Schedule background reads for ``keys``.

        ``on_node(key, (emb, ids))`` — optional sink called from the worker
        thread when a read completes; the future is dropped immediately so
        prefetched data lives in the caller's (byte-budgeted) cache, not
        pinned here.  Without a sink, results wait in the in-flight table
        (bounded by ``max_inflight``) until a demand read consumes them.
        """
        submitted = []
        with self._lock:
            if self._ex is None:
                return
            gate = self._gate()
            if gate == "closed":
                self.prefetch_suppressed += len(keys)
                return
            if gate == "probe":
                self.prefetch_suppressed += len(keys) - 1
                keys = keys[:1]
            for n_taken, key in enumerate(keys):
                if key in self._futures:
                    continue
                if self._inflight_bytes + self._node_bytes_est > self.max_inflight_bytes:
                    self.prefetch_suppressed += len(keys) - n_taken
                    break
                if len(self._futures) >= self._max_inflight:
                    # drop consumed-done entries first; if still full, skip
                    done = [k for k, f in self._futures.items() if f.done()]
                    for k in done[: len(self._futures) - self._max_inflight + 1]:
                        fut = self._futures.pop(k)
                        self._drop(k)
                        if not fut.cancelled() and fut.exception() is None:
                            emb, ids = fut.result()  # read, never consumed
                            self.inner.io.count_prefetch(
                                wasted_bytes=emb.nbytes + ids.nbytes
                            )
                    if len(self._futures) >= self._max_inflight:
                        break
                f = self._ex.submit(self.inner.get_node, *key)
                self._futures[key] = f
                self._charged[key] = self._node_bytes_est
                self._inflight_bytes += self._node_bytes_est
                self.prefetch_issued += 1
                self.inner.io.count_prefetch(issued=1)
                submitted.append((key, f))
        if on_node is None:
            return
        for key, f in submitted:
            # registered OUTSIDE the lock: a completed future runs the
            # callback inline, and the callback takes the lock itself
            def _done(fut, key=key):
                # whoever pops the key owns delivery: if a demand read (or
                # eviction/close) already popped it, the payload was consumed
                # (and counted) there — delivering to the sink as well would
                # double-count the hit and later flush it as wasted
                with self._lock:
                    owned = self._futures.pop(key, None) is not None
                    self._drop(key)
                if owned and not fut.cancelled() and fut.exception() is None:
                    emb, ids = fut.result()
                    # refine the per-node size estimate from real payloads
                    self._node_bytes_est = max(1, (emb.nbytes + ids.nbytes))
                    on_node(key, (emb, ids))

            f.add_done_callback(_done)

    def drain(self) -> None:
        """Block until every in-flight prefetch has completed (and counted
        its I/O).  Benchmarks call this before snapshotting ``io`` so async
        reads issued during a pass are attributed to that pass."""
        with self._lock:
            pending = list(self._futures.values())
        for f in pending:
            try:
                f.result()
            except Exception:
                pass  # a failed prefetch surfaces on the demand-read path

    def _pop(self, key):
        with self._lock:
            f = self._futures.pop(key, None)
            if f is not None:
                self._drop(key)
            return f

    def get_node(self, level: int, node: int) -> tuple[np.ndarray, np.ndarray]:
        # racy-but-safe emptiness check: when the throttle has the gate
        # closed there is usually nothing in flight, and demand reads
        # should not pay the lock on every node
        if self._futures:
            f = self._pop((level, node))
            if f is not None:
                self.prefetch_hits += 1
                self.inner.io.count_prefetch(hits=1)
                return f.result()
        return self.inner.get_node(level, node)

    def get_nodes(self, keys: list) -> list:
        if not self._futures:  # same fast path as get_node
            return self.inner.get_nodes(keys)
        out: list = [None] * len(keys)
        missing, missing_i = [], []
        for i, key in enumerate(keys):
            f = self._pop(tuple(key))
            if f is not None:
                self.prefetch_hits += 1
                self.inner.io.count_prefetch(hits=1)
                out[i] = f.result()
            else:
                missing.append(key)
                missing_i.append(i)
        if missing:
            for i, v in zip(missing_i, self.inner.get_nodes(missing)):
                out[i] = v
        return out

    def read_attrs(self, path: str) -> dict:
        return self.inner.read_attrs(path)

    def write_attrs(self, path: str, attrs: dict) -> None:
        self.inner.write_attrs(path, attrs)

    def _invalidate(self, level: int, node: int) -> None:
        """Drop an in-flight prefetch of a node that is being rewritten —
        otherwise its stale payload could satisfy a later demand read."""
        f = self._pop((level, node))
        if f is not None:
            if not f.cancel() and f.done() and f.exception() is None:
                emb, ids = f.result()  # completed but now stale: read for nothing
                self.inner.io.count_prefetch(wasted_bytes=emb.nbytes + ids.nbytes)

    def write_node(self, level: int, node: int, emb, ids, **kw) -> None:
        self._invalidate(level, node)
        self.inner.write_node(level, node, emb, ids, **kw)

    def append_rows(self, level: int, node: int, emb, ids, **kw) -> None:
        self._invalidate(level, node)
        self.inner.append_rows(level, node, emb, ids, **kw)

    def delete_rows(self, level: int, node: int, drop_ids) -> int:
        self._invalidate(level, node)
        return self.inner.delete_rows(level, node, drop_ids)

    def free_slot(self, level: int, node: int) -> None:
        self._invalidate(level, node)
        self.inner.free_slot(level, node)

    def close(self) -> None:
        with self._lock:
            ex, self._ex = self._ex, None
            self._futures.clear()
            self._charged.clear()
            self._inflight_bytes = 0
        if ex is not None:
            ex.shutdown(wait=False)
        self.inner.close()


# ------------------------------------------------------------------- factory
def open_store(
    path: "str | os.PathLike | Store",
    backend: str = "auto",
    *,
    create: bool = False,
    prefetch: bool = False,
    prefetch_workers: int = 4,
) -> Store:
    """Open an index's node storage.

    backend="fstore"  -> the zarr-v2 directory hierarchy (paper's mode).
    backend="blob"    -> the page-aligned single-file form (``convert()``).
    backend="auto"    -> blob when ``path`` is a blob file or a directory
                         holding ``index.blob``; otherwise fstore.
    prefetch=True     -> wrap the backend in ``AsyncPrefetchStore``; the
                         spelling ``backend="<name>+prefetch"`` is
                         equivalent.
    """
    if backend.endswith("+prefetch"):
        backend = backend[: -len("+prefetch")]
        prefetch = True
    if isinstance(path, Store):
        store = path
    elif isinstance(path, FStore):
        store = FStoreBackend(path)
    else:
        p = Path(path)
        if backend == "auto":
            if p.is_file() or (p / BLOB_FILENAME).is_file():
                backend = "blob"
            else:
                if p.is_dir() and not create and not (p / ".zgroup").exists():
                    # a directory that is not itself an index but HOLDS
                    # index-looking children is almost certainly a shard
                    # collection missing its federation manifest — say so
                    # instead of failing deep inside the fstore parser
                    shards = sorted(
                        c.name
                        for c in p.iterdir()
                        if (c.is_file() and c.suffix == ".blob")
                        or (c.is_dir() and ((c / BLOB_FILENAME).is_file() or (c / ".zgroup").exists()))
                    )
                    if shards:
                        raise ValueError(
                            f"{p} is not an index: it contains what look like "
                            f"per-shard index files ({', '.join(shards[:4])}"
                            f"{', ...' if len(shards) > 4 else ''}) but no "
                            "federation manifest.  To open them as one "
                            "federated index, write a 'federation.json' "
                            "manifest (repro.core.federation.FederationManifest) "
                            "or open a single shard path directly."
                        )
                backend = "fstore"
        if backend == "fstore":
            store = FStoreBackend(p, create=create)
        elif backend == "blob":
            if create:
                raise ValueError("blob stores are created with convert(), not create=True")
            store = BlobStore(p)
        else:
            raise ValueError(f"unknown store backend: {backend!r} (fstore|blob|auto)")
    if prefetch and not isinstance(store, AsyncPrefetchStore):
        store = AsyncPrefetchStore(store, workers=prefetch_workers)
    return store
