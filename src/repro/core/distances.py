"""Distance computations shared by build, search, and the kernels' reference.

All metrics are *distances*: smaller is better.
  l2      : squared euclidean  ||q - c||^2
  ip      : negative inner product  -<q, c>
  cosine  : 1 - cos(q, c)
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

METRICS = ("l2", "ip", "cosine")


def _check(metric: str) -> None:
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r}; one of {METRICS}")


# ------------------------------------------------------------------ numpy
def np_distances(
    q: np.ndarray, c: np.ndarray, metric: str, *, c_sqnorms: np.ndarray | None = None
) -> np.ndarray:
    """q: [B, D] or [D]; c: [N, D] -> [B, N] or [N] float32 distances.

    ``c_sqnorms`` optionally supplies precomputed ``(c * c).sum(-1)`` for
    the l2 and cosine metrics (per-node norm caching in the search
    engine).  It MUST equal that exact expression over the float32 ``c``
    — then results are bit-identical to the uncached path (for cosine,
    ``np.sqrt`` of the reduction is bitwise what ``np.linalg.norm``
    computes).  Ignored for ip.
    """
    _check(metric)
    q = np.asarray(q, np.float32)
    c = np.asarray(c, np.float32)
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None]
    if metric == "ip":
        d = -(q @ c.T)
    elif metric == "l2":
        qn = (q * q).sum(-1, keepdims=True)
        cn = ((c * c).sum(-1) if c_sqnorms is None else np.asarray(c_sqnorms, np.float32))[None, :]
        d = qn + cn - 2.0 * (q @ c.T)
    else:  # cosine
        qn = q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        if c_sqnorms is None:
            c_norm = np.linalg.norm(c, axis=-1)
        else:
            c_norm = np.sqrt(np.asarray(c_sqnorms, np.float32))
        cn = c / np.maximum(c_norm[:, None], 1e-12)
        d = 1.0 - qn @ cn.T
    return d[0] if squeeze else d


# ------------------------------------------------------------------ jax
def jnp_distances(q, c, metric: str):
    """q: [..., B, D]; c: [..., N, D] -> [..., B, N] distances (f32 accum)."""
    _check(metric)
    q = q.astype(jnp.float32)
    c = c.astype(jnp.float32)
    if metric == "ip":
        return -jnp.einsum("...bd,...nd->...bn", q, c)
    if metric == "l2":
        qn = jnp.sum(q * q, axis=-1)[..., :, None]
        cn = jnp.sum(c * c, axis=-1)[..., None, :]
        return qn + cn - 2.0 * jnp.einsum("...bd,...nd->...bn", q, c)
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    cn = c / jnp.maximum(jnp.linalg.norm(c, axis=-1, keepdims=True), 1e-12)
    return 1.0 - jnp.einsum("...bd,...nd->...bn", qn, cn)
