"""TPU-native batched eCP search (level-synchronous beam + resumable state).

The paper's single-query priority queue is inherently sequential; the TPU
adaptation (DESIGN.md §3) restores eCP's per-level synchronization so a
whole query batch advances level-by-level with dense, MXU-friendly distance
blocks and ``lax.top_k`` selections:

  1. score the root centroids, take the best ``b`` lvl_1 nodes;
  2. per internal level: gather children centroid blocks, score, re-top-b;
  3. at the last internal level, *rank* every candidate leaf (not just the
     top-b) — this ranking is the device analogue of the priority queue and
     is what makes the search resumable;
  4. scan ``b`` leaves at a time, merging scanned items into a bounded,
     sorted candidate buffer per query.

``BatchedQueryState`` is a pytree: (leaf ranking, visit pointer, candidate
buffer).  It is owned by a ``BatchedQuery`` handle: ``search`` returns a
``ResultSet`` whose ``.query.next(k)`` emits the best ``k`` unseen items
and advances the state — the batched equivalent of Algorithm 2 behind the
same unified API as the file-mode searcher.  Exhausting the ranked leaf
list mirrors the paper's T-queue running empty.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .api import Query, ResultSet, SearchStats
from .distances import jnp_distances
from .packed import PackedIndex

__all__ = ["BatchedQuery", "BatchedQueryState", "BatchedSearcher"]

_INF = jnp.float32(jnp.inf)


@jax.tree_util.register_pytree_node_class
@dataclass
class BatchedQueryState:
    leaf_rank: jnp.ndarray    # [B, R] int32 leaf ids in visit order (-1 pad)
    leaf_rank_d: jnp.ndarray  # [B, R] centroid distance of each ranked leaf
    next_ptr: jnp.ndarray     # [B] int32 next rank position to visit
    buf_d: jnp.ndarray        # [B, C] sorted candidate distances (+inf pad)
    buf_i: jnp.ndarray        # [B, C] candidate item ids (-1 pad)

    def tree_flatten(self):
        return (self.leaf_rank, self.leaf_rank_d, self.next_ptr, self.buf_d, self.buf_i), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _ascending_top_k(d, ids, k):
    """Smallest-k by distance; returns (d_k, ids_k) ascending."""
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, jnp.take_along_axis(ids, idx, axis=-1)


class BatchedQuery(Query):
    """Handle over the device-resident state of one batched search call."""

    def __init__(self, searcher: "BatchedSearcher", q: jnp.ndarray, state: BatchedQueryState, *, b: int, single: bool):
        self._searcher = searcher
        self._q = q
        self._state = state
        self._b = b
        self._single = single

    @property
    def state(self) -> BatchedQueryState:
        self._ensure_open()
        return self._state

    def next(self, k: int) -> ResultSet:
        self._ensure_open()
        d, i, self._state = self._searcher._advance(self._q, self._state, k, self._b)
        return self._searcher._result(d, i, self._state, self._single, self)

    def close(self) -> None:
        self._q = None
        self._state = None
        super().close()


class BatchedSearcher:
    """Device-resident packed index + jitted search stages (the ``Searcher``
    for packed mode)."""

    def __init__(self, packed: PackedIndex, *, scorer=None):
        self.info = packed.info
        self.metric = packed.info.metric
        self.root = jnp.asarray(packed.root_emb)
        self.int_emb = [jnp.asarray(p.emb) for p in packed.levels[:-1]]
        self.int_ids = [jnp.asarray(p.ids) for p in packed.levels[:-1]]
        self.int_mask = [jnp.asarray(p.mask) for p in packed.levels[:-1]]
        leaf = packed.leaf
        self.leaf_emb = jnp.asarray(leaf.emb)
        self.leaf_ids = jnp.asarray(leaf.ids)
        self.leaf_mask = jnp.asarray(leaf.mask)
        # scorer(q[B,D], c[B,N,D]) -> [B,N] distances; pluggable so the
        # Pallas distance kernel can be swapped in (kernels/distance_topk).
        self._scorer = scorer

    # ---------------------------------------------------------------- util
    def _score(self, q, c):
        if self._scorer is not None:
            return self._scorer(q, c)
        return jnp_distances(q[:, None, :], c, self.metric)[:, 0, :] if c.ndim == 3 else jnp_distances(q, c, self.metric)

    # ------------------------------------------------------------- stage 1
    @partial(jax.jit, static_argnames=("self", "b_internal"))
    def rank_leaves(self, q: jnp.ndarray, b_internal: int):
        """[B, D] queries -> ranked candidate leaves [B, R] (+ distances)."""
        B = q.shape[0]
        d = jnp_distances(q, self.root, self.metric)           # [B, n1]
        n1 = d.shape[-1]
        if not self.int_emb:  # L == 1: root children are the leaves
            order = jnp.argsort(d, axis=-1)
            return order.astype(jnp.int32), jnp.take_along_axis(d, order, axis=-1)
        b = min(b_internal, n1)
        node_d, node = _ascending_top_k(d, jnp.broadcast_to(jnp.arange(n1, dtype=jnp.int32), d.shape), b)
        for li, (emb, ids, mask) in enumerate(zip(self.int_emb, self.int_ids, self.int_mask)):
            ce = emb[node]                                      # [B, b, maxc, D]
            cd = jnp_distances(q[:, None, None, :], ce, self.metric)[:, :, 0, :]  # [B, b, maxc]
            cm = mask[node]
            cd = jnp.where(cm, cd, _INF)
            cid = jnp.where(cm, ids[node], -1)
            flat_d = cd.reshape(B, -1)
            flat_i = cid.reshape(B, -1)
            is_last = li == len(self.int_emb) - 1
            if is_last:
                order = jnp.argsort(flat_d, axis=-1)            # rank ALL leaves seen
                return (
                    jnp.take_along_axis(flat_i, order, axis=-1).astype(jnp.int32),
                    jnp.take_along_axis(flat_d, order, axis=-1),
                )
            bb = min(b_internal, flat_d.shape[-1])
            node_d, node = _ascending_top_k(flat_d, flat_i, bb)
            node = jnp.maximum(node, 0)                        # guard -1 pads
        raise AssertionError("unreachable")

    # ------------------------------------------------------------- stage 2
    @partial(jax.jit, static_argnames=("self", "b"))
    def _scan_chunk(self, q, state: BatchedQueryState, b: int):
        """Visit the next ``b`` ranked leaves; merge items into the buffer."""
        B = q.shape[0]
        R = state.leaf_rank.shape[1]
        pos = state.next_ptr[:, None] + jnp.arange(b)[None, :]          # [B, b]
        valid = pos < R
        pos_c = jnp.minimum(pos, R - 1)
        leaf = jnp.take_along_axis(state.leaf_rank, pos_c, axis=-1)     # [B, b]
        lvalid = valid & (leaf >= 0)
        leaf_c = jnp.maximum(leaf, 0)
        emb = self.leaf_emb[leaf_c]                                     # [B, b, cap, D]
        ids = self.leaf_ids[leaf_c]                                     # [B, b, cap]
        mask = self.leaf_mask[leaf_c] & lvalid[..., None]
        cap = emb.shape[2]
        d = self._score(q, emb.reshape(B, b * cap, -1))                  # [B, b*cap]
        d = jnp.where(mask.reshape(B, -1), d, _INF)
        i = jnp.where(mask.reshape(B, -1), ids.reshape(B, -1), -1)
        # merge with buffer, re-sort, keep best C
        C = state.buf_d.shape[1]
        all_d = jnp.concatenate([state.buf_d, d], axis=-1)
        all_i = jnp.concatenate([state.buf_i, i], axis=-1)
        buf_d, buf_i = _ascending_top_k(all_d, all_i, C)
        return BatchedQueryState(
            leaf_rank=state.leaf_rank,
            leaf_rank_d=state.leaf_rank_d,
            next_ptr=state.next_ptr + b,
            buf_d=buf_d,
            buf_i=buf_i,
        )

    @partial(jax.jit, static_argnames=("self", "k"))
    def _emit(self, state: BatchedQueryState, k: int):
        out_d = state.buf_d[:, :k]
        out_i = state.buf_i[:, :k]
        C = state.buf_d.shape[1]
        rem_d = jnp.concatenate([state.buf_d[:, k:], jnp.full((state.buf_d.shape[0], k), _INF)], axis=-1)
        rem_i = jnp.concatenate([state.buf_i[:, k:], jnp.full((state.buf_i.shape[0], k), -1, jnp.int32)], axis=-1)
        new = BatchedQueryState(state.leaf_rank, state.leaf_rank_d, state.next_ptr, rem_d[:, :C], rem_i[:, :C])
        return out_d, out_i, new

    # ---------------------------------------------------------------- API
    def search(
        self,
        q,
        k: int = 100,
        *,
        b: int | None = 8,
        b_internal: int | None = None,
        buffer_cap: int | None = None,
    ) -> ResultSet:
        """New batched search over [D] or [B, D] queries -> ``ResultSet``."""
        b = 8 if b is None else int(b)
        q = jnp.asarray(q, jnp.float32)
        single = q.ndim == 1
        if single:
            q = q[None, :]
        B = q.shape[0]
        bi = b_internal if b_internal is not None else max(b, 8)
        leaf_rank, leaf_rank_d = self.rank_leaves(q, bi)
        C = buffer_cap if buffer_cap is not None else max(4 * k, 256)
        state = BatchedQueryState(
            leaf_rank=leaf_rank,
            leaf_rank_d=leaf_rank_d,
            next_ptr=jnp.zeros((B,), jnp.int32),
            buf_d=jnp.full((B, C), _INF),
            buf_i=jnp.full((B, C), -1, jnp.int32),
        )
        state = self._scan_chunk(q, state, min(b, leaf_rank.shape[1]))
        d, i, state = self._advance(q, state, k, b)
        return self._result(d, i, state, single, BatchedQuery(self, q, state, b=b, single=single))

    def _advance(self, q: jnp.ndarray, state: BatchedQueryState, k: int, b: int):
        """Emit the next k items, scanning further leaves if needed."""
        R = state.leaf_rank.shape[1]
        # scan until every query has k buffered candidates or leaves exhaust
        for _ in range(64):  # hard bound; python loop keeps jit graphs small
            have = jnp.sum(jnp.isfinite(state.buf_d[:, :k]), axis=-1)
            exhausted = state.next_ptr >= R
            if bool(jnp.all((have >= k) | exhausted)):
                break
            state = self._scan_chunk(q, state, min(b, R))
        return self._emit(state, k)

    def _result(self, d, i, state: BatchedQueryState, single: bool, query) -> ResultSet:
        d = np.asarray(d, np.float32)
        i = np.asarray(i, np.int64)
        # leaves actually scanned per query (ranked positions visited)
        ptr = np.asarray(state.next_ptr)
        stats = [SearchStats(leaves_opened=int(p)) for p in ptr]
        if single:
            return ResultSet(dists=d[0], ids=i[0], stats=stats[0], query=query)
        return ResultSet(dists=d, ids=i, stats=stats, query=query)

    def __repr__(self) -> str:  # handy in session listings
        return f"BatchedSearcher(levels={self.info.levels}, metric={self.metric!r})"
