"""Packed (dense, device-shardable) view of the index hierarchy.

The file structure is the source of truth; for TPU-batched traversal we pack
each level's children lists into rectangular arrays:

  emb  [n_nodes, max_children, D]  float32 (padded with +inf-distance rows)
  ids  [n_nodes, max_children]     int32   (padded with -1)
  mask [n_nodes, max_children]     bool

Internal-level ids are child node indices at the next level; leaf-level ids
are item ids. Padding rows are zero vectors with mask False — search code
masks distances to +inf before any top-k.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import layout
from .fstore import FStore
from .store import FStoreBackend, Store, open_store


@dataclass
class PackedLevel:
    emb: np.ndarray   # [n_nodes, max_children, D] float32
    ids: np.ndarray   # [n_nodes, max_children] int32
    mask: np.ndarray  # [n_nodes, max_children] bool

    @property
    def n_nodes(self) -> int:
        return self.emb.shape[0]

    @property
    def max_children(self) -> int:
        return self.emb.shape[1]


def pack_children(
    emb_lists: list[np.ndarray],
    id_lists: list[np.ndarray],
    dim: int,
    *,
    pad_multiple: int = 8,
) -> PackedLevel:
    """Pack per-node ragged children into a PackedLevel."""
    n_nodes = len(emb_lists)
    max_c = max((len(x) for x in id_lists), default=1)
    max_c = max(1, -(-max_c // pad_multiple) * pad_multiple)
    emb = np.zeros((n_nodes, max_c, dim), np.float32)
    ids = np.full((n_nodes, max_c), -1, np.int32)
    mask = np.zeros((n_nodes, max_c), bool)
    for j, (e, i) in enumerate(zip(emb_lists, id_lists)):
        n = len(i)
        if n:
            emb[j, :n] = np.asarray(e, np.float32)
            ids[j, :n] = np.asarray(i, np.int32)
            mask[j, :n] = True
    return PackedLevel(emb, ids, mask)


@dataclass
class PackedIndex:
    """Root centroids + one PackedLevel per lvl_1..lvl_L."""

    info: "layout.IndexInfo"
    root_emb: np.ndarray            # [n_1, D] float32
    levels: list[PackedLevel]       # levels[i] = children of lvl_{i+1} nodes

    @property
    def leaf(self) -> PackedLevel:
        return self.levels[-1]


def load_packed(store, *, max_leaf_pad: int = 8, batch: int = 256) -> PackedIndex:
    """Read a whole index into a PackedIndex (for device search).

    ``store`` is any ``Store`` backend (fstore hierarchy or blob file), a
    raw ``FStore``, or a path — node data comes through the protocol's
    batched ``get_nodes`` so e.g. the blob backend coalesces its reads.
    """
    if isinstance(store, FStore):
        store = FStoreBackend(store)
    elif not isinstance(store, Store):
        store = open_store(store)
    attrs = store.read_attrs(layout.INFO)
    info = layout.IndexInfo.from_attrs(attrs)
    if attrs.get(layout.DELETED_IDS):
        raise ValueError(
            "index holds tombstoned items, which the packed device search "
            "does not filter; run ECPIndex.compact() before load_packed()"
        )
    root_emb, _ = store.get_node(0, 0)
    levels = []
    for lv in range(1, info.levels + 1):
        keys = [(lv, j) for j in range(info.nodes_per_level[lv - 1])]
        emb_lists, id_lists = [], []
        for lo in range(0, len(keys), batch):
            for emb, ids in store.get_nodes(keys[lo : lo + batch]):
                emb_lists.append(emb)
                id_lists.append(ids)
        levels.append(pack_children(emb_lists, id_lists, info.dim, pad_multiple=max_leaf_pad))
    return PackedIndex(info=info, root_emb=root_emb, levels=levels)
