"""Top-down eCP index construction (paper §3), JAX-accelerated.

The build follows eCP faithfully:
  * cluster leaders are sampled uniformly at random from the collection
    (the paper: "crude, but simple and fast");
  * upper-level centroids are nested random prefixes of the leader set;
  * the hierarchy is built *top-down*: level i+1 nodes are assigned to their
    nearest level-i centroid, then every item is inserted by traversing the
    partially-built tree along the most-similar edge (beam=1, as the paper's
    footnote 1 describes);
  * the result is written to the transparent file structure (layout.py).

Distance math runs on-device (jit) in batches; the scatter of items into
clusters and all file writes are host-side.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import layout
from .distances import jnp_distances
from .packed import PackedLevel, pack_children
from .store import FStoreBackend, open_store

__all__ = ["ECPBuildConfig", "build_index"]


@dataclass(frozen=True)
class ECPBuildConfig:
    levels: int = 2                  # L
    metric: str = "l2"
    cluster_cap: int | None = None   # target vectors per cluster (C/V)
    cluster_bytes: int | None = 128 * 1024  # C; used if cluster_cap is None
    storage_dtype: str = "float16"   # on-disk embedding dtype (paper stores f16)
    seed: int = 0
    insert_batch: int = 8192         # items per device batch during insertion
    leaf_chunk_rows: int | None = None  # one chunk per cluster by default


def _resolve_cap(cfg: ECPBuildConfig, dim: int, itemsize: int) -> int:
    if cfg.cluster_cap is not None:
        return max(1, int(cfg.cluster_cap))
    assert cfg.cluster_bytes is not None
    return max(1, int(cfg.cluster_bytes) // (dim * itemsize))


@partial(jax.jit, static_argnames=("metric",))
def _assign_level(child_emb: jnp.ndarray, parent_emb: jnp.ndarray, metric: str):
    """Nearest parent centroid for each child centroid. [n_child] int32."""
    d = jnp_distances(child_emb, parent_emb, metric)
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def _make_insert_fn(root_emb: np.ndarray, internal: list[PackedLevel], metric: str):
    """Batched top-down traversal: items -> leaf node indices (beam=1)."""
    root = jnp.asarray(root_emb)
    embs = [jnp.asarray(p.emb) for p in internal]
    idss = [jnp.asarray(p.ids) for p in internal]
    masks = [jnp.asarray(p.mask) for p in internal]

    @jax.jit
    def insert(q):  # q: [B, D] float32 -> [B] int32 leaf ids
        d = jnp_distances(q, root, metric)                     # [B, n1]
        node = jnp.argmin(d, axis=-1).astype(jnp.int32)        # lvl_1 node
        for emb, ids, mask in zip(embs, idss, masks):
            ce = emb[node]                                     # [B, maxc, D]
            cd = jnp_distances(q[:, None, :], ce, metric)[:, 0, :]  # [B, maxc]
            cd = jnp.where(mask[node], cd, jnp.inf)
            best = jnp.argmin(cd, axis=-1)
            node = ids[node, best]                             # next-level node
        return node

    return insert


def build_index(
    data: np.ndarray,
    path: str,
    cfg: ECPBuildConfig = ECPBuildConfig(),
    *,
    item_ids: np.ndarray | None = None,
) -> FStoreBackend:
    """Build an eCP-FS index over ``data`` [N, D] at directory ``path``.

    The index is always built into the writable file-structure backend
    (the paper's human-readable form); serialize it afterwards with
    ``repro.core.store.convert(path, blob_path)`` for the blob backend.
    """
    data = np.asarray(data)
    n_items, dim = data.shape
    if item_ids is None:
        item_ids = np.arange(n_items, dtype=np.int64)
    store_dt = np.dtype(cfg.storage_dtype)
    cap = _resolve_cap(cfg, dim, store_dt.itemsize)
    n_leaders, fanout, nodes_per_level = layout.derive_shape(n_items, cap, cfg.levels)
    L = cfg.levels

    rng = np.random.default_rng(cfg.seed)
    leader_idx = rng.choice(n_items, size=n_leaders, replace=False)
    leaders = np.asarray(data[leader_idx], np.float32)         # [l, D]

    # --- internal hierarchy: nested prefixes + nearest-parent assignment ---
    # centroids at lvl_i are leaders[:nodes_per_level[i-1]]
    children: list[list[np.ndarray]] = []  # children[i] -> per-node child idx lists at lvl_{i+1}
    for i in range(1, L):                  # parents at lvl_i, children at lvl_{i+1}
        n_parent = nodes_per_level[i - 1]
        n_child = nodes_per_level[i]
        assign = np.asarray(
            _assign_level(jnp.asarray(leaders[:n_child]), jnp.asarray(leaders[:n_parent]), cfg.metric)
        )
        lists: list[list[int]] = [[] for _ in range(n_parent)]
        for child, parent in enumerate(assign):
            lists[int(parent)].append(child)
        children.append([np.asarray(x, np.int32) for x in lists])

    internal_packed: list[PackedLevel] = []
    for i, lists in enumerate(children):
        emb_lists = [leaders[ids] for ids in lists]
        internal_packed.append(pack_children(emb_lists, lists, dim))

    # --- item insertion: batched beam-1 traversal -------------------------
    root_emb = leaders[: nodes_per_level[0]]
    insert = _make_insert_fn(root_emb, internal_packed, cfg.metric)
    leaf_of = np.empty(n_items, np.int32)
    for lo in range(0, n_items, cfg.insert_batch):
        hi = min(lo + cfg.insert_batch, n_items)
        q = jnp.asarray(data[lo:hi], jnp.float32)
        leaf_of[lo:hi] = np.asarray(insert(q))

    # --- write the file structure -----------------------------------------
    store = open_store(path, backend="fstore", create=True)
    info = layout.IndexInfo(
        levels=L,
        metric=cfg.metric,
        dim=dim,
        dtype=str(store_dt),
        n_items=n_items,
        cluster_cap=cap,
        n_leaders=n_leaders,
        fanout=fanout,
        nodes_per_level=nodes_per_level,
        seed=cfg.seed,
    )
    store.create_group(layout.INFO, attrs=info.to_attrs())
    store.write_array(layout.REP_EMB, leaders.astype(store_dt), chunk_rows=4096)
    store.write_array(layout.REP_IDS, leader_idx.astype(np.int64), chunk_rows=65536)
    # the root is node (0, 0) of the Store protocol
    store.write_node(
        0, 0, root_emb.astype(store_dt), np.arange(len(root_emb), dtype=np.int32)
    )

    # internal levels: lvl_1 .. lvl_{L-1}
    for i, lists in enumerate(children):
        lv = i + 1
        store.create_group(layout.lvl_group(lv))
        for j, ids in enumerate(lists):
            store.write_node(lv, j, leaders[ids].astype(store_dt), ids.astype(np.int32))

    # leaf level: lvl_L clusters (item embeddings + item ids)
    store.create_group(layout.lvl_group(L))
    order = np.argsort(leaf_of, kind="stable")
    sorted_leaf = leaf_of[order]
    bounds = np.searchsorted(sorted_leaf, np.arange(n_leaders + 1))
    for j in range(n_leaders):
        members = order[bounds[j] : bounds[j + 1]]
        store.write_node(
            L,
            j,
            np.asarray(data[members], store_dt),
            item_ids[members].astype(np.int64),
            chunk_rows=cfg.leaf_chunk_rows,
        )
    return store
