"""Top-down eCP index construction (paper §3) — stable import site.

The build machinery moved into the staged lifecycle subsystem
(``core/lifecycle.py``), where the one-shot build is one stage among
streaming out-of-core construction, incremental insert/delete, and
compaction.  This module re-exports the construction API so existing
imports (``repro.core.build``) keep working.
"""
from __future__ import annotations

from .lifecycle import ECPBuildConfig, build_index, build_index_streaming

__all__ = ["ECPBuildConfig", "build_index", "build_index_streaming"]
