"""Index lifecycle — the mutable half of eCP-FS.

The paper's claim is that mapping the index to a transparent file structure
makes it easy to read, analyze, *and manipulate*; this module supplies the
manipulation: the one-shot in-memory build becomes one stage of a staged
lifecycle that also covers streaming construction, incremental mutation,
and compaction.

  * ``build_index(data, path, cfg)`` — the one-shot build (paper §3),
    unchanged semantics: leaders sampled uniformly at random, nested-prefix
    upper levels, top-down beam-1 insertion, written to the file structure.
  * ``build_index_streaming(source, path, cfg)`` — the same index built
    out-of-core: the collection arrives as an iterator of ``[B, D]``
    chunks and peak memory stays O(chunk + leaders), never O(collection).
    Three streaming passes: (1) count, (2) gather the sampled leaders,
    (3) assign + append items to leaf blocks through the Store protocol.
    Leader sampling defaults to the one-shot build's exact
    ``rng.choice(N, l)`` draw (possible once pass 1 knows N), so a
    streamed build is **bit-identical** to ``build_index`` over the same
    collection — chunk boundaries don't leak into the result because the
    assignment pass re-batches rows to ``cfg.insert_batch``.  With an
    explicit ``n_leaders``, pass 1 instead runs single-pass reservoir
    sampling (Algorithm R, ``reservoir_sample``) and the gather pass is
    skipped.  A one-shot (non-re-iterable) source is spooled to disk.
  * ``insert_items(index, vectors, ids)`` — route new vectors down the
    tree (beam-1, the build's own insertion rule), append to leaf blocks,
    and split any leaf that outgrows ``cluster_cap`` with a deterministic
    local 2-means step, registering the new centroid with the parent node.
  * ``delete_items(index, ids)`` — tombstones recorded in the index
    metadata; both traversal engines filter them during leaf scoring.
  * ``compact(index)`` — purge tombstones and rebalance split chains by
    deterministically rebuilding the tree from the index's own live
    items (spooled to disk, streamed back through the builder with the
    index's recorded seed/cap/levels).  Because the rebuild IS the build
    pipeline run over the logical collection in canonical (id-sorted)
    order, the compacted index answers queries **bit-identically** to a
    fresh ``build_index`` of the same logical collection — on either
    backend, under either traversal engine.  fstore compaction rewrites
    nodes in place through the Store protocol (freeing stale slots);
    blob compaction rebuilds into a scratch hierarchy and atomically
    replaces the blob file.

The mutation entry points here are free functions over a duck-typed
``ECPIndex`` (they use only its ``store``/``info``/``get_node``/cache
surface); ``ECPIndex.insert/delete/compact`` are thin wrappers.  The
*logical collection* of an index is its set of live ``(id, vector)``
pairs **in the storage dtype** (float16 by default): an inserted vector
is stored rounded, so that rounded value is what rebuilds compare equal.
"""
from __future__ import annotations

import os
import tempfile
from dataclasses import dataclass, replace as dc_replace
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import layout
from .distances import jnp_distances, np_distances
from .packed import PackedLevel, pack_children
from .store import BLOB_FILENAME, FStoreBackend, Store, convert, open_store

__all__ = [
    "ECPBuildConfig",
    "build_index",
    "build_index_streaming",
    "reservoir_sample",
    "insert_items",
    "delete_items",
    "compact",
]


@dataclass(frozen=True)
class ECPBuildConfig:
    levels: int = 2                  # L
    metric: str = "l2"
    cluster_cap: int | None = None   # target vectors per cluster (C/V)
    cluster_bytes: int | None = 128 * 1024  # C; used if cluster_cap is None
    storage_dtype: str = "float16"   # on-disk embedding dtype (paper stores f16)
    seed: int = 0
    insert_batch: int = 8192         # items per device batch during insertion
    leaf_chunk_rows: int | None = None  # one chunk per cluster by default
    spill_s: int = 0                 # max ADDITIONAL leaf replicas per vector:
                                     # border vectors near several leaders are
                                     # written into up to s extra leaves
    spill_eps: float = 0.25          # spill band vs the nearest-leader distance
                                     # d1: a leader at d_j qualifies when
                                     # d_j <= d1 + eps*|d1| (l2/cosine) or
                                     # d_j <= d1 + eps (ip)


def _resolve_cap(cfg: ECPBuildConfig, dim: int, itemsize: int) -> int:
    if cfg.cluster_cap is not None:
        return max(1, int(cfg.cluster_cap))
    assert cfg.cluster_bytes is not None
    return max(1, int(cfg.cluster_bytes) // (dim * itemsize))


@partial(jax.jit, static_argnames=("metric",))
def _assign_level(child_emb: jnp.ndarray, parent_emb: jnp.ndarray, metric: str):
    """Nearest parent centroid for each child centroid. [n_child] int32."""
    d = jnp_distances(child_emb, parent_emb, metric)
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def _make_insert_fn(root_emb: np.ndarray, internal: list[PackedLevel], metric: str):
    """Batched top-down traversal: items -> leaf node indices (beam=1)."""
    root = jnp.asarray(root_emb)
    embs = [jnp.asarray(p.emb) for p in internal]
    idss = [jnp.asarray(p.ids) for p in internal]
    masks = [jnp.asarray(p.mask) for p in internal]

    @jax.jit
    def insert(q):  # q: [B, D] float32 -> [B] int32 leaf ids
        d = jnp_distances(q, root, metric)                     # [B, n1]
        node = jnp.argmin(d, axis=-1).astype(jnp.int32)        # lvl_1 node
        for emb, ids, mask in zip(embs, idss, masks):
            ce = emb[node]                                     # [B, maxc, D]
            cd = jnp_distances(q[:, None, :], ce, metric)[:, 0, :]  # [B, maxc]
            cd = jnp.where(mask[node], cd, jnp.inf)
            best = jnp.argmin(cd, axis=-1)
            node = ids[node, best]                             # next-level node
        return node

    return insert


# ----------------------------------------------------------- shared stages
def _spill_targets(
    Q: np.ndarray,
    leader_emb: np.ndarray,
    primary: np.ndarray,
    s: int,
    eps: float,
    metric: str,
    *,
    leaf_ids: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Build-time spill assignment: for each row of ``Q``, the extra leaves
    (beyond its tree-routed ``primary``) it should be replicated into.

    Candidates are the row's nearest leaf leaders in ``(distance, leaf)``
    order; one qualifies when its distance ``d_j`` is within the eps band
    of the row's globally nearest leader distance ``d1`` — multiplicative
    for l2/cosine (``d_j <= d1 + eps*|d1|``), additive for ip — capped at
    ``s`` replicas.  Pure numpy (``np_distances`` per batch), so identical
    batches always produce identical assignments: the one-shot build, the
    streaming build, and compact()'s rebuild all re-batch rows the same
    way and therefore spill bit-identically.

    ``leaf_ids`` maps leader rows to leaf node ids (insert time, where the
    centroids come from the parent level); by default row j IS leaf j (the
    builds' leader array).  Returns ``(rows, leaves)`` index arrays.
    """
    s = int(s)
    if s <= 0 or len(Q) == 0 or len(leader_emb) < 2:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    d = np_distances(np.asarray(Q, np.float32), np.asarray(leader_emb, np.float32), metric)
    if d.ndim == 1:
        d = d[None, :]
    n, l = d.shape
    ids_of = np.arange(l, dtype=np.int64) if leaf_ids is None else np.asarray(leaf_ids, np.int64)
    take = min(s + 1, l)  # the primary is usually among the nearest
    if take < l:
        part = np.argpartition(d, take - 1, axis=1)[:, :take]
    else:
        part = np.broadcast_to(np.arange(l), (n, l))
    rows_out: list[int] = []
    leaves_out: list[int] = []
    for r in range(n):
        cand = part[r]
        dc = d[r, cand].astype(np.float64)
        o = np.lexsort((ids_of[cand], dc))  # by distance, ties by leaf id
        d1 = float(dc[o[0]])  # argpartition keeps the global nearest in cand
        thresh = d1 + eps if metric == "ip" else d1 + eps * abs(d1)
        p = int(primary[r])
        cnt = 0
        for oo in o:
            j = int(ids_of[cand[oo]])
            if j == p:
                continue
            if float(dc[oo]) > thresh:
                break
            rows_out.append(r)
            leaves_out.append(j)
            cnt += 1
            if cnt >= s:
                break
    return np.asarray(rows_out, np.int64), np.asarray(leaves_out, np.int64)


def _validate_build(n_items: int, dim: int, cfg: ECPBuildConfig, n_ids: int | None) -> None:
    if n_items == 0:
        raise ValueError(
            "cannot build an index over an empty collection (0 items)"
        )
    if dim < 1:
        raise ValueError(f"collection must be [N, D] with D >= 1, got dim {dim}")
    if cfg.levels < 1:
        raise ValueError(f"levels must be >= 1, got {cfg.levels}")
    if cfg.cluster_cap is not None and cfg.cluster_cap < 1:
        raise ValueError(f"cluster_cap must be >= 1, got {cfg.cluster_cap}")
    if n_ids is not None and n_ids != n_items:
        raise ValueError(
            f"item_ids length {n_ids} does not match collection size {n_items}"
        )
    if cfg.spill_s < 0:
        raise ValueError(f"spill_s must be >= 0, got {cfg.spill_s}")
    if cfg.spill_eps < 0:
        raise ValueError(f"spill_eps must be >= 0, got {cfg.spill_eps}")


def _hierarchy(leaders: np.ndarray, nodes_per_level, metric: str) -> list[list[np.ndarray]]:
    """Internal levels: nested prefixes + nearest-parent assignment.
    children[i][j] = child node indices (at lvl_{i+2}) of node j at lvl_{i+1}."""
    children: list[list[np.ndarray]] = []
    for i in range(1, len(nodes_per_level)):
        n_parent = nodes_per_level[i - 1]
        n_child = nodes_per_level[i]
        assign = np.asarray(
            _assign_level(
                jnp.asarray(leaders[:n_child]), jnp.asarray(leaders[:n_parent]), metric
            )
        )
        lists: list[list[int]] = [[] for _ in range(n_parent)]
        for child, parent in enumerate(assign):
            lists[int(parent)].append(child)
        children.append([np.asarray(x, np.int32) for x in lists])
    return children


def _write_skeleton(
    store,
    info: layout.IndexInfo,
    leaders: np.ndarray,
    leader_item_ids: np.ndarray,
    children: list[list[np.ndarray]],
    store_dt: np.dtype,
) -> None:
    """Info + representatives + root + internal levels (everything above
    the leaves — O(leaders) data)."""
    is_fstore = getattr(store, "fstore", None) is not None
    if is_fstore:
        store.create_group(layout.INFO, attrs=info.to_attrs())
        store.write_array(layout.REP_EMB, leaders.astype(store_dt), chunk_rows=4096)
        store.write_array(layout.REP_IDS, leader_item_ids.astype(np.int64), chunk_rows=65536)
    else:
        store.write_attrs(layout.INFO, info.to_attrs())
    root_emb = leaders[: info.nodes_per_level[0]]
    store.write_node(
        0, 0, root_emb.astype(store_dt), np.arange(len(root_emb), dtype=np.int32)
    )
    for i, lists in enumerate(children):
        lv = i + 1
        if is_fstore:
            store.create_group(layout.lvl_group(lv))
        for j, ids in enumerate(lists):
            store.write_node(lv, j, leaders[ids].astype(store_dt), ids.astype(np.int32))
    if is_fstore:
        store.create_group(layout.lvl_group(info.levels))


def _sample_positions(seed: int, n_items: int, n_leaders: int) -> np.ndarray:
    """The one-shot build's leader draw: uniform without replacement, in
    draw order (the order IS the leader numbering)."""
    if n_leaders > n_items:
        raise ValueError(
            f"cannot sample {n_leaders} leaders from {n_items} items; "
            "collection is smaller than the requested leader count"
        )
    return np.random.default_rng(seed).choice(n_items, size=n_leaders, replace=False)


def reservoir_sample(chunks, k: int, *, seed: int = 0):
    """Single-pass uniform sample WITHOUT replacement of ``k`` rows from an
    iterator of ``[B, D]`` chunks (Algorithm R, vectorized per chunk).

    Returns ``(sample [k', D] float32, positions [k'] int64, n_seen)`` with
    ``k' = min(k, n_seen)``.  O(k) memory — the streaming build's sampler
    when the leader count is known up front (``n_leaders=...``), since the
    exact one-shot draw needs the collection size before it can be made.
    """
    if k < 1:
        raise ValueError(f"reservoir size must be >= 1, got {k}")
    rng = np.random.default_rng(seed)
    sample: np.ndarray | None = None
    pos = np.empty(k, np.int64)
    t = 0  # rows seen so far
    for chunk in chunks:
        chunk = np.asarray(chunk, np.float32)
        if chunk.ndim != 2:
            raise ValueError(f"chunks must be [B, D], got shape {chunk.shape}")
        m = len(chunk)
        if m == 0:
            continue
        if sample is None:
            sample = np.empty((k, chunk.shape[1]), np.float32)
        at = 0
        if t < k:  # fill phase
            take = min(k - t, m)
            sample[t : t + take] = chunk[:take]
            pos[t : t + take] = np.arange(t, t + take)
            t += take
            at = take
        if at < m:  # replacement phase: row at global index g replaces a
            # reservoir slot with probability k / (g + 1)
            g = t + np.arange(m - at)
            js = (rng.random(m - at) * (g + 1)).astype(np.int64)
            for h in np.flatnonzero(js < k):  # few hits; sequential = exact R
                sample[js[h]] = chunk[at + h]
                pos[js[h]] = g[h]
            t += m - at
    if sample is None:
        raise ValueError("cannot sample from an empty collection")
    kk = min(k, t)
    return sample[:kk], pos[:kk], t


# ------------------------------------------------------------ chunk sources
class _ChunkSource:
    """Re-iterable view over a collection of ``[B, D]`` chunks.

    Accepts an ndarray (sliced into ``chunk_rows`` views), a sequence of
    arrays, a callable returning a fresh iterator per pass, or a one-shot
    iterator — the latter is spooled to a scratch directory during the
    first pass so later passes can re-read it (out-of-core, not in RAM).
    Chunks may be ``(emb, ids)`` pairs; otherwise ids are the global row
    positions (or ``item_ids`` indexed by position).
    """

    def __init__(self, source, *, item_ids=None, chunk_rows: int = 8192):
        self._item_ids = None if item_ids is None else np.asarray(item_ids, np.int64)
        self._chunk_rows = max(1, int(chunk_rows))
        self.saw_pairs = False  # source yields (emb, ids) tuples
        self._spool: tempfile.TemporaryDirectory | None = None
        self._spooled: list[tuple[str, str]] = []
        self._array = None
        self._seq = None
        self._fn = None
        self._iter = None
        if isinstance(source, np.ndarray):
            self._array = source
        elif callable(source):
            self._fn = source
        elif isinstance(source, (list, tuple)):
            self._seq = source
        else:
            self._iter = iter(source)

    def _norm(self, raw, offset: int):
        if isinstance(raw, tuple):
            self.saw_pairs = True
            emb, ids = raw
            emb = np.asarray(emb, np.float32)
            ids = np.asarray(ids, np.int64)
            if len(emb) != len(ids):
                raise ValueError(f"chunk emb/ids length mismatch: {len(emb)} vs {len(ids)}")
        else:
            emb = np.asarray(raw, np.float32)
            if self._item_ids is not None:
                ids = self._item_ids[offset : offset + len(emb)]
            else:
                ids = np.arange(offset, offset + len(emb), dtype=np.int64)
        if emb.ndim != 2:
            raise ValueError(f"chunks must be [B, D], got shape {emb.shape}")
        return emb, ids

    def chunks(self):
        """One pass over the collection as (emb f32 [B, D], ids [B])."""
        offset = 0
        if self._array is not None:
            a = self._array
            for lo in range(0, len(a), self._chunk_rows):
                emb, ids = self._norm(a[lo : lo + self._chunk_rows], lo)
                yield emb, ids
        elif self._seq is not None or self._fn is not None:
            it = self._seq if self._seq is not None else self._fn()
            for raw in it:
                emb, ids = self._norm(raw, offset)
                offset += len(emb)
                yield emb, ids
        elif self._iter is not None:
            # one-shot iterator: consume + spool to disk for later passes
            self._spool = tempfile.TemporaryDirectory(prefix="ecpfs_spool_")
            root = Path(self._spool.name)
            it, self._iter = self._iter, None
            for i, raw in enumerate(it):
                emb, ids = self._norm(raw, offset)
                offset += len(emb)
                pe, pi = str(root / f"{i:06d}_emb.npy"), str(root / f"{i:06d}_ids.npy")
                np.save(pe, emb)  # lossless: replayed passes must see the
                np.save(pi, ids)  # exact values the first pass counted
                self._spooled.append((pe, pi))
                yield emb, ids
        else:  # replay the spool
            for pe, pi in self._spooled:
                yield np.load(pe).astype(np.float32), np.load(pi)


# ------------------------------------------------------------------- builds
def build_index(
    data: np.ndarray,
    path: str,
    cfg: ECPBuildConfig = ECPBuildConfig(),
    *,
    item_ids: np.ndarray | None = None,
) -> FStoreBackend:
    """Build an eCP-FS index over ``data`` [N, D] at directory ``path``.

    The one-shot stage of the lifecycle: the whole collection is in
    memory, leaves are written once each.  ``build_index_streaming``
    produces a bit-identical index from a chunk iterator with bounded
    memory; ``convert()`` serializes either result for the blob backend.
    """
    data = np.asarray(data)
    if data.ndim != 2:
        raise ValueError(f"collection must be [N, D], got shape {data.shape}")
    n_items, dim = data.shape
    _validate_build(n_items, dim, cfg, None if item_ids is None else len(item_ids))
    if item_ids is None:
        item_ids = np.arange(n_items, dtype=np.int64)
    else:
        item_ids = np.asarray(item_ids, np.int64)
    store_dt = np.dtype(cfg.storage_dtype)
    cap = _resolve_cap(cfg, dim, store_dt.itemsize)
    n_leaders, fanout, nodes_per_level = layout.derive_shape(n_items, cap, cfg.levels)

    leader_idx = _sample_positions(cfg.seed, n_items, n_leaders)
    leaders = np.asarray(data[leader_idx], np.float32)         # [l, D]
    children = _hierarchy(leaders, nodes_per_level, cfg.metric)

    # --- item insertion: batched beam-1 traversal -------------------------
    internal_packed = [
        pack_children([leaders[ids] for ids in lists], lists, dim)
        for lists in children
    ]
    insert = _make_insert_fn(leaders[: nodes_per_level[0]], internal_packed, cfg.metric)
    # (row, leaf) assignment pairs, built PER insert batch: each batch
    # contributes its primary assignments in row order, then its spill
    # replicas — exactly the order build_index_streaming's flush() appends
    # them in, so the final stable sort by leaf groups rows identically
    # for both builds.  At spill_s=0 this is today's arange/leaf_of pair.
    pair_rows_l: list[np.ndarray] = []
    pair_leaf_l: list[np.ndarray] = []
    for lo in range(0, n_items, cfg.insert_batch):
        hi = min(lo + cfg.insert_batch, n_items)
        q = jnp.asarray(data[lo:hi], jnp.float32)
        leaf_b = np.asarray(insert(q)).astype(np.int64)
        pair_rows_l.append(np.arange(lo, hi, dtype=np.int64))
        pair_leaf_l.append(leaf_b)
        if cfg.spill_s > 0:
            sr, slv = _spill_targets(
                np.asarray(data[lo:hi], np.float32), leaders, leaf_b,
                cfg.spill_s, cfg.spill_eps, cfg.metric,
            )
            pair_rows_l.append(sr + lo)
            pair_leaf_l.append(slv)
    pair_rows = np.concatenate(pair_rows_l)
    pair_leaf = np.concatenate(pair_leaf_l)

    # --- write the file structure -----------------------------------------
    store = open_store(path, backend="fstore", create=True)
    info = layout.IndexInfo(
        levels=cfg.levels,
        metric=cfg.metric,
        dim=dim,
        dtype=str(store_dt),
        n_items=n_items,
        cluster_cap=cap,
        n_leaders=n_leaders,
        fanout=fanout,
        nodes_per_level=nodes_per_level,
        seed=cfg.seed,
        insert_batch=cfg.insert_batch,
        next_id=int(item_ids.max()) + 1,
        spill_s=max(0, int(cfg.spill_s)),
        spill_eps=float(cfg.spill_eps),
    )
    _write_skeleton(store, info, leaders, item_ids[leader_idx], children, store_dt)
    order = np.argsort(pair_leaf, kind="stable")
    sorted_leaf = pair_leaf[order]
    bounds = np.searchsorted(sorted_leaf, np.arange(n_leaders + 1))
    for j in range(n_leaders):
        members = pair_rows[order[bounds[j] : bounds[j + 1]]]
        store.write_node(
            cfg.levels,
            j,
            np.asarray(data[members], store_dt),
            item_ids[members].astype(np.int64),
            chunk_rows=cfg.leaf_chunk_rows,
        )
    return store


def build_index_streaming(
    source,
    path: str | None = None,
    cfg: ECPBuildConfig = ECPBuildConfig(),
    *,
    item_ids: np.ndarray | None = None,
    store: Store | None = None,
    n_leaders: int | None = None,
    generation: int = 0,
    next_id: int | None = None,
) -> Store:
    """Out-of-core build: consume the collection as ``[B, D]`` chunks with
    peak memory O(chunk + leaders + insert_batch), never O(collection).

    ``source``: an ndarray, a sequence of chunks, a callable returning a
    fresh chunk iterator per pass, or a one-shot iterator (spooled to a
    scratch directory on the first pass).  Chunks may be ``(emb, ids)``
    pairs.  Items are appended to leaf blocks through the Store protocol
    as they are assigned — no cluster is ever materialized in RAM.

    Default sampling draws the one-shot build's exact leader set once
    pass 1 has counted the collection, so the result is bit-identical to
    ``build_index`` over the same data (same seed, any chunking).  An
    explicit ``n_leaders`` switches pass 1 to single-pass reservoir
    sampling instead (one fewer pass; leaders then differ from the
    one-shot draw).

    ``store`` writes into an existing (fstore-protocol) store in place —
    the compaction path; otherwise ``path`` is created.  ``generation``
    seeds the written index's generation counter and ``next_id`` floors
    its default-id allocator (compaction carries both forward, so purged
    ids are never reissued).
    """
    if (path is None) == (store is None):
        raise ValueError("exactly one of path / store must be given")
    src = _ChunkSource(source, item_ids=item_ids, chunk_rows=cfg.insert_batch)

    # ---- pass 1: count (and, with explicit n_leaders, reservoir-sample)
    reservoir = None
    n_items = 0
    dim = 0
    max_id = -1
    if n_leaders is not None:
        def counting():
            nonlocal n_items, dim, max_id
            for emb, ids in src.chunks():
                n_items += len(emb)
                dim = emb.shape[1]
                if len(ids):
                    max_id = max(max_id, int(ids.max()))
                yield emb

        leaders, leader_pos, _ = reservoir_sample(counting(), n_leaders, seed=cfg.seed)
        reservoir = (leaders, leader_pos)
        if n_leaders > n_items:
            raise ValueError(
                f"cannot sample {n_leaders} leaders from {n_items} items; "
                "collection is smaller than the requested leader count"
            )
    else:
        for emb, ids in src.chunks():
            n_items += len(emb)
            dim = emb.shape[1]
            if len(ids):
                max_id = max(max_id, int(ids.max()))
    if n_items == 0:
        raise ValueError("cannot build an index over an empty collection (0 items)")
    _validate_build(n_items, dim, cfg, None)
    store_dt = np.dtype(cfg.storage_dtype)
    cap = _resolve_cap(cfg, dim, store_dt.itemsize)
    n_l, fanout, nodes_per_level = layout.derive_shape(
        n_items, cap, cfg.levels, n_leaders=n_leaders
    )

    # ---- pass 2: gather the sampled leader rows (skipped in reservoir
    # mode: the reservoir already holds the embeddings, and the sampled
    # ids are derivable without re-streaming unless the source itself
    # yields (emb, ids) pairs)
    if reservoir is not None:
        leaders, leader_pos = reservoir
        if item_ids is not None:
            leader_item_ids = np.asarray(item_ids, np.int64)[leader_pos]
        elif src.saw_pairs:
            leader_item_ids = _gather_ids(src, leader_pos)
        else:  # default ids ARE the positions
            leader_item_ids = leader_pos.astype(np.int64)
    else:
        leader_pos = _sample_positions(cfg.seed, n_items, n_l)
        leaders, leader_item_ids = _gather_rows(src, leader_pos, dim)

    children = _hierarchy(leaders, nodes_per_level, cfg.metric)
    internal_packed = [
        pack_children([leaders[ids] for ids in lists], lists, dim)
        for lists in children
    ]
    insert = _make_insert_fn(leaders[: nodes_per_level[0]], internal_packed, cfg.metric)

    if store is None:
        store = open_store(path, backend="fstore", create=True)
    info = layout.IndexInfo(
        levels=cfg.levels,
        metric=cfg.metric,
        dim=dim,
        dtype=str(store_dt),
        n_items=n_items,
        cluster_cap=cap,
        n_leaders=n_l,
        fanout=fanout,
        nodes_per_level=nodes_per_level,
        seed=cfg.seed,
        generation=generation,
        insert_batch=cfg.insert_batch,
        next_id=max(max_id + 1, next_id or 0),
        spill_s=max(0, int(cfg.spill_s)),
        spill_eps=float(cfg.spill_eps),
    )
    _write_skeleton(store, info, leaders, leader_item_ids, children, store_dt)

    # ---- pass 3: assign + append.  Rows are re-batched to cfg.insert_batch
    # so the jit'd assignment sees the one-shot build's exact batch
    # sequence — chunk boundaries cannot perturb the result.
    L = cfg.levels
    leaf_chunk = cfg.leaf_chunk_rows or cap
    touched = np.zeros(n_l, bool)
    is_fstore = getattr(store, "fstore", None) is not None
    buf_q = np.empty((cfg.insert_batch, dim), np.float32)
    buf_ids = np.empty(cfg.insert_batch, np.int64)
    fill = 0

    def flush() -> None:
        nonlocal fill
        if fill == 0:
            return
        q, ids_b = buf_q[:fill], buf_ids[:fill]
        leaf = np.asarray(insert(jnp.asarray(q))).astype(np.int64)
        rows_all = np.arange(fill, dtype=np.int64)
        leaf_all = leaf
        if cfg.spill_s > 0:
            # spill replicas append AFTER this batch's primaries — the
            # same (batch-primaries, batch-spills) order build_index's
            # pair list records, so both builds write identical leaves
            sr, slv = _spill_targets(
                q, leaders, leaf, cfg.spill_s, cfg.spill_eps, cfg.metric
            )
            rows_all = np.concatenate([rows_all, sr])
            leaf_all = np.concatenate([leaf, slv])
        order = np.argsort(leaf_all, kind="stable")
        sl = leaf_all[order]
        starts = np.flatnonzero(np.r_[True, sl[1:] != sl[:-1]])
        for s, e in zip(starts, np.r_[starts[1:], len(sl)]):
            j = int(sl[s])
            rows = rows_all[order[s:e]]
            emb_w = q[rows].astype(store_dt)
            ids_w = ids_b[rows]
            if touched[j]:
                store.append_rows(L, j, emb_w, ids_w)
            elif is_fstore:
                # first touch replaces whatever a previous tree left here
                store.write_node(L, j, emb_w, ids_w, chunk_rows=leaf_chunk)
                touched[j] = True
            else:
                store.write_node(L, j, emb_w, ids_w)
                touched[j] = True
        fill = 0

    for emb, ids in src.chunks():
        at = 0
        while at < len(emb):
            take = min(cfg.insert_batch - fill, len(emb) - at)
            buf_q[fill : fill + take] = emb[at : at + take]
            buf_ids[fill : fill + take] = ids[at : at + take]
            fill += take
            at += take
            if fill == cfg.insert_batch:
                flush()
    flush()

    # empty clusters still get (empty) nodes, exactly like the one-shot build
    empty_e = np.zeros((0, dim), store_dt)
    empty_i = np.zeros((0,), np.int64)
    for j in np.flatnonzero(~touched):
        if is_fstore:
            store.write_node(L, int(j), empty_e, empty_i, chunk_rows=leaf_chunk)
        else:
            store.write_node(L, int(j), empty_e, empty_i)
    return store


def _gather_rows(src: _ChunkSource, positions: np.ndarray, dim: int):
    """One streaming pass collecting the rows at ``positions`` (and their
    ids), returned in ``positions`` order — O(len(positions)) memory."""
    srt = np.argsort(positions, kind="stable")
    sorted_pos = positions[srt]
    out = np.empty((len(positions), dim), np.float32)
    out_ids = np.empty(len(positions), np.int64)
    seen = 0
    offset = 0
    for emb, ids in src.chunks():
        lo = np.searchsorted(sorted_pos, offset)
        hi = np.searchsorted(sorted_pos, offset + len(emb))
        if hi > lo:
            rel = sorted_pos[lo:hi] - offset
            out[srt[lo:hi]] = emb[rel]
            out_ids[srt[lo:hi]] = ids[rel]
            seen += hi - lo
        offset += len(emb)
    if seen != len(positions):
        raise ValueError(
            f"chunk source changed between passes: gathered {seen} of "
            f"{len(positions)} sampled rows"
        )
    return out, out_ids


def _gather_ids(src: _ChunkSource, positions: np.ndarray) -> np.ndarray:
    """Ids at ``positions`` without re-reading embeddings into the result."""
    srt = np.argsort(positions, kind="stable")
    sorted_pos = positions[srt]
    out_ids = np.empty(len(positions), np.int64)
    seen = 0
    offset = 0
    for _emb, ids in src.chunks():
        lo = np.searchsorted(sorted_pos, offset)
        hi = np.searchsorted(sorted_pos, offset + len(ids))
        if hi > lo:
            out_ids[srt[lo:hi]] = ids[sorted_pos[lo:hi] - offset]
            seen += hi - lo
        offset += len(ids)
    if seen != len(positions):
        raise ValueError(
            f"chunk source changed between passes: gathered {seen} of "
            f"{len(positions)} sampled rows"
        )
    return out_ids


# ---------------------------------------------------------------- mutation
def publish_generation(index, attrs: dict, new_info, tombstones: set, written) -> None:
    """THE commit point of every non-structural mutation.

    A mutation becomes visible — to this process's searchers, to the
    serving scheduler's snapshot manager, and to EXTERNAL readers of the
    blob file — at the single ``write_attrs`` below, which publishes the
    bumped ``generation`` together with the new counts, node registry, and
    tombstone list atomically (one header rewrite on blob, one tmp+replace
    JSON write on fstore).  Until this write, appended rows and
    split-created leaves exist on disk but are unreachable: the old info
    still describes the old tree, so a reader (or a crash) that never sees
    the new attrs never sees a half-applied mutation.

    External readers of the blob format poll ``info.generation`` and call
    ``ECPIndex.refresh()`` when it moves; ``launch/scheduler.py`` instead
    re-pins a fresh ``ECPIndex.snapshot()`` after each mutation returns.
    ``_apply_mutation`` then updates this process's in-memory state (cache
    invalidation + cache-key version bumps + metadata/root refresh).

    Structural rewrites (``compact``) have their own commit points: the
    fstore rebuild's final info write, or the blob's ``os.replace`` swap.
    """
    attrs.update(new_info.to_attrs())
    index.store.write_attrs(layout.INFO, layout.write_tombstones(attrs, tombstones))
    index._apply_mutation(new_info, written, tombstones=tombstones)


def _node_rows(index, keys: list) -> list[int]:
    rows_fn = getattr(index.store, "node_rows", None)
    if rows_fn is not None:
        return rows_fn(keys)
    return [len(ids) for _, ids in index.store.get_nodes(keys)]


def _route_batch(index, Q: np.ndarray):
    """Beam-1 descent for a batch: [n, D] -> (leaf ids [n], parent_of).

    ``parent_of[leaf] = (level, node)`` of the internal node whose child
    list holds the leaf (the root ``(0, 0)`` for a 1-level index) — the
    node a split must register its new centroid with.  Internal children
    with no children of their own are skipped (next-nearest wins), so
    routing never dead-ends in an empty subtree.
    """
    info = index.info
    metric = info.metric
    L = info.levels
    n = len(Q)
    d = np_distances(Q, index.root_emb, metric)
    d = d[None, :] if d.ndim == 1 else d
    if L == 1:
        best = np.argmin(d, axis=1)
        leaf = np.asarray(index.root_ids, np.int64)[best]
        return leaf, {int(j): (0, 0) for j in np.unique(leaf)}
    rows1 = np.asarray(_node_rows(index, [(1, int(c)) for c in index.root_ids]))
    dd = np.where(rows1[None, :] == 0, np.inf, d)
    if not np.isfinite(dd).any(axis=1).all():
        raise RuntimeError("index has no reachable leaves from the root")
    cur = np.asarray(index.root_ids, np.int64)[np.argmin(dd, axis=1)]
    parent_of: dict[int, tuple[int, int]] = {}
    for lv in range(1, L):
        child_level = lv + 1
        nxt = np.empty(n, np.int64)
        for nd in np.unique(cur):
            rows_i = np.flatnonzero(cur == nd)
            emb, ids = index.get_node(lv, int(nd))
            if len(ids) == 0:
                raise RuntimeError(
                    f"routing reached empty internal node (lvl {lv}, node {int(nd)})"
                )
            d = np_distances(Q[rows_i], emb, metric)
            d = d[None, :] if d.ndim == 1 else d
            if child_level < L:
                rows_c = np.asarray(_node_rows(index, [(child_level, int(c)) for c in ids]))
                d = np.where(rows_c[None, :] == 0, np.inf, d)
                if not np.isfinite(d).any(axis=1).all():
                    raise RuntimeError(
                        f"no reachable leaves under internal node (lvl {lv}, node {int(nd)})"
                    )
            best = np.argmin(d, axis=1)
            chosen = np.asarray(ids, np.int64)[best]
            nxt[rows_i] = chosen
            if child_level == L:
                for j in np.unique(chosen):
                    parent_of[int(j)] = (lv, int(nd))
        cur = nxt
    return cur, parent_of


def _two_means(emb: np.ndarray, iters: int = 8):
    """Deterministic local 2-means: farthest-point init, Lloyd iterations,
    ties to side 0.  Returns (mask_side0, centroid0, centroid1); degenerate
    inputs (all rows identical) fall back to an index-halves split."""
    n = len(emb)
    halves = np.zeros(n, bool)
    halves[: (n + 1) // 2] = True
    mu = emb.mean(0)
    i0 = int(np.argmax(((emb - mu) ** 2).sum(1)))
    i1 = int(np.argmax(((emb - emb[i0]) ** 2).sum(1)))
    if not ((emb[i0] - emb[i1]) ** 2).sum() > 0:
        return halves, emb[halves].mean(0), emb[~halves].mean(0)
    c0, c1 = emb[i0].copy(), emb[i1].copy()
    m = halves
    for _ in range(iters):
        d0 = ((emb - c0) ** 2).sum(1)
        d1 = ((emb - c1) ** 2).sum(1)
        m = d0 <= d1
        if m.all() or not m.any():
            return halves, emb[halves].mean(0), emb[~halves].mean(0)
        nc0, nc1 = emb[m].mean(0), emb[~m].mean(0)
        if np.array_equal(nc0, c0) and np.array_equal(nc1, c1):
            break
        c0, c1 = nc0, nc1
    return m, c0, c1


def _split_parts(emb: np.ndarray, ids: np.ndarray, cap: int) -> list:
    """Recursively 2-means-split until every part holds <= cap rows.
    Returns [(emb, ids, centroid), ...] in deterministic order."""
    if len(emb) <= cap:
        return [(emb, ids, emb.mean(0) if len(emb) else np.zeros(emb.shape[1], np.float32))]
    m, c0, c1 = _two_means(emb)
    return _split_parts(emb[m], ids[m], cap) + _split_parts(emb[~m], ids[~m], cap)


def _split_leaf(index, ctx: dict, leaf: int, emb: np.ndarray, ids: np.ndarray, parent) -> None:
    """Split one over-full leaf: part 0 stays at ``leaf``, the rest become
    new nodes at the end of the leaf level; the parent's routing row for
    ``leaf`` becomes part 0's centroid and one row per new node is
    appended (paper's tree stays valid: internal ids keep pointing at
    next-level nodes)."""
    info = index.info
    L = info.levels
    dt = np.dtype(info.dtype)
    cap = max(1, info.cluster_cap)
    parts = _split_parts(np.asarray(emb, np.float32), np.asarray(ids, np.int64), cap)
    store = index.store
    # pre-flight BEFORE any write: a fixed-block backend must fit both the
    # grown parent and the new nodes' header growth (slot map, v1→v2
    # upgrade), or the split would strand already-written data — the leaf
    # is overwritten with part 0 first, so a late failure loses rows
    cap_rows = getattr(store, "capacity_rows", None)
    if cap_rows is not None:
        p_rows = _node_rows(index, [parent])[0]
        if p_rows + len(parts) - 1 > cap_rows:
            raise ValueError(
                f"splitting leaf {leaf} would grow its parent "
                f"(lvl {parent[0]}, node {parent[1]}) to {p_rows + len(parts) - 1} "
                f"rows, past the blob's fixed block ({cap_rows} rows); "
                "compact() the index to rebalance before further inserts"
            )
    ensure = getattr(store, "ensure_capacity", None)
    if ensure is not None:
        ensure(L, len(parts) - 1)
    store.write_node(L, leaf, parts[0][0].astype(dt), parts[0][1])
    ctx["written"].add((L, leaf))
    new_nodes = []
    for p_emb, p_ids, _c in parts[1:]:
        j = ctx["npl"][-1]
        ctx["npl"][-1] += 1
        store.write_node(L, j, p_emb.astype(dt), p_ids)
        ctx["written"].add((L, j))
        new_nodes.append(j)
    ctx["splits"] += len(new_nodes)
    # register the new centroids with the parent
    p_lv, p_nd = parent
    p_emb, p_ids = store.get_node(p_lv, p_nd)
    pos = np.flatnonzero(np.asarray(p_ids, np.int64) == leaf)
    if len(pos) != 1:
        raise RuntimeError(
            f"parent (lvl {p_lv}, node {p_nd}) does not list leaf {leaf} exactly once"
        )
    p_emb = np.asarray(p_emb, np.float32)
    p_emb[pos[0]] = parts[0][2]
    add_emb = np.stack([c for _, _, c in parts[1:]])
    new_emb = np.concatenate([p_emb, add_emb]).astype(dt)
    new_ids = np.concatenate([np.asarray(p_ids), np.asarray(new_nodes, p_ids.dtype)])
    store.write_node(p_lv, p_nd, new_emb, new_ids)
    ctx["written"].add((p_lv, p_nd))


def _leaf_leaders(index) -> tuple[np.ndarray, np.ndarray]:
    """Leaf-leader centroids and their leaf node ids, read from the parent
    level (the root when levels == 1) through the index's node cache —
    the same pre-mutation tree view beam routing uses."""
    info = index.info
    L = info.levels
    if L == 1:
        return (
            np.asarray(index.root_emb, np.float32),
            np.asarray(index.root_ids, np.int64),
        )
    embs: list[np.ndarray] = []
    idss: list[np.ndarray] = []
    for nd in range(info.nodes_per_level[L - 2]):
        e, i = index.get_node(L - 1, nd)
        if len(i):
            embs.append(np.asarray(e, np.float32))
            idss.append(np.asarray(i, np.int64))
    return np.concatenate(embs), np.concatenate(idss)


def insert_items(index, vectors: np.ndarray, ids: np.ndarray | None = None) -> dict:
    """Insert ``vectors`` [n, D] (or [D]) with item ``ids`` into a live
    index: beam-1 routing to the nearest leaf, append through the Store
    protocol, deterministic 2-means splits for leaves that outgrow
    ``cluster_cap``.  Without explicit ids, new items take the positions
    ``n_items ..`` (correct for indexes built with default ids).

    Inserting a tombstoned id resurrects it: the tombstone is dropped and
    the id's OLD physical row is purged first (one scan of the leaf
    level), so the new row is the only live one and ``compact()`` never
    sees a duplicate.  Returns counters: inserted / splits / leaves /
    generation.
    """
    Q = np.asarray(vectors, np.float32)
    if Q.ndim == 1:
        Q = Q[None, :]
    info = index.info
    if Q.ndim != 2 or (len(Q) and Q.shape[1] != info.dim):
        raise ValueError(f"vectors must be [n, {info.dim}], got {list(Q.shape)}")
    n = len(Q)
    if ids is None:
        # next_id is monotonic across mutations AND compaction, so default
        # ids never collide with a live item (or reuse a purged one)
        ids = np.arange(info.next_id, info.next_id + n, dtype=np.int64)
    else:
        ids = np.asarray(ids, np.int64)
        if ids.shape != (n,):
            raise ValueError(f"ids must be [n]={n}, got {list(ids.shape)}")
        if len(np.unique(ids)) != n:
            raise ValueError("inserted ids must be unique")
    if n == 0:
        return {"inserted": 0, "splits": 0, "leaves": 0, "generation": info.generation}
    drain = getattr(index.store, "drain", None)
    if drain is not None:
        drain()  # no in-flight prefetch may land stale payloads mid-mutation

    attrs = index.store.read_attrs(layout.INFO)
    tombs = layout.read_tombstones(attrs)
    resurrected = tombs & {int(x) for x in ids}
    purged_keys: set = set()
    purged_rows = 0     # physical rows removed (spill replicas count each)
    purged_logical = 0  # distinct resurrected ids actually found + purged
    # ids below the allocator's floor may already exist in the index; one
    # pass over the leaf level finds them.  Tombstoned hits are purged
    # (the resurrect path — the new row must be the only one); LIVE hits
    # are an error and are detected BEFORE anything is written.
    suspects = {int(x) for x in ids if x < info.next_id}
    if suspects:
        sus_arr = np.fromiter(suspects, np.int64, len(suspects))
        L0 = info.levels
        hits: list[tuple[tuple, np.ndarray]] = []
        found: set = set()
        for lo in range(0, info.nodes_per_level[-1], 64):
            keys = [(L0, j) for j in range(lo, min(lo + 64, info.nodes_per_level[-1]))]
            for (lv, nd), (_e, nids) in zip(keys, index.store.get_nodes(keys)):
                if len(nids) == 0:
                    continue
                present = np.asarray(nids, np.int64)[
                    np.isin(np.asarray(nids, np.int64), sus_arr)
                ]
                if len(present):
                    hits.append(((lv, nd), present))
                    found |= {int(x) for x in present}
        live_dupes = found - resurrected
        if live_dupes:
            raise ValueError(
                f"ids already live in the index: {sorted(live_dupes)[:10]}"
                f"{'...' if len(live_dupes) > 10 else ''}; delete() them first"
            )
        if resurrected:
            res_arr = np.fromiter(resurrected, np.int64, len(resurrected))
            for key, present in hits:
                purged_rows += index.store.delete_rows(key[0], key[1], res_arr)
                purged_keys.add(key)
            purged_logical = len(found & resurrected)
    # a resurrected id above the allocator floor (or a phantom tombstone)
    # has no physical row to purge, but its tombstone must still drop —
    # the row being inserted now is the live one
    tombs -= resurrected

    leaf, parent_of = _route_batch(index, Q)
    L = info.levels
    dt = np.dtype(info.dtype)
    cap = max(1, info.cluster_cap)
    # spill replica plan, computed against the SAME pre-mutation tree view
    # beam routing used.  Replication at insert time is best-effort: a
    # target leaf at capacity is skipped rather than split (a replica is
    # a recall hint, never worth a structural change).
    spill_pairs: list[tuple[int, np.ndarray]] = []
    if info.spill_s > 0:
        lead_emb, lead_ids = _leaf_leaders(index)
        sr, slv = _spill_targets(
            Q, lead_emb, leaf.astype(np.int64),
            info.spill_s, info.spill_eps, info.metric, leaf_ids=lead_ids,
        )
        if len(sr):
            so = np.argsort(slv, kind="stable")
            ssr, ssl = sr[so], slv[so]
            st = np.flatnonzero(np.r_[True, ssl[1:] != ssl[:-1]])
            for s0, e0 in zip(st, np.r_[st[1:], len(ssl)]):
                spill_pairs.append((int(ssl[s0]), ssr[s0:e0]))
    ctx = {"npl": list(info.nodes_per_level), "written": set(), "splits": 0}
    order = np.argsort(leaf, kind="stable")
    sl = leaf[order]
    starts = np.flatnonzero(np.r_[True, sl[1:] != sl[:-1]])
    touched_leaves = 0
    appended = 0  # rows of COMPLETED leaf groups (the abort path records them)
    spilled = 0   # replica rows actually placed (capacity permitting)
    try:
        for s, e in zip(starts, np.r_[starts[1:], len(sl)]):
            j = int(sl[s])
            rows = order[s:e]
            touched_leaves += 1
            rows_now = _node_rows(index, [(L, j)])[0]
            if rows_now + len(rows) <= cap:
                index.store.append_rows(L, j, Q[rows].astype(dt), ids[rows])
                ctx["written"].add((L, j))
            else:
                old_emb, old_ids = index.store.get_node(L, j)
                all_emb = np.concatenate([np.asarray(old_emb, np.float32), Q[rows]])
                all_ids = np.concatenate([np.asarray(old_ids, np.int64), ids[rows]])
                _split_leaf(index, ctx, j, all_emb, all_ids, parent_of[j])
            appended += len(rows)
        for j, rows in spill_pairs:
            fit = cap - _node_rows(index, [(L, j)])[0]
            if fit <= 0:
                continue
            rows = rows[:fit]
            index.store.append_rows(L, j, Q[rows].astype(dt), ids[rows])
            ctx["written"].add((L, j))
            spilled += len(rows)
    except Exception:
        # partial failure (e.g. a later split refused by a full parent
        # block): the prefix that DID complete must be recorded — its
        # split-created leaves would otherwise sit outside the registered
        # nodes_per_level and compact() would drop their rows — and the
        # rewritten nodes must not be served stale from the cache
        try:
            part_info = dc_replace(
                info,
                n_items=info.n_items + appended - purged_logical,
                n_leaders=ctx["npl"][-1],
                nodes_per_level=tuple(ctx["npl"]),
                generation=info.generation + 1,
                next_id=max(info.next_id, int(ids.max()) + 1),
            )
            publish_generation(index, attrs, part_info, tombs, ctx["written"] | purged_keys)
        except Exception:
            index._apply_mutation(None, ctx["written"] | purged_keys)
        raise

    # metadata: counts, id allocator, generation, resurrected tombstones.
    # n_items tracks LOGICAL items: +n inserted, -ids actually purged (a
    # resurrected id that never physically existed purges nothing; spill
    # replicas are extra physical rows of the same item, never counted).
    new_info = dc_replace(
        info,
        n_items=info.n_items + n - purged_logical,
        n_leaders=ctx["npl"][-1],
        nodes_per_level=tuple(ctx["npl"]),
        generation=info.generation + 1,
        next_id=max(info.next_id, int(ids.max()) + 1),
    )
    publish_generation(index, attrs, new_info, tombs, ctx["written"] | purged_keys)
    return {
        "inserted": n,
        "splits": ctx["splits"],
        "leaves": touched_leaves,
        "spilled": spilled,
        "generation": new_info.generation,
    }


def delete_items(index, ids) -> int:
    """Tombstone ``ids``: the rows stay on disk but both traversal engines
    filter them during leaf scoring; ``compact()`` purges them physically.
    Returns the number of newly tombstoned ids.  Ids are not checked for
    liveness (a delete of an absent id is a harmless no-op tombstone)."""
    ids = np.unique(np.asarray(ids, np.int64).reshape(-1))
    attrs = index.store.read_attrs(layout.INFO)
    tombs = layout.read_tombstones(attrs)
    before = len(tombs)
    tombs |= {int(x) for x in ids}
    added = len(tombs) - before
    if added == 0:
        return 0
    new_info = dc_replace(index.info, generation=index.info.generation + 1)
    publish_generation(index, attrs, new_info, tombs, ())
    return added


def compact(index) -> dict:
    """Rewrite the index as a deterministic rebuild of its live items.

    Tombstoned rows are purged, split chains rebalanced, and the result
    answers queries bit-identically to a fresh ``build_index`` over the
    same logical collection (live ``(id, vector-as-stored)`` pairs in
    ascending-id order) with the index's recorded seed/levels/cap/metric
    — because the rebuild IS that build, streamed from a disk spool with
    O(chunk + leaders) peak memory.

    fstore: nodes are rewritten in place through the Store protocol and
    stale nodes freed (not crash-atomic; saved query states are cleared).
    blob: rebuilt into a scratch hierarchy, converted, and atomically
    swapped over the blob file (readers holding the old fd keep the old
    view until they reopen).
    """
    info = index.info
    store = index.store
    drain = getattr(store, "drain", None)
    if drain is not None:
        drain()
    L = info.levels
    dt = np.dtype(info.dtype)
    tombs = index.tombstones
    tomb_arr = (
        np.fromiter(tombs, np.int64, len(tombs)) if tombs else np.empty(0, np.int64)
    )
    n_leaf = info.nodes_per_level[-1]
    old_npl = list(info.nodes_per_level)

    with tempfile.TemporaryDirectory(prefix="ecpfs_compact_") as td:
        # ---- spool live leaf rows (storage dtype) + collect their ids
        raw = Path(td) / "live.rows"
        all_ids: list[np.ndarray] = []
        n_live = 0
        n_scanned = 0
        with open(raw, "wb") as f:
            batch = 64
            for lo in range(0, n_leaf, batch):
                keys = [(L, j) for j in range(lo, min(lo + batch, n_leaf))]
                for emb, nids in store.get_nodes(keys):
                    if len(nids) == 0:
                        continue
                    n_scanned += len(nids)
                    nids = np.asarray(nids, np.int64)
                    if len(tomb_arr):
                        keep = ~np.isin(nids, tomb_arr)
                        emb, nids = emb[keep], nids[keep]
                    if len(nids) == 0:
                        continue
                    np.ascontiguousarray(emb, dtype=dt).tofile(f)
                    all_ids.append(nids)
                    n_live += len(nids)
        if n_live == 0:
            raise ValueError(
                "compact() would produce an empty index (every item is "
                "tombstoned); delete the index instead"
            )
        ids_flat = np.concatenate(all_ids)
        order = np.argsort(ids_flat, kind="stable")
        sorted_ids = ids_flat[order]
        if len(sorted_ids) > 1 and (sorted_ids[1:] == sorted_ids[:-1]).any():
            if info.spill_s <= 0:
                raise RuntimeError("duplicate item ids in the index; cannot compact")
            # spill-built index: replicas of one id are expected; keep the
            # first physical occurrence (they are bitwise-identical rows).
            # The rebuild below re-derives fresh replicas from spill_s.
            keep = np.r_[True, sorted_ids[1:] != sorted_ids[:-1]]
            order = order[keep]
            sorted_ids = sorted_ids[keep]
        n_logical = len(sorted_ids)
        mm = np.memmap(raw, dtype=dt, mode="r", shape=(n_live, info.dim))

        def canonical_chunks():
            # live items in ascending-id order, O(chunk) resident
            for lo in range(0, n_logical, 8192):
                sel = order[lo : lo + 8192]
                yield np.asarray(mm[sel], np.float32), sorted_ids[lo : lo + 8192]

        cfg = ECPBuildConfig(
            levels=L,
            metric=info.metric,
            cluster_cap=info.cluster_cap,
            storage_dtype=info.dtype,
            seed=info.seed,
            insert_batch=info.insert_batch,  # replay the build's exact
            # assignment batching: jit'd argmin results must not shift
            spill_s=info.spill_s,
            spill_eps=info.spill_eps,
        )
        gen = info.generation + 1
        if getattr(store, "fstore", None) is not None:
            # ---- in place through the Store protocol
            build_index_streaming(canonical_chunks, cfg=cfg, store=store,
                                  generation=gen, next_id=info.next_id)
            new_info = layout.IndexInfo.from_attrs(store.read_attrs(layout.INFO))
            for lv in range(1, L + 1):
                for nd in range(new_info.nodes_per_level[lv - 1], old_npl[lv - 1]):
                    store.free_slot(lv, nd)
            # saved query states reference the old node numbering
            if store.exists("query_states"):
                store.delete("query_states")
        else:
            # ---- blob: rebuild a scratch hierarchy, convert, atomic swap
            blob_path = Path(store.path)
            if not index._owns_store:
                raise ValueError(
                    "blob compaction replaces the file and must reopen it; "
                    "open the index from a path (not a Store object) to compact"
                )
            scratch = Path(td) / "rebuild"
            tmp_store = build_index_streaming(canonical_chunks, str(scratch), cfg=cfg,
                                              generation=gen, next_id=info.next_id)
            page = getattr(store, "page_size", 4096)
            with tempfile.TemporaryDirectory(dir=blob_path.parent) as swap_td:
                tmp_blob = convert(tmp_store, Path(swap_td) / BLOB_FILENAME, page_size=page,
                                   quant=getattr(store, "quant_format", None))
                os.replace(tmp_blob, blob_path)
            new_info = layout.IndexInfo.from_attrs(tmp_store.read_attrs(layout.INFO))
            index._reload_store()

    index._apply_mutation(new_info, (), tombstones=set(), structural=True)
    return {
        "live": n_logical,
        "purged": n_scanned - n_live,
        "leaves": new_info.nodes_per_level[-1],
        "generation": new_info.generation,
    }
