"""Flat-array traversal structures for the file-mode search engine.

The paper's Algorithm 3 keeps two per-query collections that used to be
plain Python objects — a ``heapq`` of ``(d, tie, is_leaf, level, node)``
tuples and an unbounded ``[(d, item_id)]`` list fully re-sorted on every
increment.  At benchmark scale most of eCP-FS's measured latency was this
interpreter overhead, not file I/O.  This module replaces both with flat
numpy columns and batch operations while preserving the *exact* ordering
semantics of the tuple code (ties included), so results stay bit-identical:

``Frontier``
    The priority queue T.  Entries live in preallocated, growable
    ``float32``/``int32`` columns (``d``/``tie``/``leaf``/``level``/
    ``node``).  A whole node expansion is pushed in ONE call
    (``push_batch``): the batch is stably argsorted by distance — which,
    because ties are assigned in insertion order, equals sorting by
    ``(d, tie)`` — and appended to the arena as a sorted run.  Pops merge
    the runs through a tiny ``heapq`` of run heads keyed by ``(d, tie)``;
    the global pop order is therefore exactly the tuple heap's
    ``(d, tie)`` lexicographic order, at one heap operation per *node*
    expansion batch instead of one per child.

``CandidateBuffer``
    The result list I.  Scanned leaf items are appended as whole arrays
    (``stage``); ``commit()`` performs one C-level stable argsort over
    ``[sorted live region + staged batches]`` — the exact permutation the
    old code produced by list-append + repeated stable ``list.sort``.
    Emission advances a start offset instead of reslicing the list.

Both structures serialize back to the on-disk query-state schema of
paper §6.2 (``export_*``), so ``Query.save()``/``load_query`` and
``next(k)`` continuation are unchanged.
"""
from __future__ import annotations

import heapq

import numpy as np

__all__ = ["Frontier", "CandidateBuffer"]


class Frontier:
    """Flat-array priority queue over index-tree nodes.

    Pop order is lexicographic ``(d, tie)`` where ``tie`` is the global
    insertion counter — bit-identical to a ``heapq`` of
    ``(d, tie, is_leaf, level, node)`` tuples (``tie`` is unique, so the
    remaining tuple fields never participate in comparisons).
    """

    __slots__ = ("d", "tie", "leaf", "level", "node", "size", "_heads", "_n", "_next_tie")

    def __init__(self, capacity: int = 256):
        capacity = max(1, int(capacity))
        self.d = np.empty(capacity, np.float32)
        self.tie = np.empty(capacity, np.int64)
        self.leaf = np.empty(capacity, np.uint8)
        self.level = np.empty(capacity, np.int32)
        self.node = np.empty(capacity, np.int32)
        self.size = 0          # arena watermark (includes consumed rows)
        self._heads = []       # heapq of (d, tie, pos, end): sorted-run heads
        self._n = 0            # live (un-popped) entries
        self._next_tie = 0

    def __len__(self) -> int:
        return self._n

    def __bool__(self) -> bool:
        return self._n > 0

    # ------------------------------------------------------------------ grow
    def _ensure(self, extra: int) -> None:
        need = self.size + extra
        cap = len(self.d)
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        for name in ("d", "tie", "leaf", "level", "node"):
            old = getattr(self, name)
            new = np.empty(cap, old.dtype)
            new[: self.size] = old[: self.size]
            setattr(self, name, new)

    # ------------------------------------------------------------------ push
    def push_batch(self, d, nodes, is_leaf, level) -> None:
        """Push one expansion batch: ``d[i]`` is the distance of child
        ``nodes[i]``; ties are assigned in ``nodes`` order (exactly the old
        per-child ``heappush`` loop).  ``is_leaf``/``level`` are scalars for
        a node expansion or per-entry arrays (state rehydration)."""
        d = np.asarray(d, np.float32)
        w = len(d)
        if w == 0:
            return
        self._ensure(w)
        # stable sort by d == sort by (d, tie): ties keep insertion order
        order = np.argsort(d, kind="stable")
        s, e = self.size, self.size + w
        self.d[s:e] = d[order]
        self.tie[s:e] = self._next_tie + order
        nodes = np.asarray(nodes)
        self.node[s:e] = nodes[order]
        if np.ndim(is_leaf) == 0:
            self.leaf[s:e] = 1 if is_leaf else 0
        else:
            self.leaf[s:e] = np.asarray(is_leaf, np.uint8)[order]
        if np.ndim(level) == 0:
            self.level[s:e] = int(level)
        else:
            self.level[s:e] = np.asarray(level, np.int32)[order]
        self._next_tie += w
        self.size = e
        self._n += w
        heapq.heappush(self._heads, (float(self.d[s]), int(self.tie[s]), s, e))

    # ------------------------------------------------------------------- pop
    def pop(self) -> tuple[float, int, int, int]:
        """Pop the globally best entry -> ``(d, is_leaf, level, node)``."""
        if not self._n:
            raise IndexError("pop from an empty Frontier")
        d0, _, pos, end = heapq.heappop(self._heads)
        out = (d0, int(self.leaf[pos]), int(self.level[pos]), int(self.node[pos]))
        nxt = pos + 1
        if nxt < end:
            heapq.heappush(
                self._heads, (float(self.d[nxt]), int(self.tie[nxt]), nxt, end)
            )
        self._n -= 1
        return out

    def peek(self) -> tuple[float, int, int, int]:
        if not self._n:
            raise IndexError("peek on an empty Frontier")
        d0, _, pos, _ = self._heads[0]
        return (d0, int(self.leaf[pos]), int(self.level[pos]), int(self.node[pos]))

    # ----------------------------------------------------------- persistence
    def export_rows(self) -> np.ndarray:
        """Live entries as the saved-frontier array ``[n, 4]`` float64 of
        ``(d, is_leaf, level, node)`` — the §6.2 on-disk schema (row order
        is not significant; rehydration re-sorts by distance)."""
        rows = np.zeros((self._n, 4), np.float64)
        at = 0
        for _, _, pos, end in sorted(self._heads, key=lambda h: h[2]):
            m = end - pos
            rows[at : at + m, 0] = self.d[pos:end]
            rows[at : at + m, 1] = self.leaf[pos:end]
            rows[at : at + m, 2] = self.level[pos:end]
            rows[at : at + m, 3] = self.node[pos:end]
            at += m
        return rows

    @classmethod
    def from_rows(cls, rows: np.ndarray) -> "Frontier":
        """Rehydrate a saved frontier.  All rows enter as one batch with
        ties in file order — the same order the old loader's sequential
        ``heappush`` produced."""
        f = cls(capacity=max(1, len(rows)))
        if len(rows):
            f.push_batch(
                rows[:, 0],
                rows[:, 3].astype(np.int32),
                rows[:, 1].astype(np.uint8),
                rows[:, 2].astype(np.int32),
            )
        return f


class CandidateBuffer:
    """Sorted candidate items (the paper's I) as flat numpy columns.

    ``stage(d, ids)`` parks scanned-leaf arrays without per-item work;
    ``commit()`` merges them into the sorted live region with one stable
    argsort — the exact order of the old list-append + stable ``sort``:
    by distance, ties by scan order, previously-merged items first.
    ``take(k)`` emits the k best by advancing a start offset.

    ``dedup=True`` (spill-built indexes, where a vector may be replicated
    into several leaves) drops every staged id that was already committed
    or emitted, keeping the first occurrence, so ``take``/``next(k)``
    never yields an id twice.  Replica distances are bitwise identical —
    each distance is a dot product over that row's bytes alone — so which
    copy survives does not affect the emitted (d, id) values.
    """

    __slots__ = ("d", "i", "start", "_staged_d", "_staged_i", "_staged_n", "dedup", "_seen")

    def __init__(self, dedup: bool = False):
        self.d = np.empty(0, np.float32)
        self.i = np.empty(0, np.int64)
        self.start = 0
        self._staged_d: list[np.ndarray] = []
        self._staged_i: list[np.ndarray] = []
        self._staged_n = 0
        self.dedup = bool(dedup)
        self._seen: set[int] = set()

    def __len__(self) -> int:
        return (len(self.d) - self.start) + self._staged_n

    def stage(self, d: np.ndarray, ids: np.ndarray) -> None:
        """Park one scanned leaf's (already filtered) items for the next
        ``commit``; ``d``/``ids`` arrive in within-leaf scan order."""
        if len(d) == 0:
            return
        self._staged_d.append(np.asarray(d, np.float32))
        self._staged_i.append(np.asarray(ids, np.int64))
        self._staged_n += len(d)

    def commit(self) -> None:
        """Merge staged batches into the sorted live region (one stable
        argsort, C speed — replaces the old full ``list.sort`` per
        increment)."""
        if not self._staged_n:
            return
        if self.dedup:
            self._drop_seen()
            if not self._staged_n:
                return
        live_d = self.d[self.start :]
        live_i = self.i[self.start :]
        all_d = np.concatenate([live_d, *self._staged_d])
        all_i = np.concatenate([live_i, *self._staged_i])
        order = np.argsort(all_d, kind="stable")
        self.d = all_d[order]
        self.i = all_i[order]
        self.start = 0
        self._staged_d.clear()
        self._staged_i.clear()
        self._staged_n = 0

    def _drop_seen(self) -> None:
        """Filter staged batches against every id already committed (live
        or emitted), first occurrence wins; batches stay in scan order."""
        seen = self._seen
        kept_d: list[np.ndarray] = []
        kept_i: list[np.ndarray] = []
        n = 0
        for d_b, i_b in zip(self._staged_d, self._staged_i):
            keep = np.ones(len(i_b), bool)
            for p, x in enumerate(i_b):
                xi = int(x)
                if xi in seen:
                    keep[p] = False
                else:
                    seen.add(xi)
            if not keep.all():
                d_b, i_b = d_b[keep], i_b[keep]
            if len(i_b):
                kept_d.append(d_b)
                kept_i.append(i_b)
                n += len(i_b)
        self._staged_d = kept_d
        self._staged_i = kept_i
        self._staged_n = n

    def seed_seen(self, ids) -> None:
        """Mark ``ids`` as already seen (query-state rehydration)."""
        self._seen.update(int(x) for x in np.asarray(ids).ravel())

    def export_seen(self) -> np.ndarray:
        """The seen-id set as a sorted int64 array (persistence)."""
        return np.asarray(sorted(self._seen), np.int64)

    def take(self, k: int) -> tuple[np.ndarray, np.ndarray]:
        """Emit (and consume) the best ``k`` committed items."""
        n = min(k, len(self.d) - self.start)
        n = max(n, 0)
        s = self.start
        self.start = s + n
        return self.d[s : s + n], self.i[s : s + n]

    # ----------------------------------------------------------- persistence
    def export_items(self) -> tuple[np.ndarray, np.ndarray]:
        """Remaining committed items (the saved ``item_dists``/``item_ids``
        arrays).  Call ``commit()`` first if anything is staged."""
        if self._staged_n:
            self.commit()
        return self.d[self.start :].copy(), self.i[self.start :].copy()

    @classmethod
    def from_items(cls, d: np.ndarray, ids: np.ndarray) -> "CandidateBuffer":
        buf = cls()
        buf.d = np.asarray(d, np.float32).copy()
        buf.i = np.asarray(ids, np.int64).copy()
        return buf
