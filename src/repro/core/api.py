"""Unified retrieval API — the one seam every searcher implements.

The paper's headline scenario is many ANN indexes co-located under a tight
memory budget (§1, §6.1).  Before this module each searcher had its own
call shape: ``ECPIndex`` handed out raw int query ids into an append-only
``QS`` list, ``BatchedSearcher`` threaded ``(q, state)`` tuples by hand,
and the baselines returned bare ``(dists, ids)`` tuples.  This module
defines the single shape all of them speak:

  * ``Searcher``   — protocol: ``search(q, k, *, b) -> ResultSet``.  ``q``
    is one vector ``[D]`` or a batch ``[B, D]``; ``b`` is the generic
    search-effort knob (eCP expansion b, IVF nprobe, HNSW ef, Vamana
    complexity, batched leaf-scan width).
  * ``ResultSet``  — ``dists``/``ids`` numpy arrays (``[k]`` for a single
    query, ``[B, k]`` for a batch; short result lists are padded with
    ``+inf``/``-1``), per-query ``SearchStats``, and the ``Query`` handle
    that owns any incremental state.
  * ``Query``      — handle with ``.next(k)`` (more results), ``.save()``
    (persist the frontier into the index's own file structure, eCP-FS
    only), and ``.close()``; a closed handle raises ``QueryClosedError``
    instead of the old silent ``None``-hole crash.
  * ``RestartQuery`` — the continuation for searchers without native
    incremental state: ``.next(k)`` re-searches with ``emitted + k`` and
    returns the tail (the paper's restart protocol for IVF/HNSW/DiskANN).

On top of the protocol:

  * ``open_index(path, mode="file"|"packed"|"auto")`` — factory returning
    the file-structure searcher (``ECPIndex``) or the device-resident one
    (``BatchedSearcher``).
  * ``MultiIndexSession`` — N indexes under ONE shared byte-budget
    ``NodeCache``: a global LRU across indexes, runtime-resizable (the
    paper's §4.2 knob made fleet-wide).

``NodeCache`` and ``SearchStats`` live here (not in search.py) because the
cache is shared infrastructure: the session layer budgets it in bytes
across indexes, each ``ECPIndex`` namespaces its keys into it.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import numpy as np

from .store import IOStats, open_store

__all__ = [
    "SearchStats",
    "IOStats",
    "NodeCache",
    "ResultSet",
    "Query",
    "QueryClosedError",
    "StaleQueryError",
    "RestartQuery",
    "Searcher",
    "MutableIndex",
    "open_index",
    "MultiIndexSession",
]

_UNSET = object()


class QueryClosedError(RuntimeError):
    """Raised when ``next``/``save`` is called on a closed Query handle."""


class StaleQueryError(RuntimeError):
    """Raised when a Query handle outlives a structural rewrite of its
    index (``compact()`` renumbers nodes, so a saved frontier no longer
    means anything).  Inserts and deletes do NOT stale a handle — they
    are append/tombstone-only."""


@dataclass
class SearchStats:
    node_loads: int = 0            # disk reads (cache misses served from the store);
                                   # in batch mode a row counts the misses IT demanded
                                   # (solo-equivalent) — actual deduped loads live in
                                   # the handle's batch_stats
    nodes_opened: int = 0          # total nodes popped from T
    leaves_opened: int = 0
    distance_calcs: int = 0        # individual distance computations
    increments: int = 0            # b-doublings
    rounds: int = 0                # lockstep batch rounds participated in (batch mode)
    dedup_hits: int = 0            # node demands served by a load another query in the
                                   # same round triggered (cross-query fetch dedup)
    kernel_launches: int = 0       # grouped device top-k launches (quantized scan);
                                   # exactly one per traversal round that scanned leaves
    io: IOStats = field(default_factory=IOStats)  # bytes/files/reads at the store;
                                   # zero per-row in batch mode (coalesced reads have
                                   # no per-row attribution; see batch_stats.io)


# --------------------------------------------------------------------- cache
class NodeCache:
    """LRU cache over node payloads ``key -> (embeddings f32, ids)``.

    Two independent budgets, both tunable at runtime (paper §4.2):
      ``max_nodes``:  None = unbounded; 0 = caching off; n > 0 = at most n
                      resident nodes.
      ``max_bytes``:  None = unbounded; 0 = caching off; n > 0 = resident
                      node data (embeddings + ids) capped at n bytes — the
                      fleet-wide knob ``MultiIndexSession`` shares across
                      indexes.

    Keys are opaque tuples whose FIRST element is a namespace tag, so
    several indexes can share one cache without collisions; eviction is
    globally LRU across all of them.  ``ECPIndex`` keys entries as
    ``(namespace, epoch, node_version, level, node)`` — the snapshot-aware
    schema of the serving subsystem: an in-place node rewrite bumps the
    node's version and a compaction bumps the epoch, so a pinned
    ``ECPSnapshot`` (which froze the old epoch/version map) and the live
    index can share this cache while never resolving each other's bytes.

    Values are either a ``(embeddings, ids)`` node payload, a bare array
    (leaf-ids side entries of the quantized scan), or any object with an
    ``nbytes`` attribute (``QuantNode`` companion blocks).

    ``pin(key, value)`` inserts an entry EXEMPT from LRU eviction, under
    its own ``pinned_max_bytes`` budget slice (separate from
    ``max_bytes``): ``ECPIndex(pin_internal=True)`` parks the internal
    tree levels there so leaf churn can never evict the navigation
    structure.  Pinned entries still honor ``invalidate`` /
    ``invalidate_namespace`` / ``clear``, so mutations behave as before.
    """

    @staticmethod
    def _norm_budget(v):
        """None = unbounded; any budget <= 0 means caching off."""
        if v is None:
            return None
        return max(0, int(v))

    def __init__(
        self,
        max_nodes: int | None = None,
        *,
        max_bytes: int | None = None,
        pinned_max_bytes: int | None = None,
    ):
        self.max_nodes = self._norm_budget(max_nodes)
        self.max_bytes = self._norm_budget(max_bytes)
        self.pinned_max_bytes = self._norm_budget(pinned_max_bytes)
        self._d: OrderedDict[tuple, tuple[np.ndarray, np.ndarray]] = OrderedDict()
        self._nbytes = 0
        self._pinned: dict = {}
        self._pinned_nbytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def _entry_bytes(value) -> int:
        nb = getattr(value, "nbytes", None)
        if nb is not None:
            return int(nb)
        return int(sum(a.nbytes for a in value))

    def resize(self, max_nodes=_UNSET, *, max_bytes=_UNSET) -> None:
        """Change either budget live; evicts immediately if shrinking."""
        with self._lock:
            if max_nodes is not _UNSET:
                self.max_nodes = self._norm_budget(max_nodes)
            if max_bytes is not _UNSET:
                self.max_bytes = self._norm_budget(max_bytes)
            self._evict_locked()

    def _evict_locked(self) -> None:
        def over() -> bool:
            if self.max_nodes is not None and len(self._d) > self.max_nodes:
                return True
            if self.max_bytes is not None and self._nbytes > self.max_bytes:
                return True
            return False

        while self._d and over():
            _, v = self._d.popitem(last=False)
            self._nbytes -= self._entry_bytes(v)
            self.evictions += 1

    def contains(self, key) -> bool:
        """Membership probe that does NOT touch LRU order or hit/miss stats
        (used by prefetch heuristics to skip already-resident nodes)."""
        with self._lock:
            return key in self._d or key in self._pinned

    def invalidate(self, key) -> bool:
        """Drop one entry (a node that was rewritten on disk); returns
        whether it was resident."""
        with self._lock:
            v = self._pinned.pop(key, None)
            if v is not None:
                self._pinned_nbytes -= self._entry_bytes(v)
                return True
            v = self._d.pop(key, None)
            if v is None:
                return False
            self._nbytes -= self._entry_bytes(v)
            return True

    def invalidate_namespace(self, ns) -> int:
        """Drop every entry of one index's namespace (compaction rewrote
        its whole tree); returns the number of entries dropped."""
        with self._lock:
            stale = [k for k in self._d if k[0] == ns]
            for k in stale:
                self._nbytes -= self._entry_bytes(self._d.pop(k))
            pstale = [k for k in self._pinned if k[0] == ns]
            for k in pstale:
                self._pinned_nbytes -= self._entry_bytes(self._pinned.pop(k))
            return len(stale) + len(pstale)

    def get(self, key):
        with self._lock:
            v = self._pinned.get(key)
            if v is not None:
                self.hits += 1
                return v
            v = self._d.get(key)
            if v is not None:
                self._d.move_to_end(key)
                self.hits += 1
            else:
                self.misses += 1
            return v

    def pin(self, key, value) -> bool:
        """Insert an entry exempt from LRU eviction, accounted against the
        dedicated ``pinned_max_bytes`` slice (None = unbounded).  Returns
        False — after falling back to a normal ``put`` — when the slice is
        full, so callers degrade gracefully instead of overcommitting."""
        nb = self._entry_bytes(value)
        with self._lock:
            old = self._pinned.pop(key, None)
            if old is not None:
                self._pinned_nbytes -= self._entry_bytes(old)
            if (
                self.pinned_max_bytes is None
                or self._pinned_nbytes + nb <= self.pinned_max_bytes
            ):
                lru = self._d.pop(key, None)
                if lru is not None:
                    self._nbytes -= self._entry_bytes(lru)
                self._pinned[key] = value
                self._pinned_nbytes += nb
                return True
        self.put(key, value)
        return False

    def put(self, key, value) -> None:
        if self.max_nodes == 0 or self.max_bytes == 0:
            return
        with self._lock:
            if key in self._pinned:  # pinned copy is authoritative: refresh it
                self._pinned_nbytes -= self._entry_bytes(self._pinned[key])
                self._pinned[key] = value
                self._pinned_nbytes += self._entry_bytes(value)
                return
            old = self._d.pop(key, None)
            if old is not None:
                self._nbytes -= self._entry_bytes(old)
            self._d[key] = value
            self._nbytes += self._entry_bytes(value)
            self._evict_locked()

    @property
    def n_resident(self) -> int:
        return len(self._d) + len(self._pinned)

    @property
    def n_pinned(self) -> int:
        return len(self._pinned)

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return self._nbytes + self._pinned_nbytes

    @property
    def pinned_bytes(self) -> int:
        with self._lock:
            return self._pinned_nbytes

    def namespace_stats(self) -> dict:
        """Per-namespace (resident nodes, resident bytes) breakdown."""
        with self._lock:
            out: dict = {}
            for d in (self._pinned, self._d):
                for key, v in d.items():
                    ns = key[0]
                    n, b = out.get(ns, (0, 0))
                    out[ns] = (n + 1, b + self._entry_bytes(v))
            return out

    def clear(self) -> None:
        with self._lock:
            self._d.clear()
            self._nbytes = 0
            self._pinned.clear()
            self._pinned_nbytes = 0


# ------------------------------------------------------------------ results
def pack_rows(
    dists_rows: list, ids_rows: list, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Pad per-query result lists to rectangular [B, k] (+inf / -1 pads)."""
    B = len(dists_rows)
    d = np.full((B, k), np.inf, np.float32)
    i = np.full((B, k), -1, np.int64)
    for r, (dr, ir) in enumerate(zip(dists_rows, ids_rows)):
        n = min(len(ir), k)
        if n:
            d[r, :n] = np.asarray(dr[:n], np.float32)
            i[r, :n] = np.asarray(ir[:n], np.int64)
    return d, i


@dataclass
class ResultSet:
    """One emission of search results.

    ``dists``/``ids`` are ``[k]`` for a single-vector query and ``[B, k]``
    for a batch; rows with fewer than k hits are padded with ``+inf``/-1.
    ``stats`` is one ``SearchStats`` (single) or a list (batch); searchers
    without meaningful counters may leave it None.  ``query`` is the handle
    owning the incremental state — call ``.next(k)`` on it for more.
    """

    dists: np.ndarray
    ids: np.ndarray
    stats: SearchStats | list | None = None
    query: "Query | None" = None

    @property
    def batched(self) -> bool:
        return self.ids.ndim == 2

    def pairs(self) -> list[tuple[float, int]]:
        """Valid (dist, id) pairs of a single-query result, pads dropped."""
        if self.batched:
            raise ValueError("pairs() is for single-query results; index rows instead")
        return [(float(d), int(i)) for d, i in zip(self.dists, self.ids) if i >= 0]

    def row_ids(self, r: int) -> list[int]:
        if not self.batched and r != 0:
            raise IndexError(f"single-query ResultSet has only row 0, got {r}")
        ids = self.ids[r] if self.batched else self.ids
        return [int(i) for i in ids if i >= 0]

    def __len__(self) -> int:
        if self.batched:
            return int(self.ids.shape[0])
        return int((self.ids >= 0).sum())


# ------------------------------------------------------------------ queries
class Query:
    """Handle owning the incremental state of one ``search`` call."""

    _closed = False

    @property
    def closed(self) -> bool:
        return self._closed

    def _ensure_open(self) -> None:
        if self._closed:
            raise QueryClosedError(f"{type(self).__name__} is closed")

    def next(self, k: int) -> ResultSet:
        raise NotImplementedError

    def save(self, name: str | None = None) -> str:
        raise NotImplementedError(
            f"{type(self).__name__} has no persistent form; only file-structure "
            "(eCP-FS) queries support save()"
        )

    def close(self) -> None:
        self._closed = True


class RestartQuery(Query):
    """Continuation for searchers with no native incremental state.

    ``next(k)`` re-runs the underlying search asking for ``emitted + k``
    results and returns the tail — the paper's restart protocol for
    IVF / HNSW / DiskANN in the incremental workload (§5, Table 4).
    """

    def __init__(self, searcher: "Searcher", q: np.ndarray, k: int, *, b=None, opts: dict | None = None):
        self._searcher = searcher
        self._q = np.asarray(q)
        self._b = b
        self._opts = dict(opts or {})
        self._emitted = k

    def next(self, k: int) -> ResultSet:
        self._ensure_open()
        want = self._emitted + k
        rs = self._searcher.search(self._q, want, b=self._b, **self._opts)
        lo = self._emitted
        self._emitted = want
        if rs.batched:
            d, i = rs.dists[:, lo:want], rs.ids[:, lo:want]
        else:
            d, i = rs.dists[lo:want], rs.ids[lo:want]
        # re-pad to exactly k
        if i.shape[-1] < k:
            pad = k - i.shape[-1]
            pd = np.full(i.shape[:-1] + (pad,), np.inf, np.float32)
            pi = np.full(i.shape[:-1] + (pad,), -1, np.int64)
            d = np.concatenate([d, pd], axis=-1)
            i = np.concatenate([i, pi], axis=-1)
        return ResultSet(dists=d, ids=i, stats=rs.stats, query=self)


# ----------------------------------------------------------------- protocol
@runtime_checkable
class Searcher(Protocol):
    """Anything that answers k-NN queries through the unified shape."""

    def search(self, q, k: int = 100, *, b=None, **opts) -> ResultSet:
        ...


@runtime_checkable
class MutableIndex(Protocol):
    """A searcher whose index mutates while serving (core/lifecycle.py):
    ``insert`` appends + splits leaves, ``delete`` tombstones, ``compact``
    rewrites the tree to equal a fresh build of the live collection."""

    def search(self, q, k: int = 100, *, b=None, **opts) -> ResultSet:
        ...

    def insert(self, vectors, ids=None) -> dict:
        ...

    def delete(self, ids) -> int:
        ...

    def compact(self) -> dict:
        ...


# ------------------------------------------------------------------ factory
def open_index(
    path,
    mode: str = "auto",
    *,
    backend: str = "auto",
    prefetch: bool = False,
    cache: NodeCache | None = None,
    namespace: str | None = None,
    cache_max_nodes: int | None = None,
    cache_max_bytes: int | None = None,
    **kw,
) -> Searcher:
    """Open an eCP index as a ``Searcher``.

    mode="file"    -> ``ECPIndex``: lazy node loading, LRU cache, true
                      incremental search (the paper's mode).
    mode="packed"  -> ``BatchedSearcher``: whole hierarchy packed onto the
                      device for level-synchronous batched search.
    mode="auto"    -> "packed" when a non-CPU jax backend is available,
                      else "file".

    ``backend`` picks the node storage under either mode (core/store.py):
    "fstore" (the zarr-v2 hierarchy), "blob" (page-aligned single file),
    or "auto" (blob when ``path`` is/contains a blob, else fstore).
    ``prefetch=True`` wraps the store with async frontier prefetching
    (file mode only).

    Extra keywords flow to the opened class; notably ``probe_m=<m>``
    (file mode and federations) sets the default multi-probe width —
    how many frontier nodes each traversal step descends through.
    ``probe_m=1`` is the paper's strict best-first traversal and is
    bit-identical to it; larger values trade extra leaf reads for
    recall.  Per-call override: ``search(..., probe_m=m)``.

    A path holding a federation manifest (``federation.json``) opens as a
    ``FederatedIndex`` — one logical index scatter-gathering over its
    shards (core/federation.py); it is file-mode only.
    """
    if isinstance(path, (str, os.PathLike)):
        from .federation import FederatedIndex, find_manifest

        if find_manifest(path) is not None:
            if mode not in ("auto", "file"):
                raise ValueError(
                    f"a federated index only supports mode='file', got {mode!r}"
                )
            return FederatedIndex(
                path,
                backend=backend,
                prefetch=prefetch,
                cache=cache,
                namespace=namespace,
                cache_max_nodes=cache_max_nodes,
                cache_max_bytes=cache_max_bytes,
                **kw,
            )
    wants_cache = (
        cache is not None
        or namespace is not None
        or cache_max_nodes is not None
        or cache_max_bytes is not None
    )
    wants_prefetch = prefetch or backend.endswith("+prefetch")
    if mode == "auto":
        if wants_cache or wants_prefetch:
            mode = "file"  # cache budgets / prefetch are file-mode requests
        else:
            import jax

            mode = "packed" if jax.default_backend() != "cpu" else "file"
    if mode == "file":
        from .search import ECPIndex

        return ECPIndex(
            path,
            backend=backend,
            prefetch=prefetch,
            cache=cache,
            namespace=namespace,
            cache_max_nodes=cache_max_nodes,
            cache_max_bytes=cache_max_bytes,
            **kw,
        )
    if mode == "packed":
        if wants_cache or wants_prefetch:
            raise ValueError(
                "packed mode loads the whole hierarchy onto the device; "
                "cache/namespace/cache_max_*/prefetch only apply to mode='file'"
            )
        from .batched import BatchedSearcher
        from .packed import load_packed

        return BatchedSearcher(load_packed(open_store(path, backend=backend)), **kw)
    raise ValueError(f"unknown open_index mode: {mode!r} (file|packed|auto)")


# ------------------------------------------------------------------ session
class MultiIndexSession:
    """N indexes under one shared byte-budget node cache (paper §1, §6.1).

    Every index opened through the session shares a single globally-LRU
    ``NodeCache`` budgeted in bytes; a node loaded for any index can evict
    the coldest node of any other.  The budget is runtime-resizable —
    the paper's "limit changeable at run-time" made fleet-wide.

        sess = MultiIndexSession(cache_bytes=8 << 20)
        lifelog = sess.open("/idx/lifelog")
        docs = sess.open("/idx/docs")
        rs = lifelog.search(q, k=10, b=8)
        sess.resize(cache_bytes=2 << 20)     # shrink the whole fleet live
    """

    def __init__(
        self,
        *,
        cache_bytes: int | None = None,
        cache_nodes: int | None = None,
    ):
        self.cache = NodeCache(cache_nodes, max_bytes=cache_bytes)
        self._indexes: dict[str, Searcher] = {}

    def open(self, path, name: str | None = None, *, mode: str = "file", **kw) -> Searcher:
        """Open an index under the shared cache and register it by name."""
        if name is None:
            name = str(path).rstrip("/").rsplit("/", 1)[-1]
        if name in self._indexes:
            raise ValueError(f"index name already open in session: {name!r}")
        if mode == "file":
            s = open_index(path, mode="file", cache=self.cache, namespace=name, **kw)
        else:
            # packed/auto indexes are device-resident; they do not draw from
            # the shared node budget but stay addressable via the session.
            s = open_index(path, mode=mode, **kw)
        self._indexes[name] = s
        return s

    def __getitem__(self, name: str) -> Searcher:
        return self._indexes[name]

    def __contains__(self, name: str) -> bool:
        return name in self._indexes

    def names(self) -> list[str]:
        return list(self._indexes)

    def search(self, name: str, q, k: int = 100, *, b=None, **opts) -> ResultSet:
        return self._indexes[name].search(q, k, b=b, **opts)

    def resize(self, *, cache_bytes=_UNSET, cache_nodes=_UNSET) -> None:
        self.cache.resize(
            cache_nodes if cache_nodes is not _UNSET else _UNSET,
            max_bytes=cache_bytes if cache_bytes is not _UNSET else _UNSET,
        )

    def stats(self) -> dict:
        raw = self.cache.namespace_stats()
        # a federated index registers its shards under "<name>/<shard>"
        # namespaces: roll those up so per_index charges each index for
        # everything it holds
        per: dict = {}
        for ns, (n, b) in raw.items():
            base = ns.split("/", 1)[0]
            pn, pb = per.get(base, (0, 0))
            per[base] = (pn + n, pb + b)
        return {
            "indexes": self.names(),
            "resident_nodes": self.cache.n_resident,
            "resident_bytes": self.cache.resident_bytes,
            "budget_bytes": self.cache.max_bytes,
            "hits": self.cache.hits,
            "misses": self.cache.misses,
            "evictions": self.cache.evictions,
            "per_index": {
                n: {"nodes": per.get(n, (0, 0))[0], "bytes": per.get(n, (0, 0))[1]}
                for n in self._indexes
            },
        }

    def invalidate(self, name: str) -> int:
        """Resynchronize one index whose files changed on disk outside
        this process: refresh its in-memory metadata/root/tombstones when
        the searcher supports it (``ECPIndex.refresh``), and drop its
        cached nodes.  Indexes opened through the session invalidate
        themselves on their own writes — this is for external writers."""
        s = self._indexes.get(name)
        refresh = getattr(s, "refresh", None)
        if refresh is not None:
            refresh()  # includes invalidate_namespace(name)
            return 0
        return self.cache.invalidate_namespace(name)

    def close(self) -> None:
        """Close every index opened through the session (freeing prefetch
        executors and store fds) and drop the shared cache."""
        for s in self._indexes.values():
            close = getattr(s, "close", None)
            if close is not None:
                close()
        self._indexes.clear()
        self.cache.clear()

    def __enter__(self) -> "MultiIndexSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
