"""On-disk layout of the eCP-FS index (paper Fig. 1).

root/
  info                      group; .zattrs holds index metadata
  rep/embeddings            [l, D]  all cluster leaders (representatives)
  rep/item_ids              [l]     dataset ids the leaders came from
  index_root/embeddings     [n_1, D] level-1 node centroids
  index_root/ids            [n_1]    level-1 node indices (0..n_1-1)
  lvl_1/node_<j>/embeddings [n_children, D]  centroids of children at lvl_2
  lvl_1/node_<j>/ids        [n_children]     child node indices at lvl_2
  ...
  lvl_L/node_<j>/embeddings [cluster_n, D]   item embeddings of cluster j
  lvl_L/node_<j>/ids        [cluster_n]      item ids of cluster j

Internal node ids point at nodes of the next level; leaf (lvl_L) ids are
dataset item ids. ``index_root`` plays the role of the single lvl_0 node.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

INFO = "info"
REP = "rep"
ROOT = "index_root"
EMB = "embeddings"
IDS = "ids"
REP_EMB = "rep/embeddings"
REP_IDS = "rep/item_ids"

# Mutation metadata (index lifecycle, core/lifecycle.py).  Both live in the
# ``info`` group's attributes next to the IndexInfo fields so every backend
# (including the single-file blob, whose only attribute store is the header's
# ``info`` dict) carries them:
#   GENERATION   int, bumped by every mutation (insert/delete/compact);
#                readers use it to detect that an index changed under them.
#   DELETED_IDS  sorted list of tombstoned item ids; the search engines
#                filter them during leaf scoring and compact() purges them.
GENERATION = "generation"
DELETED_IDS = "deleted_ids"


def read_tombstones(attrs: dict) -> set:
    """The tombstone set recorded in the ``info`` attributes."""
    return {int(x) for x in attrs.get(DELETED_IDS, [])}


def write_tombstones(attrs: dict, tombstones: set) -> dict:
    """Return ``attrs`` updated with a canonical (sorted) tombstone list."""
    attrs = dict(attrs)
    attrs[DELETED_IDS] = sorted(int(x) for x in tombstones)
    return attrs


def lvl_group(level: int) -> str:
    return f"lvl_{level}"


def node_group(level: int, node: int) -> str:
    return f"lvl_{level}/node_{node:08d}"


def node_emb(level: int, node: int) -> str:
    return f"{node_group(level, node)}/{EMB}"


def node_ids(level: int, node: int) -> str:
    return f"{node_group(level, node)}/{IDS}"


@dataclass(frozen=True)
class IndexInfo:
    """Contents of the ``info`` group's attributes."""

    levels: int              # L: leaves live at lvl_L
    metric: str              # l2 | ip | cosine
    dim: int                 # V (feature dimensionality)
    dtype: str               # storage dtype of embeddings, e.g. "float16"
    n_items: int             # N
    cluster_cap: int         # C/V: target vectors per leaf cluster
    n_leaders: int           # l = ceil(N / cluster_cap)
    fanout: int              # w = ceil(l ** (1/L))
    nodes_per_level: tuple[int, ...] = field(default_factory=tuple)  # n_1..n_L
    seed: int = 0
    version: str = "ecp-fs/1"
    generation: int = 0      # bumped by every mutation (lifecycle.py)
    insert_batch: int = 8192  # build-time assignment batch; compact() replays
                              # it so its rebuild is bit-reproducible
    next_id: int = 0         # smallest never-used item id: default insert ids
                             # allocate from here (monotonic across compact(),
                             # so purged ids are never reissued)
    spill_s: int = 0         # build-time spill: max ADDITIONAL leaf replicas
                             # per vector (0 = single assignment, the default)
    spill_eps: float = 0.0   # spill eligibility band vs the nearest leader:
                             # l2/cosine  d_j <= (1+eps)*d_1 (multiplicative),
                             # ip         d_j <= d_1 + eps   (additive)

    def to_attrs(self) -> dict:
        return {
            "levels": self.levels,
            "metric": self.metric,
            "dim": self.dim,
            "dtype": self.dtype,
            "n_items": self.n_items,
            "cluster_cap": self.cluster_cap,
            "n_leaders": self.n_leaders,
            "fanout": self.fanout,
            "nodes_per_level": list(self.nodes_per_level),
            "seed": self.seed,
            "version": self.version,
            GENERATION: self.generation,
            "insert_batch": self.insert_batch,
            "next_id": self.next_id,
            "spill_s": self.spill_s,
            "spill_eps": self.spill_eps,
        }

    @staticmethod
    def from_attrs(a: dict) -> "IndexInfo":
        return IndexInfo(
            levels=int(a["levels"]),
            metric=str(a["metric"]),
            dim=int(a["dim"]),
            dtype=str(a["dtype"]),
            n_items=int(a["n_items"]),
            cluster_cap=int(a["cluster_cap"]),
            n_leaders=int(a["n_leaders"]),
            fanout=int(a["fanout"]),
            nodes_per_level=tuple(int(x) for x in a.get("nodes_per_level", [])),
            seed=int(a.get("seed", 0)),
            version=str(a.get("version", "ecp-fs/1")),
            generation=int(a.get(GENERATION, 0)),
            insert_batch=int(a.get("insert_batch", 8192)),
            # legacy indexes (no next_id) used default positional ids
            next_id=int(a.get("next_id", a.get("n_items", 0))),
            spill_s=int(a.get("spill_s", 0)),
            spill_eps=float(a.get("spill_eps", 0.0)),
        )


def derive_shape(
    n_items: int, cluster_cap: int, levels: int, *, n_leaders: int | None = None
) -> tuple[int, int, tuple[int, ...]]:
    """Paper §3: l = N·V/C leaders, w = l^(1/L) fanout.

    Returns (n_leaders, fanout, nodes_per_level) where nodes_per_level[i]
    is the node count at lvl_{i+1} (so [-1] == n_leaders).  ``n_leaders``
    overrides the derived leader count (the streaming build's reservoir
    mode, where the collection size is unknown until the stream ends).
    """
    if levels < 1:
        raise ValueError("levels must be >= 1")
    if n_leaders is None:
        n_leaders = max(1, math.ceil(n_items / max(1, cluster_cap)))
    n_leaders = max(1, int(n_leaders))
    fanout = max(1, math.ceil(n_leaders ** (1.0 / levels)))
    nodes = []
    for i in range(1, levels + 1):
        nodes.append(min(n_leaders, fanout**i))
    nodes[-1] = n_leaders
    return n_leaders, fanout, tuple(nodes)
