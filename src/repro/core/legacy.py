"""Reference (pre-vectorization) traversal engine — Python-object hot path.

This is the paper's Algorithms 1-3 exactly as first implemented: a
``heapq`` of ``(d, tie, is_leaf, level, node)`` tuples for T, an unbounded
``[(d, item_id)]`` list for I re-sorted on every increment, and per-item
Python conversions throughout.  The vectorized engine (core/frontier.py +
core/search.py) replaces this as the default, but the reference stays in
the tree for two jobs:

  * **parity oracle** — the vectorized engine must return bit-identical
    ``(dists, ids)``; tests and the ``search-engine`` benchmark scenario
    compare against this implementation (``ECPIndex(engine="legacy")``).
  * **measured baseline** — the benchmark's "legacy-equivalent" row
    quantifies how much of eCP-FS's file-mode latency was interpreter
    overhead rather than file I/O (the paper's central question).

Functions take the ``ECPIndex`` as an explicit parameter (node IO, cache
and prefetch plumbing stay shared); only the per-query state and the
traversal inner loop live here.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field

import numpy as np

from .api import SearchStats
from .distances import np_distances

__all__ = ["LegacyQueryState"]


@dataclass
class LegacyQueryState:
    """Persistent per-query state (paper §4.3): Q.q, Q.T, Q.I."""

    q: np.ndarray
    b: int                                  # configured base leaf budget
    mx_inc: int
    exclude: set = field(default_factory=set)
    T: list = field(default_factory=list)   # heap of (d, tie, is_leaf, level, node)
    I: list = field(default_factory=list)   # sorted [(d, item_id)]
    started: bool = False
    increments: int = 0
    emitted: int = 0
    probe_m: int = 1                        # frontier pops per traversal step
    b_cur: int = 0                          # transient budget: reset to b at the
                                            # start of every increment, doubled
                                            # in place of the old ``qs.b *= 2``
    seen: set = field(default_factory=set)  # ids ever appended to I (spill dedup)
    stats: SearchStats = field(default_factory=SearchStats)
    _tie: "itertools.count" = field(default_factory=itertools.count)


# ----------------------------------------------------------- Algorithm 2
def next_items(index, qs: LegacyQueryState, k: int) -> tuple[list, list]:
    cnt = min(len(qs.I), k)
    if cnt < k and qs.T:
        incremental_search(index, qs, k)
        cnt = min(len(qs.I), k)
    out, qs.I = qs.I[:cnt], qs.I[cnt:]
    qs.emitted += len(out)
    return [x[0] for x in out], [x[1] for x in out]


# ----------------------------------------------------------- Algorithm 3
def incremental_search(index, qs: LegacyQueryState, k: int) -> None:
    info = index.info
    metric = info.metric
    leaf_cnt = 0
    qs.b_cur = qs.b  # each increment starts from the configured budget
    dedup = info.spill_s > 0
    loads_before = index.load_node_count
    io_before = index.store.io.snapshot()

    if not qs.started:
        qs.started = True
        d = np_distances(qs.q, index.root_emb, metric)
        qs.stats.distance_calcs += len(index.root_emb)
        is_leaf = 1 if info.levels == 1 else 0
        for c, dist in zip(index.root_ids, d):
            heapq.heappush(qs.T, (float(dist), next(qs._tie), is_leaf, 1, int(c)))

    # Each step pops a probe group — the top-min(probe_m, |T|) frontier
    # entries taken BEFORE any of them is expanded (children pushed by the
    # group land in the next group, exactly one batch-engine round).
    # Budget/termination checks stay inline per leaf but only break at the
    # group boundary, so a group may stage up to probe_m - 1 leaves past
    # the stopping point — that overshoot is the recall widening.
    # probe_m=1 is today's loop.
    while qs.T:
        stop = False
        group = [
            heapq.heappop(qs.T) for _ in range(min(qs.probe_m, len(qs.T)))
        ]
        for dist, _, is_leaf, level, node in group:
            qs.stats.nodes_opened += 1
            emb, ids = index.get_node(level, node)
            if len(ids) == 0:
                continue
            d = np_distances(qs.q, emb, metric)
            qs.stats.distance_calcs += len(ids)
            if is_leaf:
                qs.stats.leaves_opened += 1
                tomb = index._tombstones  # lifecycle deletes filter at scan time
                for c, cd in zip(ids, d):
                    c = int(c)
                    if c in qs.exclude or c in tomb:
                        continue
                    if dedup:
                        if c in qs.seen:
                            continue
                        qs.seen.add(c)
                    qs.I.append((float(cd), c))
                leaf_cnt += 1
            else:
                next_is_leaf = 1 if (level + 1) == info.levels else 0
                for c, cd in zip(ids, d):
                    heapq.heappush(
                        qs.T, (float(cd), next(qs._tie), next_is_leaf, level + 1, int(c))
                    )
                if index._store_prefetch is not None:
                    order = np.argsort(d)[: index.prefetch_fanout]
                    want = [
                        (level + 1, int(ids[j]))
                        for j in order
                        if not index.cache.contains(index._key(level + 1, int(ids[j])))
                    ]
                    if want:
                        index._store_prefetch(want, on_node=index._on_prefetched)
            if is_leaf and leaf_cnt >= qs.b_cur:
                if len(qs.I) >= k:
                    stop = True
                elif qs.mx_inc == -1 or qs.increments < qs.mx_inc:
                    qs.increments += 1
                    qs.stats.increments += 1
                    qs.b_cur *= 2
                else:
                    stop = True
        if stop:
            break
    qs.stats.node_loads += index.load_node_count - loads_before
    qs.stats.io.add(index.store.io.delta(io_before))
    qs.I.sort(key=lambda t: t[0])


# -------------------------------------------------------------- persistence
def export_state(qs: LegacyQueryState) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(item_dists, item_ids, frontier_rows) in the §6.2 on-disk schema."""
    if qs.I:
        d = np.asarray([x[0] for x in qs.I], np.float32)
        i = np.asarray([x[1] for x in qs.I], np.int64)
    else:
        d = np.zeros((0,), np.float32)
        i = np.zeros((0,), np.int64)
    if qs.T:
        t = np.asarray([(e[0], e[2], e[3], e[4]) for e in qs.T], np.float64)
    else:
        t = np.zeros((0, 4), np.float64)
    return d, i, t


def load_state(
    q: np.ndarray,
    attrs: dict,
    item_d: np.ndarray,
    item_i: np.ndarray,
    frontier_rows: np.ndarray,
    seen_ids: np.ndarray | None = None,
) -> LegacyQueryState:
    qs = LegacyQueryState(
        q=q,
        b=int(attrs["b"]),
        mx_inc=int(attrs["mx_inc"]),
        exclude=set(attrs.get("exclude", [])),
        probe_m=int(attrs.get("probe_m", 1)),
    )
    qs.increments = int(attrs["increments"])
    qs.emitted = int(attrs["emitted"])
    qs.started = bool(attrs["started"])
    qs.I = [(float(x), int(y)) for x, y in zip(item_d, item_i)]
    if seen_ids is not None:
        qs.seen = {int(x) for x in seen_ids}
    for row in frontier_rows:
        heapq.heappush(
            qs.T, (float(row[0]), next(qs._tie), int(row[1]), int(row[2]), int(row[3]))
        )
    return qs
