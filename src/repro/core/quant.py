"""Scalar quantization for leaf blocks — the compressed-scan side of the
device-resident scoring pipeline (blob format v3).

Two tiers, chosen per blob at ``convert(..., quant=...)`` time:

  ``int8``     per-node affine quantization: one (scale, offset) pair for
               the whole node, codes in [-127, 127].  4x smaller than
               float32 rows, ~2.25x smaller than the f16+ids rows the
               full-precision block stores at dim=32.
  ``float16``  a lossless-ish middle tier: codes are the rows cast to
               f16.  When the index's storage dtype already is float16
               (the default), decode is bit-exact and the reconstruction
               radius is 0 — the quantized scan IS the fp scan.

The engine never trusts decoded distances: every scanned row carries a
reconstruction radius ``r`` (max L2 error between the decoded row and the
stored full-precision row), from which ``distance_bounds`` derives sound
lower/upper bounds on the exact distance.  Survivor selection keeps every
row whose lower bound could still make the top-R, so the full-precision
rerank reproduces the fp32 scan bit-for-bit (see core/search.py).

Codes are always computed from the *storage-dtype-rounded* rows (what
``get_node`` returns), so a blob's persisted codes and an fstore's
on-the-fly codes agree bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "QFORMATS",
    "QuantNode",
    "qdtype",
    "encode_node",
    "decode_codes",
    "reconstruction_radius",
    "distance_bounds",
]

QFORMATS = ("int8", "float16")

# int8 codes span [-127, 127]: 254 steps, -128 left unused so the range
# is symmetric around the offset
_INT8_STEPS = 254.0


def qdtype(qformat: str) -> np.dtype:
    if qformat == "int8":
        return np.dtype(np.int8)
    if qformat == "float16":
        return np.dtype(np.float16)
    raise ValueError(f"unknown quant format: {qformat!r} (int8|float16)")


@dataclass
class QuantNode:
    """One node's quantized rows + the decode/error parameters.

    ``scale`` doubles as the error carrier: for int8 it is the affine
    step; for float16 it is 0.0 when the cast roundtrips exactly (decode
    is bit-identical) else an upper bound on 2x the per-coordinate cast
    error.  Either way the L2 reconstruction radius of any row is
    ``0.5 * scale * sqrt(dim)``.
    """

    codes: np.ndarray  # [n_rows, dim] int8 | float16
    scale: float
    offset: float
    qformat: str

    @property
    def n_rows(self) -> int:
        return int(self.codes.shape[0])

    @property
    def dim(self) -> int:
        return int(self.codes.shape[1])

    @property
    def nbytes(self) -> int:
        return int(self.codes.nbytes) + 8  # + packed scale/offset

    _radius: float | None = None

    @property
    def radius(self) -> float:
        if self._radius is None:
            self._radius = reconstruction_radius(self.scale, self.dim)
        return self._radius

    def decode(self) -> np.ndarray:
        return decode_codes(self.codes, self.scale, self.offset, self.qformat)


def encode_node(emb: np.ndarray, qformat: str) -> QuantNode:
    """Quantize one node's float32 rows (as returned by ``get_node``)."""
    emb = np.ascontiguousarray(np.asarray(emb, np.float32))
    if emb.ndim != 2:
        raise ValueError(f"encode_node expects [n_rows, dim], got {emb.shape}")
    if qformat == "float16":
        codes = emb.astype(np.float16)
        if np.array_equal(codes.astype(np.float32), emb):
            scale = 0.0  # storage was already f16: decode is bit-exact
        else:
            # half-ulp cast error <= max_abs * 2^-11 per coordinate for
            # normal f16; the 2^-24 floor covers subnormal spacing
            max_abs = float(np.max(np.abs(emb))) if emb.size else 0.0
            scale = max(max_abs * 2.0**-10, 2.0**-24)
        return QuantNode(codes, scale, 0.0, qformat)
    if qformat != "int8":
        raise ValueError(f"unknown quant format: {qformat!r} (int8|float16)")
    if emb.size == 0:
        return QuantNode(emb.astype(np.int8), 0.0, 0.0, qformat)
    lo = float(emb.min())
    hi = float(emb.max())
    # scale/offset are persisted as f32 in the blob companion: round them
    # BEFORE computing codes so every path (blob-persisted, fstore
    # on-the-fly) lands on identical codes AND identical decode params
    offset = float(np.float32(0.5 * (lo + hi)))
    step = float(np.float32((hi - lo) / _INT8_STEPS))
    if step <= 0.0:  # constant node: offset reconstructs exactly
        return QuantNode(np.zeros(emb.shape, np.int8), 0.0, offset, qformat)
    codes = np.clip(np.rint((emb - offset) / step), -127, 127).astype(np.int8)
    return QuantNode(codes, step, offset, qformat)


def decode_codes(codes: np.ndarray, scale: float, offset: float, qformat: str) -> np.ndarray:
    """Codes -> approximate float32 rows (must match the kernel's dequant)."""
    if qformat == "float16":
        return codes.astype(np.float32)
    return codes.astype(np.float32) * np.float32(scale) + np.float32(offset)


def reconstruction_radius(scale: float, dim: int) -> float:
    """Max L2 distance between a decoded row and its source row: the
    per-coordinate error is <= scale/2 (int8 rounding step, or the f16
    cast bound ``encode_node`` stores in ``scale``), widened by a small
    factor to cover the f32 rounding of scale/offset and extreme-value
    clipping (bounded by ~127 * scale * 2^-23 per coordinate)."""
    return 0.5 * (1.0 + 2.0**-12) * float(scale) * float(np.sqrt(dim))


def distance_bounds(
    d_approx: np.ndarray, radius: float, metric: str, q_norm: float = 0.0
) -> tuple[np.ndarray, np.ndarray]:
    """Sound (lb, ub) on the exact distance given distances computed
    against decoded rows with L2 reconstruction error <= ``radius``.

    l2 distances here are SQUARED (np_distances convention): with
    ``s = sqrt(d~)`` the true euclidean distance lies in [s-r, s+r].
    ip/cosine are the negated-similarity forms; for ip the error is
    bounded by ``|q| * r`` (Cauchy-Schwarz).  cosine normalizes by the
    *decoded* row norm, which admits no cheap sound bound — every scanned
    row survives to the rerank (still bit-identical, just no candidate
    pruning).  Returns float64 arrays shaped like ``d_approx``.
    """
    d = np.asarray(d_approx, np.float64)
    r = float(radius)
    if metric == "l2":
        s = np.sqrt(np.maximum(d, 0.0))
        lb = np.square(np.maximum(s - r, 0.0))
        ub = np.square(s + r)
    elif metric == "ip":
        m = float(q_norm) * r
        lb = d - m
        ub = d + m
    elif metric == "cosine":
        lb = np.full(d.shape, -np.inf)
        ub = np.full(d.shape, np.inf)
    else:
        raise ValueError(f"unknown metric: {metric!r}")
    return lb, ub
