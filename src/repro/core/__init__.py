"""eCP-FS core: the paper's contribution as a composable library.

Public API (everything speaks core/api.py's unified shape):
  Searcher / ResultSet / Query     — the retrieval protocol: any searcher's
                                     ``search(q, k, *, b)`` returns a
                                     ``ResultSet`` whose ``.query`` handle
                                     owns incremental state
  open_index(path, mode)           — file | packed | auto searcher factory
  MultiIndexSession                — N indexes under one shared byte-budget
                                     NodeCache (global LRU, live-resizable)
  build_index / ECPBuildConfig     — top-down index construction (lifecycle.py,
                                     re-exported through build.py)
  build_index_streaming            — out-of-core build from a chunk iterator:
                                     O(chunk + leaders) peak memory, result
                                     bit-identical to the one-shot build
  ECPIndex / ECPQuery              — file-structure retrieval with LRU cache
                                     and incremental search (search.py); a
                                     MutableIndex: insert (leaf appends +
                                     2-means splits), delete (tombstones),
                                     compact (deterministic rebuild equal to
                                     a fresh build of the live collection)
  BatchedSearcher / BatchedQuery   — TPU-native batched beam search (batched.py)
  Store / open_store               — pluggable node storage (store.py):
                                     FStoreBackend (zarr-v2 hierarchy),
                                     BlobStore (page-aligned single file,
                                     built with convert()), and
                                     AsyncPrefetchStore (threaded prefetch);
                                     IOStats counts bytes/files/reads plus
                                     prefetch accuracy (hits/wasted bytes)
  ECPSnapshot / BlobSnapshot       — generation-pinned read-only views for
                                     concurrent serving (ECPIndex.snapshot /
                                     BlobStore.pin): searches never block on
                                     a writer and stay bit-identical to the
                                     pinned generation (launch/scheduler.py)
  FederatedIndex / build_federation — one logical index over N shard files
                                     (federation.py): manifest-described
                                     shards, router-scored scatter-gather
                                     with conserved effort split, routed
                                     inserts, fan-out deletes, per-shard
                                     background compaction
  FStore                           — the raw transparent zarr-v2 file layer
  load_packed / PackedIndex        — dense device view of the hierarchy
  baselines                        — BruteForce / IVF / HNSWLite / VamanaLite
"""
from .api import (
    MultiIndexSession,
    MutableIndex,
    NodeCache,
    Query,
    QueryClosedError,
    RestartQuery,
    ResultSet,
    Searcher,
    SearchStats,
    StaleQueryError,
    open_index,
)
from .build import ECPBuildConfig, build_index
from .federation import (
    FederatedIndex,
    FederatedQuery,
    FederatedSnapshot,
    FederationInfo,
    FederationManifest,
    allocate_effort,
    build_federation,
)
from .lifecycle import build_index_streaming, reservoir_sample
from .batched import BatchedQuery, BatchedQueryState, BatchedSearcher
from .frontier import CandidateBuffer, Frontier
from .fstore import FStore
from .layout import IndexInfo, derive_shape
from .legacy import LegacyQueryState
from .packed import PackedIndex, load_packed
from .search import ECPIndex, ECPQuery, ECPSnapshot, QueryState, make_kernel_scorer
from .store import (
    AsyncPrefetchStore,
    BlobSnapshot,
    BlobStore,
    FStoreBackend,
    IOStats,
    NodeNormCache,
    Store,
    convert,
    open_store,
)

__all__ = [
    "Searcher",
    "MutableIndex",
    "ResultSet",
    "Query",
    "QueryClosedError",
    "StaleQueryError",
    "RestartQuery",
    "SearchStats",
    "IOStats",
    "NodeCache",
    "open_index",
    "MultiIndexSession",
    "Store",
    "open_store",
    "convert",
    "FStoreBackend",
    "BlobStore",
    "AsyncPrefetchStore",
    "ECPBuildConfig",
    "build_index",
    "build_index_streaming",
    "reservoir_sample",
    "FederatedIndex",
    "FederatedQuery",
    "FederatedSnapshot",
    "FederationInfo",
    "FederationManifest",
    "allocate_effort",
    "build_federation",
    "BatchedQuery",
    "BatchedQueryState",
    "BatchedSearcher",
    "FStore",
    "IndexInfo",
    "derive_shape",
    "PackedIndex",
    "load_packed",
    "ECPIndex",
    "ECPQuery",
    "ECPSnapshot",
    "BlobSnapshot",
    "QueryState",
    "LegacyQueryState",
    "Frontier",
    "CandidateBuffer",
    "NodeNormCache",
    "make_kernel_scorer",
]
