"""eCP-FS core: the paper's contribution as a composable library.

Public API:
  build_index / ECPBuildConfig     — top-down index construction (build.py)
  ECPIndex                         — file-structure retrieval with LRU cache
                                     and incremental search (search.py)
  BatchedSearcher                  — TPU-native batched beam search (batched.py)
  FStore                           — the transparent zarr-v2 file store
  load_packed / PackedIndex        — dense device view of the hierarchy
  baselines                        — BruteForce / IVF / HNSWLite / VamanaLite
"""
from .build import ECPBuildConfig, build_index
from .batched import BatchedQueryState, BatchedSearcher
from .fstore import FStore
from .layout import IndexInfo, derive_shape
from .packed import PackedIndex, load_packed
from .search import ECPIndex, NodeCache, QueryState, SearchStats

__all__ = [
    "ECPBuildConfig",
    "build_index",
    "BatchedQueryState",
    "BatchedSearcher",
    "FStore",
    "IndexInfo",
    "derive_shape",
    "PackedIndex",
    "load_packed",
    "ECPIndex",
    "NodeCache",
    "QueryState",
    "SearchStats",
]
