"""Pure-jnp oracle for the fused distance+top-k kernel.

Materializes the full [B, N] distance matrix (exactly what the Pallas kernel
avoids) and selects with lax.top_k. Smaller distance = better; ties broken
by lower candidate index (both here and in the kernel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.distances import jnp_distances


def distance_topk_ref(q, c, k: int, metric: str = "l2"):
    """q: [B, D]; c: [N, D] -> (dists [B, k], idx [B, k]) ascending."""
    d = jnp_distances(q, c, metric)                    # [B, N] f32
    n = d.shape[-1]
    # encode index into the mantissa-free tiebreak: top_k on (-d, -idx)
    neg_d, idx = jax.lax.top_k(-d, k)
    # lax.top_k is stable (prefers lower index on ties) — matches the kernel
    return -neg_d, idx.astype(jnp.int32)
