"""Pure-jnp oracle for the fused distance+top-k kernel.

Materializes the full [B, N] distance matrix (exactly what the Pallas kernel
avoids) and selects with lax.top_k. Smaller distance = better; ties broken
by lower candidate index (both here and in the kernel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.distances import jnp_distances


def distance_topk_ref(q, c, k: int, metric: str = "l2"):
    """q: [B, D]; c: [N, D] -> (dists [B, k], idx [B, k]) ascending."""
    d = jnp_distances(q, c, metric)                    # [B, N] f32
    n = d.shape[-1]
    # encode index into the mantissa-free tiebreak: top_k on (-d, -idx)
    neg_d, idx = jax.lax.top_k(-d, k)
    # lax.top_k is stable (prefers lower index on ties) — matches the kernel
    return -neg_d, idx.astype(jnp.int32)


def grouped_distance_topk_ref(
    q, codes, scales, offsets, n_rows, k: int, metric: str = "l2", qformat: str = "int8"
):
    """Pure-numpy oracle for the grouped quantized kernel (and the CPU
    serving path): batch-decode every group's codes, score them with the
    same formulas as ``np_distances``, stable top-k.  q [G, D]; codes
    [G, N, D]; scales/offsets/n_rows [G] -> (dists [G, k] f32, idx
    [G, k] i32); rows past n_rows[g] come back as (inf, -1)."""
    import numpy as np

    q = np.asarray(q, np.float32)
    G = q.shape[0]
    codes = np.asarray(codes)
    if G == 0 or codes.shape[1] == 0:
        return (
            np.full((G, k), np.inf, np.float32),
            np.full((G, k), -1, np.int32),
        )
    nr = np.asarray(n_rows, np.int64)
    # one batched decode + score over all groups (the CPU serving path
    # runs this once per traversal round — a python loop per group would
    # dominate the warm search)
    if qformat == "float16":
        c = codes.astype(np.float32)
    else:
        c = (
            codes.astype(np.float32) * np.asarray(scales, np.float32)[:, None, None]
            + np.asarray(offsets, np.float32)[:, None, None]
        )
    if metric == "ip":
        d = -np.einsum("gd,gnd->gn", q, c, optimize=True)
    elif metric == "l2":
        qn = (q * q).sum(-1)[:, None]
        cn = (c * c).sum(-1)
        d = qn + cn - 2.0 * np.einsum("gd,gnd->gn", q, c, optimize=True)
    else:  # cosine — mirror np_distances' normalization
        qq = q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        cc = c / np.maximum(np.linalg.norm(c, axis=-1, keepdims=True), 1e-12)
        d = 1.0 - np.einsum("gd,gnd->gn", qq, cc, optimize=True)
    d = d.astype(np.float32, copy=False)
    pad = np.arange(codes.shape[1])[None, :] >= nr[:, None]
    d[pad] = np.inf
    order = np.argsort(d, axis=1, kind="stable")[:, :k]
    out_d = np.take_along_axis(d, order, axis=1)
    out_i = np.where(np.isinf(out_d), -1, order).astype(np.int32)
    if out_d.shape[1] < k:  # kop larger than the padded leaf width
        fill_d = np.full((G, k - out_d.shape[1]), np.inf, np.float32)
        fill_i = np.full((G, k - out_i.shape[1]), -1, np.int32)
        out_d = np.concatenate([out_d, fill_d], axis=1)
        out_i = np.concatenate([out_i, fill_i], axis=1)
    return out_d, out_i
