"""Pallas TPU kernel: grouped quantized distance + running top-k.

The batched traversal's per-round launch (core/search.py): each group g
is one (query, leaf) scan unit — query row ``q[g]`` against that leaf's
quantized codes — and ALL units in a round go up in a single
``pallas_call`` instead of one kernel launch per leaf.  Groups are
independent (grid axis 0 is parallel); the candidate axis reuses the
running-top-k scratch pattern of ``distance_topk``.

Inputs are padded to a common leaf size: codes [G, N_pad, D] in the
quantized dtype (int8 | float16), per-group dequant params [G, 2]
(scale, offset — f32, exactly as the blob companion stores them) and
per-group valid row counts [G, 1] (int32).  Dequantization happens
in-kernel right before the MXU, so HBM only ever holds the compressed
codes — the whole point of the quantized scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .distance_topk import _CompilerParams, _merge_topk

NEG_ONE = -1


def _gkernel(
    q_ref, c_ref, prm_ref, nr_ref, out_d_ref, out_i_ref, run_d, run_i,
    *, k, bn, n_steps, metric, qformat,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        run_d[...] = jnp.full(run_d.shape, jnp.inf, run_d.dtype)
        run_i[...] = jnp.full(run_i.shape, NEG_ONE, run_i.dtype)

    q = q_ref[...].astype(jnp.float32)                          # [1, D]
    c = c_ref[0].astype(jnp.float32)                            # [bn, D]
    if qformat == "int8":
        c = c * prm_ref[0, 0] + prm_ref[0, 1]                   # dequant on VPU
    # float16 codes ARE the (cast) rows: astype above is the full decode
    if metric == "cosine":
        q = q * jax.lax.rsqrt(jnp.sum(q * q, -1, keepdims=True) + 1e-12)
        c = c * jax.lax.rsqrt(jnp.sum(c * c, -1, keepdims=True) + 1e-12)
    scores = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                           # [1, bn] MXU
    if metric == "ip":
        d = -scores
    elif metric == "l2":
        d = (
            jnp.sum(q * q, -1)[:, None]
            + jnp.sum(c * c, -1)[None, :]
            - 2.0 * scores
        )
    else:  # cosine (pre-normalized above)
        d = 1.0 - scores

    gidx = j * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    valid = gidx < nr_ref[0, 0]                                 # per-group tail
    d = jnp.where(valid, d, jnp.inf)
    gidx = jnp.where(valid, gidx, NEG_ONE)  # groups may have < k valid rows

    md = jnp.concatenate([run_d[...], d], axis=1)               # [1, k+bn]
    mi = jnp.concatenate([run_i[...], gidx], axis=1)
    new_d, new_i = _merge_topk(md, mi, k)
    run_d[...] = new_d
    run_i[...] = new_i

    @pl.when(j == n_steps - 1)
    def _flush():
        out_d_ref[...] = run_d[...]
        # a group with < k valid rows pads with (inf, -1); _merge_topk's
        # exhausted-extraction re-reads position-0's id, so mask by value
        out_i_ref[...] = jnp.where(
            jnp.isinf(run_d[...]), NEG_ONE, run_i[...]
        ).astype(run_i.dtype)


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "qformat", "bn", "interpret")
)
def grouped_distance_topk_pallas(
    q: jnp.ndarray,
    codes: jnp.ndarray,
    scales: jnp.ndarray,
    offsets: jnp.ndarray,
    n_rows: jnp.ndarray,
    k: int,
    metric: str = "l2",
    qformat: str = "int8",
    *,
    bn: int = 128,
    interpret: bool = False,
):
    """q [G, D], codes [G, N_pad, D] (int8|f16), scales/offsets [G],
    n_rows [G] -> (dists [G, k] f32, idx [G, k] i32) ascending; rows past
    each group's n_rows come back as (inf, -1)."""
    G, D = q.shape
    N = codes.shape[1]
    N_pad = -(-max(N, 1) // bn) * bn
    if N_pad != N:
        codes = jnp.pad(codes, ((0, 0), (0, N_pad - N), (0, 0)))
    n_steps = N_pad // bn
    prm = jnp.stack(
        [jnp.asarray(scales, jnp.float32), jnp.asarray(offsets, jnp.float32)], axis=1
    )                                                           # [G, 2]
    nr = jnp.asarray(n_rows, jnp.int32)[:, None]                # [G, 1]
    kern = functools.partial(
        _gkernel, k=k, bn=bn, n_steps=n_steps, metric=metric, qformat=qformat
    )
    out_d, out_i = pl.pallas_call(
        kern,
        grid=(G, n_steps),
        in_specs=[
            pl.BlockSpec((1, D), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, k), jnp.float32),
            jax.ShapeDtypeStruct((G, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, codes, prm, nr)
    return out_d, out_i
