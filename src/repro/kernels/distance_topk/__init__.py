from .ops import distance_topk, grouped_distance_topk
from .ref import distance_topk_ref, grouped_distance_topk_ref
from .distance_topk import distance_topk_pallas
from .grouped import grouped_distance_topk_pallas

__all__ = [
    "distance_topk",
    "distance_topk_ref",
    "distance_topk_pallas",
    "grouped_distance_topk",
    "grouped_distance_topk_ref",
    "grouped_distance_topk_pallas",
]
