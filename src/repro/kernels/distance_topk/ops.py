"""Public op: distance_topk — jit'd wrapper choosing kernel vs reference.

On TPU the Pallas kernel runs compiled; in this CPU container it is
validated with ``interpret=True``. ``impl="auto"`` uses the reference path
on CPU (fast) and the kernel on TPU, so callers never branch themselves.
"""
from __future__ import annotations

import jax

from .distance_topk import distance_topk_pallas
from .grouped import grouped_distance_topk_pallas
from .ref import distance_topk_ref, grouped_distance_topk_ref


def distance_topk(q, c, k: int, metric: str = "l2", *, impl: str = "auto", **kw):
    """q [B, D], c [N, D] -> (dists [B, k], idx [B, k]), ascending distance.

    impl: "auto" | "ref" | "pallas" | "pallas_interpret"
    """
    if impl == "auto":
        platform = jax.devices()[0].platform
        impl = "pallas" if platform == "tpu" else "ref"
    if impl == "ref":
        return distance_topk_ref(q, c, k, metric)
    if impl == "pallas":
        return distance_topk_pallas(q, c, k, metric, **kw)
    if impl == "pallas_interpret":
        return distance_topk_pallas(q, c, k, metric, interpret=True, **kw)
    raise ValueError(f"unknown impl {impl!r}")


def grouped_distance_topk(
    q,
    codes,
    scales,
    offsets,
    n_rows,
    k: int,
    metric: str = "l2",
    qformat: str = "int8",
    *,
    impl: str = "auto",
    **kw,
):
    """One device launch for a whole traversal round: group g scores
    q[g] against its leaf's quantized codes[g].  Returns numpy
    (dists [G, k], idx [G, k]); invalid tail entries are (inf, -1).

    impl: "auto" | "ref" | "pallas" | "pallas_interpret"
    """
    import numpy as np

    if impl == "auto":
        platform = jax.devices()[0].platform
        impl = "pallas" if platform == "tpu" else "ref"
    if impl == "ref":
        d, i = grouped_distance_topk_ref(
            q, codes, scales, offsets, n_rows, k, metric, qformat
        )
    elif impl == "pallas":
        d, i = grouped_distance_topk_pallas(
            q, codes, scales, offsets, n_rows, k, metric, qformat, **kw
        )
    elif impl == "pallas_interpret":
        d, i = grouped_distance_topk_pallas(
            q, codes, scales, offsets, n_rows, k, metric, qformat, interpret=True, **kw
        )
    else:
        raise ValueError(f"unknown impl {impl!r}")
    return np.asarray(d), np.asarray(i)
