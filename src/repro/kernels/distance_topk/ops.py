"""Public op: distance_topk — jit'd wrapper choosing kernel vs reference.

On TPU the Pallas kernel runs compiled; in this CPU container it is
validated with ``interpret=True``. ``impl="auto"`` uses the reference path
on CPU (fast) and the kernel on TPU, so callers never branch themselves.
"""
from __future__ import annotations

import jax

from .distance_topk import distance_topk_pallas
from .ref import distance_topk_ref


def distance_topk(q, c, k: int, metric: str = "l2", *, impl: str = "auto", **kw):
    """q [B, D], c [N, D] -> (dists [B, k], idx [B, k]), ascending distance.

    impl: "auto" | "ref" | "pallas" | "pallas_interpret"
    """
    if impl == "auto":
        platform = jax.devices()[0].platform
        impl = "pallas" if platform == "tpu" else "ref"
    if impl == "ref":
        return distance_topk_ref(q, c, k, metric)
    if impl == "pallas":
        return distance_topk_pallas(q, c, k, metric, **kw)
    if impl == "pallas_interpret":
        return distance_topk_pallas(q, c, k, metric, interpret=True, **kw)
    raise ValueError(f"unknown impl {impl!r}")
