"""Pallas TPU kernel: fused distance computation + running top-k.

The eCP-FS hot spot (DESIGN.md §7): score a query block against a large
candidate set (cluster leaders, leaf items, recsys candidates, KV-cluster
centroids) and keep only the k best — without ever materializing the [B, N]
distance matrix in HBM.

Layout / tiling:
  * grid = (B/bq, N/bn); the candidate axis is ``arbitrary`` (sequential) so
    a VMEM scratch accumulator carries the running top-k across blocks.
  * q block [bq, D] and c block [bn, D] live in VMEM; the MXU computes
    q @ cᵀ with f32 accumulation (preferred_element_type).
  * bq/bn default 128 — MXU-aligned (multiples of 128 on both matmul dims).
  * selection is a k-step masked-argmin extraction over the concatenated
    [bq, k + bn] candidates — pure VPU ops (min/compare/cumsum), no
    unsupported sort/top_k primitives inside the kernel.

VMEM budget at defaults (D=1152, bq=bn=128, k=128):
  q 128·1152·4 = 576 KB, c 576 KB, scores 64 KB, scratch 2·64 KB ≈ 1.4 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_ONE = -1


def _merge_topk(md, mi, k):
    """k-step extraction of the k smallest (value, id) pairs.

    md: [bq, M] distances, mi: [bq, M] int32 ids. Ties resolved to the
    first (lowest position ⇒ lowest candidate index) via a cumsum mask.
    Returns ([bq, k], [bq, k]) ascending.
    """
    out_d, out_i = [], []
    for _ in range(k):
        m = jnp.min(md, axis=1, keepdims=True)                  # [bq, 1]
        is_min = md == m
        first = is_min & (jnp.cumsum(is_min.astype(jnp.int32), axis=1) == 1)
        sel_i = jnp.sum(jnp.where(first, mi, 0), axis=1)        # unique hit
        out_d.append(m[:, 0])
        out_i.append(sel_i)
        md = jnp.where(first, jnp.inf, md)
    return jnp.stack(out_d, axis=1), jnp.stack(out_i, axis=1).astype(jnp.int32)


def _kernel(q_ref, c_ref, out_d_ref, out_i_ref, run_d, run_i, *, k, bn, n_total, n_steps, metric):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        run_d[...] = jnp.full(run_d.shape, jnp.inf, run_d.dtype)
        run_i[...] = jnp.full(run_i.shape, NEG_ONE, run_i.dtype)

    q = q_ref[...].astype(jnp.float32)                          # [bq, D]
    c = c_ref[...].astype(jnp.float32)                          # [bn, D]
    if metric == "cosine":
        q = q * jax.lax.rsqrt(jnp.sum(q * q, -1, keepdims=True) + 1e-12)
        c = c * jax.lax.rsqrt(jnp.sum(c * c, -1, keepdims=True) + 1e-12)
    scores = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                           # [bq, bn] MXU
    if metric == "ip":
        d = -scores
    elif metric == "l2":
        d = (
            jnp.sum(q * q, -1)[:, None]
            + jnp.sum(c * c, -1)[None, :]
            - 2.0 * scores
        )
    else:  # cosine (pre-normalized above)
        d = 1.0 - scores

    bq = d.shape[0]
    gidx = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bq, bn), 1)
    d = jnp.where(gidx < n_total, d, jnp.inf)                   # tail mask

    md = jnp.concatenate([run_d[...], d], axis=1)               # [bq, k+bn]
    mi = jnp.concatenate([run_i[...], gidx], axis=1)
    new_d, new_i = _merge_topk(md, mi, k)
    run_d[...] = new_d
    run_i[...] = new_i

    @pl.when(j == n_steps - 1)
    def _flush():
        out_d_ref[...] = run_d[...]
        out_i_ref[...] = run_i[...]


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "bq", "bn", "interpret")
)
def distance_topk_pallas(
    q: jnp.ndarray,
    c: jnp.ndarray,
    k: int,
    metric: str = "l2",
    *,
    bq: int = 128,
    bn: int = 128,
    interpret: bool = False,
):
    """Fused top-k nearest candidates. q [B, D], c [N, D] -> ([B,k],[B,k])."""
    B, D = q.shape
    N = c.shape[0]
    B_pad = -(-B // bq) * bq
    N_pad = -(-N // bn) * bn
    if B_pad != B:
        q = jnp.pad(q, ((0, B_pad - B), (0, 0)))
    if N_pad != N:
        c = jnp.pad(c, ((0, N_pad - N), (0, 0)))
    n_steps = N_pad // bn
    kern = functools.partial(
        _kernel, k=k, bn=bn, n_total=N, n_steps=n_steps, metric=metric
    )
    out_d, out_i = pl.pallas_call(
        kern,
        grid=(B_pad // bq, n_steps),
        in_specs=[
            pl.BlockSpec((bq, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((B_pad, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, c)
    return out_d[:B], out_i[:B]
