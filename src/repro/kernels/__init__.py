"""Pallas TPU kernels for the framework's compute hot spots.

  distance_topk    — fused distance + running top-k (the eCP-FS hot path:
                     leaf scans, centroid scoring, recsys candidate scoring)
  flash_attention  — online-softmax attention forward (LM prefill/decode)

Each subpackage: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper with impl dispatch), ref.py (pure-jnp oracle).
"""
from .distance_topk import distance_topk
from .flash_attention import flash_attention

__all__ = ["distance_topk", "flash_attention"]
