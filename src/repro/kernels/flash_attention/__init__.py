from .ops import flash_attention
from .ref import mha_ref
from .flash_attention import flash_attention_pallas

__all__ = ["flash_attention", "mha_ref", "flash_attention_pallas"]
