"""Pallas TPU kernel: online-softmax (flash) attention forward, GQA-aware.

Tiling:
  * grid = (B, Hq, Sq/bq, Skv/bk); the kv axis is sequential ("arbitrary"),
    carrying (m, l, acc) in VMEM scratch — the classic flash recurrence.
  * q block [bq, d], k/v blocks [bk, d] in VMEM; scores on the MXU with f32
    accumulation. bq = bk = 128 by default (MXU-aligned).
  * GQA: query head h reads kv head h // (Hq // Hkv) via the BlockSpec
    index maps — no repeat/materialization of kv heads.
  * causal masking aligns the LAST query with the last valid kv position
    (works for both prefill Sq == Skv and chunked/decode Sq < Skv);
    per-batch valid kv length arrives as an SMEM scalar block.
  * fully-masked kv blocks are skipped with pl.when (causal wedge skip).

VMEM at defaults (d=128): q/k/v blocks 64 KB each, acc 64 KB — ~0.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG_INF = float("-inf")


def _kernel(
    len_ref,  # SMEM [1] int32: valid kv length for this batch row
    q_ref, k_ref, v_ref,  # VMEM blocks
    o_ref,
    m_scr, l_scr, acc_scr,
    *,
    causal: bool,
    scale: float,
    bq: int,
    bk: int,
    sq: int,
    skv: int,
    n_kv_steps: int,
):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, _NEG_INF, m_scr.dtype)
        l_scr[...] = jnp.zeros(l_scr.shape, l_scr.dtype)
        acc_scr[...] = jnp.zeros(acc_scr.shape, acc_scr.dtype)

    i = pl.program_id(2)
    kv_len = len_ref[0]
    q_end_offset = kv_len - sq  # causal alignment shift

    # skip kv blocks entirely in the causal future or past the valid length
    q_hi = (i + 1) * bq - 1 + q_end_offset
    block_live = (j * bk <= q_hi) if causal else (j * bk < kv_len)

    @pl.when(block_live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)                    # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)                    # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                      # [bq, bk]
        kv_idx = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kv_idx < kv_len
        if causal:
            q_idx = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            mask &= kv_idx <= (q_idx + q_end_offset)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]                                    # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # guard rows with no live keys yet (m == -inf)
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(j == n_kv_steps - 1)
    def _flush():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "bq", "bk", "interpret", "scale"),
)
def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    kv_lens: jnp.ndarray | None = None,
    causal: bool = True,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
):
    """q [B,Hq,Sq,d]; k,v [B,Hkv,Skv,d] -> [B,Hq,Sq,d] (f32).

    kv_lens [B] int32: per-sequence valid kv length (default: full Skv).
    """
    B, Hq, Sq, d = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    if kv_lens is None:
        kv_lens = jnp.full((B,), Skv, jnp.int32)
    bq_ = min(bq, Sq)
    bk_ = min(bk, Skv)
    Sq_pad = -(-Sq // bq_) * bq_
    Skv_pad = -(-Skv // bk_) * bk_
    if Sq_pad != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_pad - Sq), (0, 0)))
    if Skv_pad != Skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Skv_pad - Skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Skv_pad - Skv), (0, 0)))
    n_kv_steps = Skv_pad // bk_
    kern = functools.partial(
        _kernel,
        causal=causal,
        scale=scale,
        bq=bq_,
        bk=bk_,
        sq=Sq,
        skv=Skv,
        n_kv_steps=n_kv_steps,
    )
    grid = (B, Hq, Sq_pad // bq_, n_kv_steps)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i, j: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, bq_, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk_, d), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk_, d), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq_pad, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(kv_lens.astype(jnp.int32), q, k, v)
    return out[:, :, :Sq]
