"""Pure-jnp oracle for flash attention (GQA-aware, causal, length-masked)."""
from __future__ import annotations

import jax.numpy as jnp


def mha_ref(q, k, v, *, causal: bool = True, kv_lens=None, scale: float | None = None):
    """q [B, Hq, Sq, d]; k,v [B, Hkv, Skv, d]; kv_lens [B] or None.

    GQA: Hq must be a multiple of Hkv; query head h attends kv head
    h // (Hq // Hkv). Causal alignment: the LAST query aligns with the last
    valid kv position (decode convention).
    Returns [B, Hq, Sq, d] float32.
    """
    B, Hq, Sq, d = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    q = q.astype(jnp.float32)
    k = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    v = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    kv_idx = jnp.arange(Skv)[None, None, None, :]
    if kv_lens is not None:
        s = jnp.where(kv_idx < kv_lens[:, None, None, None], s, -jnp.inf)
        end = kv_lens[:, None, None, None]
    else:
        end = Skv
    if causal:
        q_idx = jnp.arange(Sq)[None, None, :, None]
        # last query aligns with last valid kv position
        allowed = kv_idx <= (q_idx + (end - Sq))
        s = jnp.where(allowed, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
