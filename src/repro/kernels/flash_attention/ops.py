"""Public op: flash_attention — jit'd wrapper choosing kernel vs reference.

Training paths in models/ use the differentiable chunked-jnp attention
(models/attention.py); this op serves the inference paths (prefill/decode)
where the Pallas kernel is the TPU hot path. On CPU, "auto" falls back to
the reference for speed; the kernel itself is validated in interpret mode.
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention_pallas
from .ref import mha_ref


def flash_attention(q, k, v, *, kv_lens=None, causal=True, scale=None, impl="auto", **kw):
    if impl == "auto":
        impl = "pallas" if jax.devices()[0].platform == "tpu" else "ref"
    if impl == "ref":
        return mha_ref(q, k, v, causal=causal, kv_lens=kv_lens, scale=scale)
    if impl == "pallas":
        return flash_attention_pallas(
            q, k, v, kv_lens=kv_lens, causal=causal, scale=scale, **kw
        )
    if impl == "pallas_interpret":
        return flash_attention_pallas(
            q, k, v, kv_lens=kv_lens, causal=causal, scale=scale, interpret=True, **kw
        )
    raise ValueError(f"unknown impl {impl!r}")
