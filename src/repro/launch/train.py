"""End-to-end training driver (examples/ and the fault-tolerance tests use
this; the dry-run lowers the same train_step via cells.py).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
      --steps 200 --ckpt-dir /tmp/ckpt

Runs a reduced (or full, on real hardware) config on the current devices:
deterministic data, AdamW + cosine schedule, checkpoint every N steps via
the supervisor (restart-safe), optional cross-pod int8 gradient compression.
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data import StepLoader, ctr_batch, lm_batch
from repro.distributed import TrainSupervisor
from repro.launch.cells import make_train_step
from repro.models import gnn, init_params, recsys
from repro.models import transformer as T
from repro.optim import adamw, compress_decompress, init_ef_state, warmup_cosine


def make_lm_trainer(cfg: T.LMConfig, *, lr=3e-4, total_steps=10_000, compress=False):
    opt = adamw(warmup_cosine(lr, min(200, total_steps // 10 + 1), total_steps))
    loss_fn = lambda p, b: T.lm_loss(p, b, cfg)

    def step(state, batch):
        params, opt_state, ef = state
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        if compress:
            grads, ef = compress_decompress(grads, ef)
        updates, opt_state = opt.update(grads, opt_state, params)
        from repro.optim import apply_updates

        params = apply_updates(params, updates)
        return (params, opt_state, ef), {"loss": loss, **metrics}

    def init(rng):
        params = init_params(T.param_specs(cfg), rng)
        ef = init_ef_state(params) if compress else None
        return (params, opt.init(params), ef)

    return jax.jit(step, donate_argnums=(0,)), init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    family, cfg = get_arch(args.arch, reduced=args.reduced)
    if family != "lm":
        raise SystemExit("train.py drives LM archs; see examples/ for others")
    from dataclasses import replace

    cfg = replace(cfg, max_seq=args.seq)
    step_jit, init = make_lm_trainer(cfg, lr=args.lr, total_steps=args.steps, compress=args.compress)
    state = init(jax.random.key(0))

    loader = StepLoader(
        make=partial(lm_batch, batch=args.batch, seq=args.seq, vocab=cfg.vocab),
        seed=0,
    )
    ckpt = CheckpointManager(args.ckpt_dir, keep_n=2)

    losses = []

    def on_metrics(step, metrics, dt):
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            print(
                f"step {step:5d} loss {float(metrics['loss']):.4f} "
                f"xent {float(metrics.get('xent', 0.0)):.4f} {dt*1e3:.0f} ms",
                flush=True,
            )

    sup = TrainSupervisor(
        step_fn=lambda s, b, i: step_jit(s, {"tokens": jnp.asarray(b["tokens"])}),
        loader=loader,
        ckpt=ckpt,
        ckpt_every=args.ckpt_every,
    )
    t0 = time.time()
    state, stats = sup.run(state, args.steps, on_metrics=on_metrics)
    dt = time.time() - t0
    print(
        f"done: {args.steps} steps in {dt:.1f}s; loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
        f"restarts={stats['restarts']} stragglers={stats['stragglers']}"
    )


if __name__ == "__main__":
    main()
