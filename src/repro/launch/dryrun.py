import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, prove it fits, and extract the roofline inputs.

  PYTHONPATH=src python -m repro.launch.dryrun --arch phi4-mini-3.8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out benchmarks/results

Per cell it records: compile wall time, per-device peak HBM
(memory_analysis), HLO FLOPs/bytes (cost_analysis), per-collective wire
bytes (hlo_analysis), and the three roofline terms. Failures here are
sharding bugs by definition (see the assignment) — the run aborts loudly.
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

# repo root on sys.path so the benchmarks package resolves when invoked
# as `python -m repro.launch.dryrun` from anywhere
sys.path.insert(0, str(Path(__file__).resolve().parents[3]))

from repro.configs import ALL_CELLS
from repro.launch.cells import build_cell, lower_cell
from repro.launch.mesh import make_production_mesh


def _hlo_modules():
    from benchmarks import hlo_analysis  # repo-root benchmarks package

    return hlo_analysis


def run_cell(arch: str, shape: str, mesh, *, verbose: bool = True) -> dict:
    hlo = _hlo_modules()
    from benchmarks import analytic
    from repro.configs import arch_shapes

    n_chips = mesh.devices.size
    t0 = time.time()
    cell = build_cell(arch, shape, mesh_axes=mesh.axis_names)
    lowered = lower_cell(cell, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    # collectives: loop-aware (XLA's numbers count while bodies once)
    coll = hlo.loop_aware_collective_bytes(text)
    # compute/memory: exact analytic counts (HLO undercounts through scans)
    sh = dict(arch_shapes(arch)[shape])
    flops_global = analytic.cell_flops(cell.meta, sh["kind"], sh)
    hbm_global = analytic.cell_hbm_bytes(cell.meta, sh["kind"], sh)
    terms = {
        "compute_s": flops_global / n_chips / hlo.PEAK_FLOPS,
        "memory_s": hbm_global / n_chips / hlo.HBM_BW,
        "collective_s": coll["total_bytes"] / hlo.ICI_BW,
        "flops_per_chip": flops_global / n_chips,
        "bytes_per_chip": hbm_global / n_chips,
        "coll_bytes_per_chip": float(coll["total_bytes"]),
    }
    # keep the raw HLO numbers for reference (documented-undercounted)
    hlo_flops_once = float(cost.get("flops", 0.0))
    hlo_bytes_once = float(cost.get("bytes accessed", 0.0))

    # The CPU backend ignores buffer donation, so memory_analysis double-
    # counts donated state (params/opt/caches appear as arg AND output).
    # On TPU the donated pairs alias; subtract them for the honest figure.
    def _sharded_bytes(sds_tree, ps_tree):
        import numpy as _np
        from jax.sharding import PartitionSpec as _P

        tot = 0
        leaves = jax.tree.leaves(sds_tree)
        specs = jax.tree.leaves(ps_tree, is_leaf=lambda x: isinstance(x, _P))
        for leaf, ps in zip(leaves, specs):
            shards = 1
            for entry in ps:
                axes = entry if isinstance(entry, tuple) else (entry,)
                for a in axes:
                    if a is not None:
                        shards *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
            tot += int(_np.prod(leaf.shape)) * jax.dtypes.canonicalize_dtype(leaf.dtype).itemsize // shards
        return tot

    donated = sum(_sharded_bytes(cell.args[i], cell.in_pspecs[i]) for i in cell.donate)

    rec = {
        "arch": arch,
        "shape": shape,
        "kind": cell.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_chips": int(n_chips),
        "n_params": int(cell.meta.get("n_params", 0)),
        "tokens": int(cell.meta.get("tokens", 0)),
        "n_candidates": int(cell.meta.get("n_candidates", 0)),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "peak_hbm_bytes": int(getattr(mem, "temp_size_in_bytes", 0))
        + int(getattr(mem, "argument_size_in_bytes", 0))
        + int(getattr(mem, "output_size_in_bytes", 0)),
        "donated_bytes": int(donated),
        "peak_hbm_adjusted": int(getattr(mem, "temp_size_in_bytes", 0))
        + int(getattr(mem, "argument_size_in_bytes", 0))
        + int(getattr(mem, "output_size_in_bytes", 0))
        - int(donated),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "out_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "flops_per_chip": terms["flops_per_chip"],
        "hbm_bytes_per_chip": terms["bytes_per_chip"],
        "coll_bytes_per_chip": terms["coll_bytes_per_chip"],
        "coll_by_type": coll["by_type"],
        "coll_bytes_static": coll.get("static_bytes", 0),
        "hlo_flops_once": hlo_flops_once,
        "hlo_bytes_once": hlo_bytes_once,
        "compute_s": terms["compute_s"],
        "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: rec[k])
    rec["bottleneck"] = dom.replace("_s", "")
    if verbose:
        hbm_gb = rec["peak_hbm_adjusted"] / 2**30
        print(
            f"[dryrun] {arch:28s} {shape:14s} mesh={rec['mesh']:10s} "
            f"compile={t_compile:6.1f}s hbm/dev={hbm_gb:7.2f}GiB "
            f"T_comp={rec['compute_s']:.3e} T_mem={rec['memory_s']:.3e} "
            f"T_coll={rec['collective_s']:.3e} -> {rec['bottleneck']}",
            flush=True,
        )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = (
        list(ALL_CELLS)
        if args.all
        else [(args.arch, s) for a, s in ALL_CELLS if a == args.arch and (args.shape in (None, s))]
    )
    if not cells:
        raise SystemExit(f"no cells selected (arch={args.arch} shape={args.shape})")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = []
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        tag = "multi" if multi_pod else "single"
        for arch, shape in cells:
            fp = outdir / f"dryrun_{tag}_{arch}_{shape}.json"
            if args.skip_existing and fp.exists():
                print(f"[dryrun] skip existing {fp.name}", flush=True)
                continue
            try:
                rec = run_cell(arch, shape, mesh)
                fp.write_text(json.dumps(rec, indent=1))
            except Exception as e:  # sharding bug: report and continue sweep
                failures.append((tag, arch, shape, repr(e)))
                print(f"[dryrun] FAIL {arch} {shape} ({tag}): {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f[:3], f[3][:160])
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
