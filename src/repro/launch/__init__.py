"""Launch layer: mesh construction, dry-run, training and serving drivers.

LAZY on purpose: ``python -m repro.launch.dryrun`` imports this package
BEFORE dryrun.py runs, and dryrun.py must set XLA_FLAGS (512 host devices)
before anything touches jax. No eager imports here.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    "Cell": "cells",
    "build_cell": "cells",
    "example_inputs": "cells",
    "lower_cell": "cells",
    "make_rules": "cells",
    "make_train_step": "cells",
    "batch_axes_of": "mesh",
    "make_host_mesh": "mesh",
    "make_production_mesh": "mesh",
    "Server": "serve",
    "ServeStats": "serve",
    "LatencyRing": "serve",
    "DeadlinePolicy": "scheduler",
    "RequestScheduler": "scheduler",
    "SnapshotManager": "scheduler",
    "ServerOverloadedError": "scheduler",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        mod = importlib.import_module(f".{_EXPORTS[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(name)
