"""Mesh construction for the production topology.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is a second (slow, DCN-linked) data-parallel axis; gradients
cross it once per step, optionally int8-compressed (optim/compress.py).

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "batch_axes_of"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke tests (axes exist, extent 1)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def batch_axes_of(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
