"""Mesh construction for the production topology.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is a second (slow, DCN-linked) data-parallel axis; gradients
cross it once per step, optionally int8-compressed (optim/compress.py).

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

__all__ = [
    "make_production_mesh",
    "make_host_mesh",
    "batch_axes_of",
    "set_mesh",
    "get_abstract_mesh",
    "shard_map",
]


def set_mesh(mesh):
    """Version-compat mesh activation: ``with set_mesh(mesh): ...``.

    jax >= 0.5 exposes ``jax.sharding.set_mesh`` (also usable as a context
    manager); some 0.4.x releases only have ``jax.sharding.use_mesh``; on
    anything older, ``Mesh`` itself is the context manager.  All call sites
    in this repo (and its tests) go through this helper.
    """
    fn = getattr(jax.sharding, "set_mesh", None) or getattr(jax.sharding, "use_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh


def get_abstract_mesh():
    """Version-compat read of the ambient mesh set by ``set_mesh``.

    jax >= 0.5 has ``jax.sharding.get_abstract_mesh``; older releases keep
    the active mesh in the xmap-era thread resources.  Either way the
    result exposes ``axis_names`` / ``axis_sizes`` and is accepted as
    ``shard_map``'s mesh argument.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax.interpreters import pxla

    return pxla.thread_resources.env.physical_mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-compat ``shard_map``: top-level ``jax.shard_map`` with the
    ``check_vma`` flag on new jax, ``jax.experimental.shard_map.shard_map``
    with its ``check_rep`` spelling on old jax."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke tests (axes exist, extent 1)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def batch_axes_of(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
