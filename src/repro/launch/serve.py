"""ANN serving driver — the paper's own application as a service loop.

Two serving modes over one eCP-FS index:
  * interactive  — host-driven incremental search (Algorithms 1-3): per-query
    state, get-next-k continuation, LRU-bounded memory. The paper's mode.
  * batched      — device-side level-synchronous beam search
    (core/batched.py): request batching with a fixed tick, the TPU mode.

  PYTHONPATH=src python -m repro.launch.serve --demo
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import (
    BatchedSearcher,
    ECPBuildConfig,
    ECPIndex,
    build_index,
    load_packed,
)
from repro.data import clustered_vectors


@dataclass
class ServeStats:
    queries: int = 0
    continuations: int = 0
    latencies_ms: list = field(default_factory=list)

    def summary(self) -> dict:
        lat = sorted(self.latencies_ms)
        n = len(lat)
        return {
            "queries": self.queries,
            "continuations": self.continuations,
            "p50_ms": lat[n // 2] if n else None,
            "p99_ms": lat[int(n * 0.99)] if n else None,
        }


class InteractiveServer:
    """The paper's serving mode: query states + incremental retrieval."""

    def __init__(self, index_path: str, *, cache_max_nodes: int | None = None):
        self.index = ECPIndex(index_path, cache_max_nodes=cache_max_nodes)
        self.stats = ServeStats()

    def search(self, q, k=100, b=8):
        t0 = time.perf_counter()
        res, qid = self.index.new_search(np.asarray(q, np.float32), k, b=b)
        self.stats.queries += 1
        self.stats.latencies_ms.append((time.perf_counter() - t0) * 1e3)
        return res, qid

    def more(self, qid, k=100):
        t0 = time.perf_counter()
        res = self.index.get_next_k(qid, k)
        self.stats.continuations += 1
        self.stats.latencies_ms.append((time.perf_counter() - t0) * 1e3)
        return res


class BatchedServer:
    """TPU mode: collect requests, run one device beam-search per tick."""

    def __init__(self, index_path: str):
        self.searcher = BatchedSearcher(load_packed(ECPIndex(index_path).store))
        self.stats = ServeStats()
        self._sessions: dict[int, tuple] = {}
        self._next_sid = 0

    def search_batch(self, Q, k=100, b=8):
        t0 = time.perf_counter()
        d, i, state = self.searcher.search(np.asarray(Q, np.float32), k, b=b)
        sid = self._next_sid
        self._next_sid += 1
        self._sessions[sid] = (np.asarray(Q, np.float32), state)
        self.stats.queries += Q.shape[0]
        self.stats.latencies_ms.append((time.perf_counter() - t0) * 1e3)
        return np.asarray(d), np.asarray(i), sid

    def more_batch(self, sid, k=100, b=8):
        t0 = time.perf_counter()
        Q, state = self._sessions[sid]
        d, i, state = self.searcher.next_k(Q, state, k, b=b)
        self._sessions[sid] = (Q, state)
        self.stats.continuations += Q.shape[0]
        self.stats.latencies_ms.append((time.perf_counter() - t0) * 1e3)
        return np.asarray(d), np.asarray(i)


def demo() -> None:
    import tempfile

    data, _ = clustered_vectors(0, n=50_000, dim=128, n_clusters=256)
    with tempfile.TemporaryDirectory() as td:
        path = td + "/idx"
        print("building index ...")
        build_index(data, path, ECPBuildConfig(levels=2, cluster_cap=200, metric="l2"))
        srv = InteractiveServer(path, cache_max_nodes=64)
        rng = np.random.default_rng(1)
        qs = data[rng.integers(0, len(data), 32)] + 0.01 * rng.normal(size=(32, 128)).astype(np.float32)
        sessions = []
        for q in qs:
            res, qid = srv.search(q, k=20, b=8)
            sessions.append(qid)
        for qid in sessions[:8]:
            srv.more(qid, k=20)
        print("interactive:", srv.stats.summary())
        bsrv = BatchedServer(path)
        d, i, sid = bsrv.search_batch(qs, k=20, b=8)
        bsrv.more_batch(sid, k=20)
        print("batched:    ", bsrv.stats.summary())


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--demo", action="store_true")
    args = ap.parse_args()
    if args.demo:
        demo()
    else:
        print("use --demo (library mode: import InteractiveServer/BatchedServer)")
