"""ANN serving driver — the paper's own application as a service loop.

One ``Server`` class over ANY ``Searcher`` (core/api.py): the serving
logic no longer cares whether requests hit the host-driven file structure
(``open_index(path, mode="file")`` — per-query state, get-next-k
continuation, LRU-bounded memory: the paper's mode) or the device-side
level-synchronous beam search (``mode="packed"`` — request batching, the
TPU mode).  Continuations are tracked as ``Query`` handles behind integer
session ids; closing a session frees its state and later use raises
``QueryClosedError`` — not a silent crash.

When the searcher is a ``MutableIndex`` (file-mode eCP-FS), the server
also exposes the write path: ``insert`` / ``delete`` apply while read
sessions stay valid (inserts append, deletes tombstone); ``compact``
rewrites the tree, after which resuming a pre-compaction session raises
``StaleQueryError`` — the client re-issues the search.

  PYTHONPATH=src python -m repro.launch.serve --demo
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import (
    ECPBuildConfig,
    MutableIndex,
    QueryClosedError,
    ResultSet,
    Searcher,
    build_index,
    convert,
    open_index,
)
from repro.data import clustered_vectors


@dataclass
class ServeStats:
    queries: int = 0
    continuations: int = 0
    inserts: int = 0
    deletes: int = 0
    compactions: int = 0
    latencies_ms: list = field(default_factory=list)

    def summary(self) -> dict:
        lat = sorted(self.latencies_ms)
        n = len(lat)
        out = {
            "queries": self.queries,
            "continuations": self.continuations,
            "p50_ms": lat[n // 2] if n else None,
            "p99_ms": lat[int(n * 0.99)] if n else None,
        }
        if self.inserts or self.deletes or self.compactions:
            out.update(
                inserts=self.inserts, deletes=self.deletes, compactions=self.compactions
            )
        return out


class Server:
    """Serving loop over any unified-API searcher.

    ``search`` answers one vector or a whole request batch and returns
    ``(ResultSet, session_id)``; ``more`` resumes a session via its Query
    handle; ``close`` drops it.  Works identically for file-mode eCP-FS,
    the packed device searcher, and any baseline.
    """

    def __init__(self, searcher: Searcher):
        self.searcher = searcher
        self.stats = ServeStats()
        self._sessions: dict[int, object] = {}
        self._next_sid = 0

    def search(self, q, k: int = 100, *, b=None, **opts) -> tuple[ResultSet, int]:
        t0 = time.perf_counter()
        rs = self.searcher.search(np.asarray(q, np.float32), k, b=b, **opts)
        sid = self._next_sid
        self._next_sid += 1
        self._sessions[sid] = rs.query
        self.stats.queries += 1 if rs.ids.ndim == 1 else rs.ids.shape[0]
        self.stats.latencies_ms.append((time.perf_counter() - t0) * 1e3)
        return rs, sid

    def _session(self, sid: int):
        q = self._sessions.get(sid)
        if q is None:
            raise QueryClosedError(f"unknown or closed session: {sid}")
        return q

    def more(self, sid: int, k: int = 100) -> ResultSet:
        t0 = time.perf_counter()
        rs = self._session(sid).next(k)
        self.stats.continuations += 1 if rs.ids.ndim == 1 else rs.ids.shape[0]
        self.stats.latencies_ms.append((time.perf_counter() - t0) * 1e3)
        return rs

    def close(self, sid: int) -> None:
        q = self._session(sid)
        del self._sessions[sid]
        q.close()

    @property
    def open_sessions(self) -> int:
        return len(self._sessions)

    # ------------------------------------------------------------ mutation
    def _mutable(self) -> MutableIndex:
        s = self.searcher
        if not isinstance(s, MutableIndex):
            raise TypeError(
                f"{type(s).__name__} is not a MutableIndex; the write path "
                "needs a file-mode eCP index (open_index(mode='file'))"
            )
        return s

    def insert(self, vectors, ids=None) -> dict:
        """Ingest vectors while serving; open sessions stay valid."""
        r = self._mutable().insert(vectors, ids)
        self.stats.inserts += r["inserted"]
        return r

    def delete(self, ids) -> int:
        """Tombstone items; results filter them immediately."""
        n = self._mutable().delete(ids)
        self.stats.deletes += n
        return n

    def compact(self) -> dict:
        """Rewrite the index; pre-compaction sessions turn stale (resuming
        one raises StaleQueryError) but stay registered until closed."""
        r = self._mutable().compact()
        self.stats.compactions += 1
        return r

    def shutdown(self) -> None:
        """Close every open session and the searcher itself."""
        for sid in list(self._sessions):
            self.close(sid)
        close = getattr(self.searcher, "close", None)
        if close is not None:
            close()

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()


def demo(backend: str = "fstore") -> None:
    import tempfile

    data, _ = clustered_vectors(0, n=50_000, dim=128, n_clusters=256)
    with tempfile.TemporaryDirectory() as td:
        path = td + "/idx"
        print("building index ...")
        build_index(data, path, ECPBuildConfig(levels=2, cluster_cap=200, metric="l2"))
        blob = str(convert(path, td + "/idx.blob"))
        rng = np.random.default_rng(1)
        qs = data[rng.integers(0, len(data), 32)] + 0.01 * rng.normal(size=(32, 128)).astype(np.float32)

        # interactive: the paper's mode — one request at a time, bounded RAM;
        # the node storage is the --backend axis (fstore | blob | blob+prefetch)
        idx = open_index(
            path if backend == "fstore" else blob,
            mode="file", backend=backend, cache_max_nodes=64,
        )
        with Server(idx) as srv:  # shutdown() closes sessions + the index
            sids = [srv.search(q, k=20, b=8)[1] for q in qs]
            for sid in sids[:8]:
                srv.more(sid, k=20)
            for sid in sids:
                srv.close(sid)

            # the write path: ingest + tombstone while serving, then compact
            new = data[:64] + 0.02 * rng.normal(size=(64, 128)).astype(np.float32)
            srv.insert(new, np.arange(len(data), len(data) + 64))
            srv.delete(np.arange(0, 500, 7))
            hit = srv.search(new[0], k=5, b=8)[0]
            assert len(data) in hit.row_ids(0), "inserted item must be findable"
            print(f"compacted: {srv.compact()}")
            print(f"interactive[{backend}]:", srv.stats.summary())
            print("  store io:", idx.store.io.as_dict())

        # batched: same Server, device searcher, whole batch per tick
        with Server(open_index(path, mode="packed")) as bsrv:
            rs, sid = bsrv.search(qs, k=20, b=8)
            bsrv.more(sid, k=20)
            bsrv.close(sid)
            print("batched:    ", bsrv.stats.summary())


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--demo", action="store_true")
    ap.add_argument(
        "--backend", choices=("fstore", "blob", "blob+prefetch"), default="fstore",
        help="node storage for the interactive (file-mode) server",
    )
    args = ap.parse_args()
    if args.demo:
        demo(args.backend)
    else:
        print("use --demo (library mode: import Server + repro.core.open_index)")
