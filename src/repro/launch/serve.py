"""ANN serving driver — the paper's own application as a service loop.

One ``Server`` class over ANY ``Searcher`` (core/api.py): the serving
logic no longer cares whether requests hit the host-driven file structure
(``open_index(path, mode="file")`` — per-query state, get-next-k
continuation, LRU-bounded memory: the paper's mode) or the device-side
level-synchronous beam search (``mode="packed"`` — request batching, the
TPU mode).  Continuations are tracked as ``Query`` handles behind integer
session ids; closing a session frees its state and later use raises
``QueryClosedError`` — not a silent crash.

Concurrency (``workers > 0``): searches go through a
``launch/scheduler.RequestScheduler`` — a bounded admission queue (full
queue rejects with ``ServerOverloadedError``: backpressure, not unbounded
buffering), a worker pool, per-request deadlines mapped onto the effort
knob ``b`` (overload degrades recall, not latency), and snapshot-isolated
reads on pinning (blob) stores so searches never block on a writer.
``workers=0`` (the default) keeps the original synchronous behavior.

Sessions are bounded too: at most ``session_cap`` live continuations
(least-recently-used evicted first) and an optional ``session_ttl_s``
idle timeout; using an evicted session raises ``QueryClosedError``.

When the searcher is a ``MutableIndex`` (file-mode eCP-FS), the server
also exposes the write path: ``insert`` / ``delete`` apply while read
sessions stay valid (inserts append, deletes tombstone); ``compact``
rewrites the tree, after which resuming a pre-compaction session raises
``StaleQueryError`` — the client re-issues the search.  (Sessions served
from a snapshot keep their pinned generation and never turn stale.)

  PYTHONPATH=src python -m repro.launch.serve --demo
"""
from __future__ import annotations

import argparse
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from repro.core import (
    ECPBuildConfig,
    MutableIndex,
    QueryClosedError,
    ResultSet,
    Searcher,
    build_index,
    convert,
    open_index,
)
from repro.data import clustered_vectors
from repro.launch.scheduler import (
    DeadlinePolicy,
    RequestScheduler,
    ServerOverloadedError,
)

__all__ = ["LatencyRing", "Server", "ServeStats", "ServerOverloadedError", "demo"]


class LatencyRing:
    """Fixed-capacity ring of latency samples: O(capacity) memory no
    matter how long the server runs, percentiles over the most recent
    ``capacity`` observations.  Callers synchronize (ServeStats holds the
    lock)."""

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._buf = np.zeros(self.capacity, np.float64)
        self.count = 0  # total ever recorded (>= len(values()))

    def record(self, ms: float) -> None:
        self._buf[self.count % self.capacity] = ms
        self.count += 1

    def values(self) -> np.ndarray:
        return self._buf[: min(self.count, self.capacity)].copy()

    def percentile(self, p: float):
        n = min(self.count, self.capacity)
        if n == 0:
            return None
        return float(np.percentile(self._buf[:n], p))


class ServeStats:
    """Thread-safe serving counters with bounded latency memory.

    Latencies are kept in per-phase ``LatencyRing`` buffers ("search",
    "more", ...) instead of an append-forever list; every update happens
    under one lock so the multi-threaded scheduler path can share it.
    """

    def __init__(self, ring_capacity: int = 4096):
        self._lock = threading.Lock()
        self._capacity = int(ring_capacity)
        self._rings: dict[str, LatencyRing] = {}
        self.queries = 0
        self.continuations = 0
        self.inserts = 0
        self.deletes = 0
        self.compactions = 0
        self.evicted_sessions = 0

    def record(self, phase: str, ms: float) -> None:
        with self._lock:
            ring = self._rings.get(phase)
            if ring is None:
                ring = self._rings[phase] = LatencyRing(self._capacity)
            ring.record(ms)

    def count(self, field_name: str, n: int = 1) -> None:
        with self._lock:
            setattr(self, field_name, getattr(self, field_name) + n)

    def ring(self, phase: str) -> LatencyRing | None:
        with self._lock:
            return self._rings.get(phase)

    def summary(self) -> dict:
        with self._lock:
            merged = (
                np.concatenate([r.values() for r in self._rings.values()])
                if self._rings
                else np.empty(0)
            )
            out = {
                "queries": self.queries,
                "continuations": self.continuations,
                "p50_ms": float(np.percentile(merged, 50)) if merged.size else None,
                "p99_ms": float(np.percentile(merged, 99)) if merged.size else None,
            }
            for phase, ring in self._rings.items():
                out[f"{phase}_p50_ms"] = ring.percentile(50)
                out[f"{phase}_p99_ms"] = ring.percentile(99)
            if self.inserts or self.deletes or self.compactions:
                out.update(
                    inserts=self.inserts,
                    deletes=self.deletes,
                    compactions=self.compactions,
                )
            if self.evicted_sessions:
                out["evicted_sessions"] = self.evicted_sessions
        return out


@dataclass
class _Session:
    query: object               # the Query continuation handle
    lease: object = None        # ECPSnapshot lease backing it (or None)
    last_used: float = 0.0
    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def dispose(self) -> None:
        try:
            self.query.close()
        finally:
            if self.lease is not None:
                self.lease.release()
                self.lease = None


class Server:
    """Serving loop over any unified-API searcher.

    ``search`` answers one vector or a whole request batch and returns
    ``(ResultSet, session_id)``; ``more`` resumes a session via its Query
    handle; ``close`` drops it.  Works identically for file-mode eCP-FS,
    the packed device searcher, and any baseline.

    With ``workers > 0`` searches run on a ``RequestScheduler`` worker
    pool: pass ``deadline_ms=`` to ``search`` to let the deadline policy
    shrink ``b``; a full admission queue raises ``ServerOverloadedError``.
    Continuations (``more``) always run on the calling thread — their
    state is single-owner — under the session's own lock.
    """

    def __init__(
        self,
        searcher: Searcher,
        *,
        workers: int = 0,
        queue_depth: int = 64,
        session_cap: int = 1024,
        session_ttl_s: float | None = None,
        policy: DeadlinePolicy | None = None,
        default_b: int = 8,
        clock=time.monotonic,
    ):
        self.searcher = searcher
        self.stats = ServeStats()
        self.session_cap = int(session_cap)
        self.session_ttl_s = session_ttl_s
        self._clock = clock
        self._lock = threading.Lock()
        self._sessions: OrderedDict[int, _Session] = OrderedDict()
        self._next_sid = 0
        self.scheduler: RequestScheduler | None = None
        if workers > 0:
            self.scheduler = RequestScheduler(
                searcher,
                workers=workers,
                queue_depth=queue_depth,
                policy=policy,
                default_b=default_b,
            )

    # ------------------------------------------------------------- sessions
    def _register(self, query, lease=None) -> int:
        evicted: list[_Session] = []
        with self._lock:
            now = self._clock()
            self._evict_locked(now, evicted)
            while len(self._sessions) >= self.session_cap:
                _, old = self._sessions.popitem(last=False)
                evicted.append(old)
            sid = self._next_sid
            self._next_sid += 1
            self._sessions[sid] = _Session(query=query, lease=lease, last_used=now)
        for s in evicted:
            self.stats.count("evicted_sessions")
            s.dispose()
        return sid

    def _evict_locked(self, now: float, out: list) -> None:
        if self.session_ttl_s is None:
            return
        while self._sessions:
            sid, sess = next(iter(self._sessions.items()))
            if now - sess.last_used <= self.session_ttl_s:
                break
            del self._sessions[sid]
            out.append(sess)

    def _session(self, sid: int) -> _Session:
        with self._lock:
            sess = self._sessions.get(sid)
            if sess is None:
                raise QueryClosedError(f"unknown, closed, or evicted session: {sid}")
            sess.last_used = self._clock()
            self._sessions.move_to_end(sid)
            return sess

    # -------------------------------------------------------------- reading
    def search(
        self, q, k: int = 100, *, b=None, deadline_ms=None, **opts
    ) -> tuple[ResultSet, int]:
        """Serve one search; extra ``opts`` (e.g. the recall knob
        ``probe_m``) flow through to the underlying searcher."""
        t0 = time.perf_counter()
        if self.scheduler is not None:
            res = self.scheduler.search(q, k, b=b, deadline_ms=deadline_ms, **opts)
            rs, lease = res.rs, res.lease
        else:
            rs = self.searcher.search(np.asarray(q, np.float32), k, b=b, **opts)
            lease = None
        sid = self._register(rs.query, lease)
        n = 1 if rs.ids.ndim == 1 else rs.ids.shape[0]
        self.stats.count("queries", n)
        self.stats.record("search", (time.perf_counter() - t0) * 1e3)
        return rs, sid

    def submit(self, q, k: int = 100, *, b=None, deadline_ms=None, **opts):
        """Async variant (needs ``workers > 0``): returns a Future of a
        ``(ResultSet, session_id)`` pair; may raise ServerOverloadedError."""
        if self.scheduler is None:
            raise RuntimeError("submit() needs Server(..., workers>0)")
        t0 = time.perf_counter()
        inner = self.scheduler.submit(q, k, b=b, deadline_ms=deadline_ms, **opts)
        from concurrent.futures import Future

        outer: Future = Future()

        def _done(f):
            if f.exception() is not None:
                outer.set_exception(f.exception())
                return
            res = f.result()
            sid = self._register(res.rs.query, res.lease)
            n = 1 if res.rs.ids.ndim == 1 else res.rs.ids.shape[0]
            self.stats.count("queries", n)
            self.stats.record("search", (time.perf_counter() - t0) * 1e3)
            outer.set_result((res.rs, sid))

        inner.add_done_callback(_done)
        return outer

    def more(self, sid: int, k: int = 100) -> ResultSet:
        t0 = time.perf_counter()
        sess = self._session(sid)
        guard = (
            self.scheduler.read_lock()
            if self.scheduler is not None and sess.lease is None
            else _NULL_CTX
        )
        with sess.lock, guard:
            rs = sess.query.next(k)
        self.stats.count(
            "continuations", 1 if rs.ids.ndim == 1 else rs.ids.shape[0]
        )
        self.stats.record("more", (time.perf_counter() - t0) * 1e3)
        return rs

    def close(self, sid: int) -> None:
        with self._lock:
            sess = self._sessions.pop(sid, None)
        if sess is None:
            raise QueryClosedError(f"unknown, closed, or evicted session: {sid}")
        sess.dispose()

    @property
    def open_sessions(self) -> int:
        with self._lock:
            return len(self._sessions)

    # ------------------------------------------------------------ mutation
    def _mutable(self) -> MutableIndex:
        s = self.searcher
        if not isinstance(s, MutableIndex):
            raise TypeError(
                f"{type(s).__name__} is not a MutableIndex; the write path "
                "needs a file-mode eCP index (open_index(mode='file'))"
            )
        return s

    def _mutate(self, fn):
        if self.scheduler is not None:
            return self.scheduler.mutate(fn)
        return fn()

    def insert(self, vectors, ids=None) -> dict:
        """Ingest vectors while serving; open sessions stay valid."""
        r = self._mutate(lambda: self._mutable().insert(vectors, ids))
        self.stats.count("inserts", r["inserted"])
        return r

    def delete(self, ids) -> int:
        """Tombstone items; results filter them immediately."""
        n = self._mutate(lambda: self._mutable().delete(ids))
        self.stats.count("deletes", n)
        return n

    def compact(self) -> dict:
        """Rewrite the index; pre-compaction live sessions turn stale
        (resuming one raises StaleQueryError) but stay registered until
        closed.  Snapshot-backed sessions keep their pinned generation."""
        r = self._mutate(lambda: self._mutable().compact())
        self.stats.count("compactions")
        return r

    def shutdown(self) -> None:
        """Close every open session, the scheduler, and the searcher."""
        with self._lock:
            sessions = list(self._sessions.values())
            self._sessions.clear()
        for s in sessions:
            s.dispose()
        if self.scheduler is not None:
            self.scheduler.shutdown()
        close = getattr(self.searcher, "close", None)
        if close is not None:
            close()

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()


class _NullCtx:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


_NULL_CTX = _NullCtx()


def demo(backend: str = "fstore") -> None:
    import tempfile

    data, _ = clustered_vectors(0, n=50_000, dim=128, n_clusters=256)
    with tempfile.TemporaryDirectory() as td:
        path = td + "/idx"
        print("building index ...")
        build_index(data, path, ECPBuildConfig(levels=2, cluster_cap=200, metric="l2"))
        blob = str(convert(path, td + "/idx.blob"))
        rng = np.random.default_rng(1)
        qs = data[rng.integers(0, len(data), 32)] + 0.01 * rng.normal(size=(32, 128)).astype(np.float32)

        # interactive: the paper's mode — one request at a time, bounded RAM;
        # the node storage is the --backend axis (fstore | blob | blob+prefetch)
        idx = open_index(
            path if backend == "fstore" else blob,
            mode="file", backend=backend, cache_max_nodes=64,
        )
        with Server(idx) as srv:  # shutdown() closes sessions + the index
            sids = [srv.search(q, k=20, b=8)[1] for q in qs]
            for sid in sids[:8]:
                srv.more(sid, k=20)
            for sid in sids:
                srv.close(sid)

            # the write path: ingest + tombstone while serving, then compact
            new = data[:64] + 0.02 * rng.normal(size=(64, 128)).astype(np.float32)
            srv.insert(new, np.arange(len(data), len(data) + 64))
            srv.delete(np.arange(0, 500, 7))
            hit = srv.search(new[0], k=5, b=8)[0]
            assert len(data) in hit.row_ids(0), "inserted item must be findable"
            print(f"compacted: {srv.compact()}")
            print(f"interactive[{backend}]:", srv.stats.summary())
            print("  store io:", idx.store.io.as_dict())

        # concurrent: worker pool + deadline-aware effort on the blob store
        # (snapshot-isolated reads: searches never block on the writer)
        cidx = open_index(blob, mode="file", backend="blob", cache_max_nodes=64)
        with Server(cidx, workers=4, queue_depth=32) as csrv:
            futs = [csrv.submit(q, k=20, b=8, deadline_ms=50.0) for q in qs]
            csrv.insert(new, np.arange(len(data) + 64, len(data) + 128))
            for f in futs:
                _, sid = f.result()
                csrv.close(sid)
            print("concurrent: ", csrv.stats.summary())
            print("  scheduler:", csrv.scheduler.stats.as_dict())

        # batched: same Server, device searcher, whole batch per tick
        with Server(open_index(path, mode="packed")) as bsrv:
            rs, sid = bsrv.search(qs, k=20, b=8)
            bsrv.more(sid, k=20)
            bsrv.close(sid)
            print("batched:    ", bsrv.stats.summary())


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--demo", action="store_true")
    ap.add_argument(
        "--backend", choices=("fstore", "blob", "blob+prefetch"), default="fstore",
        help="node storage for the interactive (file-mode) server",
    )
    args = ap.parse_args()
    if args.demo:
        demo(args.backend)
    else:
        print("use --demo (library mode: import Server + repro.core.open_index)")
