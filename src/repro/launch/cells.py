"""Cell construction: one lowerable (step_fn, abstract inputs, shardings)
per (architecture x input shape).

A Cell is everything the dry-run needs and nothing it must materialize:
  fn          the step function (train_step / prefill / decode / serve ...)
  args        ShapeDtypeStruct pytrees (weak-type-correct stand-ins)
  in_pspecs   PartitionSpec pytrees, same structure as args
  donate      argnums donated (state/caches) — buffer reuse in the compile
  meta        param counts / token counts for the roofline bench

``example_inputs`` materializes tiny concrete inputs for the SAME cell
definitions at reduced scale — smoke tests and the dry-run share one code
path, so what we smoke-test is what we lower.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import arch_shapes, get_arch
from repro.launch import mesh as mesh_lib
from repro.models import abstract_params, gnn, param_count, param_pspecs, recsys
from repro.models import transformer as T
from repro.models.base import init_params
from repro.models.retrieval_attention import ClusteredKVCache
from repro.optim import adamw, apply_updates, warmup_cosine

__all__ = ["Cell", "build_cell", "make_rules", "example_inputs", "lower_cell", "make_train_step"]

SDS = jax.ShapeDtypeStruct


@dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: tuple
    in_pspecs: tuple
    donate: tuple = ()
    out_pspecs: Any = None     # optional out_shardings pytree
    meta: dict = field(default_factory=dict)


def make_rules(mesh_axes) -> T.ShardingRules:
    batch = tuple(a for a in ("pod", "data") if a in mesh_axes)
    return T.ShardingRules(
        batch=batch, model="model" if "model" in mesh_axes else None
    )


# ------------------------------------------------------------- train step
def make_train_step(loss_fn, opt, *, microbatches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    microbatches > 1: gradient accumulation via lax.scan over batch chunks —
    activation memory scales 1/n while the optimizer state is touched once.
    """

    def step(params, opt_state, batch):
        if microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            chunks = jax.tree.map(split, batch)

            def acc_body(carry, chunk):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, chunk)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), ms = jax.lax.scan(acc_body, (g0, 0.0), chunks)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, **metrics}

    return step


def _opt_for(cfg) -> Any:
    mdt = jnp.bfloat16 if getattr(cfg, "param_dtype", jnp.float32) == jnp.bfloat16 else jnp.float32
    return adamw(warmup_cosine(3e-4, 200, 10_000), moment_dtype=mdt)


def _abstract_opt(aparams, moment_dtype):
    m = jax.tree.map(lambda s: SDS(s.shape, moment_dtype), aparams)
    return {"mu": m, "nu": jax.tree.map(lambda s: SDS(s.shape, moment_dtype), aparams), "step": SDS((), jnp.int32)}


def _opt_pspecs(pparams):
    return {"mu": pparams, "nu": pparams, "step": P()}


# ------------------------------------------------------------------ LM
def _lm_cell(arch: str, cfg: T.LMConfig, shape_id: str, sh: dict, rules: T.ShardingRules) -> Cell:
    seq, batch = sh["seq"], sh["batch"]
    cfg = replace(cfg, max_seq=seq)
    specs = T.param_specs(cfg)
    aparams = abstract_params(specs)
    pparams = param_pspecs(specs)
    n_params = param_count(specs)
    Bax = rules.batch if rules.batch else None
    meta = {"n_params": n_params, "family": "lm", "cfg": cfg}
    # Megatron-style sequence parallelism for the residual stream: the
    # per-layer saved activations shard their seq dim over "model" (the
    # 123B x 88L checkpoint chain is 141 GiB/device without this).
    sp_rules = replace(rules, seq=rules.model) if rules.model else rules

    if sh["kind"] == "train":
        # Distribution policy (EXPERIMENTS.md §Perf iteration 2): dense LMs
        # on the single pod train pure-FSDP — batch over data x model (256-
        # way DP), params ZeRO-3 over both axes, ZERO activation
        # collectives. At 4096 tokens/device the parameter all-gather sits
        # at the ICI break-even (~3.9 kFLOP/byte), beating Megatron-SP whose
        # activation AG/RS dominated. MoE archs keep SP + expert-parallel
        # (replicating expert weights is never affordable); the multi-pod
        # mesh keeps TP=16 because GBS 256 < 512 chips.
        single_pod = "pod" not in (rules.batch or ()) and rules.model is not None
        if single_pod and cfg.moe is None and batch % 256 == 0:
            cfg = replace(cfg, fsdp_axis=("data", "model"), pure_fsdp=True, microbatches=1)
            t_rules = T.ShardingRules(batch=("data", "model"), model=None, seq=None)
            Bax_t = ("data", "model")
        else:
            t_rules = sp_rules
            Bax_t = Bax
        specs_t = T.param_specs(cfg)
        aparams_t = abstract_params(specs_t)
        pparams_t = param_pspecs(specs_t)
        meta["cfg"] = cfg
        opt = _opt_for(cfg)
        mdt = jnp.bfloat16 if cfg.param_dtype == jnp.bfloat16 else jnp.float32
        loss_fn = lambda p, b: T.lm_loss(p, b, cfg, t_rules)
        fn = make_train_step(loss_fn, opt, microbatches=cfg.microbatches)
        args = (aparams_t, _abstract_opt(aparams_t, mdt), {"tokens": SDS((batch, seq), jnp.int32)})
        pspecs = (pparams_t, _opt_pspecs(pparams_t), {"tokens": P(Bax_t, None)})
        out_ps = (pparams_t, _opt_pspecs(pparams_t), {"loss": P(), "xent": P(), "aux": P()})
        meta["tokens"] = batch * (seq - 1)
        return Cell(arch, shape_id, "train", fn, args, pspecs, donate=(0, 1),
                    out_pspecs=out_ps, meta=meta)

    if sh["kind"] == "prefill":
        fn = lambda params, tokens: T.prefill(params, tokens, cfg, sp_rules, max_seq=seq)
        args = (aparams, SDS((batch, seq), jnp.int32))
        pspecs = (pparams, P(Bax, None))
        out_ps = (
            P(Bax, None),                                       # last-pos logits
            T.KVCache(k=P(None, Bax, None, "model", None),
                      v=P(None, Bax, None, "model", None), pos=P()),
        )
        meta["tokens"] = batch * seq
        return Cell(arch, shape_id, "prefill", fn, args, pspecs, out_pspecs=out_ps, meta=meta)

    if sh["kind"] == "decode":
        cshape = (cfg.n_layers, batch, cfg.n_kv_heads, seq, cfg.d_head)
        acache = T.KVCache(k=SDS(cshape, cfg.dtype), v=SDS(cshape, cfg.dtype), pos=SDS((), jnp.int32))
        pcache = T.KVCache(
            k=P(None, Bax, None, "model", None),
            v=P(None, Bax, None, "model", None),
            pos=P(),
        )
        fn = lambda params, cache, tokens: T.decode_step(params, cache, tokens, cfg, rules)
        args = (aparams, acache, SDS((batch,), jnp.int32))
        pspecs = (pparams, pcache, P(Bax))
        out_ps = (P(Bax, None), pcache)
        meta["tokens"] = batch
        return Cell(arch, shape_id, "decode", fn, args, pspecs, donate=(1,),
                    out_pspecs=out_ps, meta=meta)

    if sh["kind"] == "retrieval_decode":
        cs = cfg.retrieval.cluster_size
        nC = -(-seq // cs)
        kv = (cfg.n_layers, batch, cfg.n_kv_heads, nC, cs, cfg.d_head)
        ce = (cfg.n_layers, batch, cfg.n_kv_heads, nC, cfg.d_head)
        seq_ax = (tuple(rules.batch) + ("model",)) if rules.batch else None
        acache = ClusteredKVCache(
            k=SDS(kv, cfg.dtype), v=SDS(kv, cfg.dtype),
            centroids=SDS(ce, jnp.float32), pos=SDS((), jnp.int32),
        )
        pcache = ClusteredKVCache(
            k=P(None, None, None, seq_ax, None, None),
            v=P(None, None, None, seq_ax, None, None),
            centroids=P(None, None, None, seq_ax, None),
            pos=P(),
        )
        fn = lambda params, cache, tokens: T.retrieval_decode_step(params, cache, tokens, cfg, rules)
        args = (aparams, acache, SDS((batch,), jnp.int32))
        pspecs = (pparams, pcache, P(None))
        out_ps = (P(None, None), pcache)
        meta["tokens"] = batch
        meta["n_clusters"] = nC
        return Cell(arch, shape_id, "retrieval_decode", fn, args, pspecs, donate=(1,),
                    out_pspecs=out_ps, meta=meta)

    raise ValueError(sh["kind"])


# ------------------------------------------------------------------ GNN
def _gnn_cell(arch: str, cfg0, shape_id: str, sh: dict, rules) -> Cell:
    Bax = rules.batch if rules.batch else None
    node_ax = (tuple(rules.batch) + ("model",)) if rules.batch else None

    if sh["kind"] == "full_graph":
        cfg = replace(cfg0, d_in=sh["d_feat"], n_classes=sh["n_classes"])
        specs = gnn.param_specs(cfg)
        aparams, pparams = abstract_params(specs), param_pspecs(specs)
        opt = adamw(3e-3)
        loss_fn = lambda p, b: gnn.gnn_loss_full(p, b, cfg)
        fn = make_train_step(loss_fn, opt)
        # pad node/edge counts to shard-divisible sizes (512 covers both
        # production meshes); pads carry edge_weight 0 / label_mask 0
        mult = 512
        N = -(-sh["n_nodes"] // mult) * mult
        E = -(-sh["n_edges"] // mult) * mult
        batch = {
            "feats": SDS((N, sh["d_feat"]), jnp.float32),
            "edge_src": SDS((E,), jnp.int32),
            "edge_dst": SDS((E,), jnp.int32),
            "edge_weight": SDS((E,), jnp.float32),
            "labels": SDS((N,), jnp.int32),
            "label_mask": SDS((N,), jnp.float32),
        }
        pbatch = {
            "feats": P(node_ax, None),
            "edge_src": P(node_ax),
            "edge_dst": P(node_ax),
            "edge_weight": P(node_ax),
            "labels": P(node_ax),
            "label_mask": P(node_ax),
        }
        args = (aparams, _abstract_opt(aparams, jnp.float32), batch)
        pspecs = (pparams, _opt_pspecs(pparams), pbatch)
        return Cell(arch, shape_id, "train", fn, args, pspecs, donate=(0, 1),
                    meta={"n_params": param_count(specs), "family": "gnn", "cfg": cfg})

    if sh["kind"] == "sampled":
        cfg = replace(cfg0, d_in=sh["d_feat"], n_classes=sh["n_classes"], fanouts=sh["fanouts"])
        specs = gnn.param_specs(cfg)
        aparams, pparams = abstract_params(specs), param_pspecs(specs)
        opt = adamw(3e-3)
        loss_fn = lambda p, b: gnn.gnn_loss_sampled(p, b, cfg)
        fn = make_train_step(loss_fn, opt)
        B, d = sh["batch_nodes"], sh["d_feat"]
        f1, f2 = sh["fanouts"]
        batch = {
            "hops": (
                SDS((B, f1, f2, d), jnp.float32),
                SDS((B, f1, d), jnp.float32),
                SDS((B, d), jnp.float32),
            ),
            "labels": SDS((B,), jnp.int32),
        }
        pbatch = {
            "hops": (P(Bax, None, None, None), P(Bax, None, None), P(Bax, None)),
            "labels": P(Bax),
        }
        args = (aparams, _abstract_opt(aparams, jnp.float32), batch)
        pspecs = (pparams, _opt_pspecs(pparams), pbatch)
        return Cell(arch, shape_id, "train", fn, args, pspecs, donate=(0, 1),
                    meta={"n_params": param_count(specs), "family": "gnn", "cfg": cfg})

    if sh["kind"] == "graphs":
        cfg = replace(cfg0, d_in=sh["d_feat"], n_classes=sh["n_classes"])
        specs = gnn.param_specs(cfg)
        aparams, pparams = abstract_params(specs), param_pspecs(specs)
        opt = adamw(3e-3)
        loss_fn = lambda p, b: gnn.gnn_loss_graphs(p, b, cfg)
        fn = make_train_step(loss_fn, opt)
        G, N, E = sh["batch"], sh["n_nodes"], sh["n_edges"]
        batch = {
            "feats": SDS((G, N, sh["d_feat"]), jnp.float32),
            "edge_src": SDS((G, E), jnp.int32),
            "edge_dst": SDS((G, E), jnp.int32),
            "node_mask": SDS((G, N), jnp.float32),
            "labels": SDS((G,), jnp.int32),
        }
        pbatch = {
            "feats": P(Bax, None, None),
            "edge_src": P(Bax, None),
            "edge_dst": P(Bax, None),
            "node_mask": P(Bax, None),
            "labels": P(Bax),
        }
        args = (aparams, _abstract_opt(aparams, jnp.float32), batch)
        pspecs = (pparams, _opt_pspecs(pparams), pbatch)
        return Cell(arch, shape_id, "train", fn, args, pspecs, donate=(0, 1),
                    meta={"n_params": param_count(specs), "family": "gnn", "cfg": cfg})

    raise ValueError(sh["kind"])


# --------------------------------------------------------------- recsys
def _recsys_batch_specs(cfg, batch: int, *, labeled: bool):
    n_plain = cfg.n_fields - cfg.seq_fields
    out = {"cat": SDS((batch, n_plain), jnp.int32)}
    if cfg.n_dense:
        out["dense"] = SDS((batch, cfg.n_dense), jnp.float32)
    if cfg.seq_len:
        out["seq"] = SDS((batch, cfg.seq_len, cfg.seq_fields), jnp.int32)
        out["seq_mask"] = SDS((batch, cfg.seq_len), jnp.float32)
        out["target"] = SDS((batch, cfg.seq_fields), jnp.int32)
    if labeled:
        out["label"] = SDS((batch,), jnp.float32)
    return out


def _recsys_batch_pspecs(batch_specs, Bax):
    # batch-1 cells (retrieval_cand) cannot shard their batch dim
    return {
        k: P(*(((Bax if v.shape[0] > 1 else None),) + (None,) * (len(v.shape) - 1)))
        for k, v in batch_specs.items()
    }


def _recsys_cell(arch: str, cfg, shape_id: str, sh: dict, rules) -> Cell:
    Bax = rules.batch if rules.batch else None
    specs = recsys.param_specs(cfg)
    aparams, pparams = abstract_params(specs), param_pspecs(specs)
    meta = {"n_params": param_count(specs), "family": "recsys", "cfg": cfg}

    if sh["kind"] == "train":
        opt = adamw(1e-3)
        loss_fn = lambda p, b: recsys.recsys_loss(p, b, cfg)
        fn = make_train_step(loss_fn, opt)
        bs = _recsys_batch_specs(cfg, sh["batch"], labeled=True)
        args = (aparams, _abstract_opt(aparams, jnp.float32), bs)
        pspecs = (pparams, _opt_pspecs(pparams), _recsys_batch_pspecs(bs, Bax))
        return Cell(arch, shape_id, "train", fn, args, pspecs, donate=(0, 1), meta=meta)

    if sh["kind"] == "serve":
        fn = lambda params, batch: jax.nn.sigmoid(recsys.forward(params, batch, cfg))
        bs = _recsys_batch_specs(cfg, sh["batch"], labeled=False)
        args = (aparams, bs)
        pspecs = (pparams, _recsys_batch_pspecs(bs, Bax))
        return Cell(arch, shape_id, "serve", fn, args, pspecs, meta=meta)

    if sh["kind"] == "retrieval":
        n_cand = sh["n_candidates"]
        n_pad = -(-n_cand // 512) * 512 if n_cand > 512 else n_cand
        cand_ax = (tuple(rules.batch) + ("model",)) if rules.batch else None
        bs = _recsys_batch_specs(cfg, sh["batch"], labeled=False)

        def fn(params, batch, cand_emb):
            q = recsys.user_tower(params, batch, cfg)
            s = q @ cand_emb.T                                  # [B, n_pad]
            s = jnp.where(jnp.arange(s.shape[-1]) < n_cand, s, -jnp.inf)
            return jax.lax.top_k(s, 100)

        args = (aparams, bs, SDS((n_pad, cfg.embed_dim), jnp.float32))
        pspecs = (pparams, _recsys_batch_pspecs(bs, Bax), P(cand_ax, None))
        meta["n_candidates"] = n_cand
        return Cell(arch, shape_id, "retrieval", fn, args, pspecs, meta=meta)

    raise ValueError(sh["kind"])


# ------------------------------------------------------------------ API
def build_cell(arch_id: str, shape_id: str, *, mesh_axes=("data", "model"), reduced: bool = False) -> Cell:
    family, cfg = get_arch(arch_id, reduced=reduced)
    sh = dict(arch_shapes(arch_id)[shape_id])
    rules = make_rules(mesh_axes) if mesh_axes else T.ShardingRules.null()
    if reduced:  # shrink the shape cell to smoke scale
        sh = _reduce_shape(family, sh)
    if family == "lm":
        return _lm_cell(arch_id, cfg, shape_id, sh, rules)
    if family == "gnn":
        return _gnn_cell(arch_id, cfg, shape_id, sh, rules)
    if family == "recsys":
        return _recsys_cell(arch_id, cfg, shape_id, sh, rules)
    raise ValueError(family)


def _reduce_shape(family: str, sh: dict) -> dict:
    sh = dict(sh)
    if family == "lm":
        sh["seq"] = min(sh["seq"], 64 if sh["kind"] != "retrieval_decode" else 128)
        sh["batch"] = min(sh["batch"], 4)
    elif family == "gnn":
        if sh["kind"] == "full_graph":
            sh.update(n_nodes=200, n_edges=800, d_feat=16, n_classes=5)
        elif sh["kind"] == "sampled":
            sh.update(batch_nodes=8, fanouts=(3, 2), d_feat=16, n_classes=5)
        else:
            sh.update(batch=4, n_nodes=10, n_edges=20, d_feat=16, n_classes=5)
    else:
        sh["batch"] = min(sh["batch"], 16)
        if sh["kind"] == "retrieval":
            sh["n_candidates"] = 1000
    return sh


def example_inputs(cell: Cell, seed: int = 0):
    """Materialize concrete inputs for a (reduced) cell: zeros/randints."""
    rng = np.random.default_rng(seed)
    cfg = cell.meta.get("cfg")

    def concrete(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", ""))) for p in path)
        if leaf.dtype in (jnp.int32, jnp.int64):
            if name.endswith("step") or name.endswith("pos"):
                return jnp.zeros(leaf.shape, leaf.dtype)
            return jnp.asarray(rng.integers(0, 2, size=leaf.shape), leaf.dtype)
        if "mask" in name or "weight" in name:
            return jnp.ones(leaf.shape, leaf.dtype)
        return jnp.zeros(leaf.shape, leaf.dtype)

    out = []
    for i, a in enumerate(cell.args):
        if i == 0 and isinstance(a, dict) and "cfg" in cell.meta:
            # params: properly initialized (not zeros) for numerically live runs
            fam = cell.meta["family"]
            if fam == "lm":
                out.append(init_params(T.param_specs(cfg), jax.random.key(seed)))
                continue
            if fam == "gnn":
                out.append(init_params(gnn.param_specs(cfg), jax.random.key(seed)))
                continue
            if fam == "recsys":
                out.append(init_params(recsys.param_specs(cfg), jax.random.key(seed)))
                continue
        out.append(jax.tree_util.tree_map_with_path(concrete, a))
    return tuple(out)


def lower_cell(cell: Cell, mesh):
    """jit + lower the cell on a mesh; returns the Lowered object."""
    from jax.sharding import NamedSharding

    is_ps = lambda x: isinstance(x, P)
    in_shardings = jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), cell.in_pspecs, is_leaf=is_ps
    )
    kw = {}
    if cell.out_pspecs is not None:
        kw["out_shardings"] = jax.tree.map(
            lambda ps: NamedSharding(mesh, ps) if isinstance(ps, P) else ps,
            cell.out_pspecs,
            is_leaf=lambda x: isinstance(x, P) or x is None,
        )
    jf = jax.jit(cell.fn, in_shardings=in_shardings, donate_argnums=cell.donate, **kw)
    with mesh_lib.set_mesh(mesh):
        return jf.lower(*cell.args)
