"""Concurrent request scheduling for the serving layer.

The paper's serving scenario is many queries against memory-constrained
indexes; ``BENCH_search.json`` showed the naive single-threaded loop pays
~15x search-latency inflation the moment a writer is active (the search
waits for every insert batch to finish).  This module turns serving into
a concurrent, deadline-aware pipeline with three pieces:

  ``RequestScheduler``
    A bounded admission queue in front of a worker thread pool.  A full
    queue REJECTS (``ServerOverloadedError``) instead of buffering without
    bound — backpressure the client can act on.  Each worker executes one
    search per request against an isolated snapshot (below), so reads
    never block on ``insert``/``delete``/``compact``.

  ``DeadlinePolicy``
    Maps a request's remaining deadline onto the paper's effort knob
    ``b`` (leaves scanned per increment): an EWMA of observed
    seconds-per-unit-``b`` estimates what effort still fits, and the
    request's ``b`` shrinks toward ``b_min`` as the deadline nears.
    Overload therefore degrades RECALL (fewer leaves scanned) instead of
    latency — the knob the paper exposes, applied end-to-end.

  ``SnapshotManager``
    Leases generation-pinned ``ECPSnapshot`` views to workers.  Reads are
    always served from the freshest *committed* snapshot: after each
    mutation the scheduler re-pins; while a mutation is mid-flight,
    readers keep the previous generation (never a torn state, never a
    block).  Requires a pinning store (blob); for fstore the scheduler
    falls back to a readers-writer lock — reads still run concurrently
    with each other, only writes are exclusive.

Replica setup: because a ``BlobSnapshot`` is just a dup'd fd over the one
blob file, N read-only server processes can serve the same file while a
single writer process mutates it; external readers poll
``info.generation`` (see ``core/lifecycle.publish_generation``) and
``refresh()`` when it moves.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "DeadlinePolicy",
    "RequestScheduler",
    "ScheduledResult",
    "SchedulerStats",
    "ServerOverloadedError",
    "SnapshotManager",
]


class ServerOverloadedError(RuntimeError):
    """Admission queue full — backpressure: back off and retry, lower the
    request rate, or raise ``queue_depth``/``workers``."""


# ---------------------------------------------------------------- deadlines
class DeadlinePolicy:
    """Shrink the effort knob ``b`` to fit a request's remaining deadline.

    Keeps an EWMA of observed seconds-per-unit-``b`` across completed
    searches; ``choose_b`` returns the largest ``b <= b_requested`` whose
    estimated cost (with a safety factor) fits the remaining time, floored
    at ``b_min`` so a late request still returns *some* answer instead of
    an error.  Thread-safe.
    """

    def __init__(
        self,
        *,
        b_min: int = 1,
        alpha: float = 0.2,
        safety: float = 1.5,
        init_s_per_b: float = 5e-4,
    ):
        self.b_min = max(1, int(b_min))
        self._alpha = float(alpha)
        self._safety = float(safety)
        self._s_per_b = float(init_s_per_b)
        self._lock = threading.Lock()

    @property
    def s_per_b(self) -> float:
        with self._lock:
            return self._s_per_b

    def choose_b(self, b: int, remaining_s: float) -> int:
        if remaining_s <= 0:
            return self.b_min
        with self._lock:
            est = self._s_per_b
        fits = int(remaining_s / (est * self._safety)) if est > 0 else b
        return max(self.b_min, min(int(b), fits))

    def observe(self, b_used: int, elapsed_s: float) -> None:
        if b_used <= 0 or elapsed_s < 0:
            return
        obs = elapsed_s / b_used
        with self._lock:
            self._s_per_b += self._alpha * (obs - self._s_per_b)


# ---------------------------------------------------------------- snapshots
class SnapshotManager:
    """Refcounted leases over the freshest committed ``ECPSnapshot``.

    ``lease()`` hands out the current snapshot (taking one reference; the
    caller must ``release()`` it).  When the index's published generation
    has moved past the cached snapshot, the manager re-pins — but only if
    the mutation lock is free: mid-mutation readers keep the previous
    committed generation rather than blocking.  ``refresh()`` (called by
    the scheduler after each mutation returns) force-pins the new
    generation.
    """

    def __init__(self, index):
        self._index = index
        self._lock = threading.Lock()
        self._cur = None
        self.refreshes = 0

    def lease(self):
        with self._lock:
            cur = self._cur
            stale = cur is None or cur.generation != self._index.info.generation
            if stale:
                # block only for the very first snapshot; afterwards a
                # busy writer means "serve the previous generation"
                if self._index._mut_lock.acquire(blocking=cur is None):
                    try:
                        self._repin_locked()
                    finally:
                        self._index._mut_lock.release()
            return self._cur.acquire()

    def refresh(self) -> None:
        """Re-pin after a mutation committed (the writer has released the
        mutation lock, so this never serves a torn state)."""
        with self._lock:
            with self._index._mut_lock:
                self._repin_locked()

    def _repin_locked(self) -> None:
        new = self._index.snapshot()
        old, self._cur = self._cur, new
        self.refreshes += 1
        if old is not None:
            old.release()

    @property
    def current_generation(self):
        with self._lock:
            return None if self._cur is None else self._cur.generation

    def close(self) -> None:
        with self._lock:
            cur, self._cur = self._cur, None
        if cur is not None:
            cur.release()


# ------------------------------------------------------------------ RW lock
class _RWLock:
    """Many concurrent readers / one exclusive writer, writer-preferring —
    the fallback isolation for stores without generation pinning."""

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    def acquire_read(self) -> None:
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self) -> None:
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()

    def acquire_write(self) -> None:
        with self._cond:
            self._writers_waiting += 1
            try:
                while self._writer or self._readers:
                    self._cond.wait()
            finally:
                self._writers_waiting -= 1
            self._writer = True

    def release_write(self) -> None:
        with self._cond:
            self._writer = False
            self._cond.notify_all()


# ---------------------------------------------------------------- scheduler
@dataclass
class SchedulerStats:
    """Deadline/admission accounting (guarded by ``lock``).  Invariants
    the serving smoke test asserts: ``submitted == completed + rejected +
    failed + pending``; ``deadline_misses <= completed``; ``degraded``
    only counts requests that carried a deadline."""

    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    failed: int = 0
    degraded: int = 0          # b shrunk below the requested effort
    deadline_misses: int = 0   # finished after their deadline anyway
    queue_wait_ms: float = 0.0  # cumulative admission-to-start wait
    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def as_dict(self) -> dict:
        with self.lock:
            d = {
                "submitted": self.submitted,
                "completed": self.completed,
                "rejected": self.rejected,
                "failed": self.failed,
                "degraded": self.degraded,
                "deadline_misses": self.deadline_misses,
                "queue_wait_ms": round(self.queue_wait_ms, 3),
            }
        return d


@dataclass
class ScheduledResult:
    """What a scheduled search resolves to: the ``ResultSet``, the snapshot
    lease backing its query handle (``None`` in RW-lock mode — the caller
    owns releasing it), the effort actually spent, and the queue wait."""

    rs: object
    lease: object
    b_requested: int
    b_effective: int
    queue_wait_ms: float


@dataclass
class _Req:
    q: np.ndarray
    k: int
    b: int | None
    deadline: float | None  # absolute time.monotonic()
    opts: dict
    future: Future
    t_submit: float


_STOP = object()


class RequestScheduler:
    """Thread-pool searches over an index, with bounded admission and
    snapshot-isolated reads.

    ``submit`` enqueues one search and returns a ``Future`` resolving to a
    ``ScheduledResult``; a full queue raises ``ServerOverloadedError``
    instead of queueing unboundedly.  ``search`` is the blocking
    convenience.  ``mutate(fn)`` runs a write: with a pinning (blob) store
    the mutation runs concurrently with reads (they hold snapshots) and
    the manager re-pins afterwards; with fstore it takes the writer side
    of a RW lock.  ``read_lock()`` brackets non-snapshot reads (query
    continuations) in RW-lock mode and is free otherwise.
    """

    def __init__(
        self,
        index,
        *,
        workers: int = 4,
        queue_depth: int = 64,
        policy: DeadlinePolicy | None = None,
        default_b: int = 8,
    ):
        self.index = index
        self.policy = policy if policy is not None else DeadlinePolicy()
        self.default_b = int(default_b)
        self.stats = SchedulerStats()
        # snapshot isolation needs a generation-pinning index: either it
        # says so itself (ECPIndex / FederatedIndex expose
        # supports_snapshot) or its raw store pins (blob behind a bare
        # searcher)
        pinnable = getattr(index, "supports_snapshot", False) or (
            getattr(getattr(index, "store", None), "pin", None) is not None
        )
        self.snapshots = (
            SnapshotManager(index)
            if pinnable and hasattr(index, "snapshot")
            else None
        )
        self._rw = _RWLock()
        self.queue_depth = int(queue_depth)
        self._q: "queue.Queue" = queue.Queue(maxsize=self.queue_depth)
        self._threads = [
            threading.Thread(target=self._worker, name=f"serve-worker-{i}", daemon=True)
            for i in range(max(1, int(workers)))
        ]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------ requests
    def submit(self, q, k: int = 100, *, b=None, deadline_ms=None, **opts) -> Future:
        f: Future = Future()
        now = time.monotonic()
        deadline = None if deadline_ms is None else now + float(deadline_ms) / 1e3
        req = _Req(q=q, k=int(k), b=b, deadline=deadline, opts=opts, future=f, t_submit=now)
        try:
            self._q.put_nowait(req)
        except queue.Full:
            with self.stats.lock:
                self.stats.rejected += 1
                self.stats.submitted += 1
            raise ServerOverloadedError(
                f"admission queue full ({self.queue_depth} requests pending); "
                "back off and retry"
            ) from None
        with self.stats.lock:
            self.stats.submitted += 1
        return f

    def search(self, q, k: int = 100, *, b=None, deadline_ms=None, **opts) -> ScheduledResult:
        return self.submit(q, k, b=b, deadline_ms=deadline_ms, **opts).result()

    # ------------------------------------------------------------ mutation
    def mutate(self, fn):
        """Run one mutation; readers never observe a torn state.  With
        snapshots, reads proceed concurrently on pinned generations and
        the manager re-pins once the mutation commits; without, the
        mutation holds the write lock."""
        if self.snapshots is not None:
            out = fn()  # ECPIndex serializes mutators on its _mut_lock
            self.snapshots.refresh()
            return out
        self._rw.acquire_write()
        try:
            return fn()
        finally:
            self._rw.release_write()

    class _ReadLock:
        def __init__(self, rw: "_RWLock | None"):
            self._rw = rw

        def __enter__(self):
            if self._rw is not None:
                self._rw.acquire_read()
            return self

        def __exit__(self, *exc):
            if self._rw is not None:
                self._rw.release_read()

    def read_lock(self) -> "_ReadLock":
        """Context manager for reads that bypass the worker pool (query
        continuations): shares the RW lock in fstore mode, no-op when
        snapshot isolation is on."""
        return self._ReadLock(None if self.snapshots is not None else self._rw)

    # ------------------------------------------------------------- workers
    def _worker(self) -> None:
        while True:
            req = self._q.get()
            if req is _STOP:
                return
            if not req.future.set_running_or_notify_cancel():
                continue
            try:
                req.future.set_result(self._execute(req))
            except BaseException as e:  # delivered to the caller, not lost
                with self.stats.lock:
                    self.stats.failed += 1
                req.future.set_exception(e)

    def _execute(self, req: _Req) -> ScheduledResult:
        t0 = time.monotonic()
        b_req = self.default_b if req.b is None else int(req.b)
        b_eff = b_req
        if req.deadline is not None:
            b_eff = self.policy.choose_b(b_req, req.deadline - t0)
        lease = None
        if self.snapshots is not None:
            lease = self.snapshots.lease()
            searcher = lease
        else:
            self._rw.acquire_read()
            searcher = self.index
        try:
            rs = searcher.search(np.asarray(req.q, np.float32), req.k, b=b_eff, **req.opts)
        except BaseException:
            if lease is not None:
                lease.release()
            raise
        finally:
            if lease is None:
                self._rw.release_read()
        done = time.monotonic()
        self.policy.observe(b_eff, done - t0)
        with self.stats.lock:
            self.stats.completed += 1
            self.stats.queue_wait_ms += (t0 - req.t_submit) * 1e3
            if b_eff < b_req:
                self.stats.degraded += 1
            if req.deadline is not None and done > req.deadline:
                self.stats.deadline_misses += 1
        return ScheduledResult(
            rs=rs,
            lease=lease,
            b_requested=b_req,
            b_effective=b_eff,
            queue_wait_ms=(t0 - req.t_submit) * 1e3,
        )

    # ------------------------------------------------------------ lifecycle
    def shutdown(self) -> None:
        """Drain queued requests, stop the workers, drop the cached
        snapshot.  Idempotent."""
        for _ in self._threads:
            self._q.put(_STOP)
        for t in self._threads:
            t.join()
        self._threads = []
        if self.snapshots is not None:
            self.snapshots.close()

    def __enter__(self) -> "RequestScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
