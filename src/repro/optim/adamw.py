"""AdamW with decoupled weight decay, global-norm clipping, lr schedules.

Functional optax-style API (no optax in this environment):
  opt = adamw(lr_schedule, wd=0.1, clip=1.0)
  state = opt.init(params)
  updates, state = opt.update(grads, state, params)
  params = apply_updates(params, updates)

Weight decay skips 1-D parameters (norm scales, biases) by default — the
standard LM rule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["adamw", "sgd_momentum", "apply_updates", "global_norm"]


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def adamw(
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    wd: float = 0.1,
    clip: float | None = 1.0,
    decay_mask: Callable | None = None,
    moment_dtype=jnp.float32,   # bf16 moments halve optimizer HBM (400B MoE)
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        if clip is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, clip / jnp.maximum(gn, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        mu = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(moment_dtype),
            state["mu"], grads,
        )
        nu = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(moment_dtype),
            state["nu"], grads,
        )
        bc1 = 1 - b1**step.astype(jnp.float32)
        bc2 = 1 - b2**step.astype(jnp.float32)
        lr_t = lr_fn(step)

        def upd(m, v, p):
            m = m.astype(jnp.float32)
            v = v.astype(jnp.float32)
            u = -(lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps))
            apply_wd = wd > 0 and (decay_mask(p) if decay_mask else p.ndim >= 2)
            if apply_wd:
                u = u - lr_t * wd * p.astype(jnp.float32)
            return u

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "step": step}

    return Optimizer(init=init, update=update)


def sgd_momentum(lr, *, momentum: float = 0.9, clip: float | None = None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        return {
            "mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        if clip is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, clip / jnp.maximum(gn, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        mom = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mom"], grads
        )
        lr_t = lr_fn(step)
        updates = jax.tree.map(lambda m: -lr_t * m, mom)
        return updates, {"mom": mom, "step": step}

    return Optimizer(init=init, update=update)
