"""Gradient compression for the cross-pod all-reduce.

int8 uniform quantization with error feedback (EF-SGD style): each leaf is
scaled by its absmax, rounded to int8, and the quantization residual is
carried to the next step. Applied ONLY to the slow (cross-pod DCN) reduce —
intra-pod reduction stays bf16/f32 (DESIGN.md §6). Cuts cross-pod all-reduce
bytes 4× (f32) / 2× (bf16) at the cost of one extra buffer per leaf.

The transform is pure-functional: state in, state out, jit-safe, so the
train step can close over it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_ef_state", "compress_decompress", "quantize_int8", "dequantize_int8"]


def quantize_int8(x):
    absmax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_ef_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_decompress(grads, ef_state):
    """Simulate the quantize→all-reduce→dequantize round trip with EF.

    Returns (decompressed grads, new error-feedback state). In the real
    multi-pod launch the int8 payload is what crosses the DCN; here the
    numerics (and the EXPERIMENTS.md collective-byte accounting) use this
    exact function.
    """

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(tree, [o[0] for o in out]),
        jax.tree.unflatten(tree, [o[1] for o in out]),
    )
