from .adamw import adamw, apply_updates, global_norm, sgd_momentum
from .compress import compress_decompress, init_ef_state
from .schedule import constant, warmup_cosine, warmup_linear_decay

__all__ = [
    "adamw",
    "sgd_momentum",
    "apply_updates",
    "global_norm",
    "compress_decompress",
    "init_ef_state",
    "constant",
    "warmup_cosine",
    "warmup_linear_decay",
]
