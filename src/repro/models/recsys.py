"""CTR / ranking models: BST, DIEN, AutoInt, DCN-v2.

Embedding substrate (JAX has no nn.EmbeddingBag — built here, per the
assignment): all categorical fields live in ONE concatenated mega-table
[total_vocab, embed_dim] with per-field row offsets. A batch of field ids
becomes a single gather; multi-hot bags reduce with a mask
(fixed shapes) or ``jax.ops.segment_sum`` (ragged path). The mega-table
shards over "model" rows; the gather becomes an all-to-all under GSPMD —
that is the standard recsys sharding (tables >> activations).

``retrieval_cand`` cells: ``user_tower`` produces a query embedding;
candidates score via batched dot + top-k (brute force baseline) or through
the eCP index (the paper's technique, launch/serve.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from .base import ParamSpec as P
from .layers import bce_logits, layer_norm

__all__ = [
    "RecSysConfig", "param_specs", "forward", "recsys_loss", "user_tower",
    "embedding_lookup", "embedding_bag", "candidate_scores",
]


@dataclass(frozen=True)
class RecSysConfig:
    name: str
    interaction: str                  # "transformer-seq" | "augru" | "self-attn" | "cross"
    embed_dim: int
    field_vocabs: tuple               # rows per categorical field (mega-table layout)
    n_dense: int = 0                  # continuous features
    seq_len: int = 0                  # behavior sequence length (BST/DIEN)
    seq_fields: int = 0               # id fields per sequence position
    mlp: tuple = (256, 128)
    # BST / AutoInt attention params
    n_blocks: int = 1
    n_heads: int = 2
    d_attn: int = 32
    # DIEN
    gru_dim: int = 0
    # DCN
    n_cross_layers: int = 0
    dtype: Any = jnp.float32

    @property
    def n_fields(self) -> int:
        return len(self.field_vocabs)

    @property
    def total_vocab(self) -> int:
        return int(sum(self.field_vocabs))

    @property
    def field_offsets(self) -> tuple:
        offs, acc = [], 0
        for v in self.field_vocabs:
            offs.append(acc)
            acc += v
        return tuple(offs)


# ------------------------------------------------------------- embeddings
def embedding_lookup(table, ids):
    """ids [...] (already offset into the mega-table) -> [..., dim]."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(table, ids, mask, *, mode: str = "mean"):
    """Fixed-shape embedding bag: ids [B, L], mask [B, L] -> [B, dim]."""
    e = jnp.take(table, ids, axis=0) * mask[..., None]
    if mode == "sum":
        return e.sum(1)
    if mode == "mean":
        return e.sum(1) / jnp.maximum(mask.sum(1, keepdims=True), 1.0)
    if mode == "max":
        neg = jnp.where(mask[..., None] > 0, e, -jnp.inf)
        return jnp.max(neg, axis=1)
    raise ValueError(mode)


def embedding_bag_ragged(table, flat_ids, segment_ids, n_bags, *, mode: str = "sum"):
    """Ragged bag via segment_sum — the torch EmbeddingBag equivalent."""
    e = jnp.take(table, flat_ids, axis=0)
    s = jax.ops.segment_sum(e, segment_ids, n_bags)
    if mode == "sum":
        return s
    cnt = jax.ops.segment_sum(jnp.ones_like(flat_ids, jnp.float32), segment_ids, n_bags)
    return s / jnp.maximum(cnt[:, None], 1.0)


def _mlp_specs(dims, dt, prefix=""):
    out = {}
    for i in range(len(dims) - 1):
        out[f"{prefix}w{i}"] = P((dims[i], dims[i + 1]), dt)
        out[f"{prefix}b{i}"] = P((dims[i + 1],), dt, (), "zeros")
    return out


def _mlp_apply(params, x, n, prefix="", act=jax.nn.relu, final_act=False):
    for i in range(n):
        x = x @ params[f"{prefix}w{i}"] + params[f"{prefix}b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


# ----------------------------------------------------------- param specs
def param_specs(cfg: RecSysConfig):
    dt = cfg.dtype
    d = cfg.embed_dim
    # table rows padded to a shard-divisible count (model axis <= 512 on the
    # production meshes); offsets never address the pad rows
    rows = -(-cfg.total_vocab // 512) * 512 if cfg.total_vocab > 512 else cfg.total_vocab
    specs: dict = {
        "table": P((rows, d), dt, ("model", None), "embed"),
    }
    seq_d = d * cfg.seq_fields
    if cfg.interaction == "transformer-seq":  # BST
        dm = seq_d
        specs.update(
            {
                "pos_embed": P((cfg.seq_len + 1, dm), dt, (None, None), "embed"),
                "wq": P((cfg.n_blocks, dm, cfg.n_heads * cfg.d_attn), dt),
                "wk": P((cfg.n_blocks, dm, cfg.n_heads * cfg.d_attn), dt),
                "wv": P((cfg.n_blocks, dm, cfg.n_heads * cfg.d_attn), dt),
                "wo": P((cfg.n_blocks, cfg.n_heads * cfg.d_attn, dm), dt),
                "ln_g": P((cfg.n_blocks, 2, dm), dt, (None, None, None), "ones"),
                "ln_b": P((cfg.n_blocks, 2, dm), dt, (None, None, None), "zeros"),
                "ffw1": P((cfg.n_blocks, dm, 4 * dm), dt),
                "ffb1": P((cfg.n_blocks, 4 * dm), dt, (None, None), "zeros"),
                "ffw2": P((cfg.n_blocks, 4 * dm, dm), dt),
                "ffb2": P((cfg.n_blocks, dm), dt, (None, None), "zeros"),
            }
        )
        mlp_in = (cfg.seq_len + 1) * dm + (cfg.n_fields - cfg.seq_fields) * d
    elif cfg.interaction == "augru":  # DIEN
        g = cfg.gru_dim
        specs.update(
            {
                "gru_wx": P((seq_d, 3 * g), dt),
                "gru_wh": P((g, 3 * g), dt),
                "gru_b": P((3 * g,), dt, (), "zeros"),
                "att_w": P((g, seq_d), dt),
                "augru_wx": P((g, 3 * g), dt),
                "augru_wh": P((g, 3 * g), dt),
                "augru_b": P((3 * g,), dt, (), "zeros"),
            }
        )
        mlp_in = g + (cfg.n_fields - cfg.seq_fields) * d + seq_d
    elif cfg.interaction == "self-attn":  # AutoInt
        da, H = cfg.d_attn, cfg.n_heads
        specs.update(
            {
                "wq": P((1, d, H * da), dt),
                "wk": P((1, d, H * da), dt),
                "wv": P((1, d, H * da), dt),
                "w_res": P((1, d, H * da), dt),
            }
        )
        if cfg.n_blocks > 1:  # after block 0 the field dim becomes H*da
            specs["wq2"] = P((cfg.n_blocks - 1, H * da, H * da), dt)
            specs["wk2"] = P((cfg.n_blocks - 1, H * da, H * da), dt)
            specs["wv2"] = P((cfg.n_blocks - 1, H * da, H * da), dt)
            specs["w_res2"] = P((cfg.n_blocks - 1, H * da, H * da), dt)
        mlp_in = cfg.n_fields * H * da
    elif cfg.interaction == "cross":  # DCN-v2
        x0_dim = cfg.n_dense + cfg.n_fields * d
        specs.update(
            {
                "cross_w": P((cfg.n_cross_layers, x0_dim, x0_dim), dt),
                "cross_b": P((cfg.n_cross_layers, x0_dim), dt, (None, None), "zeros"),
            }
        )
        mlp_in = x0_dim
    else:
        raise ValueError(cfg.interaction)

    mlp_dims = (mlp_in,) + tuple(cfg.mlp)
    specs.update(_mlp_specs(mlp_dims, dt, "mlp_"))
    if cfg.interaction == "cross":
        # DCN-v2 parallel structure: concat(cross_out, deep_out) -> logit
        head_in = (cfg.n_dense + cfg.n_fields * d) + cfg.mlp[-1]
    else:
        head_in = cfg.mlp[-1]
    specs["w_head"] = P((head_in, 1), dt)
    specs["b_head"] = P((1,), dt, (), "zeros")
    # retrieval tower projection (retrieval_cand workload)
    specs["w_ret"] = P((d, d), dt)
    return specs


# ----------------------------------------------------------- interactions
def _bst_block(params, i, x, cfg: RecSysConfig):
    B, S, dm = x.shape
    H, da = cfg.n_heads, cfg.d_attn
    h = layer_norm(x, params["ln_g"][i, 0], params["ln_b"][i, 0])
    q = (h @ params["wq"][i]).reshape(B, S, H, da)
    k = (h @ params["wk"][i]).reshape(B, S, H, da)
    v = (h @ params["wv"][i]).reshape(B, S, H, da)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(jnp.asarray(da, jnp.float32))
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(B, S, H * da)
    x = x + o @ params["wo"][i]
    h2 = layer_norm(x, params["ln_g"][i, 1], params["ln_b"][i, 1])
    y = jax.nn.relu(h2 @ params["ffw1"][i] + params["ffb1"][i]) @ params["ffw2"][i] + params["ffb2"][i]
    return x + y


def _gru_scan(x_seq, w_x, w_h, b, g, att=None):
    """GRU / AUGRU over time. x_seq [B, S, d] -> hidden states [B, S, g].

    att [B, S] (attention scores) turns the update gate into DIEN's AUGRU:
    z_t <- a_t * z_t. With att = 1 this is exactly a plain GRU.
    """
    B, S, _ = x_seq.shape
    if att is None:
        att = jnp.ones((B, S), x_seq.dtype)

    def cell(h, xs):
        x_t, a_t = xs
        gx = x_t @ w_x + b                         # [B, 3g]
        gh = h @ w_h                               # [B, 3g]
        z = jax.nn.sigmoid(gx[:, :g] + gh[:, :g])
        r = jax.nn.sigmoid(gx[:, g : 2 * g] + gh[:, g : 2 * g])
        hh = jnp.tanh(gx[:, 2 * g :] + r * gh[:, 2 * g :])
        z = z * a_t[:, None]                       # AUGRU attentional gate
        h_new = (1 - z) * h + z * hh
        return h_new, h_new

    h0 = jnp.zeros((B, g), x_seq.dtype)
    _, hs = jax.lax.scan(cell, h0, (jnp.moveaxis(x_seq, 1, 0), jnp.moveaxis(att, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)


def _autoint_block(x, wq, wk, wv, wres, H, da):
    B, F, d = x.shape
    q = (x @ wq).reshape(B, F, H, da)
    k = (x @ wk).reshape(B, F, H, da)
    v = (x @ wv).reshape(B, F, H, da)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(jnp.asarray(da, jnp.float32))
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(B, F, H * da)
    return jax.nn.relu(o + x @ wres)


# ---------------------------------------------------------------- forward
def forward(params, batch, cfg: RecSysConfig):
    """batch fields (ids are RAW per-field; offsets applied here):
       "cat": [B, n_fields - seq_fields] non-sequence categorical ids
       "seq": [B, seq_len, seq_fields] behavior ids (BST/DIEN; field 0.. )
       "seq_mask": [B, seq_len]
       "target": [B, seq_fields] target item ids (BST/DIEN)
       "dense": [B, n_dense]
    Returns logits [B].
    """
    d = cfg.embed_dim
    table = params["table"]
    offs = jnp.asarray(cfg.field_offsets, jnp.int32)
    n_plain = cfg.n_fields - cfg.seq_fields

    if cfg.interaction == "transformer-seq":
        seq_ids = batch["seq"] + offs[None, None, :cfg.seq_fields]
        tgt_ids = batch["target"] + offs[None, :cfg.seq_fields]
        seq_e = embedding_lookup(table, seq_ids).reshape(*batch["seq"].shape[:2], -1)
        tgt_e = embedding_lookup(table, tgt_ids).reshape(batch["target"].shape[0], -1)
        x = jnp.concatenate([seq_e, tgt_e[:, None, :]], axis=1)      # [B, S+1, dm]
        x = x + params["pos_embed"][None, : x.shape[1]]
        for i in range(cfg.n_blocks):
            x = _bst_block(params, i, x, cfg)
        plain = embedding_lookup(table, batch["cat"] + offs[None, cfg.seq_fields :])
        feat = jnp.concatenate([x.reshape(x.shape[0], -1), plain.reshape(x.shape[0], -1)], axis=-1)
        h = _mlp_apply(params, feat, len(cfg.mlp), "mlp_", final_act=True)
    elif cfg.interaction == "augru":
        g = cfg.gru_dim
        seq_ids = batch["seq"] + offs[None, None, :cfg.seq_fields]
        tgt_ids = batch["target"] + offs[None, :cfg.seq_fields]
        seq_e = embedding_lookup(table, seq_ids).reshape(*batch["seq"].shape[:2], -1)
        tgt_e = embedding_lookup(table, tgt_ids).reshape(batch["target"].shape[0], -1)
        hs = _gru_scan(seq_e, params["gru_wx"], params["gru_wh"], params["gru_b"], g)
        att_logit = jnp.einsum("bsg,gd,bd->bs", hs, params["att_w"], tgt_e)
        att = jax.nn.softmax(
            jnp.where(batch["seq_mask"] > 0, att_logit, -1e9), axis=-1
        )  # -1e9 not -inf: an all-masked row degrades to uniform, never NaN
        hs2 = _gru_scan(hs, params["augru_wx"], params["augru_wh"], params["augru_b"], g, att=att)
        final = hs2[:, -1]
        plain = embedding_lookup(table, batch["cat"] + offs[None, cfg.seq_fields :])
        feat = jnp.concatenate([final, plain.reshape(final.shape[0], -1), tgt_e], axis=-1)
        h = _mlp_apply(params, feat, len(cfg.mlp), "mlp_", final_act=True)
    elif cfg.interaction == "self-attn":
        x = embedding_lookup(table, batch["cat"] + offs[None, :])    # [B, F, d]
        H, da = cfg.n_heads, cfg.d_attn
        x = _autoint_block(x, params["wq"][0], params["wk"][0], params["wv"][0], params["w_res"][0], H, da)
        for i in range(cfg.n_blocks - 1):
            x = _autoint_block(x, params["wq2"][i], params["wk2"][i], params["wv2"][i], params["w_res2"][i], H, da)
        feat = x.reshape(x.shape[0], -1)
        h = _mlp_apply(params, feat, len(cfg.mlp), "mlp_", final_act=True)
    elif cfg.interaction == "cross":
        emb = embedding_lookup(table, batch["cat"] + offs[None, :]).reshape(batch["cat"].shape[0], -1)
        x0 = jnp.concatenate([batch["dense"].astype(emb.dtype), emb], axis=-1)
        x = x0
        for i in range(cfg.n_cross_layers):
            x = x0 * (x @ params["cross_w"][i] + params["cross_b"][i]) + x
        deep = _mlp_apply(params, x0, len(cfg.mlp), "mlp_", final_act=True)
        h = jnp.concatenate([x, deep], axis=-1)
    else:
        raise ValueError(cfg.interaction)

    return (h @ params["w_head"] + params["b_head"])[:, 0]


def recsys_loss(params, batch, cfg: RecSysConfig):
    logits = forward(params, batch, cfg)
    return bce_logits(logits, batch["label"]), {}


# ---------------------------------------------------------- retrieval cand
def user_tower(params, batch, cfg: RecSysConfig):
    """Query embedding for retrieval scoring: mean field embedding -> proj."""
    table = params["table"]
    offs = jnp.asarray(cfg.field_offsets, jnp.int32)
    if cfg.seq_len and "seq" in batch:
        ids = (batch["seq"] + offs[None, None, :cfg.seq_fields]).reshape(batch["seq"].shape[0], -1)
        mask = jnp.repeat(batch["seq_mask"], cfg.seq_fields, axis=-1)
        e = embedding_bag(table, ids, mask, mode="mean")
    else:
        ids = batch["cat"] + offs[None, : batch["cat"].shape[1]]
        e = embedding_lookup(table, ids).mean(1)
    return e @ params["w_ret"]


def candidate_scores(query, cand_emb, k: int, *, impl: str = "auto"):
    """Retrieval scoring: [B, d] x [N, d] -> top-k (scores desc, ids).

    Routes through the fused distance_topk Pallas kernel (inner-product
    metric; the [B, N] score matrix never materializes in HBM on TPU) —
    "auto" uses the reference path on CPU.
    """
    from repro.kernels.distance_topk import distance_topk

    d, i = distance_topk(query, cand_emb, k, "ip", impl=impl)
    return -d, i
