"""Shared neural layers (pure functions over param dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "layer_norm", "swiglu", "gelu_mlp", "rope", "dense", "softmax_xent", "bce_logits"]


def rms_norm(x, gamma, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * scale).astype(x.dtype) * gamma


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y.astype(x.dtype) * gamma) + beta


def dense(x, w, b=None):
    y = x @ w
    if b is not None:
        y = y + b
    return y


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU FFN: (silu(x Wg) * x Wu) Wd."""
    g = jax.nn.silu(x @ w_gate)
    u = x @ w_up
    return (g * u) @ w_down


def gelu_mlp(x, w1, b1, w2, b2):
    return jax.nn.gelu(x @ w1 + b1) @ w2 + b2


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding. x [..., S, H, d]; positions [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (jnp.log(theta) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs[None, :]  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]   # [..., S, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softmax_xent(logits, labels, *, mask=None):
    """Mean cross-entropy over valid positions. logits [..., V], labels [...]"""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def bce_logits(logits, labels):
    """Binary cross-entropy with logits; mean over batch."""
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0.0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
