"""eCP retrieval attention — the paper's index running inside the model.

For long-context decode (seq 500k+) full attention is infeasible; the KV
cache is instead organized exactly like an eCP leaf level: fixed-size
clusters of ``cs`` consecutive tokens, each with a centroid (running mean of
its keys — the "cluster leader"). A decode step:

  1. scores the query against all cluster centroids (the paper's index
     traversal; with n_clusters ~ 1024 this is the L=1 case — an L=2
     centroid tree is supported for >100k clusters),
  2. selects the top-b clusters per kv head (search expansion b, paper §3),
  3. gathers those clusters' K/V blocks and runs exact attention over them,
     plus the current (partial) cluster — the paper's "incremental" bias
     toward recent context.

Complexity per step: O(nC·d + b·cs·d) instead of O(S·d): at S=524288,
cs=512, b=32 that is 1024 + 16384 token scores vs 524288 — a 32× cut.

The clustered cache is a pytree shardable over the sequence/cluster axis
("data" axis at batch=1 — sequence parallelism), which is how the 500k cell
distributes: centroid scoring is local, the top-b reduce is a tiny
all-gather, gathers stay shard-local in expectation.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.launch import mesh as mesh_compat

__all__ = ["ClusteredKVCache", "RetrievalAttnConfig", "init_clustered_cache", "retrieval_decode_attention", "retrieval_decode_attention_sharded", "clustered_cache_update"]


@dataclass(frozen=True)
class RetrievalAttnConfig:
    cluster_size: int = 512     # cs: tokens per KV cluster (eCP cluster cap)
    top_clusters: int = 32      # b: search expansion


@jax.tree_util.register_pytree_node_class
@dataclass
class ClusteredKVCache:
    k: jnp.ndarray          # [L, B, Hkv, nC, cs, d]
    v: jnp.ndarray          # [L, B, Hkv, nC, cs, d]
    centroids: jnp.ndarray  # [L, B, Hkv, nC, d] running mean of keys
    pos: jnp.ndarray        # [] int32 — tokens written so far

    def tree_flatten(self):
        return (self.k, self.v, self.centroids, self.pos), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_clustered_cache(n_layers, batch, n_kv, max_seq, cs, d, dtype=jnp.bfloat16):
    nC = -(-max_seq // cs)
    return ClusteredKVCache(
        k=jnp.zeros((n_layers, batch, n_kv, nC, cs, d), dtype),
        v=jnp.zeros((n_layers, batch, n_kv, nC, cs, d), dtype),
        centroids=jnp.zeros((n_layers, batch, n_kv, nC, d), jnp.float32),
        pos=jnp.zeros((), jnp.int32),
    )


def clustered_cache_update(layer_k, layer_v, layer_cent, k_new, v_new, pos, cs):
    """Write one token's k/v into its cluster; update the centroid mean.

    layer_k/v [B, Hkv, nC, cs, d]; k_new/v_new [B, Hkv, d]; pos scalar.
    """
    cid = pos // cs
    off = pos % cs
    layer_k = jax.lax.dynamic_update_slice(
        layer_k, k_new[:, :, None, None, :].astype(layer_k.dtype), (0, 0, cid, off, 0)
    )
    layer_v = jax.lax.dynamic_update_slice(
        layer_v, v_new[:, :, None, None, :].astype(layer_v.dtype), (0, 0, cid, off, 0)
    )
    old_c = jax.lax.dynamic_slice_in_dim(layer_cent, cid, 1, axis=2)[:, :, 0]  # [B,Hkv,d]
    n = (off + 1).astype(jnp.float32)
    new_c = old_c + (k_new.astype(jnp.float32) - old_c) / n
    layer_cent = jax.lax.dynamic_update_slice(
        layer_cent, new_c[:, :, None, :], (0, 0, cid, 0)
    )
    return layer_k, layer_v, layer_cent


def retrieval_decode_attention(
    q, layer_k, layer_v, layer_cent, pos, *, cs: int, top_b: int, scale: float | None = None
):
    """One decode step of eCP retrieval attention.

    q [B, Hq, d] (single token); layer_k/v [B, Hkv, nC, cs, d];
    layer_cent [B, Hkv, nC, d]; pos scalar int32 (tokens already cached,
    INCLUDING the current token already written). Returns [B, Hq, d] f32.
    """
    B, Hq, d = q.shape
    Hkv, nC = layer_k.shape[1], layer_k.shape[2]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    qf = q.astype(jnp.float32) * scale
    qg = qf.reshape(B, Hkv, group, d)

    # 1) index traversal: score centroids (inner-product metric, as the
    #    softmax numerator is monotone in <q, k>); mean over the query group
    cur = (pos - 1) // cs                                   # current cluster id
    cent_scores = jnp.einsum("bhgd,bhnd->bhgn", qg, layer_cent).mean(2)  # [B,Hkv,nC]
    full_mask = jnp.arange(nC)[None, None, :] < cur          # only complete clusters
    cent_scores = jnp.where(full_mask, cent_scores, -jnp.inf)

    # 2) search expansion: top-b complete clusters + the current one
    b = min(top_b, nC)
    _, top_idx = jax.lax.top_k(cent_scores, b)               # [B, Hkv, b]
    sel = jnp.concatenate([top_idx, jnp.broadcast_to(cur, (B, Hkv, 1))], axis=-1)  # [B,Hkv,b+1]

    # 3) gather + exact attention over the selected clusters
    bi = jnp.arange(B)[:, None, None]
    hi = jnp.arange(Hkv)[None, :, None]
    ks = layer_k[bi, hi, sel]                                # [B, Hkv, b+1, cs, d]
    vs = layer_v[bi, hi, sel]
    # token validity: cluster j is full (cs) if j < cur, partial if j == cur
    tok_idx = sel[..., None] * cs + jnp.arange(cs)[None, None, None, :]  # [B,Hkv,b+1,cs]
    valid = (tok_idx < pos) & (sel[..., None] >= 0) & jnp.isfinite(
        jnp.concatenate([jnp.take_along_axis(cent_scores, top_idx, -1),
                         jnp.zeros((B, Hkv, 1))], axis=-1)
    )[..., None]
    s = jnp.einsum("bhgd,bhncd->bhgnc", qg, ks.astype(jnp.float32))      # [B,Hkv,g,b+1,cs]
    s = jnp.where(valid[:, :, None], s, -jnp.inf)
    sf = s.reshape(B, Hkv, group, -1)
    m = jnp.max(sf, axis=-1, keepdims=True)
    p = jnp.exp(sf - jnp.where(jnp.isfinite(m), m, 0.0))
    p = jnp.where(jnp.isfinite(sf), p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    p = (p / denom).reshape(B, Hkv, group, b + 1, cs)
    out = jnp.einsum("bhgnc,bhncd->bhgd", p, vs.astype(jnp.float32))
    return out.reshape(B, Hq, d)


def retrieval_update_and_attend_sharded(
    q, layer_k, layer_v, layer_cent, k_new, v_new, pos, *, cs: int, top_b: int, seq_axes: tuple, scale: float | None = None
):
    """Fused sharded cache update + retrieval attention (§Perf iteration 4).

    Writing one token into the nC-sharded clustered cache through GSPMD
    costs a per-layer gather of the centroid/cluster arrays (measured
    0.13 GB/step across 32 layers — most of the remaining collective time
    after iteration 1). Fused into the same shard_map, only the shard that
    OWNS the current cluster applies the dynamic-update-slice; everything
    stays local. k_new/v_new [B, Hkv, d] are replicated (tiny).

    Returns (attn_out [B,Hq,d], layer_k, layer_v, layer_cent) with the
    cache updated at ``pos`` and attention evaluated at ``pos + 1``.
    """
    mesh = mesh_compat.get_abstract_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    n_sh = 1
    for a in seq_axes:
        n_sh *= sizes[a]
    B, Hq, d = q.shape
    Hkv, nC = layer_k.shape[1], layer_k.shape[2]
    nC_loc = nC // n_sh
    if scale is None:
        scale = 1.0 / (d**0.5)
    from jax.sharding import PartitionSpec as _P

    def local(qb, kb, vb, cb, knb, vnb, posb):
        off = jnp.zeros((), jnp.int32)
        for a in seq_axes:
            off = off * sizes[a] + jax.lax.axis_index(a)
        off = off * nC_loc
        # ---- owner-local cache write
        cid = posb // cs
        tok_off = posb % cs
        mine = (cid >= off) & (cid < off + nC_loc)
        lid = jnp.clip(cid - off, 0, nC_loc - 1)
        k_upd = jax.lax.dynamic_update_slice(
            kb, knb[:, :, None, None, :].astype(kb.dtype), (0, 0, lid, tok_off, 0)
        )
        v_upd = jax.lax.dynamic_update_slice(
            vb, vnb[:, :, None, None, :].astype(vb.dtype), (0, 0, lid, tok_off, 0)
        )
        old_c = jax.lax.dynamic_slice_in_dim(cb, lid, 1, axis=2)[:, :, 0]
        new_c = old_c + (knb.astype(jnp.float32) - old_c) / (tok_off + 1).astype(jnp.float32)
        c_upd = jax.lax.dynamic_update_slice(cb, new_c[:, :, None, :], (0, 0, lid, 0))
        kb = jnp.where(mine, k_upd, kb)
        vb = jnp.where(mine, v_upd, vb)
        cb = jnp.where(mine, c_upd, cb)
        # ---- the iteration-1 sharded search/attend at pos+1
        out = _local_retrieval_attend(
            qb, kb, vb, cb, posb + 1, off=off, cs=cs, top_b=top_b,
            seq_axes=seq_axes, scale=scale, nC_loc=nC_loc, B=B, Hq=Hq, Hkv=Hkv,
        )
        return out, kb, vb, cb

    seq_spec = tuple(seq_axes)
    return mesh_compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            _P(None, None, None),
            _P(None, None, seq_spec, None, None),
            _P(None, None, seq_spec, None, None),
            _P(None, None, seq_spec, None),
            _P(None, None, None),
            _P(None, None, None),
            _P(),
        ),
        out_specs=(
            _P(None, None, None),
            _P(None, None, seq_spec, None, None),
            _P(None, None, seq_spec, None, None),
            _P(None, None, seq_spec, None),
        ),
        check_vma=False,
    )(q, layer_k, layer_v, layer_cent, k_new, v_new, pos)


def _local_retrieval_attend(qb, kb, vb, cb, posb, *, off, cs, top_b, seq_axes, scale, nC_loc, B, Hq, Hkv):
    """Shard-local body shared by the sharded retrieval attention entry
    points: local centroid scoring -> global-threshold selection -> masked
    partial attention -> flash-style psum combine."""
    group = Hq // Hkv
    qg = (qb.astype(jnp.float32) * scale).reshape(B, Hkv, group, qb.shape[-1])
    cent_s = jnp.einsum("bhgd,bhnd->bhgn", qg, cb).mean(2)
    cur = (posb - 1) // cs
    gidx = off + jnp.arange(nC_loc)
    full = gidx[None, None, :] < cur
    cent_m = jnp.where(full, cent_s, -jnp.inf)
    b_loc = min(top_b, nC_loc)
    loc_top, _ = jax.lax.top_k(cent_m, b_loc)
    allc = jax.lax.all_gather(loc_top, seq_axes)
    flat = jnp.moveaxis(allc, 0, -2).reshape(B, Hkv, -1)
    kk = min(top_b, flat.shape[-1])
    kth = jax.lax.top_k(flat, kk)[0][..., -1]
    sel = (cent_m >= kth[..., None]) & full
    sel = sel | (gidx[None, None, :] == cur)
    s = jnp.einsum("bhgd,bhncd->bhgnc", qg.astype(kb.dtype), kb, preferred_element_type=jnp.float32)
    tok = gidx[:, None] * cs + jnp.arange(cs)[None, :]
    valid = sel[:, :, None, :, None] & (tok < posb)[None, None, None]
    s = jnp.where(valid, s, -jnp.inf)
    sf = s.reshape(B, Hkv, group, -1)
    m_loc = jnp.max(sf, axis=-1, keepdims=True)
    safe = jnp.where(jnp.isfinite(m_loc), m_loc, 0.0)
    p = jnp.where(jnp.isfinite(sf), jnp.exp(sf - safe), 0.0)
    l_loc = jnp.sum(p, axis=-1, keepdims=True)
    acc_loc = jnp.einsum(
        "bhgnc,bhncd->bhgd",
        p.reshape(B, Hkv, group, nC_loc, cs).astype(vb.dtype), vb,
        preferred_element_type=jnp.float32,
    )
    m_g = jax.lax.pmax(m_loc, seq_axes)
    corr = jnp.where(jnp.isfinite(m_loc), jnp.exp(m_loc - jnp.where(jnp.isfinite(m_g), m_g, 0.0)), 0.0)
    l_g = jax.lax.psum(l_loc * corr, seq_axes)
    acc_g = jax.lax.psum(acc_loc * corr[..., 0][..., None], seq_axes)
    out = acc_g / jnp.maximum(l_g[..., 0][..., None], 1e-30)
    return out.reshape(B, Hq, qb.shape[-1])


def retrieval_decode_attention_sharded(
    q, layer_k, layer_v, layer_cent, pos, *, cs: int, top_b: int, seq_axes: tuple, scale: float | None = None
):
    """Sequence-parallel eCP retrieval attention: the clusters NEVER move.

    The clustered cache shards its cluster axis over ``seq_axes``. GSPMD's
    auto-partitioning of the gather-then-attend formulation all-reduces the
    gathered [B,Hkv,b+1,cs,d] cluster contents (measured 8.86 GB x L per
    decode step). Here each shard instead:
      1. scores ITS centroids (index traversal stays local),
      2. contributes its local top-b scores to a tiny all-gather
         ([B,Hkv,b_loc] f32) from which the global b-th best score is the
         selection threshold (ties may admit a few extra clusters —
         same-spirit approximation as MoE capacity),
      3. runs masked partial attention over its local clusters only, and
      4. combines with the flash-decoding (m, l, acc) psum — O(B·Hq·d).
    Wire bytes per layer: O(n_sh·b_loc + B·Hq·d) ~ 100 KB vs 8.86 GB.
    """
    mesh = mesh_compat.get_abstract_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    n_sh = 1
    for a in seq_axes:
        n_sh *= sizes[a]
    B, Hq, d = q.shape
    Hkv, nC = layer_k.shape[1], layer_k.shape[2]
    nC_loc = nC // n_sh
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    from jax.sharding import PartitionSpec as _P

    def local(qb, kb, vb, cb, posb):
        off = jnp.zeros((), jnp.int32)
        for a in seq_axes:
            off = off * sizes[a] + jax.lax.axis_index(a)
        off = off * nC_loc
        return _local_retrieval_attend(
            qb, kb, vb, cb, posb, off=off, cs=cs, top_b=top_b,
            seq_axes=seq_axes, scale=scale, nC_loc=nC_loc, B=B, Hq=Hq, Hkv=Hkv,
        )

    seq_spec = tuple(seq_axes)
    return mesh_compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            _P(None, None, None),
            _P(None, None, seq_spec, None, None),
            _P(None, None, seq_spec, None, None),
            _P(None, None, seq_spec, None),
            _P(),
        ),
        out_specs=_P(None, None, None),
        check_vma=False,
    )(q, layer_k, layer_v, layer_cent, pos)
