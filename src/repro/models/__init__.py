"""Model zoo: LM transformers (dense/MoE), GraphSAGE, CTR models.

All models are pure functions over ParamSpec-declared param trees (base.py),
so init / abstract lowering / sharding derive from one declaration.
"""
from . import attention, gnn, recsys, transformer
from .base import abstract_params, init_params, param_count, param_pspecs

__all__ = [
    "attention",
    "gnn",
    "recsys",
    "transformer",
    "abstract_params",
    "init_params",
    "param_count",
    "param_pspecs",
]
