"""Functional parameter-spec system.

Every model declares its parameters ONCE as a pytree of ``ParamSpec`` leaves
(shape, dtype, sharding axes, initializer). From that single declaration we
derive:
  * ``init_params``      — materialized, randomly initialized arrays
  * ``abstract_params``  — jax.ShapeDtypeStruct tree (dry-run lowering
                           without allocating a single byte)
  * ``param_pspecs``     — PartitionSpec tree for pjit in_shardings
  * ``param_count``      — exact parameter count

This is the property that makes the 512-device multi-pod dry-run honest:
the SAME spec tree feeds both the real CPU smoke tests (tiny configs) and
the abstract production lowering (full configs).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

__all__ = [
    "ParamSpec",
    "init_params",
    "abstract_params",
    "param_pspecs",
    "param_count",
    "param_bytes",
]


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    dtype: Any = jnp.float32
    pspec: tuple = ()                 # PartitionSpec entries, e.g. (None, "model")
    init: str = "fan_in"              # fan_in | normal | zeros | ones | embed
    scale: float | None = None        # stddev override
    fan_in_axis: int = -2             # axis treated as fan-in for scaling

    def partition_spec(self) -> PartitionSpec:
        if not self.pspec:
            return PartitionSpec()
        return PartitionSpec(*self.pspec)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(spec: ParamSpec, key) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 0.02
        return (jax.random.normal(key, spec.shape) * std).astype(spec.dtype)
    if spec.init == "normal":
        std = spec.scale if spec.scale is not None else 1.0
        return (jax.random.normal(key, spec.shape) * std).astype(spec.dtype)
    if spec.init == "fan_in":
        fan = spec.shape[spec.fan_in_axis] if len(spec.shape) >= 2 else spec.shape[0]
        std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(max(fan, 1))
        return (jax.random.normal(key, spec.shape) * std).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(spec_tree, rng_key):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(rng_key, max(len(leaves), 1))
    out = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(spec_tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=_is_spec
    )


def param_pspecs(spec_tree):
    return jax.tree.map(lambda s: s.partition_spec(), spec_tree, is_leaf=_is_spec)


def param_count(spec_tree) -> int:
    return sum(
        int(np.prod(s.shape)) for s in jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    )


def param_bytes(spec_tree) -> int:
    return sum(
        int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    )
