"""Decoder-only LM family: dense GQA transformers and top-1 MoE variants.

One config covers all five assigned LM architectures (RoPE, SwiGLU,
GQA, optional QKV bias, optional MoE FFN). Layers are ``lax.scan``ned over
stacked parameters — compile time and HLO size are O(1) in depth, which is
what makes the 88-layer/123B dry-run lowering tractable.

Distribution: batch shards over ("pod","data"); projections shard their
feature dim over "model" (Megatron-style TP); MoE experts shard over
"model" (EP); the long-context clustered KV cache shards its cluster axis
over "data" (sequence parallelism). ``ShardingRules`` carries the axis
names so the same code lowers for any mesh (and runs unconstrained on CPU).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from .attention import attention
from .base import ParamSpec as P
from .layers import rms_norm, rope, softmax_xent, swiglu
from .moe import MoEConfig, moe_ffn, moe_ffn_ep
from .retrieval_attention import (
    ClusteredKVCache,
    RetrievalAttnConfig,
    clustered_cache_update,
    init_clustered_cache,
    retrieval_decode_attention,
)

__all__ = ["LMConfig", "ShardingRules", "KVCache", "param_specs", "forward", "lm_loss", "prefill", "decode_step", "retrieval_decode_step", "init_cache"]


@dataclass(frozen=True)
class ShardingRules:
    """Logical-axis → mesh-axis mapping used by with_sharding_constraint."""

    batch: tuple = ()          # e.g. ("data",) or ("pod", "data")
    model: str | None = None   # tensor/expert axis
    seq: str | None = None     # sequence axis (long-context cells)

    @staticmethod
    def null() -> "ShardingRules":
        return ShardingRules()

    def spec(self, *axes) -> PartitionSpec:
        out = []
        for a in axes:
            if a == "B":
                out.append(self.batch if self.batch else None)
            elif a == "M":
                out.append(self.model)
            elif a == "S":
                out.append(self.seq)
            else:
                out.append(None)
        return PartitionSpec(*out)

    def shard(self, x, *axes):
        if not self.batch and self.model is None and self.seq is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.spec(*axes))


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    max_seq: int = 4096
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    moe: MoEConfig | None = None
    moe_every: int = 1              # 2 => alternate dense/MoE layers (Llama-4)
    retrieval: RetrievalAttnConfig = field(default_factory=RetrievalAttnConfig)
    attn_impl: str = "chunked"      # training attention path
    attn_chunk: int = 1024
    remat: bool = True
    fsdp_axis: Any = None           # axis (or tuple) to ZeRO-3 shard params over
    pure_fsdp: bool = False         # no tensor parallelism: FSDP-only layout
    microbatches: int = 1           # gradient-accumulation chunks per step

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head


# ------------------------------------------------------------------ params
def _layer_specs(cfg: LMConfig, n: int, *, moe: bool) -> dict:
    """Specs for ``n`` stacked layers with dense or MoE FFN."""
    D, F = cfg.d_model, cfg.d_ff
    pdt = cfg.param_dtype
    fs = cfg.fsdp_axis  # None -> replicate the non-"model" big dim
    tp = None if cfg.pure_fsdp else "model"   # pure FSDP: no TP axis at all
    layers: dict[str, P] = {
        "attn_norm": P((n, D), pdt, (None, None), "ones"),
        "wq": P((n, D, cfg.q_dim), pdt, (None, fs, tp)),
        "wk": P((n, D, cfg.kv_dim), pdt, (None, fs, tp)),
        "wv": P((n, D, cfg.kv_dim), pdt, (None, fs, tp)),
        "wo": P((n, cfg.q_dim, D), pdt, (None, tp, fs)),
        "ffn_norm": P((n, D), pdt, (None, None), "ones"),
    }
    if cfg.qkv_bias:
        layers["bq"] = P((n, cfg.q_dim), pdt, (None, tp), "zeros")
        layers["bk"] = P((n, cfg.kv_dim), pdt, (None, tp), "zeros")
        layers["bv"] = P((n, cfg.kv_dim), pdt, (None, tp), "zeros")
    if not moe:
        layers["w_gate"] = P((n, D, F), pdt, (None, fs, tp))
        layers["w_up"] = P((n, D, F), pdt, (None, fs, tp))
        layers["w_down"] = P((n, F, D), pdt, (None, tp, fs))
    else:
        E, Fe = cfg.moe.n_experts, cfg.moe.d_ff
        layers["router"] = P((n, D, E), pdt, (None, fs, None))
        layers["we_gate"] = P((n, E, D, Fe), pdt, (None, "model", fs, None))
        layers["we_up"] = P((n, E, D, Fe), pdt, (None, "model", fs, None))
        layers["we_down"] = P((n, E, Fe, D), pdt, (None, "model", fs, None))
    return layers


def param_specs(cfg: LMConfig):
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    pdt = cfg.param_dtype
    fs = cfg.fsdp_axis
    if cfg.moe is None:
        layers = _layer_specs(cfg, L, moe=False)
    elif cfg.moe_every == 1:
        layers = _layer_specs(cfg, L, moe=True)
    elif cfg.moe_every == 2:
        assert L % 2 == 0, "moe_every=2 needs an even layer count"
        layers = {
            "dense": _layer_specs(cfg, L // 2, moe=False),
            "moe": _layer_specs(cfg, L // 2, moe=True),
        }
    else:
        raise ValueError("moe_every must be 1 or 2")
    if cfg.pure_fsdp:
        # embed/lm_head shard their D dim (always 256-divisible; vocab like
        # phi4's 200064 is not) — the lm_head contraction psums logits
        return {
            "embed": P((V, D), pdt, (None, fs), "embed"),
            "layers": layers,
            "final_norm": P((D,), pdt, (None,), "ones"),
            "lm_head": P((D, V), pdt, (fs, None)),
        }
    return {
        "embed": P((V, D), pdt, ("model", fs), "embed"),
        "layers": layers,
        "final_norm": P((D,), pdt, (None,), "ones"),
        "lm_head": P((D, V), pdt, (fs, "model")),
    }


def _is_block(cfg: LMConfig) -> bool:
    return cfg.moe is not None and cfg.moe_every == 2


# ----------------------------------------------------------------- forward
def _sp_on(rules: ShardingRules) -> bool:
    """Megatron sequence-parallel mode: residuals seq-sharded on 'model'."""
    return rules.model is not None and rules.seq == rules.model


def _qkv(h, lp, cfg: LMConfig, positions, rules: ShardingRules = ShardingRules()):
    B, S, _ = h.shape
    q = h @ lp["wq"].astype(h.dtype)
    k = h @ lp["wk"].astype(h.dtype)
    v = h @ lp["wv"].astype(h.dtype)
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(h.dtype)
        k = k + lp["bk"].astype(h.dtype)
        v = v + lp["bv"].astype(h.dtype)
    q = q.reshape(B, S, cfg.n_heads, cfg.d_head)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    if _sp_on(rules):
        # q heads shard over model (Hq is 16-divisible in every assigned
        # arch x 16-wide mesh? 24/28 are not — GSPMD pads those two, still
        # strictly better than d-sharded contraction); kv heads (4..8 <
        # mesh) REPLICATE — this removes the per-chunk all-reduce of
        # [B,H,cq,d] scores that dominated the baseline (566 GB x2 /step).
        q = rules.shard(q, "B", None, "M", None)
        k = rules.shard(k, "B", None, None, None)
        v = rules.shard(v, "B", None, None, None)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ffn(h2, lp, cfg: LMConfig, rules: ShardingRules):
    B, S, D = h2.shape
    if "router" not in lp:
        return (
            swiglu(
                h2,
                lp["w_gate"].astype(h2.dtype),
                lp["w_up"].astype(h2.dtype),
                lp["w_down"].astype(h2.dtype),
            ),
            jnp.zeros((), jnp.float32),
        )
    flat = h2.reshape(B * S, D)
    if _sp_on(rules):  # explicit expert-parallel dispatch (zero all-to-all)
        y, aux = moe_ffn_ep(
            flat,
            lp["router"],
            lp["we_gate"].astype(h2.dtype),
            lp["we_up"].astype(h2.dtype),
            lp["we_down"].astype(h2.dtype),
            cfg.moe,
            model_axis=rules.model,
            batch_axes=rules.batch,
        )
    else:
        y, aux = moe_ffn(
            flat,
            lp["router"],
            lp["we_gate"].astype(h2.dtype),
            lp["we_up"].astype(h2.dtype),
            lp["we_down"].astype(h2.dtype),
            cfg.moe,
        )
    return y.reshape(B, S, D), aux


def _layer(x, lp, cfg: LMConfig, rules: ShardingRules, positions):
    """One transformer layer.

    Megatron-SP layout when seq==model axis (training/prefill cells):
    residual x is seq-sharded; layer entry all-gathers seq (the ONLY gather,
    [B,S,D] bf16), internals run head-/feature-sharded with no collective,
    and each residual write is a reduce-scatter back to seq-sharded.
    """
    sp = _sp_on(rules)
    h = rms_norm(x, lp["attn_norm"].astype(x.dtype))
    if sp:
        h = rules.shard(h, "B", None, None)          # all-gather seq
    q, k, v = _qkv(h, lp, cfg, positions, rules)
    o = attention(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=True,
        impl=cfg.attn_impl,
        chunk=cfg.attn_chunk,
        remat=cfg.remat,
    )                                                    # [B, Hq, S, dh] f32
    o = o.transpose(0, 2, 1, 3).reshape(x.shape[0], x.shape[1], cfg.q_dim)
    if sp:
        o = rules.shard(o.astype(x.dtype), "B", None, "M")
        att = rules.shard(o @ lp["wo"].astype(x.dtype), "B", "S", None)  # RS
    else:
        o = rules.shard(o.astype(x.dtype), "B", "S", "M")
        att = o @ lp["wo"].astype(x.dtype)
    x = x + att
    h2 = rms_norm(x, lp["ffn_norm"].astype(x.dtype))
    if sp:
        h2 = rules.shard(h2, "B", None, None)        # all-gather seq
    y, aux = _ffn(h2, lp, cfg, rules)
    if sp:
        y = rules.shard(y, "B", "S", None)           # reduce-scatter
    x = x + y
    x = rules.shard(x, "B", "S", None)
    return x, aux


def _cast_layers(layers, dtype):
    """Cast stacked layer params to the compute dtype ONCE, outside the
    layer scan — so FSDP all-gathers move bf16, not f32 (2x wire + HBM)."""
    return jax.tree.map(lambda w: w.astype(dtype), layers)


def forward(params, tokens, cfg: LMConfig, rules: ShardingRules = ShardingRules()):
    """tokens [B, S] int32 -> (logits [B, S, V] f32, aux loss)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = rules.shard(x, "B", "S", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    layers = _cast_layers(params["layers"], cfg.dtype)

    def body(carry, lp):
        if _is_block(cfg):
            h, a1 = _layer(carry, lp["dense"], cfg, rules, positions)
            h, a2 = _layer(h, lp["moe"], cfg, rules, positions)
            return h, a1 + a2
        return _layer(carry, lp, cfg, rules, positions)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, layers)
    x = rms_norm(x, params["final_norm"].astype(x.dtype))
    logits = (x @ params["lm_head"].astype(x.dtype)).astype(jnp.float32)
    # with sequence-parallel residuals (seq == model axis) keep logits
    # seq-sharded; otherwise shard the vocab dim
    if rules.seq is not None and rules.seq == rules.model:
        logits = rules.shard(logits, "B", "S", None)
    else:
        logits = rules.shard(logits, "B", "S", "M")
    return logits, jnp.sum(auxs)


def lm_loss(params, batch, cfg: LMConfig, rules: ShardingRules = ShardingRules()):
    """batch: {"tokens": [B, S]}; next-token cross entropy + MoE aux."""
    tokens = batch["tokens"]
    logits, aux = forward(params, tokens[:, :-1], cfg, rules)
    xent = softmax_xent(logits, tokens[:, 1:])
    return xent + aux, {"xent": xent, "aux": aux}


# ------------------------------------------------------------------ serving
@jax.tree_util.register_pytree_node_class
@dataclass
class KVCache:
    k: jnp.ndarray    # [L, B, Hkv, Smax, dh]
    v: jnp.ndarray
    pos: jnp.ndarray  # [] int32

    def tree_flatten(self):
        return (self.k, self.v, self.pos), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_cache(cfg: LMConfig, batch: int, max_seq: int | None = None) -> KVCache:
    S = max_seq or cfg.max_seq
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, S, cfg.d_head)
    return KVCache(
        k=jnp.zeros(shape, cfg.dtype), v=jnp.zeros(shape, cfg.dtype), pos=jnp.zeros((), jnp.int32)
    )


def _prefill_layer(carry, lp, cfg, rules, positions, Smax):
    B, S = positions.shape
    sp = _sp_on(rules)
    h = rms_norm(carry, lp["attn_norm"].astype(carry.dtype))
    if sp:
        h = rules.shard(h, "B", None, None)
    q, k, v = _qkv(h, lp, cfg, positions, rules)
    o = attention(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=True,
        impl=cfg.attn_impl,
        chunk=cfg.attn_chunk,
        remat=cfg.remat,
    )
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.q_dim).astype(carry.dtype)
    if sp:
        o = rules.shard(o, "B", None, "M")
        att = rules.shard(o @ lp["wo"].astype(carry.dtype), "B", "S", None)
    else:
        att = o @ lp["wo"].astype(carry.dtype)
    xx = carry + att
    h2 = rms_norm(xx, lp["ffn_norm"].astype(xx.dtype))
    if sp:
        h2 = rules.shard(h2, "B", None, None)
    y, _ = _ffn(h2, lp, cfg, rules)
    if sp:
        y = rules.shard(y, "B", "S", None)
    xx = rules.shard(xx + y, "B", "S", None)
    kpad = jnp.zeros((B, cfg.n_kv_heads, Smax - S, cfg.d_head), cfg.dtype)
    kc = jnp.concatenate([k.transpose(0, 2, 1, 3).astype(cfg.dtype), kpad], axis=2)
    vc = jnp.concatenate([v.transpose(0, 2, 1, 3).astype(cfg.dtype), kpad], axis=2)
    return xx, kc, vc


def prefill(params, tokens, cfg: LMConfig, rules: ShardingRules = ShardingRules(), *, max_seq: int | None = None):
    """Run the prompt; return (last-position logits, filled KVCache)."""
    B, S = tokens.shape
    Smax = max_seq or max(cfg.max_seq, S)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = rules.shard(x, "B", "S", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    layers = _cast_layers(params["layers"], cfg.dtype)

    def body(carry, lp):
        if _is_block(cfg):
            h, k1, v1 = _prefill_layer(carry, lp["dense"], cfg, rules, positions, Smax)
            h, k2, v2 = _prefill_layer(h, lp["moe"], cfg, rules, positions, Smax)
            return h, (jnp.stack([k1, k2]), jnp.stack([v1, v2]))
        h, k1, v1 = _prefill_layer(carry, lp, cfg, rules, positions, Smax)
        return h, (k1, v1)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (kall, vall) = jax.lax.scan(body, x, layers)
    if _is_block(cfg):  # [L/2, 2, ...] -> [L, ...]
        kall = kall.reshape((cfg.n_layers,) + kall.shape[2:])
        vall = vall.reshape((cfg.n_layers,) + vall.shape[2:])
    x = rms_norm(x, params["final_norm"].astype(x.dtype))
    logits = (x[:, -1] @ params["lm_head"].astype(x.dtype)).astype(jnp.float32)
    cache = KVCache(k=kall, v=vall, pos=jnp.asarray(S, jnp.int32))
    return logits, cache


def _decode_layer(carry, lp, kc, vc, cfg, rules, positions, pos):
    B = carry.shape[0]
    h = rms_norm(carry, lp["attn_norm"].astype(carry.dtype))
    q, k, v = _qkv(h, lp, cfg, positions)                 # [B,1,H,dh]
    kc = jax.lax.dynamic_update_slice(
        kc, k.transpose(0, 2, 1, 3).astype(kc.dtype), (0, 0, pos, 0)
    )
    vc = jax.lax.dynamic_update_slice(
        vc, v.transpose(0, 2, 1, 3).astype(vc.dtype), (0, 0, pos, 0)
    )
    # decode attention: explicit flash-decoding over the model-axis-sharded
    # cache when distributed (shard_map; GSPMD otherwise all-gathers the
    # cache), plain chunked attention on a single device
    if rules.model is not None:
        from .attention import flash_decode_sharded

        o = flash_decode_sharded(
            q.transpose(0, 2, 1, 3), kc, vc,
            jnp.full((B,), pos + 1, jnp.int32), model_axis=rules.model,
        )
    else:
        o = attention(
            q.transpose(0, 2, 1, 3),
            kc,
            vc,
            causal=True,
            kv_lens=jnp.full((B,), pos + 1, jnp.int32),
            impl="chunked",
            remat=False,
        )
    o = o.transpose(0, 2, 1, 3).reshape(B, 1, cfg.q_dim).astype(carry.dtype)
    xx = carry + o @ lp["wo"].astype(carry.dtype)
    h2 = rms_norm(xx, lp["ffn_norm"].astype(xx.dtype))
    y, _ = _ffn(h2, lp, cfg, rules)
    return xx + y, kc, vc


def decode_step(params, cache: KVCache, tokens, cfg: LMConfig, rules: ShardingRules = ShardingRules()):
    """One token per sequence. tokens [B] -> (logits [B, V], new cache)."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cfg.dtype)  # [B,1,D]
    pos = cache.pos
    positions = jnp.full((B, 1), pos, jnp.int32)
    block = _is_block(cfg)
    layers = _cast_layers(params["layers"], cfg.dtype)
    kk, vv = cache.k, cache.v
    if block:  # [L, ...] -> [L/2, 2, ...]
        kk = kk.reshape((cfg.n_layers // 2, 2) + kk.shape[1:])
        vv = vv.reshape((cfg.n_layers // 2, 2) + vv.shape[1:])

    def body(carry, xs):
        lp, kc, vc = xs
        if block:
            h, k1, v1 = _decode_layer(carry, lp["dense"], kc[0], vc[0], cfg, rules, positions, pos)
            h, k2, v2 = _decode_layer(h, lp["moe"], kc[1], vc[1], cfg, rules, positions, pos)
            return h, (jnp.stack([k1, k2]), jnp.stack([v1, v2]))
        h, k1, v1 = _decode_layer(carry, lp, kc, vc, cfg, rules, positions, pos)
        return h, (k1, v1)

    x, (kall, vall) = jax.lax.scan(body, x, (layers, kk, vv))
    if block:
        kall = kall.reshape((cfg.n_layers,) + kall.shape[2:])
        vall = vall.reshape((cfg.n_layers,) + vall.shape[2:])
    x = rms_norm(x, params["final_norm"].astype(x.dtype))
    logits = (x[:, 0] @ params["lm_head"].astype(x.dtype)).astype(jnp.float32)
    return logits, KVCache(k=kall, v=vall, pos=pos + 1)


def _retrieval_layer(carry, lp, kc, vc, cent, cfg, rules, positions, pos):
    B = carry.shape[0]
    cs = cfg.retrieval.cluster_size
    h = rms_norm(carry, lp["attn_norm"].astype(carry.dtype))
    q, k, v = _qkv(h, lp, cfg, positions)
    if rules.model is not None:
        # sequence-parallel eCP search with owner-local cache write:
        # clusters stay put, scores move (§Perf iterations 1 + 4)
        from .retrieval_attention import retrieval_update_and_attend_sharded

        o, kc, vc, cent = retrieval_update_and_attend_sharded(
            q[:, 0], kc, vc, cent, k[:, 0], v[:, 0], pos, cs=cs,
            top_b=cfg.retrieval.top_clusters,
            seq_axes=tuple(rules.batch) + (rules.model,),
        )
    else:
        kc, vc, cent = clustered_cache_update(kc, vc, cent, k[:, 0], v[:, 0], pos, cs)
        o = retrieval_decode_attention(
            q[:, 0], kc, vc, cent, pos + 1, cs=cs, top_b=cfg.retrieval.top_clusters
        )
    o = o.reshape(B, 1, cfg.q_dim).astype(carry.dtype)
    xx = carry + o @ lp["wo"].astype(carry.dtype)
    h2 = rms_norm(xx, lp["ffn_norm"].astype(xx.dtype))
    y, _ = _ffn(h2, lp, cfg, rules)
    return xx + y, kc, vc, cent


def retrieval_decode_step(
    params, cache: ClusteredKVCache, tokens, cfg: LMConfig, rules: ShardingRules = ShardingRules()
):
    """Long-context decode with eCP retrieval attention (paper technique)."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cfg.dtype)
    pos = cache.pos
    positions = jnp.full((B, 1), pos, jnp.int32)
    block = _is_block(cfg)
    layers = _cast_layers(params["layers"], cfg.dtype)
    kk, vv, cc = cache.k, cache.v, cache.centroids
    if block:
        kk = kk.reshape((cfg.n_layers // 2, 2) + kk.shape[1:])
        vv = vv.reshape((cfg.n_layers // 2, 2) + vv.shape[1:])
        cc = cc.reshape((cfg.n_layers // 2, 2) + cc.shape[1:])

    def body(carry, xs):
        lp, kc, vc, cent = xs
        if block:
            h, k1, v1, c1 = _retrieval_layer(carry, lp["dense"], kc[0], vc[0], cent[0], cfg, rules, positions, pos)
            h, k2, v2, c2 = _retrieval_layer(h, lp["moe"], kc[1], vc[1], cent[1], cfg, rules, positions, pos)
            return h, (jnp.stack([k1, k2]), jnp.stack([v1, v2]), jnp.stack([c1, c2]))
        h, k1, v1, c1 = _retrieval_layer(carry, lp, kc, vc, cent, cfg, rules, positions, pos)
        return h, (k1, v1, c1)

    x, (kall, vall, call) = jax.lax.scan(body, x, (layers, kk, vv, cc))
    if block:
        kall = kall.reshape((cfg.n_layers,) + kall.shape[2:])
        vall = vall.reshape((cfg.n_layers,) + vall.shape[2:])
        call = call.reshape((cfg.n_layers,) + call.shape[2:])
    x = rms_norm(x, params["final_norm"].astype(x.dtype))
    logits = (x[:, 0] @ params["lm_head"].astype(x.dtype)).astype(jnp.float32)
    return logits, ClusteredKVCache(k=kall, v=vall, centroids=call, pos=pos + 1)
