"""Mixture-of-Experts FFN (top-1 routing, sort-based capacity dispatch).

Dispatch is the sort/scatter formulation (not the GShard [T, E, C] one-hot
einsum, which materializes T·E·C): tokens are argsorted by expert id,
positions within each expert group are computed from group starts, tokens
beyond capacity are dropped (mode='drop' scatter), experts run as a single
batched einsum over the [E, C, D] buffer, and outputs are scattered back.
Expert axis shards on "model" (expert parallelism); GSPMD inserts the
all-to-alls around the sharded scatter/gather.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.launch import mesh as mesh_compat

__all__ = ["MoEConfig", "moe_ffn", "moe_ffn_ep"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int = 1                 # assigned archs use top-1 (Switch-style)
    d_ff: int = 8192
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


def moe_ffn(x, router_w, w_gate, w_up, w_down, cfg: MoEConfig):
    """x [T, D] -> ([T, D], aux_loss). Top-1 routing with capacity drop.

    router_w [D, E]; w_gate/w_up [E, D, F]; w_down [E, F, D].
    """
    T, D = x.shape
    E = cfg.n_experts
    C = max(1, int(cfg.capacity_factor * T / E))

    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))      # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate = jnp.max(probs, axis=-1)                                       # [T]
    eidx = jnp.argmax(probs, axis=-1).astype(jnp.int32)                  # [T]

    # Switch load-balancing aux loss: E * sum_e f_e * P_e
    frac = jnp.mean(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=0)  # [E]
    mean_p = jnp.mean(probs, axis=0)                                     # [E]
    aux = E * jnp.sum(frac * mean_p) * cfg.aux_loss_weight

    order = jnp.argsort(eidx)                                            # [T]
    sorted_e = eidx[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))                   # [E]
    pos_in_e = jnp.arange(T, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)

    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[sorted_e, pos_in_e].set(x[order], mode="drop")

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    y = jnp.einsum("ecf,efd->ecd", g * u, w_down)                        # [E, C, D]

    kept = pos_in_e < C
    out_sorted = y[sorted_e, jnp.minimum(pos_in_e, C - 1)] * kept[:, None].astype(y.dtype)
    out = jnp.zeros((T, D), y.dtype).at[order].set(out_sorted)
    out = out * gate[:, None].astype(y.dtype)
    return out.astype(x.dtype), aux


def moe_ffn_ep(x, router_w, w_gate, w_up, w_down, cfg: MoEConfig, *, model_axis: str, batch_axes: tuple):
    """Expert-parallel MoE with ZERO dispatch all-to-all (shard_map).

    Precondition (Megatron-SP layers): x [T, D] is batch-sharded over
    ``batch_axes`` and REPLICATED over ``model_axis``; experts are sharded
    over ``model_axis``. Each model column therefore already holds every
    token — it routes/computes only the tokens whose top-1 expert it owns
    and contributes zeros otherwise, so the combine is ONE psum of [T, D]
    over the model axis. GSPMD's auto-partitioned scatter for the same
    dispatch all-reduces the [E, C, D] buffers (measured 10.5 TB/step/device
    on scout train_4k); this is the structural fix.
    """
    from jax.sharding import PartitionSpec as _P

    mesh = mesh_compat.get_abstract_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    n_m = sizes[model_axis]
    E = cfg.n_experts
    assert E % n_m == 0, (E, n_m)
    E_loc = E // n_m
    bx = tuple(a for a in batch_axes if a in mesh.axis_names) or None

    def local(xb, rw, wg, wu, wd):
        T_loc, D = xb.shape
        C = max(1, int(cfg.capacity_factor * T_loc / E))
        m_idx = jax.lax.axis_index(model_axis)
        logits = xb.astype(jnp.float32) @ rw.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate = jnp.max(probs, axis=-1)
        eidx = jnp.argmax(probs, axis=-1).astype(jnp.int32)
        lo = m_idx * E_loc
        mine = (eidx >= lo) & (eidx < lo + E_loc)
        e_loc = jnp.where(mine, eidx - lo, E_loc)          # E_loc = drop bucket
        order = jnp.argsort(e_loc)
        sorted_e = e_loc[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(E_loc))
        pos = jnp.arange(T_loc, dtype=jnp.int32) - starts[
            jnp.minimum(sorted_e, E_loc - 1)
        ].astype(jnp.int32)
        buf = jnp.zeros((E_loc, C, D), xb.dtype)
        buf = buf.at[sorted_e, pos].set(xb[order], mode="drop")  # drops e_loc==E_loc too
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        y = jnp.einsum("ecf,efd->ecd", g * u, wd)
        kept = (pos < C) & (sorted_e < E_loc) & (pos >= 0)
        out_sorted = y[jnp.minimum(sorted_e, E_loc - 1), jnp.clip(pos, 0, C - 1)]
        out_sorted = out_sorted * kept[:, None].astype(y.dtype)
        out = jnp.zeros((T_loc, D), y.dtype).at[order].set(out_sorted)
        out = out * gate[:, None].astype(y.dtype)
        return jax.lax.psum(out, model_axis)               # one owner per token

    out = mesh_compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            _P(bx, None),
            _P(None, None),
            _P(model_axis, None, None),
            _P(model_axis, None, None),
            _P(model_axis, None, None),
        ),
        out_specs=_P(bx, None),
        check_vma=False,
    )(x, router_w, w_gate, w_up, w_down)

    # aux load-balance loss on the (cheap, replicated) router pass
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    eidx = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0)) * cfg.aux_loss_weight
    return out.astype(x.dtype), aux
