"""Attention dispatch for the LM family.

Three implementations, one math:
  * ``full``    — plain einsum softmax attention (tiny smoke configs);
  * ``chunked`` — lax.scan over kv blocks with the online-softmax
                  recurrence; differentiable; with jax.checkpoint on the
                  body its live memory is O(Sq·chunk) instead of O(Sq·Skv).
                  This is the TRAINING path for the big configs.
  * ``flash``   — the Pallas kernel (kernels/flash_attention), serving path.

All are GQA-aware ([B, Hq, Sq, d] queries vs [B, Hkv, Skv, d] kv).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.launch import mesh as mesh_compat

from repro.kernels.flash_attention import flash_attention_pallas, mha_ref

__all__ = ["attention"]


def _chunked(q, k, v, *, causal, scale, chunk, kv_lens=None, remat=True):
    """Exact attention, scanned over QUERY blocks, flat-head layout.

    Why q-blocks and not the kv-block online-softmax recurrence: under
    ``lax.scan`` autodiff the kv formulation must save its carry — the full
    [B, H, Sq, d] accumulator — once per kv chunk (O(Sq·Skv·d / chunk)
    residual memory; this was a measured 410 GiB/device on the 123B train
    cell). The q formulation has NO carry: each block's softmax over the
    whole kv is exact and independent, the checkpointed body recomputes its
    [cq, Skv] score block in the backward pass, and the only saved tensors
    are the per-block inputs/outputs (O(Sq·d)).

    Why flat heads + bf16 repeat instead of a [B, Hkv, group, S, d] view:
    Hkv (4..8) and group (3..12) do not divide a 16-wide model axis, so
    GSPMD replicates the 5D layout across it; the flat Hq axis (24..96)
    shards evenly. The repeat is in the storage dtype and head-sharded —
    measured 33->19 GiB/device on the 123B train cell.
    """
    B, Hq, Sq, d = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    cq = min(chunk, Sq)
    n_chunks = -(-Sq // cq)
    pad = n_chunks * cq - Sq
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else q
    qc = qp.reshape(B, Hq, n_chunks, cq, d).transpose(2, 0, 1, 3, 4)
    # GQA expansion: storage-dtype repeat on the flat (shardable) head axis;
    # f32 accumulation comes from preferred_element_type, never an f32 copy.
    ke = jnp.repeat(k, group, axis=1) if group > 1 else k    # [B,Hq,Skv,d]
    ve = jnp.repeat(v, group, axis=1) if group > 1 else v
    kv_idx = jnp.arange(Skv)
    end = kv_lens[:, None] if kv_lens is not None else jnp.full((B, 1), Skv)

    def body(_, xs):
        qb, j = xs                                   # qb [B,Hq,cq,d]
        s = jnp.einsum(
            "bhqd,bhkd->bhqk", qb, ke, preferred_element_type=jnp.float32
        ) * scale                                    # [B,Hq,cq,Skv] f32
        mask = kv_idx[None, None, :] < end[:, None, :]       # [B,1,Skv]
        if causal:
            q_idx = j * cq + jnp.arange(cq)
            mask = mask & (
                kv_idx[None, None, :] <= (q_idx[None, :, None] + (end[:, :, None] - Sq))
            )
        s = jnp.where(mask[:, None], s, -jnp.inf)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - jnp.where(jnp.isfinite(m), m, 0.0))
        p = jnp.where(mask[:, None], p, 0.0)
        denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        o = jnp.einsum(
            "bhqk,bhkd->bhqd", (p / denom).astype(ve.dtype), ve,
            preferred_element_type=jnp.float32,
        )
        return None, o

    if remat:
        body = jax.checkpoint(body)
    _, oc = jax.lax.scan(body, None, (qc, jnp.arange(n_chunks)))
    out = oc.transpose(1, 2, 0, 3, 4).reshape(B, Hq, n_chunks * cq, d)
    return out[:, :, :Sq]


def flash_decode_sharded(q, k, v, kv_lens, *, model_axis: str, scale: float | None = None):
    """Decode attention with the KV cache seq-sharded over ``model_axis``.

    Explicit flash-decoding via shard_map: each shard computes its partial
    (m, l, acc) over its local cache slice, then a 3-scalar-tree psum/pmax
    combines them — the ONLY cross-device traffic is O(B·Hq·d), never the
    cache. (GSPMD's auto choice for the same einsum all-gathers the cache:
    measured 8.6 GiB/device of gathered bf16 cache on the 123B decode cell.)

    q [B, Hq, 1, d]; k/v [B, Hkv, Skv, d] sharded (B: data, Skv: model).
    """
    from jax.sharding import PartitionSpec as _P

    B, Hq, _, d = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    mesh = mesh_compat.get_abstract_mesh()
    batch_ax = None
    # infer the batch axis from current mesh axes (pod+data when present)
    bx = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_ax = bx if bx else None
    n_shards = 1
    for a in (model_axis,):
        n_shards *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
    S_loc = Skv // n_shards

    def local(qb, kb, vb, lens):
        # kb/vb [Bl, Hkv, S_loc, d]; qb [Bl, Hq, 1, d]; lens [Bl]
        off = jax.lax.axis_index(model_axis) * S_loc
        ke = jnp.repeat(kb, group, axis=1) if group > 1 else kb
        ve = jnp.repeat(vb, group, axis=1) if group > 1 else vb
        s = jnp.einsum("bhqd,bhkd->bhqk", qb, ke, preferred_element_type=jnp.float32) * scale
        idx = off + jnp.arange(S_loc)
        mask = idx[None, None, None, :] < lens[:, None, None, None]
        s = jnp.where(mask, s, -jnp.inf)
        m = jnp.max(s, axis=-1, keepdims=True)                      # [B,H,1,1]
        p = jnp.where(mask, jnp.exp(s - jnp.where(jnp.isfinite(m), m, 0.0)), 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        acc = jnp.einsum("bhqk,bhkd->bhqd", p.astype(ve.dtype), ve, preferred_element_type=jnp.float32)
        # combine partial softmaxes across cache shards
        m_g = jax.lax.pmax(m, model_axis)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - jnp.where(jnp.isfinite(m_g), m_g, 0.0)), 0.0)
        l_g = jax.lax.psum(l * corr, model_axis)
        acc_g = jax.lax.psum(acc * corr, model_axis)
        return acc_g / jnp.maximum(l_g, 1e-30)

    return mesh_compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            _P(batch_ax, None, None, None),
            _P(batch_ax, None, model_axis, None),
            _P(batch_ax, None, model_axis, None),
            _P(batch_ax),
        ),
        out_specs=_P(batch_ax, None, None, None),
        check_vma=False,
    )(q, k, v, kv_lens)


def attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    kv_lens=None,
    scale: float | None = None,
    impl: str = "chunked",
    chunk: int = 1024,
    remat: bool = True,
):
    """Unified attention. Returns [B, Hq, Sq, d] in float32."""
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    if impl == "full":
        return mha_ref(q, k, v, causal=causal, kv_lens=kv_lens, scale=scale)
    if impl == "chunked":
        return _chunked(
            q, k, v, causal=causal, scale=scale, chunk=chunk, kv_lens=kv_lens, remat=remat
        )
    if impl == "flash":
        return flash_attention_pallas(q, k, v, kv_lens=kv_lens, causal=causal, scale=scale)
    if impl == "flash_interpret":
        return flash_attention_pallas(
            q, k, v, kv_lens=kv_lens, causal=causal, scale=scale, interpret=True
        )
    raise ValueError(f"unknown attention impl {impl!r}")
