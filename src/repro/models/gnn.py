"""GraphSAGE (Hamilton et al., arXiv:1706.02216) in three execution regimes.

JAX has no CSR/CSC sparse — message passing is built from first principles
(DESIGN.md §4): gather source features by edge index, ``jax.ops.segment_sum``
into destinations, degree-normalize. That segment formulation IS the system
here, not a fallback:

  * full-batch    — segment-sum over the whole edge list (Cora/ogbn scale);
                    edges shard over "data", nodes replicate or shard.
  * sampled       — dense fanout tensors from the neighbor sampler
                    (data/graph.py): hop-h features [B, f1..fh, d]; mean
                    aggregation is an axis-mean — the TPU-friendly layout.
  * batched small graphs (molecule) — per-graph edge lists flattened with
    node offsets, same segment-sum path, mean-pool readout.

The paper's technique (eCP-FS) is INAPPLICABLE to GraphSAGE (DESIGN.md §8);
this model ships without it, as required.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .base import ParamSpec as P
from .layers import softmax_xent

__all__ = ["GraphSAGEConfig", "param_specs", "full_batch_forward", "sampled_forward", "batched_graph_forward", "gnn_loss_full", "gnn_loss_sampled", "gnn_loss_graphs"]


@dataclass(frozen=True)
class GraphSAGEConfig:
    name: str
    d_in: int
    n_classes: int
    n_layers: int = 2
    d_hidden: int = 128
    aggregator: str = "mean"
    fanouts: tuple = (25, 10)
    dtype: Any = jnp.float32


def param_specs(cfg: GraphSAGEConfig):
    dims = [cfg.d_in] + [cfg.d_hidden] * cfg.n_layers
    layers = []
    for l in range(cfg.n_layers):
        layers.append(
            {
                "w_self": P((dims[l], dims[l + 1]), cfg.dtype),
                "w_neigh": P((dims[l], dims[l + 1]), cfg.dtype),
                "b": P((dims[l + 1],), cfg.dtype, (), "zeros"),
            }
        )
    return {
        "layers": layers,
        "w_out": P((cfg.d_hidden, cfg.n_classes), cfg.dtype),
        "b_out": P((cfg.n_classes,), cfg.dtype, (), "zeros"),
    }


def _sage_layer(h_self, h_agg, lp, act=True):
    y = h_self @ lp["w_self"] + h_agg @ lp["w_neigh"] + lp["b"]
    return jax.nn.relu(y) if act else y


# ------------------------------------------------------------- full batch
def full_batch_forward(params, feats, edge_src, edge_dst, cfg: GraphSAGEConfig, *, edge_weight=None):
    """feats [N, d]; edge_src/dst [E] int32 (messages flow src -> dst).

    edge_weight [E] (optional): 0-weight edges are padding — node and edge
    arrays are padded to shard-divisible sizes by the launcher, and the
    weights keep padded edges out of both the sum and the degree.
    """
    n = feats.shape[0]
    w = jnp.ones_like(edge_dst, jnp.float32) if edge_weight is None else edge_weight
    deg = jax.ops.segment_sum(w, edge_dst, n)
    inv_deg = 1.0 / jnp.maximum(deg, 1.0)
    h = feats.astype(cfg.dtype)
    for lp in params["layers"]:
        msg = jnp.take(h, edge_src, axis=0) * w[:, None]
        agg = jax.ops.segment_sum(msg, edge_dst, n) * inv_deg[:, None]
        h = _sage_layer(h, agg, lp)
    return h @ params["w_out"] + params["b_out"]


def gnn_loss_full(params, batch, cfg: GraphSAGEConfig):
    logits = full_batch_forward(
        params,
        batch["feats"],
        batch["edge_src"],
        batch["edge_dst"],
        cfg,
        edge_weight=batch.get("edge_weight"),
    )
    return softmax_xent(logits, batch["labels"], mask=batch.get("label_mask")), {}


# --------------------------------------------------------------- sampled
def sampled_forward(params, hops, cfg: GraphSAGEConfig):
    """hops: tuple of fanout tensors, outermost hop first.

    hops[-1] = seed features [B, d]; hops[-2] = 1-hop [B, f1, d];
    hops[0] = (L)-hop [B, f1, ..., fL, d]. Mean aggregation = axis mean.
    """
    hs = [h.astype(cfg.dtype) for h in hops]
    for lp in params["layers"]:
        new_hs = []
        for i in range(len(hs) - 1):
            neigh = jnp.mean(hs[i], axis=-2)  # collapse the innermost fanout axis
            new_hs.append(_sage_layer(hs[i + 1], neigh, lp))
        hs = new_hs
    return hs[0] @ params["w_out"] + params["b_out"]


def gnn_loss_sampled(params, batch, cfg: GraphSAGEConfig):
    logits = sampled_forward(params, batch["hops"], cfg)
    return softmax_xent(logits, batch["labels"]), {}


# -------------------------------------------------- batched small graphs
def batched_graph_forward(params, feats, edge_src, edge_dst, node_mask, cfg: GraphSAGEConfig):
    """feats [G, N, d]; edges [G, E] local indices; node_mask [G, N].

    Flattens graphs with node offsets and reuses the segment-sum path;
    readout = masked mean pool -> graph logits [G, n_classes].
    """
    G, N, d = feats.shape
    offs = (jnp.arange(G) * N)[:, None]
    src = (edge_src + offs).reshape(-1)
    dst = (edge_dst + offs).reshape(-1)
    flat = feats.reshape(G * N, d)
    n = G * N
    deg = jax.ops.segment_sum(jnp.ones_like(dst, jnp.float32), dst, n)
    inv_deg = 1.0 / jnp.maximum(deg, 1.0)
    h = flat.astype(cfg.dtype)
    for lp in params["layers"]:
        msg = jnp.take(h, src, axis=0)
        agg = jax.ops.segment_sum(msg, dst, n) * inv_deg[:, None]
        h = _sage_layer(h, agg, lp)
    h = h.reshape(G, N, -1) * node_mask[..., None]
    pooled = h.sum(1) / jnp.maximum(node_mask.sum(1, keepdims=True), 1.0)
    return pooled @ params["w_out"] + params["b_out"]


def gnn_loss_graphs(params, batch, cfg: GraphSAGEConfig):
    logits = batched_graph_forward(
        params, batch["feats"], batch["edge_src"], batch["edge_dst"], batch["node_mask"], cfg
    )
    return softmax_xent(logits, batch["labels"]), {}
