"""The five assigned LM architectures, exact configs from the assignment.

Sources (assignment bracket tags): phi4-mini [arXiv:2412.08905], mistral-large
[hf:mistralai/Mistral-Large-Instruct-2407], qwen2-7b [arXiv:2407.10671],
llama4 maverick/scout [hf:meta-llama/Llama-4-*].

Distribution policy per arch (DESIGN.md §6):
  * <10B dense (phi4, qwen2): TP on "model" only; params replicate over data.
  * 123B dense (mistral-large): + FSDP over "data" (f32 master fits 256 chips).
  * MoE (llama4): experts on "model" (EP) + FSDP over "data";
    maverick (400B total) additionally uses bf16 params + bf16 Adam moments —
    the 256-chip HBM budget forces it (12 B/param f32 Adam = 18.5 GB/chip).
  * maverick alternates dense/MoE layers (moe_every=2) which is what makes
    128e x 48L equal ~400B total / 17B active; scout is MoE every layer.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.retrieval_attention import RetrievalAttnConfig
from repro.models.transformer import LMConfig

FAMILY = "lm"

_RETR = RetrievalAttnConfig(cluster_size=512, top_clusters=32)


def phi4_mini_full() -> LMConfig:
    return LMConfig(
        name="phi4-mini-3.8b", n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=200064, d_head=128, qkv_bias=False, retrieval=_RETR,
    )


def mistral_large_full() -> LMConfig:
    # bf16 params + bf16 Adam moments + 2 microbatches: 123B state is
    # 123e9*(2+2+2)/256 = 2.9 GiB/chip, activations halve — fits v5e HBM
    return LMConfig(
        name="mistral-large-123b", n_layers=88, d_model=12288, n_heads=96,
        n_kv_heads=8, d_ff=28672, vocab=32768, d_head=128, fsdp_axis="data",
        param_dtype=jnp.bfloat16, microbatches=2, retrieval=_RETR,
    )


def qwen2_7b_full() -> LMConfig:
    return LMConfig(
        name="qwen2-7b", n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab=152064, d_head=128, qkv_bias=True, retrieval=_RETR,
    )


def llama4_maverick_full() -> LMConfig:
    return LMConfig(
        name="llama4-maverick-400b-a17b", n_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, d_ff=8192, vocab=202048, d_head=128,
        moe=MoEConfig(n_experts=128, top_k=1, d_ff=8192), moe_every=2,
        fsdp_axis="data", param_dtype=jnp.bfloat16, retrieval=_RETR,
    )


def llama4_scout_full() -> LMConfig:
    return LMConfig(
        name="llama4-scout-17b-a16e", n_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, d_ff=8192, vocab=202048, d_head=128,
        moe=MoEConfig(n_experts=16, top_k=1, d_ff=8192), moe_every=1,
        fsdp_axis="data", retrieval=_RETR,
    )


def _reduced(full: LMConfig) -> LMConfig:
    """Same family, smoke scale: tiny widths, few layers, CPU-friendly."""
    from dataclasses import replace

    moe = None
    if full.moe is not None:
        moe = MoEConfig(n_experts=4, top_k=1, d_ff=96, capacity_factor=full.moe.capacity_factor)
    return replace(
        full,
        n_layers=4 if full.moe_every == 2 else 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        d_head=16,
        max_seq=128,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        moe=moe,
        fsdp_axis=None,
        retrieval=RetrievalAttnConfig(cluster_size=16, top_clusters=2),
        attn_chunk=64,
    )


ARCHS = {
    "phi4-mini-3.8b": phi4_mini_full,
    "mistral-large-123b": mistral_large_full,
    "qwen2-7b": qwen2_7b_full,
    "llama4-maverick-400b-a17b": llama4_maverick_full,
    "llama4-scout-17b-a16e": llama4_scout_full,
}


def get(arch_id: str, *, reduced: bool = False) -> LMConfig:
    cfg = ARCHS[arch_id]()
    return _reduced(cfg) if reduced else cfg
