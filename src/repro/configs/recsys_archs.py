"""The four assigned recsys architectures, exact interaction configs.

  bst      [arXiv:1905.06874]  embed 32, seq 20, 1 transformer block, 8 heads,
                               MLP 1024-512-256 (Taobao-scale vocabularies)
  dien     [arXiv:1809.03672]  embed 18, seq 100, AUGRU dim 108, MLP 200-80
                               (Amazon Books vocabularies)
  autoint  [arXiv:1810.11921]  39 fields, embed 16, 3 attn layers x 2 heads,
                               total attention dim 32 (=> 16 per head)
  dcn-v2   [arXiv:2008.13535]  13 dense + 26 sparse, embed 16, 3 cross layers,
                               MLP 1024-1024-512 (Criteo vocabularies, capped)

Vocabulary sizes are the public datasets' cardinalities (large Criteo fields
capped at 1M via the usual hashing trick); they put the mega-table in the
multi-GB regime so the "model"-axis table sharding is structurally honest.
"""
from __future__ import annotations

from dataclasses import replace

from repro.models.recsys import RecSysConfig

FAMILY = "recsys"

# Criteo categorical cardinalities (capped at 1M, standard hashing trick)
_CRITEO_26 = (
    1460, 583, 1_000_000, 800_000, 305, 24, 12517, 633, 3, 93145,
    5683, 1_000_000, 3194, 27, 14992, 1_000_000, 10, 5652, 2173, 4,
    1_000_000, 18, 15, 286181, 105, 142572,
)


def bst_full() -> RecSysConfig:
    return RecSysConfig(
        name="bst", interaction="transformer-seq", embed_dim=32,
        # seq fields: item (4M), category (10k); plain: user 1M + 5 context
        field_vocabs=(4_000_000, 10_000, 1_000_000, 50_000, 10_000, 1_000, 500, 100),
        seq_len=20, seq_fields=2, n_blocks=1, n_heads=8, d_attn=8,
        mlp=(1024, 512, 256),
    )


def dien_full() -> RecSysConfig:
    return RecSysConfig(
        name="dien", interaction="augru", embed_dim=18,
        # seq fields: item (367k), category (1.6k); plain: user 543k, context
        field_vocabs=(367_983, 1_601, 543_060, 10_000),
        seq_len=100, seq_fields=2, gru_dim=108, mlp=(200, 80),
    )


def autoint_full() -> RecSysConfig:
    vocabs = tuple([100] * 13 + list(_CRITEO_26))  # 13 bucketized dense + 26 cat
    return RecSysConfig(
        name="autoint", interaction="self-attn", embed_dim=16,
        field_vocabs=vocabs, n_blocks=3, n_heads=2, d_attn=16, mlp=(64,),
    )


def dcn_v2_full() -> RecSysConfig:
    return RecSysConfig(
        name="dcn-v2", interaction="cross", embed_dim=16,
        field_vocabs=_CRITEO_26, n_dense=13, n_cross_layers=3,
        mlp=(1024, 1024, 512),
    )


def _reduced(full: RecSysConfig) -> RecSysConfig:
    small_vocabs = tuple(min(v, 100) for v in full.field_vocabs[:6]) or (100,)
    return replace(
        full,
        field_vocabs=small_vocabs,
        embed_dim=8,
        seq_len=min(full.seq_len, 8) if full.seq_len else 0,
        seq_fields=min(full.seq_fields, 2) if full.seq_len else full.seq_fields,
        mlp=tuple(min(m, 32) for m in full.mlp),
        gru_dim=min(full.gru_dim, 16) if full.gru_dim else 0,
        n_dense=full.n_dense,
        d_attn=8,
        n_heads=2,
    )


ARCHS = {
    "bst": bst_full,
    "dien": dien_full,
    "autoint": autoint_full,
    "dcn-v2": dcn_v2_full,
}


def get(arch_id: str, *, reduced: bool = False) -> RecSysConfig:
    cfg = ARCHS[arch_id]()
    return _reduced(cfg) if reduced else cfg
