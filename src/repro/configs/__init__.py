"""Config registry: ``--arch <id>`` resolution for every assigned arch.

  get_arch(arch_id, reduced=False) -> (family, model_cfg)
  arch_shapes(arch_id) -> the shape table for that arch's family
  ALL_ARCHS / ALL_CELLS -> the 10 archs / 40 (arch x shape) dry-run cells
"""
from __future__ import annotations

from . import gnn_archs, lm_archs, recsys_archs
from .ecpfs_paper import ECPFSPaperConfig, ecpfs_paper_full, ecpfs_paper_reduced
from .shapes import FAMILY_SHAPES, GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES

_FAMILY_OF = {}
for _m in (lm_archs, gnn_archs, recsys_archs):
    for _a in _m.ARCHS:
        _FAMILY_OF[_a] = (_m.FAMILY, _m)

ALL_ARCHS = tuple(_FAMILY_OF)


def get_arch(arch_id: str, *, reduced: bool = False):
    if arch_id not in _FAMILY_OF:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_FAMILY_OF)}")
    family, mod = _FAMILY_OF[arch_id]
    return family, mod.get(arch_id, reduced=reduced)


def arch_shapes(arch_id: str) -> dict:
    family, _ = _FAMILY_OF[arch_id]
    return FAMILY_SHAPES[family]


ALL_CELLS = tuple(
    (a, s) for a in ALL_ARCHS for s in arch_shapes(a)
)

__all__ = [
    "get_arch",
    "arch_shapes",
    "ALL_ARCHS",
    "ALL_CELLS",
    "LM_SHAPES",
    "GNN_SHAPES",
    "RECSYS_SHAPES",
    "FAMILY_SHAPES",
    "ECPFSPaperConfig",
    "ecpfs_paper_full",
    "ecpfs_paper_reduced",
]
