"""Assigned input-shape sets, verbatim from the assignment (40 cells total).

Each entry: kind decides WHICH step function is lowered
  lm:     train | prefill | decode | retrieval_decode (long_500k)
  gnn:    full_graph | sampled | graphs
  recsys: train | serve | retrieval
"""
from __future__ import annotations

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    # needs sub-quadratic attention -> eCP retrieval attention (paper technique)
    "long_500k": dict(kind="retrieval_decode", seq=524288, batch=1),
}

GNN_SHAPES = {
    # Cora-scale full batch
    "full_graph_sm": dict(
        kind="full_graph", n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7
    ),
    # Reddit sampled training
    "minibatch_lg": dict(
        kind="sampled",
        n_nodes=232_965,
        n_edges=114_615_892,
        batch_nodes=1024,
        fanouts=(15, 10),
        d_feat=602,
        n_classes=41,
    ),
    # ogbn-products full batch
    "ogb_products": dict(
        kind="full_graph", n_nodes=2_449_029, n_edges=61_859_140, d_feat=100, n_classes=47
    ),
    # batched small graphs
    "molecule": dict(
        kind="graphs", n_nodes=30, n_edges=64, batch=128, d_feat=16, n_classes=2
    ),
}

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262_144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}

FAMILY_SHAPES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}
