"""The paper's own experimental configuration (§5).

1M SigLIP embeddings, 1152-d float16; target cluster size C = 455 vectors
(~1 MB at 2304 B/vector); L = 3 for V3C-scale (4.1M), L = 2 for ~1M
collections; search expansion b = 64; k = 100. Benchmarks (Tables 2-4)
instantiate scaled-down versions of this config; the batched serve cell
lowers the device search at the full scale.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.build import ECPBuildConfig

FAMILY = "ann"


@dataclass(frozen=True)
class ECPFSPaperConfig:
    name: str = "ecpfs-paper"
    n_items: int = 1_000_000
    dim: int = 1152
    storage_dtype: str = "float16"
    cluster_cap: int = 455          # ~1MB clusters (paper §5.2)
    levels: int = 2                 # L=2 for 1M-scale (LSC24 / V3C1)
    levels_large: int = 3           # L=3 for V3C (4.1M)
    metric: str = "cosine"
    b: int = 64                     # search expansion (matches IVF nprobe=64)
    k: int = 100
    serve_batch: int = 128


def ecpfs_paper_full() -> ECPFSPaperConfig:
    return ECPFSPaperConfig()


def ecpfs_paper_reduced() -> ECPFSPaperConfig:
    return ECPFSPaperConfig(
        name="ecpfs-paper-reduced", n_items=20_000, dim=64, cluster_cap=100,
        levels=2, b=8, k=20, serve_batch=8,
    )


def build_cfg(cfg: ECPFSPaperConfig) -> ECPBuildConfig:
    return ECPBuildConfig(
        levels=cfg.levels, metric=cfg.metric, cluster_cap=cfg.cluster_cap,
        storage_dtype=cfg.storage_dtype,
    )
