"""graphsage-reddit [arXiv:1706.02216]: 2 layers, d_hidden=128, mean
aggregator, sample sizes 25-10 (the minibatch_lg shape overrides to 15-10).

d_in / n_classes are shape-dependent (Cora / Reddit / ogbn-products /
molecules), so the cell builder specializes the config per shape.
The paper's technique is inapplicable here (DESIGN.md §8)."""
from __future__ import annotations

from dataclasses import replace

from repro.models.gnn import GraphSAGEConfig

FAMILY = "gnn"


def graphsage_reddit_full() -> GraphSAGEConfig:
    return GraphSAGEConfig(
        name="graphsage-reddit", d_in=602, n_classes=41, n_layers=2,
        d_hidden=128, aggregator="mean", fanouts=(25, 10),
    )


def _reduced(full: GraphSAGEConfig) -> GraphSAGEConfig:
    return replace(full, d_in=16, n_classes=5, d_hidden=32, fanouts=(3, 2))


ARCHS = {"graphsage-reddit": graphsage_reddit_full}


def get(arch_id: str, *, reduced: bool = False) -> GraphSAGEConfig:
    cfg = ARCHS[arch_id]()
    return _reduced(cfg) if reduced else cfg
