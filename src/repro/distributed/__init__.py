from .collectives import psum_compressed, tree_psum
from .elastic import make_shardings, reshard_tree
from .fault_tolerance import FailureInjector, TrainSupervisor

__all__ = [
    "psum_compressed",
    "tree_psum",
    "make_shardings",
    "reshard_tree",
    "FailureInjector",
    "TrainSupervisor",
]
