"""Elastic scaling: reshard a training state onto a different mesh.

Checkpoints are host numpy (checkpoint/), so elasticity is a device_put
with the new mesh's NamedSharding — a 512-chip state restores onto 256
chips (or 1 CPU) without format changes. The ONLY invariant the caller
must respect is that the global batch is re-split over the new "data"
extent (StepLoader.n_shards), which the launcher does.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = ["reshard_tree", "make_shardings"]


def make_shardings(mesh: Mesh, pspec_tree):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def reshard_tree(tree, mesh: Mesh, pspec_tree):
    """Place a (host or device) pytree onto ``mesh`` with the given specs."""
    shardings = make_shardings(mesh, pspec_tree)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
