"""Collective helpers used inside shard_map'd train steps.

``psum_compressed`` is the cross-pod gradient reduce with int8 + error
feedback (optim/compress.py): quantize per-leaf, psum the int32 payload
over the slow axis, dequantize. Intra-pod reduction stays in the native
dtype. Under jit/GSPMD (no explicit psum), the equivalent is applying
compress_decompress to grads before the optimizer — numerically identical,
which is how launch/train.py wires it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.compress import dequantize_int8, quantize_int8

__all__ = ["psum_compressed", "tree_psum"]


def tree_psum(tree, axis_name: str):
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), tree)


def psum_compressed(tree, axis_name: str):
    """int8-quantized psum (for the cross-pod DCN axis inside shard_map)."""

    def leaf(g):
        q, scale = quantize_int8(g)
        # int8 payload crosses the wire; accumulate in int32
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale = jax.lax.pmax(scale, axis_name)  # shared conservative scale
        return dequantize_int8(total, scale).astype(g.dtype)

    return jax.tree.map(leaf, tree)
