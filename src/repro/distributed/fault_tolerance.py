"""Fault tolerance: supervised training loop with checkpoint/restart,
straggler detection, and bounded retry.

At 1000+ nodes the dominant failure modes are (a) a worker dying mid-step
(preemption, hardware), (b) a straggling worker stretching the synchronous
step, (c) a corrupted/partial checkpoint. The supervisor addresses each:

  * step-granular checkpoints (CheckpointManager, atomic rename publish) —
    a failure costs at most ``ckpt_every`` steps of work;
  * restore-latest + deterministic StepLoader — the replayed batches are
    bit-identical to the failure-free run, so restart is semantically
    invisible (tested);
  * straggler detection — per-step wall time vs a rolling median; steps
    slower than ``straggler_factor``× median are logged and counted, the
    hook point where a real deployment re-slices input or evicts the host
    (here: observable metrics, single-process);
  * bounded retries with exponential re-open backoff.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.checkpoint import CheckpointManager

__all__ = ["TrainSupervisor", "FailureInjector"]


@dataclass
class FailureInjector:
    """Deterministic failure schedule for tests: fail at given steps."""

    fail_at: dict = field(default_factory=dict)  # step -> n remaining failures

    def maybe_fail(self, step: int) -> None:
        left = self.fail_at.get(step, 0)
        if left > 0:
            self.fail_at[step] = left - 1
            raise RuntimeError(f"injected failure at step {step}")


@dataclass
class TrainSupervisor:
    step_fn: Callable                 # (state, batch, step) -> (state, metrics)
    loader: Any                       # StepLoader
    ckpt: CheckpointManager
    ckpt_every: int = 50
    max_retries: int = 8
    straggler_factor: float = 3.0

    def run(
        self,
        state,
        n_steps: int,
        *,
        start_step: int = 0,
        injector: FailureInjector | None = None,
        on_metrics: Callable | None = None,
    ):
        step = start_step
        retries = 0
        durations: list[float] = []
        stragglers = 0
        restarts = 0
        while step < n_steps:
            batch = self.loader.global_batch(step)
            t0 = time.perf_counter()
            try:
                if injector is not None:
                    injector.maybe_fail(step)
                state, metrics = self.step_fn(state, batch, step)
            except Exception:
                retries += 1
                restarts += 1
                if retries > self.max_retries:
                    raise
                restored, ck_step = self.ckpt.restore()
                if restored is not None:
                    state = restored
                    step = ck_step
                else:
                    step = start_step
                time.sleep(min(0.01 * 2**retries, 0.25))  # re-open backoff
                continue
            retries = 0
            dt = time.perf_counter() - t0
            durations.append(dt)
            med = sorted(durations)[len(durations) // 2]
            if len(durations) >= 5 and dt > self.straggler_factor * med:
                stragglers += 1
            if on_metrics is not None:
                on_metrics(step, metrics, dt)
            step += 1
            if step % self.ckpt_every == 0 or step == n_steps:
                self.ckpt.save(step, state)
        self.ckpt.wait()
        return state, {"restarts": restarts, "stragglers": stragglers, "steps": len(durations)}
