from .checkpoint import CheckpointManager, load_tree, save_tree

__all__ = ["CheckpointManager", "load_tree", "save_tree"]
