"""Checkpointing — on the paper's own transparent file structure.

Checkpoints are written through ``core.fstore`` (zarr-v2 layout), so a
training state is as inspectable as the index: every parameter is a raw
chunk file + JSON metadata, readable from any language — the paper's
transparency argument applied to the training substrate.

  ckpt_root/step_00000100/
    .zattrs                      {"step": 100, "skeleton": ...}
    leaf_000000/ ... leaf_N/     one array per pytree leaf

Features: atomic publish (write to tmp dir, rename), async save thread,
keep_n retention, restore-latest, elastic resharding on restore
(distributed/elastic.py). Supports nested dict/list/tuple pytrees.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

from repro.core.fstore import FStore

__all__ = ["CheckpointManager", "save_tree", "load_tree"]


def _flatten(tree, path=()):  # -> list[(path, leaf)], skeleton
    if tree is None:
        return "__none__", []
    if isinstance(tree, dict):
        skel, leaves = {}, []
        for k in sorted(tree):
            s, l = _flatten(tree[k], path + (k,))
            skel[k] = s
            leaves.extend(l)
        return skel, leaves
    if isinstance(tree, (list, tuple)):
        skel, leaves = [], []
        for i, v in enumerate(tree):
            s, l = _flatten(v, path + (str(i),))
            skel.append(s)
            leaves.extend(l)
        return {"__seq__": skel, "__tuple__": isinstance(tree, tuple)}, leaves
    return "__leaf__", [(path, tree)]


def _unflatten(skel, leaves_iter):
    if skel == "__none__":
        return None
    if skel == "__leaf__":
        return next(leaves_iter)
    if isinstance(skel, dict) and "__seq__" in skel:
        seq = [_unflatten(s, leaves_iter) for s in skel["__seq__"]]
        return tuple(seq) if skel["__tuple__"] else seq
    return {k: _unflatten(skel[k], leaves_iter) for k in sorted(skel)}


def save_tree(path: str, tree, *, attrs: dict | None = None) -> None:
    tmp = Path(str(path) + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    store = FStore(tmp, create=True)
    skel, leaves = _flatten(tree)
    meta = dict(attrs or {})
    meta["skeleton"] = skel
    meta["n_leaves"] = len(leaves)
    for i, (p, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        store.write_array(f"leaf_{i:06d}", arr, attrs={"path": "/".join(p), "shape": list(arr.shape)})
    store.write_attrs("", meta)
    final = Path(path)
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)


def load_tree(path: str):
    store = FStore(path)
    meta = store.read_attrs("")
    n = int(meta["n_leaves"])
    leaves = [store.read_array(f"leaf_{i:06d}") for i in range(n)]
    tree = _unflatten(meta["skeleton"], iter(leaves))
    return tree, {k: v for k, v in meta.items() if k not in ("skeleton", "n_leaves")}


class CheckpointManager:
    def __init__(self, root: str, *, keep_n: int = 3, async_save: bool = True):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        self.saves = 0

    def _step_dir(self, step: int) -> Path:
        return self.root / f"step_{step:08d}"

    def steps(self) -> list[int]:
        out = []
        for d in self.root.iterdir():
            if d.name.startswith("step_") and not d.name.endswith(".tmp"):
                out.append(int(d.name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err  # async save failures must not be silent

    def save(self, step: int, tree, *, attrs: dict | None = None) -> None:
        # device_get on the main thread (arrays may be donated next step),
        # file IO on the background thread — compute/IO overlap.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        a = dict(attrs or {})
        a["step"] = step

        def work():
            try:
                save_tree(str(self._step_dir(step)), host_tree, attrs=a)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self.wait()
        self.saves += 1
        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore(self, step: int | None = None):
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        tree, meta = load_tree(str(self._step_dir(step)))
        return tree, int(meta["step"])

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
