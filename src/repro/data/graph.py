"""Host-side graph utilities: CSR adjacency + layered neighbor sampling.

``minibatch_lg`` needs a REAL neighbor sampler (assignment note): this one
builds CSR once, then per batch samples ``fanouts`` neighbors per hop with
replacement-free sampling where degree allows (GraphSAGE's sampler), and
returns the dense fanout feature tensors the model's sampled path consumes.
"""
from __future__ import annotations

import numpy as np

__all__ = ["CSRGraph", "sample_hops"]


class CSRGraph:
    def __init__(self, n_nodes: int, edge_src: np.ndarray, edge_dst: np.ndarray):
        self.n = n_nodes
        order = np.argsort(edge_dst, kind="stable")
        self.nbr = edge_src[order].astype(np.int64)
        counts = np.bincount(edge_dst, minlength=n_nodes)
        self.ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)

    def degree(self, v: int) -> int:
        return int(self.ptr[v + 1] - self.ptr[v])

    def neighbors(self, v: int) -> np.ndarray:
        return self.nbr[self.ptr[v] : self.ptr[v + 1]]


def _sample_neighbors(g: CSRGraph, nodes: np.ndarray, fanout: int, rng) -> np.ndarray:
    """[M] node ids -> [M, fanout] sampled in-neighbors (self-loop padded)."""
    out = np.empty((len(nodes), fanout), np.int64)
    starts = g.ptr[nodes]
    degs = g.ptr[nodes + 1] - starts
    r = rng.random((len(nodes), fanout))
    has = degs > 0
    idx = (r * np.maximum(degs, 1)[:, None]).astype(np.int64)
    out = g.nbr[np.minimum(starts[:, None] + idx, len(g.nbr) - 1)]
    out[~has] = nodes[~has, None]  # isolated nodes: self loop
    return out


def sample_hops(
    g: CSRGraph,
    feats: np.ndarray,
    seeds: np.ndarray,
    fanouts: tuple,
    rng: np.random.Generator,
):
    """Returns fanout feature tensors, outermost hop first:
    [B, f1, ..., fL, d], ..., [B, f1, d], [B, d]."""
    frontiers = [seeds.astype(np.int64)]
    for f in fanouts:
        flat = frontiers[-1].reshape(-1)
        nbrs = _sample_neighbors(g, flat, f, rng)
        frontiers.append(nbrs.reshape(frontiers[-1].shape + (f,)))
    return tuple(feats[idx] for idx in reversed(frontiers))
