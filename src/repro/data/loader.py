"""Deterministic, restart-safe batch loader.

The loader is a pure map step -> global batch, optionally pre-sharded per
data-parallel rank. There is no iterator state to lose on failure: resuming
at step S after a restart replays exactly the batches a failure-free run
would have seen (tested in tests/test_fault_tolerance.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

__all__ = ["StepLoader"]


@dataclass
class StepLoader:
    """make(seed, step, shard) -> dict of np arrays for that shard."""

    make: Callable
    seed: int = 0
    n_shards: int = 1

    def global_batch(self, step: int) -> dict:
        shards = [self.make(self.seed, step, shard=s) for s in range(self.n_shards)]
        if self.n_shards == 1:
            return shards[0]
        return {
            k: np.concatenate([s[k] for s in shards], axis=0) for k in shards[0]
        }

    def shard_batch(self, step: int, shard: int) -> dict:
        return self.make(self.seed, step, shard=shard)
