"""Synthetic data generators, deterministic in (seed, step).

Determinism by construction: every batch is a pure function of
(seed, step, shard), never of iteration history — restart-from-checkpoint
reproduces the exact token/example stream (the fault-tolerance contract in
distributed/fault_tolerance.py).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "lm_batch",
    "ctr_batch",
    "clustered_vectors",
    "random_graph",
    "batched_molecules",
]


def _rng(seed: int, step: int, shard: int = 0):
    return np.random.default_rng(np.random.SeedSequence([seed, step, shard]))


def lm_batch(seed: int, step: int, *, batch: int, seq: int, vocab: int, shard: int = 0):
    r = _rng(seed, step, shard)
    return {"tokens": r.integers(0, vocab, size=(batch, seq), dtype=np.int32)}


def ctr_batch(
    seed: int,
    step: int,
    *,
    batch: int,
    field_vocabs: tuple,
    n_dense: int = 0,
    seq_len: int = 0,
    seq_fields: int = 0,
    shard: int = 0,
):
    r = _rng(seed, step, shard)
    n_plain = len(field_vocabs) - seq_fields
    out = {
        "cat": np.stack(
            [r.integers(0, v, size=batch) for v in field_vocabs[seq_fields:]], axis=1
        ).astype(np.int32)
        if n_plain
        else np.zeros((batch, 0), np.int32),
        "label": r.integers(0, 2, size=batch).astype(np.float32),
    }
    if n_dense:
        out["dense"] = r.normal(size=(batch, n_dense)).astype(np.float32)
    if seq_len:
        out["seq"] = np.stack(
            [r.integers(0, field_vocabs[f], size=(batch, seq_len)) for f in range(seq_fields)],
            axis=2,
        ).astype(np.int32)
        lens = r.integers(1, seq_len + 1, size=batch)
        out["seq_mask"] = (np.arange(seq_len)[None, :] < lens[:, None]).astype(np.float32)
        out["target"] = np.stack(
            [r.integers(0, field_vocabs[f], size=batch) for f in range(seq_fields)], axis=1
        ).astype(np.int32)
    return out


def clustered_vectors(
    seed: int, *, n: int, dim: int, n_clusters: int = 64, spread: float = 0.15
):
    """Mixture-of-Gaussians embeddings — realistic ANN benchmark data
    (isotropic Gaussian is the degenerate worst case; real CLIP/SigLIP
    embeddings cluster, which is the regime eCP exploits)."""
    r = np.random.default_rng(seed)
    centers = r.normal(size=(n_clusters, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    which = r.integers(0, n_clusters, size=n)
    x = centers[which] + spread * r.normal(size=(n, dim)).astype(np.float32)
    return x.astype(np.float32), which


def random_graph(seed: int, *, n_nodes: int, n_edges: int, d_feat: int, n_classes: int):
    r = np.random.default_rng(seed)
    src = r.integers(0, n_nodes, size=n_edges).astype(np.int32)
    dst = r.integers(0, n_nodes, size=n_edges).astype(np.int32)
    feats = r.normal(size=(n_nodes, d_feat)).astype(np.float32)
    labels = r.integers(0, n_classes, size=n_nodes).astype(np.int32)
    return {"feats": feats, "edge_src": src, "edge_dst": dst, "labels": labels}


def batched_molecules(
    seed: int, step: int, *, batch: int, n_nodes: int, n_edges: int, d_feat: int, n_classes: int
):
    r = _rng(seed, step)
    return {
        "feats": r.normal(size=(batch, n_nodes, d_feat)).astype(np.float32),
        "edge_src": r.integers(0, n_nodes, size=(batch, n_edges)).astype(np.int32),
        "edge_dst": r.integers(0, n_nodes, size=(batch, n_edges)).astype(np.int32),
        "node_mask": (
            np.arange(n_nodes)[None, :] < r.integers(n_nodes // 2, n_nodes + 1, size=(batch, 1))
        ).astype(np.float32),
        "labels": r.integers(0, n_classes, size=batch).astype(np.int32),
    }
