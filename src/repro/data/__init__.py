from .graph import CSRGraph, sample_hops
from .loader import StepLoader
from .synthetic import (
    batched_molecules,
    clustered_vectors,
    ctr_batch,
    lm_batch,
    random_graph,
)

__all__ = [
    "CSRGraph",
    "sample_hops",
    "StepLoader",
    "batched_molecules",
    "clustered_vectors",
    "ctr_batch",
    "lm_batch",
    "random_graph",
]
